"""Executable semantics of the RegC model (paper §III) + Table I properties.

Each of the paper's three formal rules gets a direct test; DRF sequential
consistency is checked property-style with hypothesis (random interval
writes inside spans / between barriers must equal a sequential oracle).
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:           # tier-1 env may lack hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core import FINE_PROTO, IDEAL_PROTO, PAGE_PROTO, RegCRuntime


def mk(protocol, n_workers=2, page_words=64, **kw):
    return RegCRuntime(n_workers, page_words=page_words, protocol=protocol,
                       track_values=True, **kw)


@pytest.mark.parametrize("proto", [FINE_PROTO, PAGE_PROTO])
def test_rule2_span_visibility(proto):
    """A consistent STORE becomes visible to a worker that subsequently
    acquires the same lock (rule 2)."""
    rt = mk(proto)
    g = rt.alloc(128)
    with rt.span(0, lock_id=7):
        rt.write(0, g, 3, 5, np.array([1.5, 2.5], np.float32))
    with rt.span(1, lock_id=7):
        got = rt.read(1, g, 3, 5)
    np.testing.assert_allclose(got, [1.5, 2.5])


@pytest.mark.parametrize("proto", [FINE_PROTO, PAGE_PROTO])
def test_rule1_ordinary_visibility_at_span_start(proto):
    """Ordinary STOREs performed before a span at P0 are performed wrt P1
    once P1 starts a span subsequently after P0's (rule 1)."""
    rt = mk(proto)
    g = rt.alloc(128)
    # P1 caches the page first (stale copy)
    _ = rt.read(1, g, 0, 4)
    rt.write(0, g, 0, 4, np.array([9, 9, 9, 9], np.float32))   # ordinary
    with rt.span(0, lock_id=1):
        pass                    # span start flushes P0's ordinary stores
    with rt.span(1, lock_id=2):  # ANY lock (not just lock 1)
        got = rt.read(1, g, 0, 4)
    np.testing.assert_allclose(got, [9, 9, 9, 9])


@pytest.mark.parametrize("proto", [FINE_PROTO, PAGE_PROTO])
def test_rule3_barrier_visibility(proto):
    rt = mk(proto)
    g = rt.alloc(128)
    _ = rt.read(1, g, 0, 2)     # stale copy at P1
    rt.write(0, g, 0, 2, np.array([4, 2], np.float32))
    rt.barrier()
    got = rt.read(1, g, 0, 2)
    np.testing.assert_allclose(got, [4, 2])


def test_fine_protocol_moves_fewer_bytes_than_page():
    """The paper's core claim: fine-grain consistency-region updates move
    only the diff; page protocol moves whole pages."""
    results = {}
    for proto in (FINE_PROTO, PAGE_PROTO):
        rt = mk(proto, page_words=1024)
        g = rt.alloc(1024)
        with rt.span(0, 1):
            rt.write(0, g, 0, 2, np.array([1, 2], np.float32))  # 2 words
        with rt.span(1, 1):
            _ = rt.read(1, g, 0, 2)
        results[proto] = rt.traffic.total_bytes
    assert results[FINE_PROTO] < results[PAGE_PROTO], results


def test_spans_of_different_locks_are_independent():
    """Spans of different locks do not force each other's consistency
    updates (rule 2 is per-consistency-region)."""
    rt = mk(FINE_PROTO)
    g = rt.alloc(128)
    base = rt.read(1, g, 0, 1).copy()   # P1 caches page
    with rt.span(0, lock_id=1):
        rt.write(0, g, 0, 1, np.array([7.0], np.float32))
    with rt.span(1, lock_id=2):
        got = rt.read(1, g, 0, 1)
    # lock 2's region has no pending updates: P1 may still see its cached copy
    np.testing.assert_allclose(got, base)
    with rt.span(1, lock_id=1):
        got2 = rt.read(1, g, 0, 1)
    np.testing.assert_allclose(got2, [7.0])


def test_reduction_extension():
    rt = mk(FINE_PROTO, n_workers=4)
    for w in range(4):
        rt.reduce(w, "residual", w + 1.0)
    rt.barrier()
    assert rt.reduction_result("residual") == 10.0
    assert rt.traffic.reduction_msgs == 3


def test_lock_serialization_advances_clock():
    rt = mk(FINE_PROTO, n_workers=4)
    g = rt.alloc(64)
    for w in range(4):
        with rt.span(w, lock_id=0):
            rt.compute(w, seconds=1.0)
    # spans serialize: total time >= 4s
    assert rt.time >= 4.0


def test_lru_capacity_eviction_counts_traffic():
    rt = mk(FINE_PROTO, n_workers=1, page_words=64, cache_pages=2)
    g = rt.alloc(64 * 8)        # 8 pages, cache holds 2
    for p in range(8):
        rt.read(0, g, p * 64, p * 64 + 1)
    f1 = rt.traffic.page_fetches
    for p in range(8):          # second sweep refetches (capacity misses)
        rt.read(0, g, p * 64, p * 64 + 1)
    assert rt.traffic.page_fetches > f1


# ---------------------------------------------------------------------------
# property: DRF programs are sequentially consistent (hypothesis)
# ---------------------------------------------------------------------------


@st.composite
def drf_program(draw):
    """A data-race-free program: every shared write happens inside a span of
    lock 0, in a random worker order; reads after a final barrier."""
    n_ops = draw(st.integers(2, 12))
    ops = []
    for _ in range(n_ops):
        w = draw(st.integers(0, 2))
        lo = draw(st.integers(0, 120))
        hi = draw(st.integers(lo + 1, min(lo + 8, 128)))
        val = draw(st.floats(-100, 100, allow_nan=False, width=32))
        ops.append((w, lo, hi, val))
    return ops


def _drf_program_np(rng) -> list:
    """Numpy-seeded mirror of the ``drf_program`` strategy for the
    deterministic twin."""
    ops = []
    for _ in range(int(rng.randint(2, 13))):
        w = int(rng.randint(0, 3))
        lo = int(rng.randint(0, 121))
        hi = int(rng.randint(lo + 1, min(lo + 8, 128) + 1))
        ops.append((w, lo, hi, float(rng.uniform(-100, 100))))
    return ops


def _check_drf_sequential_consistency(ops, proto):
    rt = RegCRuntime(3, page_words=64, protocol=proto, track_values=True)
    g = rt.alloc(128)
    oracle = np.zeros(128, np.float32)
    for (w, lo, hi, val) in ops:
        vals = np.full(hi - lo, val, np.float32)
        with rt.span(w, lock_id=0):
            rt.write(w, g, lo, hi, vals)
        oracle[lo:hi] = vals
    rt.barrier()
    for w in range(3):
        got = rt.read(w, g, 0, 128)
        np.testing.assert_allclose(got, oracle, rtol=0, atol=0)


@given(drf_program(), st.sampled_from([FINE_PROTO, PAGE_PROTO]))
@settings(max_examples=40, deadline=None)
def test_drf_sequential_consistency(ops, proto):
    _check_drf_sequential_consistency(ops, proto)


def test_drf_sequential_consistency_seeded():
    """Deterministic twin: seeded program draws, both protocols, so the
    property still runs under plain pytest (no hypothesis)."""
    for seed in range(12):
        ops = _drf_program_np(np.random.RandomState(seed))
        _check_drf_sequential_consistency(
            ops, FINE_PROTO if seed % 2 == 0 else PAGE_PROTO)


def _check_ordinary_stores(n_writes, reader):
    """Release-consistency-style property for ordinary stores + barriers."""
    rt = RegCRuntime(2, page_words=32, protocol=FINE_PROTO, track_values=True)
    g = rt.alloc(64)
    oracle = np.zeros(64, np.float32)
    rng = np.random.RandomState(n_writes)
    for i in range(n_writes):
        w = int(rng.randint(2))
        lo = int(rng.randint(0, 63))
        val = np.array([float(i + 1)], np.float32)
        # DRF: disjoint location per worker parity
        loc = (lo // 2) * 2 + w
        if loc >= 64:
            loc = w
        rt.write(w, g, loc, loc + 1, val)
        oracle[loc] = float(i + 1)
        rt.barrier()
    got = rt.read(reader, g, 0, 64)
    np.testing.assert_allclose(got, oracle)


@given(st.integers(1, 20), st.integers(0, 1))
@settings(max_examples=20, deadline=None)
def test_ordinary_stores_consistent_after_barrier(n_writes, reader):
    _check_ordinary_stores(n_writes, reader)


def test_ordinary_stores_consistent_after_barrier_seeded():
    """Deterministic twin: edge counts plus a spread, both readers."""
    for n_writes in (1, 2, 3, 7, 13, 20):
        for reader in (0, 1):
            _check_ordinary_stores(n_writes, reader)


@pytest.mark.parametrize("proto", [FINE_PROTO, PAGE_PROTO])
def test_false_sharing_disjoint_words_merge(proto):
    """Two workers write DISJOINT words of the SAME page in ordinary
    regions (classic false sharing, DRF).  Both writes must survive the
    barrier — the ordinary flush merges word-exact dirty masks instead of
    clobbering whole pages (found by the dsm_jacobi example; regression)."""
    rt = mk(proto, n_workers=2, page_words=64)
    g = rt.alloc(64)                         # ONE page
    rt.write(0, g, 0, 4, np.array([1, 1, 1, 1], np.float32))
    rt.write(1, g, 8, 12, np.array([2, 2, 2, 2], np.float32))
    # interleave more: w1 also writes inside w0's gap (still disjoint)
    rt.write(1, g, 5, 6, np.array([3], np.float32))
    rt.barrier()
    got = np.array(rt.read(0, g, 0, 12))
    np.testing.assert_allclose(got[0:4], 1.0)
    np.testing.assert_allclose(got[5], 3.0)
    np.testing.assert_allclose(got[8:12], 2.0)
    got1 = np.array(rt.read(1, g, 0, 12))
    np.testing.assert_allclose(got1, got)


def _check_false_sharing_random(seed):
    """Property: random DISJOINT single-word ordinary writes by 3 workers
    to one page, random flush orderings via spans/barriers -> home equals
    the sequential oracle."""
    rng = np.random.RandomState(seed)
    rt = RegCRuntime(3, page_words=64, protocol=FINE_PROTO,
                     track_values=True)
    g = rt.alloc(64)
    oracle = np.zeros(64, np.float32)
    owner = rng.randint(0, 3, size=64)       # word -> unique writer
    for step in range(rng.randint(2, 5)):
        for w in range(3):
            words = np.nonzero(owner == w)[0]
            pick = rng.choice(words, size=rng.randint(1, 5))
            for wd in np.unique(pick):
                val = np.array([rng.rand() * 10], np.float32)
                rt.write(w, g, int(wd), int(wd) + 1, val)
                oracle[wd] = val[0]
        if rng.rand() < 0.5:
            with rt.span(rng.randint(0, 3), lock_id=0):
                pass
        rt.barrier()
    for w in range(3):
        np.testing.assert_allclose(np.array(rt.read(w, g, 0, 64)), oracle)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_false_sharing_random_disjoint(seed):
    _check_false_sharing_random(seed)


def test_false_sharing_random_disjoint_seeded():
    """Deterministic twin: fixed seed spread including large ones."""
    for seed in (0, 1, 2, 3, 17, 1234, 2**31 - 1):
        _check_false_sharing_random(seed)
