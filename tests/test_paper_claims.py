"""Executable validation of the paper's §V claims against our benchmarks
(smaller sizes than the figure runs, same code paths).

Each test names the claim it checks; EXPERIMENTS.md §Paper-repro carries the
full-size numbers."""
import numpy as np
import pytest

from benchmarks.common import SteadyState, make_rt
from repro.dsm.apps import (jacobi, molecular_dynamics, stream_triad,
                            triad_bytes_per_iter)

ITERS = 5
N_TRIAD = 1 << 20          # 1M words (figure runs use 16M)
N_JACOBI = 1024
N_MD = 1024


def _triad(series, p, **kw):
    ss = SteadyState()
    rt = make_rt(series, p, **kw)
    stream_triad(rt, N_TRIAD, ITERS, on_iter=ss)
    return triad_bytes_per_iter(N_TRIAD) / ss.per_iter(), rt


def _jacobi(series, mode, p, n=N_JACOBI):
    ss = SteadyState()
    rt = make_rt(series, p)
    jacobi(rt, n, ITERS, mode=mode, on_iter=ss)
    return ss.per_iter(), rt


def _md(series, mode, p):
    ss = SteadyState()
    rt = make_rt(series, p)
    molecular_dynamics(rt, N_MD, ITERS, mode=mode, on_iter=ss)
    return ss.per_iter(), rt


# ---------------------------------------------------------------------------
# Fig. 2: TRIAD strong scaling at 8 cores
# ---------------------------------------------------------------------------


def test_fig2_triad_8core_ratios():
    """Paper: samhita ~85% of Pthreads bandwidth at 8 cores; samhita_page
    ~74%.  We accept +-8 points (the constants are calibrated, not fitted
    per-figure)."""
    bw = {s: _triad(s, 8)[0] for s in ("pthreads", "samhita", "samhita_page")}
    r_fine = bw["samhita"] / bw["pthreads"]
    r_page = bw["samhita_page"] / bw["pthreads"]
    assert 0.77 <= r_fine <= 0.93, r_fine
    assert 0.66 <= r_page <= 0.82, r_page
    assert r_fine > r_page          # the paper's ordering


def test_fig2_triad_samhita_scales():
    """Samhita bandwidth scales with cores past the single node."""
    bw8 = _triad("samhita", 8)[0]
    bw64 = _triad("samhita", 64)[0]
    assert bw64 > 4 * bw8


def test_fig3_triad_weak_scaling_tracks():
    """Weak scaling: once nodes are full (>= 8 workers), aggregate bandwidth
    grows linearly with node count."""
    agg = {}
    for p in (16, 64):
        ss = SteadyState()
        rt = make_rt("samhita", p)
        stream_triad(rt, N_TRIAD * p, ITERS, on_iter=ss)
        agg[p] = triad_bytes_per_iter(N_TRIAD * p) / ss.per_iter()
    assert agg[64] > 3.5 * agg[16]


def test_fig4_triad_spill_loses_at_most_2x():
    """Paper: 'we lose at most a factor of two' when the working set spills
    the cache (bulk fetch + prefetch keep it streaming)."""
    cache = 3 * (N_TRIAD // 1024) + 64
    bw_fit, _ = _triad("samhita", 4, cache_pages=cache)
    ss = SteadyState()
    rt = make_rt("samhita", 4, cache_pages=cache)
    stream_triad(rt, 2 * N_TRIAD, ITERS, on_iter=ss)
    bw_spill = triad_bytes_per_iter(2 * N_TRIAD) / ss.per_iter()
    assert rt.traffic.page_fetches > 2 * N_TRIAD // 1024  # it really spills
    assert bw_spill > bw_fit / 2.4                        # ~<= 2x loss


# ---------------------------------------------------------------------------
# Fig. 5: Jacobi — the reduction extension and fine-vs-page
# ---------------------------------------------------------------------------


def test_fig5_reduction_extension_beats_locks_at_scale():
    """Paper: the reduction extension dramatically improves the lock-bound
    Jacobi, most of all for samhita_page."""
    p = 64
    t_page_lock, _ = _jacobi("samhita_page", "lock", p)
    t_page_red, _ = _jacobi("samhita_page", "reduction", p)
    t_fine_lock, _ = _jacobi("samhita", "lock", p)
    t_fine_red, _ = _jacobi("samhita", "reduction", p)
    assert t_page_red < t_page_lock
    assert t_fine_red < t_fine_lock
    # the improvement is larger for page (its span cost is a page refetch)
    assert (t_page_lock / t_page_red) > (t_fine_lock / t_fine_red)


def test_fig5_fine_beats_page_with_locks():
    """Paper: fine-grain consistency-region updates are what let the lock
    version scale (span moves a diff, not a page)."""
    for p in (16, 64):
        t_fine, rt_f = _jacobi("samhita", "lock", p)
        t_page, rt_p = _jacobi("samhita_page", "lock", p)
        assert t_fine < t_page, p
        # mechanism check: fine ships diffs, page re-invalidates
        assert rt_f.traffic.diff_bytes > 0
        assert rt_p.traffic.diff_bytes == 0
        assert rt_p.traffic.invalidations > rt_f.traffic.invalidations


def test_fig6_jacobi_weak_scaling():
    """Computation rate scales with p (up to sync costs)."""
    rates = {}
    for p in (1, 16):
        n = int(N_JACOBI * p ** 0.5)
        n -= n % 64
        t, _ = _jacobi("samhita", "reduction", p, n=n)
        rates[p] = n * n / t
    assert rates[16] > 8 * rates[1]


# ---------------------------------------------------------------------------
# Fig. 7: MD — compute-bound scaling + instrumentation overhead
# ---------------------------------------------------------------------------


def test_fig7_md_scales_and_shows_instr_overhead():
    t1_ref, _ = _md("pthreads", "reduction", 1)
    t8_fine, _ = _md("samhita", "lock", 8)
    t8_page, _ = _md("samhita_page", "lock", 8)
    # both scale well (compute masks synchronization)
    assert t1_ref / t8_fine > 5.0
    assert t1_ref / t8_page > 6.0
    # visible instrumentation cost for fine, not for page (paper Fig. 7)
    t1_fine, _ = _md("samhita", "lock", 1)
    t1_page, _ = _md("samhita_page", "lock", 1)
    overhead_fine = t1_fine / t1_ref - 1.0
    overhead_page = t1_page / t1_ref - 1.0
    assert 0.05 < overhead_fine < 0.5, overhead_fine
    assert overhead_page < 0.05, overhead_page


# ---------------------------------------------------------------------------
# steady-state assumption of the figure runs
# ---------------------------------------------------------------------------


def test_triad_traffic_is_steady_after_first_iteration():
    per_iter = []

    def snap(it, rt):
        per_iter.append(rt.traffic.total_bytes)

    rt = make_rt("samhita", 4)
    stream_triad(rt, N_TRIAD, 4, on_iter=snap)
    d1 = per_iter[1] - per_iter[0]
    d2 = per_iter[2] - per_iter[1]
    d3 = per_iter[3] - per_iter[2]
    assert d1 == d2 == d3
