"""Seeded trace-fuzz harness for the RegC runtimes.

Generates deterministic random *phase-structured* SPMD programs — the
shape the batched driver accepts: bulk phases declared as (W,) interval
arrays, per-worker consistency-region spans between phases, barriers —
and cross-validates every runtime/driver pairing on them:

* ``RegCRuntime`` (the per-page reference) vs ``RegCScaleRuntime``:
  traffic field-for-field identical, modeled clocks allclose;
* scale ``loop`` driver vs ``batched`` ``phase_all`` driver: traffic
  identical AND clocks bit-equal (``rtol=0, atol=0``);
* ``numpy`` vs ``pallas`` directory backends (when jax is present).

Interval styles are chosen per phase to hit the engine's hard regimes:
block partitions (disjoint, fully batchable), halos (overlapping reach),
shared low ranges (false sharing), skewed widths, windows that shrink
phase over phase, and rotating blocks (each worker's dirty block lands in
its neighbours' reach next pass — the residual tick-ordered replay path).
Small ``cache_pages`` values force spill so the batched multi-worker
eviction engine, the per-op ``_danger`` screen, and the residual replay
are all exercised — ``crosscheck`` returns the batched runtime's path
counters so the test suite can assert none of them silently idles.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import FINE_PROTO, IDEAL_PROTO, PAGE_PROTO, RegCRuntime
from repro.core.regc import Traffic
from repro.core.regc_scale import RegCScaleRuntime
from repro.dsm.session import session

PROTOS = [FINE_PROTO, PAGE_PROTO, IDEAL_PROTO]
STYLES = ["blocks", "halo", "shared", "skewed", "shrink", "rotate"]


def jit_seeds(n: int, sample) -> Tuple[int, ...]:
    """Seeds to run in 'pallas-jit' lockstep for an n-trace family: the
    committed per-family sample by default (the jit tier re-traces + jit
    compiles, so full corpora are minutes, not seconds), the family's
    FULL corpus under ``FUZZ_JIT=1`` — the long-form exactness gate the
    fused flush chain must pass before a backend change ships."""
    if os.environ.get("FUZZ_JIT") == "1":
        return tuple(range(n))
    return tuple(s for s in sample if s < n)


def _intervals(rng, style: str, W: int, n_words: int, page_words: int,
               phase_idx: int, n_phases: int) -> Tuple[np.ndarray, np.ndarray]:
    """One (lo, hi) pair of (W,) word-interval arrays in the given style."""
    ids = np.arange(W, dtype=np.int64)
    chunk = max(n_words // W, 1)
    if style == "blocks":
        lo = ids * chunk
        hi = lo + chunk
        hi[-1] = n_words
    elif style == "halo":
        h = int(rng.integers(1, max(chunk, 2)))
        lo = np.maximum(ids * chunk - h, 0)
        hi = np.minimum((ids + 1) * chunk + h, n_words)
    elif style == "shared":
        lo = np.zeros(W, np.int64)
        hi = np.full(W, int(rng.integers(1, n_words + 1)), np.int64)
    elif style == "skewed":
        # zipf-ish widths: most ops tiny, a few page-spanning
        widths = np.minimum(rng.zipf(1.6, W).astype(np.int64) * page_words,
                            n_words)
        lo = rng.integers(0, n_words, W).astype(np.int64)
        hi = np.minimum(lo + np.maximum(widths, 1), n_words)
        lo = np.minimum(lo, hi - 1)
    elif style == "shrink":
        # windows tighten as the program advances
        f = (phase_idx + 1) / (n_phases + 1)
        shr = (chunk * f / 2).astype(np.int64) if hasattr(chunk, "astype") \
            else int(chunk * f / 2)
        lo = ids * chunk + shr
        hi = np.maximum((ids + 1) * chunk - shr, lo + 1)
        hi = np.minimum(hi, n_words)
        lo = np.minimum(lo, hi - 1)
    else:                              # rotate: blocks shifted per phase
        r = (ids + phase_idx) % W
        lo = r * chunk
        hi = np.where(r == W - 1, n_words, lo + chunk)
    return lo, hi


def gen_program(rng, W: int, n_words: int, page_words: int,
                n_phases: int = 7) -> List[tuple]:
    """Deterministic random program: a list of events.

    ``("phase", reads, writes, flops, mem_bytes)`` — bulk SPMD phase with
    ``reads``/``writes`` lists of ``(region_idx, lo(W,), hi(W,))``;
    ``("spans", [(w, lock, region_idx, lo, hi), ...])`` — per-worker
    critical sections; ``("barrier",)``.
    """
    prog: List[tuple] = []
    for ip in range(n_phases):
        reads, writes = [], []
        for _ in range(int(rng.integers(1, 3))):
            style = str(rng.choice(STYLES))
            lo, hi = _intervals(rng, style, W, n_words, page_words, ip,
                                n_phases)
            reads.append((int(rng.integers(0, 2)), lo, hi))
        for _ in range(int(rng.integers(0, 3))):
            style = str(rng.choice(STYLES))
            lo, hi = _intervals(rng, style, W, n_words, page_words, ip,
                                n_phases)
            writes.append((int(rng.integers(0, 2)), lo, hi))
        flops = (rng.integers(0, 40, W).astype(np.float64)
                 if rng.random() < 0.7 else 0.0)
        mem_bytes = float(rng.integers(0, 512)) if rng.random() < 0.4 else 0.0
        prog.append(("phase", reads, writes, flops, mem_bytes))
        if rng.random() < 0.4:         # contended spans between phases
            spans = []
            for w in range(W):
                if rng.random() < 0.6:
                    lo = int(rng.integers(0, n_words - 4))
                    spans.append((w, int(rng.integers(0, 3)),
                                  int(rng.integers(0, 2)), lo,
                                  min(lo + int(rng.integers(1, 9)),
                                      n_words)))
            if spans:
                prog.append(("spans", spans))
        if rng.random() < 0.5:
            prog.append(("barrier",))
    prog.append(("barrier",))
    return prog


def gen_danger_program(rng, W: int, n_words: int, page_words: int,
                       cache_pages: int, n_phases: int = 8) -> List[tuple]:
    """Danger-dense program family: every phase's per-worker window
    half-overlaps the previous phase's (sliding or rotating-sliding) and
    is sized against ``cache_pages`` so occupancy crosses the watermark
    mid-op — the reference's evict-then-refetch interleave that the
    vectorized refetch replay must reproduce exactly.  Disjoint-block
    phases keep workers on the batched path (the per-op danger screen);
    rotating phases add the residual tick-ordered replay on top."""
    chunk = max(n_words // W, page_words * 2)
    width = min(max(cache_pages * page_words, 2 * page_words), chunk)
    ids = np.arange(W, dtype=np.int64)
    prog: List[tuple] = []
    pos = 0
    for ip in range(n_phases):
        step = (width // 2 if rng.random() < 0.7
                else int(rng.integers(1, width)))
        span = max(chunk - width, 1)
        pos = (pos + step) % span
        if rng.random() < 0.5:                 # disjoint sliding blocks
            lo = ids * chunk + pos
        else:                                  # rotating sliding blocks
            r = (ids + ip) % W
            lo = r * chunk + pos
        hi = np.minimum(lo + width, n_words)
        lo = np.minimum(lo, hi - 1)
        reads = [(int(rng.integers(0, 2)), lo, hi)]
        writes = ([(int(rng.integers(0, 2)), lo.copy(), hi.copy())]
                  if rng.random() < 0.8 else [])
        flops = (rng.integers(0, 40, W).astype(np.float64)
                 if rng.random() < 0.5 else 0.0)
        prog.append(("phase", reads, writes, flops, 0.0))
        if rng.random() < 0.35:
            prog.append(("barrier",))
    prog.append(("barrier",))
    return prog


def gen_span_program(rng, W: int, n_words: int, page_words: int,
                     cache_pages, n_phases: int = 7,
                     n_regions: int = 2) -> List[tuple]:
    """Span-dense program family for the consistency-region engine:
    bulk ordinary phases (so every span pass starts with real flush
    work to hoist), batched span passes over hot / striped / mixed lock
    topologies with uniform, per-worker-jittered, or cache-busting-wide
    intervals (the last forces spill INSIDE spans — the full-serial
    fallback), masked subsets, spans aimed at the bulk-dirty region
    (flush-unsafe — serial again), plus nested per-worker spans (the
    dict-tracked scalar walk).  With ``n_regions >= 3`` span passes may
    split their ops across two clean regions (read one array, write
    another) — the multi-region uniform groups that serialized before
    the region-by-region grant-group algebra.  Together the corpus must
    drive every span_all path: the analytic uniform-group pass, the
    per-worker Tier-B body, and the serial fallbacks."""
    prog: List[tuple] = []
    ids = np.arange(W, dtype=np.int64)
    for ip in range(n_phases):
        if rng.random() < 0.8:
            reads, writes = [], []
            lo, hi = _intervals(rng, str(rng.choice(STYLES)), W, n_words,
                                page_words, ip, n_phases)
            writes.append((0, lo, hi))
            if rng.random() < 0.5:
                lo2, hi2 = _intervals(rng, str(rng.choice(STYLES)), W,
                                      n_words, page_words, ip, n_phases)
                reads.append((0, lo2, hi2))
            flops = (rng.integers(0, 20, W).astype(np.float64)
                     if rng.random() < 0.5 else 0.0)
            prog.append(("phase", reads, writes, flops, 0.0))
        for _ in range(int(rng.integers(1, 3))):
            topo = rng.random()
            if topo < 0.4:
                locks = np.zeros(W, np.int64)             # hot single lock
            elif topo < 0.8:
                k = int(rng.integers(2, min(W, 4) + 1))
                locks = ids % k                           # striped
            else:
                locks = rng.integers(0, 3, W).astype(np.int64)
            g = 1 if rng.random() < 0.7 else 0    # 0 = bulk region: unsafe
            shape = rng.random()
            if shape < 0.55:                      # uniform per lock group
                u = np.unique(locks)
                base = {int(l): int(rng.integers(0, n_words - 8)) for l in u}
                wid = {int(l): int(rng.integers(1, 8)) for l in u}
                lo = np.array([base[int(l)] for l in locks], np.int64)
                hi = np.minimum(
                    lo + np.array([wid[int(l)] for l in locks], np.int64),
                    n_words)
            elif shape < 0.85:                    # per-worker jitter
                lo = rng.integers(0, n_words - 8, W).astype(np.int64)
                hi = np.minimum(lo + rng.integers(1, 9, W), n_words)
            else:                                 # wide: spill inside spans
                wide = page_words * 2 * max(cache_pages or 4, 2)
                lo = np.zeros(W, np.int64)
                hi = np.full(W, min(n_words, wide), np.int64)
            mask = None
            if rng.random() < 0.3:
                m = rng.random(W) < 0.7
                if not m.any():
                    m[int(rng.integers(0, W))] = True
                mask = m
            reads_s = [(g, lo, hi)] if rng.random() < 0.8 else []
            writes_s = ([(g, lo.copy(), hi.copy())]
                        if rng.random() < 0.9 else [])
            if n_regions >= 3 and writes_s and rng.random() < 0.4:
                # multi-region span ops: the write lands in a DIFFERENT
                # region than the read, so uniform grant groups must
                # resolve region-by-region on the analytic path (these
                # shapes counted span_serial before PR 8)
                writes_s = [(2 if g != 2 else 1, lo.copy(), hi.copy())]
                if reads_s and rng.random() < 0.5:
                    reads_s.append((writes_s[0][0], lo.copy(), hi.copy()))
            prog.append(("span_phase", mask, locks, reads_s, writes_s))
        if rng.random() < 0.4:
            evs = []
            for w in range(W):
                if rng.random() < 0.4:
                    lo = int(rng.integers(0, n_words - 4))
                    evs.append((w, (int(rng.integers(0, 3)),
                                    3 + int(rng.integers(0, 2))),
                                int(rng.integers(0, 2)), lo,
                                min(lo + int(rng.integers(1, 9)), n_words)))
            if evs:
                prog.append(("spans_nested", evs))
        if rng.random() < 0.5:
            prog.append(("barrier",))
    prog.append(("barrier",))
    return prog


def race_trace_params(seed: int) -> Dict:
    """Race-family params: alternating racy/clean traces over the full
    cache spectrum (None / generous / forced-spill), so detection is
    exercised both on the plain batched path and under the
    eviction/refetch engine (planes must survive window ops)."""
    rng = np.random.default_rng(50_000 + seed)
    W = int(rng.integers(2, 5))
    page_words = int(rng.choice([8, 16, 32]))
    n_words = page_words * int(rng.integers(12, 32))
    cache_pages = [None, 3, 6, 9][seed % 4]
    return dict(rng=rng, W=W, page_words=page_words, n_words=n_words,
                cache_pages=cache_pages, proto=PROTOS[seed % 3],
                racy=bool(seed % 2))


def gen_race_program(rng, W: int, n_words: int, page_words: int,
                     racy: bool, n_segments: int = 6) -> List[tuple]:
    """Race-family generator.  Clean programs are race-free BY
    CONSTRUCTION: within a segment writes are owner-disjoint (or the
    whole range is serialized under ONE lock) and reads never overlap a
    peer's same-segment writes; segments are separated by barriers, so
    every cross-segment conflict is ordered.  Racy programs splice 1-3
    conflict gadgets into that skeleton — same-phase overlapping writes
    (W/W), a write->read page handoff with the barrier OMITTED (R/W),
    and a shared span range under DIFFERENT locks (no common lock, no
    happens-before) — each a guaranteed race, so the detector must flag
    every racy trace and stay silent on every clean one."""
    ids = np.arange(W, dtype=np.int64)
    # owner blocks are PAGE-disjoint: detection is page-granular, so a
    # clean program may not let two workers write the same page even at
    # disjoint word offsets (that flags — conservatively — by design)
    chunk = max(n_words // (W * page_words), 1) * page_words
    own_lo = ids * chunk
    own_hi = np.minimum(own_lo + chunk, n_words)
    shared_hi = min(n_words, max(2 * page_words, chunk))
    prog: List[tuple] = []

    def seg_own():
        return [("phase", [(0, own_lo.copy(), own_hi.copy())],
                 [(0, own_lo.copy(), own_hi.copy())], 0.0, 0.0)]

    def seg_readall():
        hi = np.full(W, int(rng.integers(2, n_words + 1)), np.int64)
        return [("phase", [(0, np.zeros(W, np.int64), hi)], [], 0.0, 0.0)]

    def seg_lockstep():
        lo = np.zeros(W, np.int64)
        hi = np.full(W, shared_hi, np.int64)
        return [("span_phase", None, np.zeros(W, np.int64),
                 [(1, lo, hi)], [(1, lo.copy(), hi.copy())])]

    def seg_rotate(k):
        r = (ids + k) % W
        lo = r * chunk
        hi = np.minimum(lo + chunk, n_words)
        return [("phase", [(0, lo, hi)], [(0, lo.copy(), hi.copy())],
                 0.0, 0.0)]

    for k in range(n_segments):
        pick = int(rng.integers(0, 4))
        prog += (seg_own, seg_readall, seg_lockstep,
                 lambda: seg_rotate(k))[pick]()
        prog.append(("barrier",))

    if not racy:
        return prog

    def gadget_ww():
        a, b = (int(x) for x in rng.choice(W, 2, replace=False))
        x = int(rng.integers(0, max(n_words - 2 * page_words, 1)))
        lo, hi = own_lo.copy(), own_hi.copy()
        lo[a] = lo[b] = x
        hi[a] = hi[b] = min(x + int(rng.integers(1, 2 * page_words)),
                            n_words)
        return [("phase", [], [(0, lo, hi)], 0.0, 0.0)]

    def gadget_rw():
        a, b = (int(x) for x in rng.choice(W, 2, replace=False))
        x = int(rng.integers(0, max(n_words - 2 * page_words, 1)))
        x_hi = min(x + int(rng.integers(1, 2 * page_words)), n_words)
        lo_w, hi_w = own_lo.copy(), own_hi.copy()
        lo_w[a], hi_w[a] = x, x_hi
        lo_r, hi_r = own_lo.copy(), own_hi.copy()
        lo_r[b], hi_r[b] = x, x_hi
        # write -> read handoff with the barrier OMITTED between phases
        return [("phase", [], [(0, lo_w, hi_w)], 0.0, 0.0),
                ("phase", [(0, lo_r, hi_r)], [], 0.0, 0.0)]

    def gadget_span_race():
        # the same shared range under DIFFERENT locks: serialized within
        # each lock group, racing across them
        lo = np.zeros(W, np.int64)
        hi = np.full(W, shared_hi, np.int64)
        return [("span_phase", None, ids % 2, [(1, lo, hi)],
                 [(1, lo.copy(), hi.copy())])]

    gadgets = [gadget_ww, gadget_rw, gadget_span_race]
    for _ in range(int(rng.integers(1, 4))):
        gev = gadgets[int(rng.integers(0, 3))]()
        pos = int(rng.integers(0, len(prog) + 1))
        # a gadget is spliced as one contiguous chunk, so no barrier can
        # land inside it and its seeded race survives later splices
        prog[pos:pos] = gev
    return prog


def race_crosscheck(seed: int, *, backends=("numpy",)) -> Dict[str, int]:
    """Run one race-family trace with ``detect_races=True`` on every
    driver pairing and assert the detection contract:

    * loop vs batched: the IDENTICAL race set after every event (the
      batched detector flags at pass granularity, but the page-granular
      race set is processing-order independent), traffic field-for-field
      and clocks bit-equal;
    * the scalar per-event oracle (``RegCRuntime``) reports the same
      final race set and counts;
    * pure observer: a detection-off batched run has bit-equal traffic
      and clocks after every event;
    * every racy trace is flagged; every clean trace is silent."""
    p = race_trace_params(seed)
    prog = gen_race_program(p["rng"], p["W"], p["n_words"],
                            p["page_words"], p["racy"])
    n = p["n_words"]
    stats: Dict[str, int] = {}
    for backend in backends:
        def make_scale(detect):
            return RegCScaleRuntime(p["W"], page_words=p["page_words"],
                                    protocol=p["proto"], prefetch=1,
                                    model_mechanism=False,
                                    cache_pages=p["cache_pages"],
                                    backend=backend, detect_races=detect)
        runs = {"loop": make_scale(True), "batched": make_scale(True)}
        off = make_scale(False)
        gas = {d: [rt.alloc(n), rt.alloc(n)] for d, rt in runs.items()}
        gas_off = [off.alloc(n), off.alloc(n)]
        ctx = (seed, p["proto"], p["cache_pages"], backend, p["racy"])
        for i, ev in enumerate(prog):
            for d, rt in runs.items():
                apply_event(rt, ev, gas[d], d)
            apply_event(off, ev, gas_off, "batched")
            assert runs["loop"].races == runs["batched"].races, \
                (ctx, i, ev[0], runs["loop"].races ^ runs["batched"].races)
            np.testing.assert_allclose(
                runs["batched"].clock, runs["loop"].clock, rtol=0, atol=0,
                err_msg=f"{ctx} event {i} ({ev[0]})")
            np.testing.assert_allclose(
                runs["batched"].clock, off.clock, rtol=0, atol=0,
                err_msg=f"{ctx} observer event {i} ({ev[0]})")
        assert_traffic_equal(runs["loop"], runs["batched"], ctx)
        assert_traffic_equal(off, runs["batched"], ctx + ("observer",))
        assert off.stats["race_ww"] == 0 and off.stats["race_rw"] == 0

        ref = RegCRuntime(p["W"], page_words=p["page_words"],
                          protocol=p["proto"], track_values=False,
                          prefetch=1, cache_pages=p["cache_pages"],
                          detect_races=True)
        run_program(ref, prog, [ref.alloc(n), ref.alloc(n)], "ref")
        assert ref.races == runs["batched"].races, \
            (ctx, ref.races ^ runs["batched"].races)
        assert ref.race_counts == runs["batched"].race_counts, ctx
        if p["racy"]:
            assert runs["batched"].races, (ctx, "seeded race not flagged")
        else:
            assert not runs["batched"].races, (ctx, runs["batched"].races)
        for k, v in runs["batched"].stats.items():
            stats[k] = stats.get(k, 0) + v
    return stats


def race_chaos_crosscheck(seed: int) -> Dict[str, int]:
    """Mid-run crash/recovery must not change the flagged race set: a
    race-family trace run under ``ChaosHarness`` (worker kills +
    barrier-checkpoint replay, with detector state riding
    ``snapshot``/``from_snapshot``) finishes with the identical race
    set, traffic, clocks and stats as the uninjected detection-on
    baseline — on both drivers."""
    import tempfile

    from repro.ft import (ChaosHarness, FailureInjector, assert_bit_equal,
                          run_uninjected)
    p = race_trace_params(seed)
    prog = gen_race_program(p["rng"], p["W"], p["n_words"],
                            p["page_words"], p["racy"])
    n = p["n_words"]

    def make_rt():
        return RegCScaleRuntime(p["W"], page_words=p["page_words"],
                                protocol=p["proto"], prefetch=1,
                                model_mechanism=False,
                                cache_pages=p["cache_pages"],
                                detect_races=True)

    rng = np.random.default_rng(60_000 + seed)
    n_crash = int(rng.integers(1, 3))
    at_steps = [int(s) for s in
                rng.choice(np.arange(1, len(prog) + 1), size=n_crash,
                           replace=False)]
    stats: Dict[str, int] = {}
    for d in ("loop", "batched"):
        base = run_uninjected(make_rt, [n, n], d, prog, apply_event)
        with tempfile.TemporaryDirectory() as td:
            inj = FailureInjector(at_steps=at_steps)
            rt, rep = ChaosHarness(make_rt, [n, n], d, td, apply_event,
                                   injector=inj).run(prog)
        assert rep.n_crashes == n_crash, (seed, d, at_steps, rep)
        assert_bit_equal(rt, base, (seed, d))
        assert rt.races == base.races, (seed, d, rt.races ^ base.races)
        if p["racy"]:
            assert rt.races, (seed, d, "race set lost in recovery")
        stats["crashes"] = stats.get("crashes", 0) + rep.n_crashes
        for k in ("race_ww", "race_rw"):
            stats[k] = stats.get(k, 0) + rt.stats[k]
    return stats


def apply_event(rt, ev, gas, driver: str):
    """Execute one program event on any runtime: ``batched``
    (phase_all), ``loop`` (per-worker phase), or ``ref`` (raw
    read/write/compute — the reference runtime has no phase API)."""
    W = rt.W
    if ev[0] == "phase":
        _, reads, writes, flops, mem_bytes = ev
        r = [(gas[g], lo, hi) for g, lo, hi in reads]
        wr = [(gas[g], lo, hi) for g, lo, hi in writes]
        if driver == "batched":
            rt.phase_all(reads=r, writes=wr, flops=flops,
                         mem_bytes=mem_bytes)
            return
        fl = np.broadcast_to(np.asarray(flops, np.float64), (W,))
        for w in range(W):
            if driver == "loop":
                rt.phase(w,
                         reads=[(ga, int(lo[w]), int(hi[w]))
                                for ga, lo, hi in r],
                         writes=[(ga, int(lo[w]), int(hi[w]))
                                 for ga, lo, hi in wr],
                         flops=float(fl[w]), mem_bytes=mem_bytes)
                continue
            for ga, lo, hi in r:
                rt.read(w, ga, int(lo[w]), int(hi[w]))
            for ga, lo, hi in wr:
                rt.write(w, ga, int(lo[w]), int(hi[w]))
            if fl[w] or mem_bytes:
                rt.compute(w, flops=float(fl[w]), mem_bytes=mem_bytes)
    elif ev[0] == "spans":
        for (w, lock, g, lo, hi) in ev[1]:
            rt.acquire(w, lock)
            rt.read(w, gas[g], lo, hi)
            rt.write(w, gas[g], lo, hi)
            rt.release(w, lock)
    elif ev[0] == "span_phase":
        _, mask, locks, reads, writes = ev
        r = [(gas[g], lo, hi) for g, lo, hi in reads]
        wr = [(gas[g], lo, hi) for g, lo, hi in writes]
        if driver == "batched":
            rt.span_all(mask, locks, reads=r, writes=wr)
        else:
            # the Session's own per-worker span body — the fuzz oracle
            # and the loop driver must be the same code, not a copy
            session(rt, "loop").span(locks, reads=r, writes=wr,
                                     w_mask=mask)
    elif ev[0] == "spans_nested":
        # nested spans: inner is dict-tracked, outer plane-tracked; the
        # write between the releases lands on the OUTER (plane) span
        for (w, locks, g, lo, hi) in ev[1]:
            for lk in locks:
                rt.acquire(w, int(lk))
            rt.read(w, gas[g], lo, hi)
            rt.write(w, gas[g], lo, hi)
            rt.release(w, int(locks[-1]))
            rt.write(w, gas[g], lo, hi)
            for lk in reversed(locks[:-1]):
                rt.release(w, int(lk))
    else:
        rt.barrier()


def run_program(rt, prog, gas, driver: str):
    for ev in prog:
        apply_event(rt, ev, gas, driver)
    return rt


def assert_traffic_equal(a, b, ctx=""):
    for f in dataclasses.fields(Traffic):
        av, bv = getattr(a.traffic, f.name), getattr(b.traffic, f.name)
        assert av == bv, (ctx, f.name, a.traffic, b.traffic)


def trace_params(seed: int) -> Dict:
    rng = np.random.default_rng(seed)
    W = int(rng.integers(2, 5))
    page_words = int(rng.choice([16, 32, 64]))
    n_words = page_words * int(rng.integers(10, 36))
    # None / generous / forced-spill cache sizes
    cache_pages = [None, 3, 5, 9][seed % 4]
    return dict(rng=rng, W=W, page_words=page_words, n_words=n_words,
                cache_pages=cache_pages, proto=PROTOS[seed % 3])


def danger_trace_params(seed: int) -> Dict:
    """Like ``trace_params`` but the cache is always present and sized
    against the window width so mid-op eviction is the common case."""
    rng = np.random.default_rng(10_000 + seed)
    W = int(rng.integers(2, 5))
    page_words = int(rng.choice([8, 16, 32]))
    n_words = page_words * int(rng.integers(16, 48)) * W
    cache_pages = int(rng.integers(2, 10))
    return dict(rng=rng, W=W, page_words=page_words, n_words=n_words,
                cache_pages=cache_pages, proto=PROTOS[seed % 2])


def span_trace_params(seed: int) -> Dict:
    """Like ``trace_params`` but tuned for the span-dense family: mostly
    cache-free runs (the lock benchmarks' regime, where the analytic
    group path must dominate) with periodic small caches that force
    spill inside spans (the full-serial fallback)."""
    rng = np.random.default_rng(20_000 + seed)
    W = int(rng.integers(2, 6))
    page_words = int(rng.choice([8, 16, 32]))
    n_words = page_words * int(rng.integers(12, 40))
    cache_pages = [None, None, 5, 8][seed % 4]
    return dict(rng=rng, W=W, page_words=page_words, n_words=n_words,
                cache_pages=cache_pages, proto=PROTOS[seed % 3])


def serving_trace_params(seed: int) -> Dict:
    """Serving-family params: page-aligned per-slot KV blocks (stride =
    max_tokens x tok_words rounded up to a page), caches mostly sized
    BELOW a slot's prompt working set so bulk prefill writes cross the
    danger screen and the sliding attention window keeps batched
    eviction live."""
    rng = np.random.default_rng(70_000 + seed)
    W = int(rng.integers(2, 5))
    page_words = int(rng.choice([8, 16, 32]))
    tok_words = int(rng.integers(2, 6))
    max_tokens = int(rng.integers(10, 25))
    stride = -(-(max_tokens * tok_words) // page_words) * page_words
    cache_pages = [2, 3, 4, None][seed % 4]
    return dict(rng=rng, W=W, page_words=page_words,
                n_words=W * stride, stride=stride, tok_words=tok_words,
                max_tokens=max_tokens, cache_pages=cache_pages,
                proto=PROTOS[seed % 3])


def gen_serving_program(rng, W: int, stride: int, tok_words: int,
                        max_tokens: int, n_steps: int = 10) -> List[tuple]:
    """Serving-shaped program family (the ``apps.kv_serving`` access
    pattern, fuzzed): region 0 is the KV arena (one page-aligned slot
    block per worker), region 1 the admission-queue cell.  Steps mix
    masked admission spans on one hot lock, bursty bulk prefill writes
    (whole-prompt KV ranges — wider than the small caches, the mid-op
    danger regime), and Zipf-skewed decode phases (trailing-window reads
    + one appended row per active slot; idle slots touch one word of
    their own block, as every worker participates in an SPMD phase).
    Slot blocks stay disjoint, so the batched driver must absorb the
    eviction pressure on the vectorized paths."""
    ids = np.arange(W, dtype=np.int64)
    base = ids * stride
    zero = np.zeros(W, np.int64)
    two = np.full(W, 2, np.int64)
    length = np.zeros(W, np.int64)
    active = np.zeros(W, bool)
    # zipf-skewed decode rates: hot slots append every round, cold rarely
    rate = np.minimum(rng.zipf(1.4, W), 4).astype(np.int64)
    prog: List[tuple] = []
    for _step in range(n_steps):
        free = ~active
        if free.any() and rng.random() < 0.6:     # bursty admissions
            adm = free & (rng.random(W) < 0.7)
            if adm.any():
                prog.append(("span_phase", adm.copy(),
                             np.zeros(W, np.int64),
                             [(1, zero.copy(), two.copy())],
                             [(1, zero.copy(), two.copy())]))
                plen = np.where(
                    adm, rng.integers(1, max(2, (3 * max_tokens) // 4), W),
                    0).astype(np.int64)
                w_hi = base + np.where(adm, plen * tok_words, 1)
                prog.append(("phase", [], [(0, base.copy(), w_hi)],
                             rng.integers(0, 20, W).astype(np.float64),
                             0.0))
                length[adm] = plen[adm]
                active |= adm
        stepping = active & (rng.integers(1, 5, W) <= rate)
        stepping &= length < max_tokens - 1       # room to append a row
        if stepping.any():                        # decode phase
            win = np.minimum(length, int(rng.integers(2, max_tokens)))
            r_lo = base + np.where(stepping, (length - win) * tok_words, 0)
            r_hi = r_lo + np.where(stepping, win * tok_words, 1)
            w_lo = base + np.where(stepping, length * tok_words, 0)
            w_hi = w_lo + np.where(stepping, tok_words, 1)
            prog.append(("phase", [(0, r_lo, r_hi)], [(0, w_lo, w_hi)],
                         rng.integers(0, 30, W).astype(np.float64), 0.0))
            length[stepping] += 1
        active &= ~(active & (rng.random(W) < 0.25))   # completions
        if rng.random() < 0.6:
            prog.append(("barrier",))
    prog.append(("barrier",))
    return prog


def crosscheck(seed: int, *, check_ref: bool = True,
               backends=("numpy",),
               family: str = "mixed") -> Dict[str, int]:
    """Run one fuzz trace on every runtime/driver pairing and assert the
    exactness contract.  Returns the batched runtime's path-counter stats
    (summed over backends) so callers can assert coverage.

    ``family``: 'mixed' is the general corpus; 'danger' draws from the
    danger-dense rotating/sliding-window generator and additionally
    cross-validates the vectorized refetch replay against the scalar
    page-walk oracle (``danger_mode='scalar'``) — traffic exact, clocks
    allclose (the schedule groups per-victim-run clock charges the
    scalar walk applies per page); 'span' draws from the span-dense
    consistency-region generator (hot/striped/nested locks, spill forced
    inside spans), where the batched runtime drives ``span_all`` and the
    loop runtime the per-worker span loop; 'serving' draws from the
    KV-serving-shaped generator (masked admission spans, bursty bulk
    prefill writes, skewed windowed decode appends under slot-scale
    caches)."""
    assert family in ("mixed", "danger", "span", "serving"), family
    if family == "danger":
        p = danger_trace_params(seed)
        prog = gen_danger_program(p["rng"], p["W"], p["n_words"],
                                  p["page_words"], p["cache_pages"])
    elif family == "serving":
        p = serving_trace_params(seed)
        prog = gen_serving_program(p["rng"], p["W"], p["stride"],
                                   p["tok_words"], p["max_tokens"])
    elif family == "span":
        p = span_trace_params(seed)
        prog = gen_span_program(p["rng"], p["W"], p["n_words"],
                                p["page_words"], p["cache_pages"],
                                n_regions=3)
    else:
        p = trace_params(seed)
        prog = gen_program(p["rng"], p["W"], p["n_words"], p["page_words"])
    n_alloc = p["n_words"]
    n_regs = 3 if family == "span" else 2

    def make_scale(backend, danger_mode="vec"):
        return RegCScaleRuntime(p["W"], page_words=p["page_words"],
                                protocol=p["proto"], prefetch=1,
                                model_mechanism=False,
                                cache_pages=p["cache_pages"],
                                backend=backend, danger_mode=danger_mode)

    ref = None
    if check_ref:
        ref = RegCRuntime(p["W"], page_words=p["page_words"],
                          protocol=p["proto"], track_values=False,
                          prefetch=1, cache_pages=p["cache_pages"])
        run_program(ref, prog,
                    [ref.alloc(n_alloc) for _ in range(n_regs)], "ref")

    stats: Dict[str, int] = {}
    for backend in backends:
        # loop vs batched run in LOCKSTEP with clocks compared bit-equal
        # after EVERY event: barriers join clocks to their max, so an
        # end-of-trace check alone can mask per-worker misattribution
        # (a charge landing on the wrong worker with the right total)
        runs = {"loop": make_scale(backend),
                "batched": make_scale(backend)}
        gas = {d: [rt.alloc(n_alloc) for _ in range(n_regs)]
               for d, rt in runs.items()}
        ctx = (seed, p["proto"], p["cache_pages"], backend)
        for i, ev in enumerate(prog):
            for d, rt in runs.items():
                apply_event(rt, ev, gas[d], d)
            np.testing.assert_allclose(
                runs["batched"].clock, runs["loop"].clock, rtol=0, atol=0,
                err_msg=f"{ctx} event {i} ({ev[0]})")
        assert_traffic_equal(runs["loop"], runs["batched"], ctx)
        if ref is not None:
            assert_traffic_equal(ref, runs["batched"], ctx)
            np.testing.assert_allclose(runs["batched"].clock, ref.clock,
                                       rtol=1e-9, atol=1e-12,
                                       err_msg=str(ctx))
        if family == "danger":
            # scalar page-walk oracle: same trace, per-page replay forced
            sca = make_scale(backend, danger_mode="scalar")
            run_program(sca, prog,
                        [sca.alloc(n_alloc), sca.alloc(n_alloc)], "batched")
            assert_traffic_equal(sca, runs["batched"], ctx + ("scalar",))
            np.testing.assert_allclose(runs["batched"].clock, sca.clock,
                                       rtol=1e-9, atol=1e-12,
                                       err_msg=f"{ctx} vec-vs-scalar")
            assert sca.stats["danger_vec_ops"] == 0
        for k, v in runs["batched"].stats.items():
            stats[k] = stats.get(k, 0) + v
    return stats


def chaos_trace_params(seed: int) -> Dict:
    """Chaos-family params: every run carries a seeded message-loss
    model (nonzero drop rate — an idle ChaosNet would test nothing) and
    a tight straggler window so barrier flags actually fire."""
    rng = np.random.default_rng(30_000 + seed)
    W = int(rng.integers(2, 5))
    page_words = int(rng.choice([16, 32]))
    n_words = page_words * int(rng.integers(10, 30))
    cache_pages = [None, 3, 5, 9][seed % 4]
    drop = float(rng.choice([0.05, 0.15, 0.3]))
    return dict(rng=rng, W=W, page_words=page_words, n_words=n_words,
                cache_pages=cache_pages, proto=PROTOS[seed % 3], drop=drop)


def chaos_crosscheck(seed: int, *, backends=("numpy",)) -> Dict[str, int]:
    """The crash-recovery analogue of :func:`crosscheck`: one seeded
    program under deterministic message loss, run four ways per backend —
    loop/batched uninjected baselines (asserted in lockstep: traffic
    field-for-field, clocks bit-equal, chaos counters identical), then
    loop/batched under injected worker crashes with barrier-checkpoint
    recovery (``ft.ChaosHarness``), each asserted bit-equal to its
    uninjected baseline — traffic, clocks, AND stats, so the replayed
    suffix provably re-took the same engine paths and retry charges.
    Returns aggregate counters (crashes, drops, retries, replays …) so
    the suite can assert no chaos path silently idled."""
    import tempfile

    from repro.dsm.costmodel import ChaosNet
    from repro.ft import (ChaosHarness, FailureInjector, StragglerMonitor,
                          assert_bit_equal, run_uninjected)
    p = chaos_trace_params(seed)
    rng = p["rng"]
    if seed % 2:
        prog = gen_span_program(rng, p["W"], p["n_words"], p["page_words"],
                                p["cache_pages"], n_phases=5, n_regions=3)
    else:
        prog = gen_program(rng, p["W"], p["n_words"], p["page_words"],
                           n_phases=5)
    n = p["n_words"]
    # crash schedule over the tick range: every event ticks exactly once
    # (harness or internal), so steps in [1, len(prog)] always fire;
    # half the entries target a specific worker, half are bare steps
    n_crash = int(rng.integers(1, 3))
    crash_steps = rng.choice(np.arange(1, len(prog) + 1), size=n_crash,
                             replace=False)
    at_steps = [((int(s), int(rng.integers(0, p["W"])))
                 if rng.random() < 0.5 else int(s)) for s in crash_steps]

    stats: Dict[str, int] = {}
    for backend in backends:
        def make_rt():
            return RegCScaleRuntime(
                p["W"], page_words=p["page_words"], protocol=p["proto"],
                prefetch=1, model_mechanism=False,
                cache_pages=p["cache_pages"], backend=backend,
                chaos=ChaosNet(seed=seed, drop_rate=p["drop"]),
                straggler=StragglerMonitor(p["W"], window=4, patience=1))

        base = {d: run_uninjected(make_rt, [n, n, n], d, prog, apply_event)
                for d in ("loop", "batched")}
        ctx = (seed, p["proto"], p["cache_pages"], p["drop"], backend)
        assert_traffic_equal(base["loop"], base["batched"], ctx)
        np.testing.assert_array_equal(base["loop"].clock,
                                      base["batched"].clock,
                                      err_msg=str(ctx))
        for k in ("chaos_msgs", "chaos_drops", "chaos_inval_retries"):
            assert base["loop"].stats[k] == base["batched"].stats[k], \
                (ctx, k)
        for d in ("loop", "batched"):
            with tempfile.TemporaryDirectory() as td:
                inj = FailureInjector(at_steps=at_steps)
                rt, rep = ChaosHarness(make_rt, [n, n, n], d, td, apply_event,
                                       injector=inj).run(prog)
            assert rep.n_crashes == n_crash, (ctx, d, at_steps, rep)
            assert_bit_equal(rt, base[d], (ctx, d))
            stats["crashes"] = stats.get("crashes", 0) + rep.n_crashes
            stats["replayed_events"] = (stats.get("replayed_events", 0)
                                        + rep.n_replayed_events)
            stats["checkpoints"] = (stats.get("checkpoints", 0)
                                    + rep.n_checkpoints)
        for k, v in base["batched"].stats.items():
            stats[k] = stats.get(k, 0) + v
    return stats


def cluster_trace_params(seed: int) -> Dict:
    """Cluster-family params: every run is sharded across 2-4 OS
    processes, alternating driver and degraded-recovery mode across the
    corpus, with 0-2 process faults (SIGKILL / one-directional link
    partitions) scheduled at random event rounds."""
    rng = np.random.default_rng(40_000 + seed)
    W = int(rng.integers(3, 7))
    page_words = int(rng.choice([16, 32]))
    n_words = page_words * int(rng.integers(10, 24))
    cache_pages = [None, 4, 6][seed % 3]
    n_shards = int(min(W, rng.integers(2, 5)))
    drop = float(rng.choice([0.0, 0.1, 0.2]))
    return dict(rng=rng, W=W, page_words=page_words, n_words=n_words,
                cache_pages=cache_pages, proto=PROTOS[seed % 3],
                n_shards=n_shards, drop=drop,
                driver=("batched", "loop")[seed % 2],
                recovery=("respawn", "rebind")[(seed // 2) % 2])


def cluster_crosscheck(seed: int, *, backends=("numpy",)) -> Dict[str, int]:
    """The process-level analogue of :func:`chaos_crosscheck`: one
    seeded program run on the sharded multi-process runtime
    (``repro.cluster``, 2-4 spawned shard processes) against the
    single-process baseline, in LOCKSTEP — every event round's
    cross-shard agreed digest must equal the baseline's state digest at
    that event — then again under injected process faults (SIGKILL and
    one-directional partitions in both directions), asserting the
    recovered finish bit-equal to the unfailed single-process run:
    traffic field-for-field, clocks bit-equal, the full stats dict.
    Returns aggregate counters (detections, kills, per-direction
    partitions, respawns, rebinds, replayed events, RPC retries) so the
    suite can assert no failure path silently idled."""
    import tempfile

    from repro.cluster.shard import make_runtime, state_digest
    from repro.ft import FailureInjector
    from repro.ft.coherence import (ClusterChaosHarness, assert_bit_equal,
                                    harness_ticks)
    p = cluster_trace_params(seed)
    rng = p["rng"]
    # program family drawn from the rng (NOT seed parity, which picks
    # the driver) so span programs also land on the batched driver
    if int(rng.integers(0, 2)):
        prog = gen_span_program(rng, p["W"], p["n_words"], p["page_words"],
                                p["cache_pages"], n_phases=4)
    else:
        prog = gen_program(rng, p["W"], p["n_words"], p["page_words"],
                           n_phases=4)
    n = p["n_words"]
    n_faults = int(rng.integers(0, 3))
    fault_steps = rng.choice(np.arange(1, len(prog) + 1), size=n_faults,
                             replace=False)
    kinds = rng.choice(FailureInjector.CLUSTER_KINDS, size=n_faults)
    ranks = rng.integers(0, p["n_shards"], size=n_faults)
    cluster_at = [(str(k), int(s), int(r))
                  for k, s, r in zip(kinds, fault_steps, ranks)]

    stats: Dict[str, int] = {}
    for backend in backends:
        cfg = dict(n_workers=p["W"], page_words=p["page_words"],
                   protocol=p["proto"], cache_pages=p["cache_pages"],
                   backend=backend,
                   chaos=(dict(seed=seed, drop_rate=p["drop"])
                          if p["drop"] else None),
                   straggler=dict(n_workers=p["W"], window=4, k=4.0,
                                  abs_floor_s=1e-4, patience=1))
        ctx = (seed, p["proto"], p["n_shards"], p["driver"],
               p["recovery"], backend)
        # single-process baseline with a per-event digest trace (same
        # tick schedule as the shards)
        rt = make_runtime(cfg)
        gas = [rt.alloc(n), rt.alloc(n)]
        base_digests = {}
        for i, ev in enumerate(prog):
            if harness_ticks(ev, p["driver"]):
                rt.chaos_tick()
            apply_event(rt, ev, gas, p["driver"])
            base_digests[i] = state_digest(rt)

        # clean sharded run: lockstep digests + bit-equal finish
        with tempfile.TemporaryDirectory() as td:
            res, rep, digests = ClusterChaosHarness(
                cfg, [n, n, n], p["driver"], td,
                ("trace_fuzz", "apply_event"),
                n_shards=p["n_shards"]).run(prog)
        assert_bit_equal(res, rt, ctx + ("clean",))
        assert digests == base_digests, ctx + ("lockstep",)
        assert rep.detections == 0, (ctx, rep)

        # faulted sharded run: recover to the same bit-equal finish
        with tempfile.TemporaryDirectory() as td:
            inj = FailureInjector(cluster_at=cluster_at)
            res, rep, digests = ClusterChaosHarness(
                cfg, [n, n, n], p["driver"], td,
                ("trace_fuzz", "apply_event"),
                n_shards=p["n_shards"], recovery=p["recovery"],
                # jax backends can stall a healthy shard for seconds on
                # first-call kernel compilation — give them slack so the
                # no-false-positive bound below stays meaningful
                rpc_timeout_s=0.1 if backend == "numpy" else 1.5,
                rpc_attempts=3, injector=inj).run(prog)
        assert_bit_equal(res, rt, ctx + ("faulted",))
        assert digests == base_digests, ctx + ("faulted-lockstep",)
        if n_faults:
            # the earliest fault always targets an alive shard, so at
            # least one injection performs and must be detected
            assert rep.kills + rep.partitions >= 1, (ctx, rep)
            assert rep.detections >= 1, (ctx, rep)
            if backend == "numpy":
                # fast replicas: every detection traces to an injected
                # fault (a compile-stalled accelerator backend may add
                # benign false positives — safe, but not bounded here)
                assert rep.detections <= rep.kills + rep.partitions, \
                    (ctx, rep)
            if p["recovery"] == "respawn":
                assert rep.respawns == rep.detections, (ctx, rep)
        if cluster_at:
            # the earliest-scheduled fault always lands on an alive
            # shard, so it is PERFORMED (later ones may be skipped if
            # their target is already quarantined)
            first = min(cluster_at, key=lambda t: t[1])
            stats["performed_" + first[0]] = (
                stats.get("performed_" + first[0], 0) + 1)
        for kind, _s, _r in cluster_at:
            stats[kind] = stats.get(kind, 0) + 1
        for k, v in rep.counters().items():
            stats[k] = stats.get(k, 0) + v
        stats["rpc_retries"] = (stats.get("rpc_retries", 0)
                                + rep.rpc_retries)
        for k in ("chaos_msgs", "chaos_drops", "straggler_checks",
                  "straggler_flags", "span_all_calls"):
            stats[k] = stats.get(k, 0) + res.stats.get(k, 0)
    return stats
