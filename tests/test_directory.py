"""Unit + cross-validation tests for the region-level sharing directory
(``repro.core.directory``) and the directory-vectorized protocol engine.

Unlike the hypothesis suite in test_regc_scale.py, these are deterministic
(seeded numpy RNG) so they run in environments without hypothesis — they
are the tier-1 oracle for the directory engine:

* random-trace cross-validation against the reference runtime, including
  cache-spill configurations (traffic exact, clocks to float tolerance);
* LRU equivalence: epoch-batched watermark eviction vs the reference's
  per-op LRU on cache-spill traces;
* STREAM / Jacobi / MD at small W through the interval fast path;
* directory primitive semantics (windows, shared intervals, notice logs).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import FINE_PROTO, IDEAL_PROTO, PAGE_PROTO, RegCRuntime
from repro.core.directory import IntervalLog, RegionDirectory
from repro.core.regc import Traffic
from repro.core.regc_scale import RegCScaleRuntime
from repro.dsm.apps import jacobi, molecular_dynamics, stream_triad

PROTOS = [FINE_PROTO, PAGE_PROTO, IDEAL_PROTO]


# ---------------------------------------------------------------------------
# directory primitives
# ---------------------------------------------------------------------------


def test_window_ensure_grow_and_shift():
    d = RegionDirectory(3, 0, 0, 100, track_touch=True)
    d.ensure(1, 10, 14)
    d.valid[1, d.sl(1, 10, 14)] = True
    d.touch[1, d.sl(1, 10, 14)] = [1, 2, 3, 4]
    # left extension shifts existing cells and records the shift
    d.ensure(1, 6, 20)
    assert int(d.base[1]) == 6 and int(d.length[1]) == 14
    assert int(d.shift[1]) == 4
    assert d.valid[1, d.sl(1, 10, 14)].all()
    assert not d.valid[1, d.sl(1, 6, 10)].any()
    np.testing.assert_array_equal(d.touch[1, d.sl(1, 10, 14)], [1, 2, 3, 4])
    # wprot-free, dirty stays clear
    assert not d.dirty[1, : d.length[1]].any()


def test_overlap_rows_and_gather():
    d = RegionDirectory(4, 0, 0, 100)
    d.ensure(0, 0, 10)
    d.ensure(2, 8, 20)
    d.ensure(3, 50, 60)
    assert d.overlap_rows(5, 9).tolist() == [0, 2]
    assert d.overlap_rows(5, 9, exclude=0).tolist() == [2]
    d.valid[0, d.sl(0, 4, 9)] = True
    d.valid[2, d.sl(2, 8, 12)] = True
    rows = d.overlap_rows(0, 100)
    sub, cols = d.gather_valid(rows, np.array([4, 8, 55]))
    # row 0 valid at {4..8}, row 2 valid at {8..11}, row 3 nothing
    np.testing.assert_array_equal(
        sub, [[True, True, False], [False, True, False],
              [False, False, False]])


def test_shared_intervals_sweep():
    d = RegionDirectory(4, 0, 0, 1000)
    d.ensure(0, 0, 100)
    d.ensure(1, 90, 200)       # overlaps 0 on [90, 100)
    d.ensure(2, 300, 400)      # alone
    d.ensure(3, 150, 160)      # inside 1
    starts, ends = d.shared_intervals()
    assert list(zip(starts.tolist(), ends.tolist())) == [(90, 100),
                                                         (150, 160)]


def test_interval_log_segment_minmax():
    log = IntervalLog()
    log.append_version([5, 9], [10, 0], [20, 4])
    log.append_version([], [], [])
    log.append_version([5, 7], [2, 1], [8, 3])
    u, lo, hi = log.pending(0, 3)
    assert u.tolist() == [5, 7, 9]
    assert lo.tolist() == [2, 1, 0]          # per-page segment min
    assert hi.tolist() == [20, 3, 4]         # per-page segment max
    u2, lo2, hi2 = log.pending(2, 3)         # only the last version
    assert u2.tolist() == [5, 7]
    assert lo2.tolist() == [2, 1] and hi2.tolist() == [8, 3]
    assert log.pending(3, 3)[0].size == 0


def test_span_planes_note_harvest_roundtrip():
    d = RegionDirectory(3, 0, 0, 100)
    d.ensure(1, 10, 20)
    # scalar single-page merges + a vector note, like in-span writes
    d.span_note(1, 12, 13, 5, 9)
    d.span_note(1, 12, 13, 2, 7)             # (min, max)-merge: (2, 9)
    d.span_note(1, 14, 17, np.array([0, 3, 1]), np.array([8, 6, 4]))
    pages, los, his = d.span_harvest(1, 10, 20)
    assert pages.tolist() == [12, 14, 15, 16]
    assert los.tolist() == [2, 0, 3, 1]
    assert his.tolist() == [9, 8, 6, 4]
    # harvest resets: a second harvest over the same bounds is empty
    assert d.span_harvest(1, 10, 20)[0].size == 0
    # other rows untouched
    assert d.span_harvest(0, 10, 20)[0].size == 0


def test_span_planes_survive_window_growth():
    d = RegionDirectory(2, 0, 0, 100)
    d.ensure(0, 10, 14)
    d.span_note(0, 11, 12, 1, 3)
    d.ensure(0, 4, 30)               # left extension + cap growth
    d.span_note(0, 25, 26, 0, 2)
    pages, los, his = d.span_harvest(0, 4, 30)
    assert pages.tolist() == [11, 25]
    assert los.tolist() == [1, 0] and his.tolist() == [3, 2]


def test_interval_log_append_versions_batched():
    a, b = IntervalLog(), IntervalLog()
    payload = (np.array([3, 7], np.int64), np.array([1, 0], np.int64),
               np.array([4, 8], np.int64))
    for _ in range(3):
        a.append_version(*payload)
    a.append_version([], [], [])
    b.append_versions(np.tile(payload[0], 3), np.tile(payload[1], 3),
                      np.tile(payload[2], 3), np.array([2, 2, 2], np.int64))
    b.append_versions(np.zeros(0, np.int64), np.zeros(0, np.int64),
                      np.zeros(0, np.int64), np.array([0], np.int64))
    assert a.voff == b.voff
    for v0 in range(4):
        for v1 in range(v0, 5):
            ua, la, ha = a.pending(v0, v1)
            ub, lb, hb = b.pending(v0, v1)
            np.testing.assert_array_equal(ua, ub)
            np.testing.assert_array_equal(la, lb)
            np.testing.assert_array_equal(ha, hb)
    assert a.page_bounds(0, 3) == (3, 8) == b.page_bounds(0, 3)
    assert a.page_bounds(3, 4) is None


# ---------------------------------------------------------------------------
# bitmask protocol-sweep kernels: packed uint32 planes vs boolean oracle
# ---------------------------------------------------------------------------


def test_bitmask_pack_popcount_matches_boolean_plane():
    from repro.kernels import protocol_sweep as ps
    rng = np.random.default_rng(7)
    for W, C in ((1, 1), (3, 31), (8, 32), (37, 1000), (256, 513)):
        plane = rng.random((W, C)) < 0.3
        bits = ps.pack_mask_rows(plane)
        assert bits.shape == (W, -(-C // 32)) and bits.dtype == np.uint32
        np.testing.assert_array_equal(ps.unpack_mask_rows(bits, C), plane)
        np.testing.assert_array_equal(ps.popcount_rows(bits),
                                      plane.sum(axis=1))


def test_bitmask_popcount_pallas_matches_numpy():
    pytest.importorskip("jax")
    from repro.kernels import protocol_sweep as ps
    rng = np.random.default_rng(11)
    plane = rng.random((41, 700)) < 0.5
    bits = ps.pack_mask_rows(plane)
    np.testing.assert_array_equal(ps.popcount_rows(bits, backend="pallas"),
                                  plane.sum(axis=1))


def test_coverage_sweep_pallas_matches_numpy():
    pytest.importorskip("jax")
    from repro.kernels import protocol_sweep as ps
    rng = np.random.default_rng(13)
    for n in (2, 9, 128, 515):
        delta = rng.choice(np.array([1, -1], np.int64), n)
        np.testing.assert_array_equal(
            ps.coverage_multi(delta, backend="pallas"),
            np.cumsum(delta) >= 2)


def test_take_first_k_matches_boolean_oracle():
    """Packed rank-select (the eviction engine's segment-LRU selection)
    vs the boolean-plane oracle: first k[i] set bits per row, little-
    endian column order."""
    from repro.kernels import protocol_sweep as ps
    rng = np.random.default_rng(17)
    for R, C in ((1, 1), (4, 31), (8, 64), (33, 517), (128, 90)):
        live = rng.random((R, C)) < 0.4
        k = rng.integers(0, C + 3, R).astype(np.int64)
        bits = ps.pack_mask_rows(live)
        got = ps.unpack_mask_rows(ps.take_first_k(bits, k), C)
        want = live & (np.cumsum(live, axis=1) <= k[:, None])
        np.testing.assert_array_equal(got, want, err_msg=f"{R}x{C}")


def test_take_first_k_pallas_matches_numpy():
    pytest.importorskip("jax")
    from repro.kernels import protocol_sweep as ps
    rng = np.random.default_rng(19)
    live = rng.random((23, 333)) < 0.5
    k = rng.integers(0, 200, 23).astype(np.int64)
    bits = ps.pack_mask_rows(live)
    np.testing.assert_array_equal(
        ps.take_first_k(bits, k, backend="pallas"),
        ps.take_first_k(bits, k, backend="numpy"))


def test_kth_set_index_matches_boolean_oracle():
    """Packed rank query (the refetch replay engine's victim-scan cut)
    vs the boolean oracle: column of each row's k-th set bit, -1 when
    the row holds fewer than k (or k <= 0)."""
    from repro.kernels import protocol_sweep as ps
    rng = np.random.default_rng(23)
    for R, C in ((1, 1), (4, 31), (8, 64), (33, 517), (128, 90)):
        live = rng.random((R, C)) < 0.4
        k = rng.integers(-1, C + 3, R).astype(np.int64)
        got = ps.kth_set_index(ps.pack_mask_rows(live), k)
        for r in range(R):
            idx = np.flatnonzero(live[r])
            want = idx[k[r] - 1] if 1 <= k[r] <= idx.size else -1
            assert got[r] == want, (R, C, r, k[r])


def test_kth_set_index_pallas_matches_numpy():
    pytest.importorskip("jax")
    from repro.kernels import protocol_sweep as ps
    rng = np.random.default_rng(29)
    live = rng.random((23, 333)) < 0.5
    k = rng.integers(0, 200, 23).astype(np.int64)
    bits = ps.pack_mask_rows(live)
    np.testing.assert_array_equal(
        ps.kth_set_index(bits, k, backend="pallas"),
        ps.kth_set_index(bits, k, backend="numpy"))


def test_jit_kernels_match_numpy_oracles():
    """Every jitted kernel tier vs its numpy oracle, with the dispatch
    accounting live: popcount, rank-select, rank-query, coverage, and
    the fused ``take_and_cut`` (one dispatch for what the unfused path
    does in two)."""
    pytest.importorskip("jax")
    from repro.kernels import protocol_sweep as ps
    rng = np.random.default_rng(37)
    live = rng.random((29, 451)) < 0.45
    k = rng.integers(0, 300, 29).astype(np.int64)
    bits = ps.pack_mask_rows(live)
    st = {}
    np.testing.assert_array_equal(
        ps.popcount_rows(bits, backend="pallas-jit", stats=st),
        ps.popcount_rows(bits))
    np.testing.assert_array_equal(
        ps.take_first_k(bits, k, backend="pallas-jit", stats=st),
        ps.take_first_k(bits, k))
    np.testing.assert_array_equal(
        ps.kth_set_index(bits, k, backend="pallas-jit", stats=st),
        ps.kth_set_index(bits, k))
    delta = rng.choice(np.array([1, -1], np.int64), 513)
    np.testing.assert_array_equal(
        ps.coverage_multi(delta, backend="pallas-jit", stats=st),
        np.cumsum(delta) >= 2)
    take_j, cut_j = ps.take_and_cut(bits, k, backend="pallas-jit",
                                    stats=st)
    np.testing.assert_array_equal(take_j, ps.take_first_k(bits, k))
    np.testing.assert_array_equal(cut_j, ps.kth_set_index(bits, k))
    # five jit entries above -> five device dispatches, no silent
    # numpy fallback
    assert st["jit_dispatches"] == 5, st


def test_phase_step_jit_matches_numpy_oracle():
    """The fused barrier-flush chain vs its numpy oracle on randomized
    multi-region stacks: per-row dirty counts AND the packed
    shared-dirty candidate masks (dirty & >=2-coverage & active row),
    including inactive rows (base=-1), masked rows, and INT32_MAX
    geometry padding."""
    pytest.importorskip("jax")
    from repro.kernels import protocol_sweep as ps
    rng = np.random.default_rng(41)
    i32max = np.iinfo(np.int32).max
    for trial in range(4):
        R, W, C = 3, 7, int(rng.integers(40, 200))
        nw = -(-C // 32)
        bits = np.zeros((R, W, nw), np.uint32)
        base = np.full((R, W), -1, np.int32)
        sbs = np.full((R, W), i32max, np.int32)
        ses = np.full((R, W), i32max, np.int32)
        for r in range(R):
            nlive = int(rng.integers(2, W + 1))
            rows = rng.choice(W, nlive, replace=False)
            b = np.sort(rng.integers(0, 5000, nlive)).astype(np.int32)
            ln = rng.integers(1, C + 1, nlive).astype(np.int32)
            base[r, rows] = b
            sbs[r, :nlive] = np.sort(b)
            ses[r, :nlive] = np.sort(b + ln)
            for i, w in enumerate(rows):
                plane = np.zeros(C, bool)
                plane[:ln[i]] = rng.random(int(ln[i])) < 0.4
                bits[r, w] = ps.pack_mask_rows(plane[None])[0]
        rowmask = rng.random((R, W)) < 0.8
        st = {}
        counts, shared = ps.phase_step(bits, base, rowmask, sbs, ses,
                                       stats=st)
        counts_np, shared_np = ps._phase_step_np(bits, base, rowmask,
                                                 sbs, ses)
        np.testing.assert_array_equal(counts, counts_np, err_msg=str(trial))
        np.testing.assert_array_equal(shared, shared_np, err_msg=str(trial))
        assert st["jit_dispatches"] == 1, st


def test_force_numpy_env_override_wins():
    """``REPRO_FORCE_NUMPY=1`` pins every backend request to the numpy
    tier through the cached one-shot probe: ``available_backends``
    collapses, ``resolve_backend`` degrades both accelerated tiers, and
    a 'pallas-jit' runtime runs the whole trace without a single device
    dispatch — while staying traffic/clock exact."""
    from repro.kernels import protocol_sweep as ps
    import os
    old = os.environ.get(ps._FORCE_ENV)
    os.environ[ps._FORCE_ENV] = "1"
    ps._reset_backend_probe()
    try:
        assert ps.available_backends() == ("numpy",)
        assert ps.resolve_backend("pallas-jit") == "numpy"
        assert ps.resolve_backend("pallas") == "numpy"
        rts = {}
        for backend in ("numpy", "pallas-jit"):
            rt = RegCScaleRuntime(4, page_words=32, protocol=PAGE_PROTO,
                                  prefetch=1, cache_pages=6,
                                  backend=backend)
            ga = rt.alloc(32 * 40)
            ids = np.arange(4, dtype=np.int64)
            for _ in range(3):
                rt.phase_all(writes=[(ga, ids * 320, ids * 320 + 340)])
                rt.barrier()
            rts[backend] = rt
        for f in dataclasses.fields(Traffic):
            assert (getattr(rts["numpy"].traffic, f.name)
                    == getattr(rts["pallas-jit"].traffic, f.name)), f.name
        np.testing.assert_array_equal(rts["numpy"].clock,
                                      rts["pallas-jit"].clock)
        assert rts["pallas-jit"].stats["jit_dispatches"] == 0
    finally:
        if old is None:
            os.environ.pop(ps._FORCE_ENV, None)
        else:
            os.environ[ps._FORCE_ENV] = old
        ps._reset_backend_probe()


@pytest.mark.parametrize("backend", ["numpy", "pallas", "pallas-jit"])
def test_take_upto_row_rank_select(backend):
    """The replay engine's one-run victim scan: first k live cells plus
    the scan cut, packed kernels on 'pallas' (and the fused one-dispatch
    ``take_and_cut`` on 'pallas-jit') vs the cumsum path — all must
    agree with the boolean oracle (caller guarantees count > k)."""
    if backend != "numpy":
        pytest.importorskip("jax")
    from repro.core.directory import RegionDirectory
    d = RegionDirectory(1, 0, 0, 64, backend=backend)
    rng = np.random.default_rng(31)
    for C in (5, 33, 64, 257):
        live = rng.random(C) < 0.5
        tot = int(live.sum())
        if tot < 2:
            live[:2] = True
            tot = int(live.sum())
        k = int(rng.integers(1, tot))          # strictly fewer than live
        take, cut = d.take_upto_row(live, k)
        idx = np.flatnonzero(live)
        want = np.zeros(C, bool)
        want[idx[:k]] = True
        np.testing.assert_array_equal(take, want, err_msg=f"{backend} {C}")
        assert cut == idx[k - 1] + 1, (backend, C, k)


@pytest.mark.parametrize("backend", ["numpy", "pallas", "pallas-jit"])
def test_evict_rows_matches_per_cell_oracle(backend):
    """The batched eviction primitive (dirty counts, wprot re-arm,
    valid/incache clears at the take cells — and only there) against a
    straight per-cell simulation, packed-vs-boolean parity on every
    backend, including the take=None whole-span fast path."""
    if backend != "numpy":
        pytest.importorskip("jax")
    rng = np.random.default_rng(23)
    for trial in range(4):
        d = RegionDirectory(8, 0, 0, 500, track_wprot=True,
                            track_touch=True, backend=backend)
        for w in range(8):
            d.ensure(w, 0, 80)
        n = 80
        d.valid[:, :n] = rng.random((8, n)) < 0.6
        d.dirty[:, :n] = rng.random((8, n)) < 0.3
        d.incache[:, :n] = d.valid[:, :n] | (rng.random((8, n)) < 0.2)
        rows = np.arange(1, 7)
        start, length = 10, 50
        take = (None if trial % 2 else
                rng.random((rows.size, length)) < 0.5)
        ref = {p: d.__getattribute__(p)[:, :n].copy()
               for p in ("valid", "dirty", "wprot", "incache")}
        tk = (np.ones((rows.size, length), bool) if take is None else take)
        exp_db = np.zeros(rows.size, np.int64)
        for i, w in enumerate(rows):
            for j in range(length):
                if not tk[i, j]:
                    continue
                c = start + j
                if ref["dirty"][w, c]:
                    exp_db[i] += 1
                    ref["dirty"][w, c] = False
                    ref["wprot"][w, c] = True
                ref["valid"][w, c] = False
                ref["incache"][w, c] = False
        db = d.evict_rows(rows, start, length, take, set_wprot=True)
        np.testing.assert_array_equal(db, exp_db)
        for p in ("valid", "dirty", "wprot", "incache"):
            np.testing.assert_array_equal(
                d.__getattribute__(p)[:, :n], ref[p], err_msg=p)


def test_run_live_and_lru_take_segment_semantics():
    """run_live: a cell is live iff its touch tick still equals the run's
    tick AND it still occupies a cache slot; lru_take picks the first k
    live cells (columnar fast path when fully live)."""
    d = RegionDirectory(3, 0, 0, 100, track_touch=True)
    for w in range(3):
        d.ensure(w, 0, 20)
    d.touch[:, :10] = 7
    d.incache[:, :10] = True
    d.touch[1, 3] = 9              # re-touched by a later run -> stale
    d.incache[2, 5] = False        # evicted -> not live
    rows = np.arange(3)
    live = d.run_live(rows, 0, 10, np.full(3, 7, np.int64))
    assert live[0].all()
    assert not live[1, 3] and live[1, :3].all() and live[1, 4:].all()
    assert not live[2, 5]
    take = d.lru_take(live, np.array([4, 4, 4]))
    np.testing.assert_array_equal(take.sum(axis=1), [4, 4, 4])
    # row 1 skips the stale cell: takes cols 0,1,2,4
    assert not take[1, 3] and take[1, 4]
    # fully-live fast path: columnar cutoff
    full = d.lru_take(live[:1], np.array([3]), np.array([10]))
    np.testing.assert_array_equal(full[0, :4], [True] * 3 + [False])


def test_directory_backends_agree():
    """dirty_counts + shared_intervals identical on every backend (the
    packed-bitmask kernels are integer-exact reformulations)."""
    pytest.importorskip("jax")
    dirs = {}
    for backend in ("numpy", "pallas", "pallas-jit"):
        d = RegionDirectory(6, 0, 0, 4000, backend=backend)
        rng2 = np.random.default_rng(3)
        for w in range(6):
            lo = int(rng2.integers(0, 3000))
            d.ensure(w, lo, lo + int(rng2.integers(1, 900)))
            n = int(d.length[w])
            d.dirty[w, :n] = rng2.random(n) < 0.2
        dirs[backend] = d
    for backend in ("pallas", "pallas-jit"):
        np.testing.assert_array_equal(dirs["numpy"].dirty_counts(),
                                      dirs[backend].dirty_counts(),
                                      err_msg=backend)
        s_np, e_np = dirs["numpy"].shared_intervals()
        s_pl, e_pl = dirs[backend].shared_intervals()
        np.testing.assert_array_equal(s_np, s_pl, err_msg=backend)
        np.testing.assert_array_equal(e_np, e_pl, err_msg=backend)


def test_runtime_backend_pallas_matches_numpy_trace():
    pytest.importorskip("jax")
    from repro.dsm.apps import jacobi
    rts = {}
    for backend in ("numpy", "pallas"):
        rt = RegCScaleRuntime(6, protocol=PAGE_PROTO, prefetch=1,
                              backend=backend)
        jacobi(rt, 128, 2, mode="lock")
        rts[backend] = rt
    for f in dataclasses.fields(Traffic):
        assert (getattr(rts["numpy"].traffic, f.name)
                == getattr(rts["pallas"].traffic, f.name)), f.name
    np.testing.assert_array_equal(rts["numpy"].clock, rts["pallas"].clock)


# ---------------------------------------------------------------------------
# random-trace cross-validation vs the reference runtime (deterministic)
# ---------------------------------------------------------------------------


def gen_trace(rng, n_ops=40):
    ops = []
    depth = {w: [] for w in range(3)}
    for _ in range(n_ops):
        w = int(rng.integers(0, 3))
        kind = rng.choice(["read", "write", "acquire", "release", "barrier"])
        if kind == "release":
            if not depth[w]:
                continue
            ops.append(("release", w, depth[w].pop()))
        elif kind == "acquire":
            if len(depth[w]) >= 2:
                continue
            lock = int(rng.integers(0, 2))
            depth[w].append(lock)
            ops.append(("acquire", w, lock))
        elif kind == "barrier":
            if any(depth.values()):
                continue
            ops.append(("barrier",))
        else:
            arr = int(rng.integers(0, 2))
            lo = int(rng.integers(0, 250))
            hi = int(rng.integers(lo + 1, min(lo + 120, 256) + 1))
            ops.append((kind, w, arr, lo, hi))
    for w in range(3):
        while depth[w]:
            ops.append(("release", w, depth[w].pop()))
    ops.append(("barrier",))
    return ops


def run_trace(rt, ops, arrays):
    for op in ops:
        if op[0] == "read":
            rt.read(op[1], arrays[op[2]], op[3], op[4])
        elif op[0] == "write":
            rt.write(op[1], arrays[op[2]], op[3], op[4])
        elif op[0] == "acquire":
            rt.acquire(op[1], op[2])
        elif op[0] == "release":
            rt.release(op[1], op[2])
        else:
            rt.barrier()
    return rt


def assert_same(ref, fast, ctx=""):
    for f in dataclasses.fields(Traffic):
        assert getattr(ref.traffic, f.name) == getattr(fast.traffic, f.name), (
            ctx, f.name, ref.traffic, fast.traffic)
    np.testing.assert_allclose(fast.clock, ref.clock, rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("cache_pages", [None, 4, 2, 7])
def test_random_traces_match_reference(cache_pages):
    for seed in range(60):
        rng = np.random.default_rng(seed)
        ops = gen_trace(rng)
        proto = PROTOS[seed % 3]
        pw = [32, 64][seed % 2]
        ref = RegCRuntime(3, page_words=pw, protocol=proto,
                          track_values=False, prefetch=1,
                          cache_pages=cache_pages)
        fast = RegCScaleRuntime(3, page_words=pw, protocol=proto, prefetch=1,
                                model_mechanism=False,
                                cache_pages=cache_pages)
        run_trace(ref, ops, [ref.alloc(256), ref.alloc(256)])
        run_trace(fast, ops, [fast.alloc(256), fast.alloc(256)])
        assert_same(ref, fast, f"seed={seed} proto={proto} pw={pw} "
                               f"cache={cache_pages}")
        if cache_pages is not None and proto != IDEAL_PROTO:
            # occupancy counter == per-worker LRU dict length of the ref
            occ = [sum(int(d.incache[w, :d.length[w]].sum())
                       for d in fast.dirs if d.base[w] >= 0)
                   for w in range(3)]
            assert occ == [len(ref.lru[w]) for w in range(3)]
            assert occ == fast.resident.tolist()


# ---------------------------------------------------------------------------
# LRU equivalence of the epoch-batched eviction (cache-spill traces)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("proto", [FINE_PROTO, PAGE_PROTO])
@pytest.mark.parametrize("cache_pages", [3, 6, 11])
def test_epoch_batched_eviction_matches_per_op_lru(proto, cache_pages):
    """Streaming sweeps over a working set larger than the cache: the
    scale engine's watermark-triggered batched eviction must produce the
    reference's per-op LRU traffic exactly — same fetch counts (capacity
    misses), same dirty-victim writebacks, same sharer invalidations."""
    ref = RegCRuntime(2, page_words=64, protocol=proto, track_values=False,
                      prefetch=1, cache_pages=cache_pages)
    fast = RegCScaleRuntime(2, page_words=64, protocol=proto, prefetch=1,
                            model_mechanism=False, cache_pages=cache_pages)
    for rt in (ref, fast):
        a = rt.alloc(64 * 10)
        b = rt.alloc(64 * 10)
        for sweep in range(3):
            for w in range(2):
                for blk in range(5):
                    rt.read(w, a, blk * 128, blk * 128 + 128)
                    rt.write(w, b, blk * 128 + 7, blk * 128 + 121)  # partial
            rt.barrier()
    assert_same(ref, fast, f"{proto} cache={cache_pages}")


def test_danger_path_prefetch_refetch():
    """The op pattern where batched eviction alone would diverge: a read
    whose prefetch page is valid at op start but evicted by the same op's
    earlier fetches (the reference refetches it mid-op)."""
    ref = RegCRuntime(1, page_words=64, protocol=FINE_PROTO,
                      track_values=False, prefetch=1, cache_pages=2)
    fast = RegCScaleRuntime(1, page_words=64, protocol=FINE_PROTO,
                            prefetch=1, model_mechanism=False, cache_pages=2)
    for rt in (ref, fast):
        ga = rt.alloc(256)
        rt.write(0, ga, 140, 148)      # page 2 resident + dirty
        rt.read(0, ga, 16, 73)         # pages 0-1 + prefetch 2: evicts 2
        rt.barrier()
    assert_same(ref, fast, "prefetch-refetch")
    assert ref.traffic.page_fetches == 4      # page 2 fetched twice


# ---------------------------------------------------------------------------
# paper apps at small W (interval fast path end-to-end)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("proto", PROTOS)
def test_apps_match_reference_stream(proto):
    ref = RegCRuntime(4, protocol=proto, track_values=False, prefetch=1)
    fast = RegCScaleRuntime(4, protocol=proto, prefetch=1,
                            model_mechanism=False)
    stream_triad(ref, 64 * 1024, 3)
    stream_triad(fast, 64 * 1024, 3)
    assert_same(ref, fast, f"stream {proto}")


@pytest.mark.parametrize("proto", PROTOS)
@pytest.mark.parametrize("mode", ["lock", "reduction"])
def test_apps_match_reference_jacobi_md(proto, mode):
    for app, kw in ((jacobi, dict(n=256, iters=3, mode=mode)),
                    (molecular_dynamics,
                     dict(n_particles=256, iters=2, mode=mode))):
        ref = RegCRuntime(4, protocol=proto, track_values=False, prefetch=1)
        fast = RegCScaleRuntime(4, protocol=proto, prefetch=1,
                                model_mechanism=False)
        app(ref, **kw)
        app(fast, **kw)
        assert_same(ref, fast, f"{app.__name__} {proto} {mode}")


def test_apps_match_reference_spill():
    """STREAM under a cache smaller than the per-worker working set."""
    for W, cache in ((4, 10), (2, 5)):
        ref = RegCRuntime(W, protocol=FINE_PROTO, track_values=False,
                          prefetch=1, cache_pages=cache)
        fast = RegCScaleRuntime(W, protocol=FINE_PROTO, prefetch=1,
                                model_mechanism=False, cache_pages=cache)
        stream_triad(ref, 64 * 1024, 3)
        stream_triad(fast, 64 * 1024, 3)
        assert_same(ref, fast, f"spill W={W} cache={cache}")
