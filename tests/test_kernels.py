"""Per-kernel allclose vs pure-jnp oracles, swept over shapes/dtypes.

Kernels run in interpret mode on CPU: the Pallas kernel *body* executes with
JAX semantics, validating the tiling/index-map/accumulator logic.
"""
import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="jax-dependent suite; the no-jax CI leg covers the numpy fallbacks")
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.ops import diff_apply, diff_encode, flash_attention, ssd_chunk

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# page_diff
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_pages,page_words", [(8, 1024), (16, 256), (32, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_diff_encode_matches_ref(n_pages, page_words, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    twin = jax.random.normal(k1, (n_pages, page_words), dtype)
    # sparse modifications: ~10% of words
    noise = jax.random.normal(k2, twin.shape, dtype)
    m = jax.random.bernoulli(k3, 0.1, twin.shape)
    curr = jnp.where(m, twin + noise, twin)
    mask, vals, count = diff_encode(curr, twin, interpret=True)
    mask_r, vals_r, count_r = ref.diff_encode_ref(curr, twin)
    np.testing.assert_array_equal(mask, mask_r)
    np.testing.assert_allclose(vals, vals_r, rtol=0, atol=0)
    np.testing.assert_array_equal(count, count_r)


@pytest.mark.parametrize("n_pages,page_words", [(8, 1024), (16, 128)])
def test_diff_roundtrip(n_pages, page_words):
    """encode(curr, twin) applied onto twin reconstructs curr exactly."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    twin = jax.random.normal(k1, (n_pages, page_words))
    m = jax.random.bernoulli(k3, 0.3, twin.shape)
    curr = jnp.where(m, jax.random.normal(k2, twin.shape), twin)
    mask, vals, _ = diff_encode(curr, twin, interpret=True)
    rebuilt = diff_apply(twin, mask, vals, interpret=True)
    np.testing.assert_allclose(rebuilt, curr, rtol=0, atol=0)
    rr = ref.diff_apply_ref(twin, mask, vals)
    np.testing.assert_allclose(rebuilt, rr, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 4, 4, 256, 64),     # MHA
    (2, 4, 2, 128, 32),     # GQA
    (1, 4, 1, 256, 64),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Hq, Hkv, S, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = (jax.random.normal(ks[0], (B, Hq, S, D)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, Hkv, S, D)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, Hkv, S, D)) * 0.5).astype(dtype)
    out = flash_attention(q, k, v, q_block=64, kv_block=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), expect.astype(jnp.float32),
        rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [None, 64, 128])
def test_flash_attention_window_softcap(window):
    B, Hq, Hkv, S, D = 1, 2, 2, 256, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D)) * 0.5
    k = jax.random.normal(ks[1], (B, Hkv, S, D)) * 0.5
    v = jax.random.normal(ks[2], (B, Hkv, S, D)) * 0.5
    out = flash_attention(q, k, v, window=window, softcap=30.0,
                          q_block=64, kv_block=64, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, window=window, softcap=30.0)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


def test_flash_attention_vs_model_blocked_path():
    """Kernel agrees with the XLA blocked_attention used by the models."""
    from repro.models.layers import blocked_attention, repeat_kv
    B, Hq, Hkv, S, D = 2, 4, 2, 128, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D)) * 0.5
    k = jax.random.normal(ks[1], (B, S, Hkv, D)) * 0.5
    v = jax.random.normal(ks[2], (B, S, Hkv, D)) * 0.5
    xla = blocked_attention(q, repeat_kv(k, 2), repeat_kv(v, 2),
                            scale=D ** -0.5, q_block=64, kv_block=64)
    pallas = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), q_block=64, kv_block=64, interpret=True)
    np.testing.assert_allclose(
        xla, pallas.transpose(0, 2, 1, 3), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd_chunk
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,Q,P,N", [(4, 64, 32, 64), (2, 128, 64, 128),
                                     (8, 32, 16, 32)])
def test_ssd_chunk_matches_ref(M, Q, P, N):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (M, Q, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (M, Q, 1)))
    dA = -jax.nn.softplus(jax.random.normal(ks[2], (M, Q, 1)))
    cum = jnp.cumsum(dA, axis=1)
    B_ = jax.random.normal(ks[3], (M, Q, N)) * 0.3
    C_ = jax.random.normal(ks[4], (M, Q, N)) * 0.3
    y, st = ssd_chunk(x, dt, cum, B_, C_, interpret=True)
    y_r, st_r = ref.ssd_chunk_ref(x, dt, cum, B_, C_)
    np.testing.assert_allclose(y, y_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st, st_r, rtol=1e-4, atol=1e-4)


def test_ssd_chunk_composes_to_full_ssd():
    """Kernel intra-chunk + XLA inter-chunk recurrence == sequential oracle."""
    from repro.models.ssm import ssd_reference
    B, S, H, P, G, N = 1, 128, 2, 16, 1, 32
    Q = 32
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B_ = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    C_ = jax.random.normal(ks[4], (B, S, G, N)) * 0.3

    nc = S // Q
    # pack (B, nc, H) grid cells
    xm = x.reshape(B, nc, Q, H, P).transpose(0, 1, 3, 2, 4).reshape(-1, Q, P)
    dtm = dt.reshape(B, nc, Q, H).transpose(0, 1, 3, 2).reshape(-1, Q, 1)
    dA = dt * A
    cum_full = dA.reshape(B, nc, Q, H).transpose(0, 1, 3, 2)
    cum = jnp.cumsum(cum_full, axis=-1).reshape(-1, Q, 1)
    hg = H // G
    Bh = jnp.repeat(B_, hg, axis=2)
    Ch = jnp.repeat(C_, hg, axis=2)
    Bm = Bh.reshape(B, nc, Q, H, N).transpose(0, 1, 3, 2, 4).reshape(-1, Q, N)
    Cm = Ch.reshape(B, nc, Q, H, N).transpose(0, 1, 3, 2, 4).reshape(-1, Q, N)

    y_inner, states = ssd_chunk(xm, dtm, cum, Bm, Cm, interpret=True)
    y_inner = y_inner.reshape(B, nc, H, Q, P)
    states = states.reshape(B, nc, H, P, N)
    cumr = cum.reshape(B, nc, H, Q)

    # inter-chunk recurrence in XLA
    chunk_decay = jnp.exp(cumr[..., -1])            # (B, nc, H)
    h0 = jnp.zeros((B, H, P, N))
    def step(h, inp):
        dec, s_in = inp
        return h * dec[..., None, None] + s_in, h
    _, prev = jax.lax.scan(
        step, h0, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prev = prev.transpose(1, 0, 2, 3, 4)            # (B, nc, H, P, N)
    y_inter = jnp.einsum("bcqhn,bchpn->bchqp",
                         Cm.reshape(B, nc, H, Q, N).transpose(0, 1, 3, 2, 4),
                         prev)
    y_inter = y_inter * jnp.exp(cumr)[..., None]
    y = (y_inner + y_inter).transpose(0, 1, 3, 2, 4).reshape(B, S, H, P)

    y_ref, _ = ssd_reference(x, dt, A, B_, C_)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,Q,P,N", [(4, 64, 32, 64), (2, 256, 64, 128)])
def test_ssd_chunk_bf16_inputs(M, Q, P, N):
    """bf16 inputs: kernel accumulates f32 internally; tolerance scales
    with bf16 resolution."""
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (M, Q, P)).astype(jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (M, Q, 1))).astype(jnp.bfloat16)
    dA = -jax.nn.softplus(jax.random.normal(ks[2], (M, Q, 1)))
    cum = jnp.cumsum(dA, axis=1).astype(jnp.bfloat16)
    B_ = (jax.random.normal(ks[3], (M, Q, N)) * 0.3).astype(jnp.bfloat16)
    C_ = (jax.random.normal(ks[4], (M, Q, N)) * 0.3).astype(jnp.bfloat16)
    y, st = ssd_chunk(x, dt, cum, B_, C_, interpret=True)
    y_r, st_r = ref.ssd_chunk_ref(x, dt, cum, B_, C_)
    np.testing.assert_allclose(y, y_r, rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(st, st_r, rtol=3e-2, atol=3e-2)


def test_diff_encode_denormals_and_signed_zero():
    """Bitwise (memcmp) semantics: denormals and -0.0 vs +0.0 are real
    changes even when float comparison would miss them (regression for the
    FTZ bug found by hypothesis)."""
    twin = jnp.zeros((8, 1024), jnp.float32)
    curr = twin.at[0, 3].set(1e-45)          # denormal
    curr = curr.at[1, 7].set(-0.0)           # signed zero
    mask, vals, count = diff_encode(curr, twin, interpret=True)
    assert int(count[0]) == 1 and bool(mask[0, 3])
    assert int(count[1]) == 1 and bool(mask[1, 7])
    rebuilt = diff_apply(twin, mask, vals, interpret=True)
    np.testing.assert_array_equal(
        jax.lax.bitcast_convert_type(rebuilt, jnp.int32),
        jax.lax.bitcast_convert_type(curr, jnp.int32))


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [(1, 8, 8, 512, 128)])
def test_flash_attention_large_tile(B, Hq, Hkv, S, D):
    """MXU-aligned production tile (D=128, 128-blocks)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D)) * 0.5
    k = jax.random.normal(ks[1], (B, Hkv, S, D)) * 0.5
    v = jax.random.normal(ks[2], (B, Hkv, S, D)) * 0.5
    out = flash_attention(q, k, v, q_block=128, kv_block=128, interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)
