"""Tests for the data pipeline, checkpoint store, and FT runtime — the
substrate that makes the framework restartable at scale."""
import time

import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="jax-dependent suite; the no-jax CI leg covers the numpy fallbacks")
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:           # tier-1 env may lack hypothesis
    from _hypothesis_stub import given, settings, st

from repro.checkpoint import CheckpointManager, latest_step, save_checkpoint
from repro.checkpoint.store import gc_incomplete, restore_checkpoint
from repro.data import DataConfig, MemmapTokens, SyntheticTokens, \
    make_pipeline, write_token_file
from repro.ft import ElasticPlan, FailureInjector, StragglerMonitor, \
    WorkerFailure
from repro.ft.runtime import plan_rescale


# ---------------------------------------------------------------------------
# data sources
# ---------------------------------------------------------------------------


def test_synthetic_deterministic_and_rank_sharded():
    src = SyntheticTokens(vocab_size=97, seq_len=16, global_batch=8, seed=3)
    a = src.batch_at(5)
    b = src.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # world=4 ranks partition the world=1 batch exactly
    full = src.batch_at(5)["tokens"]
    parts = [src.batch_at(5, rank=r, world=4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)
    # targets are next-token shifted
    c = src.batch_at(0)
    assert c["tokens"].shape == (8, 16)
    assert c["targets"].shape == (8, 16)
    assert c["tokens"].min() >= 0 and c["tokens"].max() < 97


def test_synthetic_has_learnable_structure():
    """Bigram structure: next-token conditional entropy must be far below
    uniform (this is what lets tiny overfit tests converge)."""
    src = SyntheticTokens(vocab_size=31, seq_len=512, global_batch=4)
    b = src.batch_at(0)
    t, y = b["tokens"].ravel(), b["targets"].ravel()
    match = np.mean(y == (t * 31 + 7) % 31)
    assert match > 0.5, match


def _check_synthetic_stateless(step, world):
    src = SyntheticTokens(vocab_size=53, seq_len=8, global_batch=4)
    for r in range(world):
        a = src.batch_at(step, rank=r, world=world)
        b = src.batch_at(step, rank=r, world=world)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


@given(st.integers(0, 1000), st.sampled_from([1, 2, 4]))
@settings(max_examples=20, deadline=None)
def test_synthetic_stateless_by_step(step, world):
    _check_synthetic_stateless(step, world)


def test_synthetic_stateless_by_step_seeded():
    """Deterministic twin: step edges x every world size."""
    for step in (0, 1, 7, 999, 1000):
        for world in (1, 2, 4):
            _check_synthetic_stateless(step, world)


def test_memmap_source_roundtrip(tmp_path):
    corpus = np.arange(1000, dtype=np.uint32) % 113
    f = tmp_path / "corpus.bin"
    write_token_file(f, corpus)
    src = MemmapTokens(f, seq_len=16, global_batch=4)
    b = src.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][0], corpus[:16].astype(np.int32))
    np.testing.assert_array_equal(b["targets"][0], corpus[1:17].astype(np.int32))
    # windows wrap deterministically
    late = src.batch_at(10_000)
    again = src.batch_at(10_000)
    np.testing.assert_array_equal(late["tokens"], again["tokens"])


def test_prefetcher_orders_and_jumps():
    cfg = DataConfig(kind="synthetic", vocab_size=11, seq_len=4,
                     global_batch=2)
    pipe = make_pipeline(cfg, start_step=3)
    try:
        s0, b0 = next(pipe)
        s1, b1 = next(pipe)
        assert (s0, s1) == (3, 4)
        # jump (restart): stream resumes exactly at the requested step
        pipe.at(100)
        s2, b2 = next(pipe)
        assert s2 == 100
        expect = cfg.make_source().batch_at(100)
        np.testing.assert_array_equal(np.asarray(b2["tokens"]),
                                      expect["tokens"])
    finally:
        pipe.close()


# ---------------------------------------------------------------------------
# checkpoint store
# ---------------------------------------------------------------------------


def _tree(v=0.0):
    return {"params": {"w": jnp.full((4, 3), 1.5 + v), "b": jnp.zeros((3,))},
            "opt": {"m": {"w": jnp.ones((4, 3)) * 2, "b": jnp.zeros((3,))}}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t)
    assert latest_step(tmp_path) == 7
    out = restore_checkpoint(tmp_path, 7, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_incomplete_ignored(tmp_path):
    save_checkpoint(tmp_path, 5, _tree())
    # simulate a crash mid-write: step dir exists, no manifest
    bad = tmp_path / "step_000000009"
    bad.mkdir()
    (bad / "shard_00000.npz").write_bytes(b"partial garbage")
    assert latest_step(tmp_path) == 5          # uncommitted step invisible
    gc_incomplete(tmp_path)
    assert not bad.exists()


def test_checkpoint_manager_rotation_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=True)
    for s in (10, 20, 30, 40):
        mgr.save(s, _tree(float(s)), extra={"loss": s * 0.1})
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [30, 40]
    out = mgr.restore(40, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree()))
    np.testing.assert_allclose(np.asarray(out["params"]["w"]), 41.5)


def test_checkpoint_restore_reshards_dtype_and_template(tmp_path):
    t = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)}
    save_checkpoint(tmp_path, 1, t)
    # restore into a bf16 template (mixed-precision restart)
    tmpl = {"w": jax.ShapeDtypeStruct((3, 4), jnp.bfloat16)}
    out = restore_checkpoint(tmp_path, 1, tmpl)
    assert out["w"].dtype == jnp.bfloat16
    with pytest.raises(AssertionError):
        restore_checkpoint(tmp_path, 1,
                           {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)})


# ---------------------------------------------------------------------------
# FT runtime
# ---------------------------------------------------------------------------


def test_failure_injector_fires_once():
    inj = FailureInjector(at_steps=[3])
    inj.check(2)
    with pytest.raises(WorkerFailure):
        inj.check(3)
    inj.check(3)   # second pass does not re-fire


def test_straggler_monitor_flags_persistent_outlier():
    mon = StragglerMonitor(4, window=16, k=4.0, patience=2)
    flagged = []
    for i in range(20):
        base = [0.100, 0.101, 0.099, 0.100]
        if i >= 10:
            base[2] = 0.500                     # worker 2 degrades
        flagged = mon.observe(base)
    assert flagged == [2]


def test_straggler_monitor_ignores_single_blip():
    mon = StragglerMonitor(1, window=16, patience=3)
    out = []
    for i in range(20):
        d = 0.5 if i == 10 else 0.1             # one GC pause
        out.append(mon.observe([d]))
    assert all(not f for f in out)


def _check_elastic_plan(world, fails, gb):
    fails = min(fails, world - 1)
    plan = plan_rescale(world, list(range(fails)), gb)
    assert plan.new_world == world - fails
    assert plan.new_global_batch % plan.new_world == 0
    assert plan.new_global_batch <= gb
    assert plan.dropped_samples < plan.new_world


@given(st.integers(2, 64), st.integers(1, 8), st.integers(8, 512))
@settings(max_examples=30, deadline=None)
def test_elastic_plan_preserves_batch_invariants(world, fails, gb):
    _check_elastic_plan(world, fails, gb)


def test_elastic_plan_preserves_batch_invariants_seeded():
    """Deterministic twin: corner triples plus seeded draws."""
    for world, fails, gb in [(2, 1, 8), (64, 8, 512), (64, 1, 8),
                             (3, 2, 13), (17, 5, 100)]:
        _check_elastic_plan(world, fails, gb)
    rng = np.random.RandomState(5)
    for _ in range(12):
        _check_elastic_plan(int(rng.randint(2, 65)),
                            int(rng.randint(1, 9)),
                            int(rng.randint(8, 513)))
