"""Numerical equivalence of the §Perf variant configurations vs baseline:
the optimized shardings/implementations must compute the SAME function.
Multi-device parts run in an 8-device subprocess (main process keeps 1)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

pytest.importorskip(
    "jax", reason="jax-dependent suite (subprocess scripts import jax); "
    "the no-jax CI leg covers the numpy fallbacks")


def _run(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout[-2000:] + "\n" + out.stderr[-3000:]
    return out.stdout


DECODE2D_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.models import model as M
from repro.models.sharding import (DECODE_2D_RULES, SERVE_RULES, ShardingCtx)

cfg = get_reduced("llama3-405b")
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
params = M.init_model_params(cfg, jax.random.PRNGKey(0), jnp.float32)
B, S = 4, 16
prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                       cfg.vocab_size)}
outs = {}
for tag, rules, gf in (("baseline", SERVE_RULES, True),
                       ("decode2d", DECODE_2D_RULES, False)):
    ctx = ShardingCtx(mesh=mesh, rules=rules, gather_fsdp=gf)
    hidden, caches, plen = M.prefill(cfg, params, prompt, max_len=32,
                                     ctx=ctx, cache_dtype=jnp.float32)
    step = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, _ = M.decode_step(cfg, params, step, caches, plen, ctx)
    outs[tag] = np.asarray(logits)
np.testing.assert_allclose(outs["baseline"], outs["decode2d"],
                           rtol=2e-4, atol=2e-4)
print("DECODE2D_EQ_OK")
"""


@pytest.mark.slow
def test_decode2d_rules_same_logits():
    """B2 variant (2-D no-regather decode) computes identical logits."""
    assert "DECODE2D_EQ_OK" in _run(DECODE2D_SCRIPT)


SEGMENT_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.models import model as M

cfg = get_reduced("llama3-405b", n_periods=4)   # 4 superblocks -> seg 2
params = M.init_model_params(cfg, jax.random.PRNGKey(0), jnp.float32)
ks = jax.random.split(jax.random.PRNGKey(1), 2)
batch = {"tokens": jax.random.randint(ks[0], (2, 32), 0, cfg.vocab_size),
         "targets": jax.random.randint(ks[1], (2, 32), 0, cfg.vocab_size)}

def loss(p, seg):
    return M.loss_fn(cfg, p, batch, remat="full", ce_chunk=32,
                     remat_segment=seg)[0]

l0, g0 = jax.value_and_grad(lambda p: loss(p, 0))(params)
l1, g1 = jax.value_and_grad(lambda p: loss(p, 2))(params)
np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-6)
print("SEGMENT_EQ_OK")
"""


def test_segmented_remat_same_loss_and_grads():
    """C-series sqrt-N segmented remat is a pure recompute schedule: loss
    AND gradients must match the unsegmented scan."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", SEGMENT_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout[-2000:] + "\n" + out.stderr[-3000:]
    assert "SEGMENT_EQ_OK" in out.stdout


EP_TRAIN_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.models import model as M
from repro.models.sharding import DEFAULT_RULES, ShardingCtx
from repro.optim.adamw import init_opt_state
from repro.train.train_step import TrainHParams, make_train_step

cfg = get_reduced("grok-1-314b")    # MoE 8e->4e reduced, top-2
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
params = M.init_model_params(cfg, jax.random.PRNGKey(0), jnp.float32)
opt = init_opt_state(params)
ks = jax.random.split(jax.random.PRNGKey(1), 2)
batch = {"tokens": jax.random.randint(ks[0], (8, 32), 0, cfg.vocab_size),
         "targets": jax.random.randint(ks[1], (8, 32), 0, cfg.vocab_size)}
losses = {}
for impl in ("dense", "ep"):
    ctx = ShardingCtx(mesh=mesh, rules=DEFAULT_RULES, moe_impl=impl)
    hp = TrainHParams(remat=None, ce_chunk=32)
    step = jax.jit(make_train_step(cfg, hp, ctx))
    p2, o2, m = step(params, opt, batch, jnp.zeros((), jnp.int32))
    losses[impl] = float(m["loss"])
    assert np.isfinite(losses[impl])
np.testing.assert_allclose(losses["dense"], losses["ep"], rtol=3e-2)
print("EP_TRAIN_OK")
"""


@pytest.mark.slow
def test_ep_moe_full_train_step():
    """A1 variant (manual-EP MoE) through the full train step."""
    assert "EP_TRAIN_OK" in _run(EP_TRAIN_SCRIPT)


HYBRID_2D_SCRIPT = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.models import model as M
from repro.models.sharding import (DECODE_2D_RULES, SERVE_RULES, ShardingCtx)

cfg = get_reduced("jamba-1.5-large-398b")     # hybrid SSM+attn+MoE
# MoE token dropping is PER DISPATCH GROUP and groups follow the batch
# sharding (GShard semantics) — equivalence across shardings holds only
# when capacity is high enough that nothing drops
cfg = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
params = M.init_model_params(cfg, jax.random.PRNGKey(0), jnp.float32)
B, S = 4, 16
prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                       cfg.vocab_size)}
outs = {}
for tag, rules, gf in (("baseline", SERVE_RULES, True),
                       ("decode2d", DECODE_2D_RULES, False)):
    ctx = ShardingCtx(mesh=mesh, rules=rules, gather_fsdp=gf)
    hidden, caches, plen = M.prefill(cfg, params, prompt, max_len=32,
                                     ctx=ctx, cache_dtype=jnp.float32)
    step = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, _ = M.decode_step(cfg, params, step, caches, plen, ctx)
    outs[tag] = np.asarray(logits)
np.testing.assert_allclose(outs["baseline"], outs["decode2d"],
                           rtol=5e-4, atol=5e-4)
print("HYBRID2D_EQ_OK")
"""


@pytest.mark.slow
def test_decode2d_rules_hybrid_same_logits():
    """decode2d on the hybrid SSM+attn+MoE arch (jamba 21.8x in §Perf):
    SSM-state and KV caches both reshard correctly."""
    assert "HYBRID2D_EQ_OK" in _run(HYBRID_2D_SCRIPT)
