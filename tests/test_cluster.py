"""Partition-tolerant cluster runtime (``repro.cluster``): trace-fuzz
corpus plus deterministic fault scenarios and unit oracles.

The fuzz family (``trace_fuzz.cluster_crosscheck``) runs every seeded
program sharded across 2-4 spawned OS processes and asserts the cluster
contract on every trace: the sharded run finishes traffic
field-for-field, clock bit-equal, and stats-identical to the unfailed
single-process run — in LOCKSTEP (every round's cross-shard agreed
digest equals the baseline's state digest at that event) — both clean
and under injected process faults (mid-phase SIGKILL, one-directional
link partitions in either direction) with degraded-mode recovery in
both flavours (respawn-and-replay, rebind-to-survivor).

The aggregate counters guard against silently-idle fault paths: kills,
both partition directions, detections, respawns, rebinds, and replayed
events must all fire across the corpus.
"""
import numpy as np
import pytest

import trace_fuzz
from repro.cluster import (ClusterRuntime, HeartbeatDetector,
                           MembershipTable, ShardError, ShardState,
                           make_runtime, state_digest)
from repro.core.regc_scale import RegCScaleRuntime
from repro.ft import FailureInjector
from repro.ft.coherence import assert_bit_equal, run_uninjected

N_CLUSTER_TRACES = 12


def test_cluster_fuzz_traces_recovery_exact():
    agg = {}
    for seed in range(N_CLUSTER_TRACES):
        stats = trace_fuzz.cluster_crosscheck(seed)
        for k, v in stats.items():
            agg[k] = agg.get(k, 0) + v
    # every fault class must actually be PERFORMED (not merely
    # scheduled) somewhere in the corpus, and detected + recovered
    assert agg["performed_kill"] > 0, agg
    assert agg["performed_partition_c2s"] > 0, agg
    assert agg["performed_partition_s2c"] > 0, agg
    assert agg["rec_kills"] > 0, agg
    assert agg["rec_partitions"] > 0, agg
    assert agg["rec_detections"] >= (agg["performed_kill"]
                                     + agg["performed_partition_c2s"]
                                     + agg["performed_partition_s2c"]), agg
    # both degraded-recovery modes fire
    assert agg["rec_respawns"] > 0, agg
    assert agg["rec_rebinds"] > 0, agg
    assert agg["rec_replayed_events"] > 0, agg
    # partitions are detected by deadline+retry, never silently eaten
    assert agg["rpc_retries"] > 0, agg
    # every round reaches cross-shard digest agreement; every barrier
    # cut a composed checkpoint
    assert agg["rec_digest_rounds"] > 4 * N_CLUSTER_TRACES, agg
    assert agg["rec_checkpoints"] > 2 * N_CLUSTER_TRACES, agg
    # the sharded corpus crosses the engine's chaos + span paths too
    assert agg["chaos_msgs"] > 0, agg
    assert agg["chaos_drops"] > 0, agg
    assert agg["span_all_calls"] > 0, agg
    assert agg["straggler_checks"] > 0, agg


def test_cluster_fuzz_backends_agree():
    """The sharded runtime on the pallas directory backend must hold the
    same lockstep + recovery contract (shard processes import jax)."""
    pytest.importorskip("jax")
    for seed in (0, 3):
        trace_fuzz.cluster_crosscheck(seed, backends=("numpy", "pallas"))


def test_cluster_fuzz_jit_lockstep():
    """The sharded multi-process runtime on 'pallas-jit': per-round
    digests lockstep with the single-process jit baseline, and fault
    recovery lands bit-equal (jit dispatch topology differs per shard —
    excluded from the exactness bar).  FUZZ_JIT=1 runs the full
    corpus."""
    pytest.importorskip("jax")
    for seed in trace_fuzz.jit_seeds(N_CLUSTER_TRACES, (2, 5)):
        trace_fuzz.cluster_crosscheck(seed, backends=("pallas-jit",))


# ---------------------------------------------------------------------------
# deterministic fault scenarios
# ---------------------------------------------------------------------------

_W = 4
_PAGE = 16
_NW = _PAGE * 30


def _cfg():
    return dict(n_workers=_W, page_words=_PAGE, protocol="fine",
                cache_pages=6, chaos=dict(seed=3, drop_rate=0.1),
                straggler=None)


def _prog():
    rng = np.random.default_rng(1)
    return trace_fuzz.gen_span_program(rng, _W, _NW, _PAGE, 6, n_phases=6)


def _baseline(prog):
    return run_uninjected(lambda: make_runtime(_cfg()), [_NW, _NW // 2],
                          "batched", prog, trace_fuzz.apply_event)


def _cluster(prog, root, injector=None, recovery="respawn"):
    with ClusterRuntime(_cfg(), [_NW, _NW // 2], n_shards=2,
                        driver="batched",
                        apply_ref=("trace_fuzz", "apply_event"),
                        root=root, injector=injector, recovery=recovery,
                        rpc_timeout_s=0.15, rpc_attempts=3) as cl:
        res = cl.run(prog)
        return res, dict(cl.digests)


def test_cluster_clean_lockstep(tmp_path):
    prog = _prog()
    base = _baseline(prog)
    res, digests = _cluster(prog, tmp_path)
    assert_bit_equal(res, base, "clean")
    assert res.report.detections == 0
    assert res.report.digest_rounds == len(prog)
    # re-derive the baseline digest trace and hold it to lockstep
    rt = make_runtime(_cfg())
    gas = [rt.alloc(_NW), rt.alloc(_NW // 2)]
    for i, ev in enumerate(prog):
        from repro.ft.coherence import harness_ticks
        if harness_ticks(ev, "batched"):
            rt.chaos_tick()
        trace_fuzz.apply_event(rt, ev, gas, "batched")
        assert digests[i] == state_digest(rt), (i, ev)


def test_cluster_sigkill_midphase_recovers_bit_equal(tmp_path):
    """SIGKILL a shard between two phase events (mid-phase, not at a
    barrier): quarantine, respawn from the last barrier checkpoint,
    replay the suffix, finish bit-equal."""
    prog = _prog()
    base = _baseline(prog)
    inj = FailureInjector(cluster_at=[("kill", 5, 1)])
    res, _ = _cluster(prog, tmp_path, injector=inj)
    assert_bit_equal(res, base, "kill")
    c = res.report.counters()
    assert c["rec_kills"] == 1 and c["rec_detections"] == 1, c
    assert c["rec_respawns"] == 1, c


@pytest.mark.parametrize("direction", ["partition_c2s", "partition_s2c"])
@pytest.mark.parametrize("mode", ["respawn", "rebind"])
def test_cluster_partition_one_direction_recovers(tmp_path, direction,
                                                  mode):
    """A one-directional link partition (requests eaten, or replies
    eaten) must be detected by deadline + backoff-retry exhaustion,
    the partitioned-but-healthy process fenced, and the run recovered
    bit-equal in BOTH degraded modes."""
    prog = _prog()
    base = _baseline(prog)
    inj = FailureInjector(cluster_at=[(direction, 7, 0)])
    res, _ = _cluster(prog, tmp_path, injector=inj, recovery=mode)
    assert_bit_equal(res, base, (direction, mode))
    c = res.report.counters()
    assert c["rec_partitions"] == 1 and c["rec_detections"] == 1, c
    # the deadline chain retried before declaring the shard dead
    assert res.report.rpc_retries >= 2, res.report
    if mode == "rebind":
        assert c["rec_rebinds"] == 1 and c["rec_respawns"] == 0, c
    else:
        assert c["rec_respawns"] == 1, c


def test_cluster_shard_error_propagates(tmp_path):
    """A shard-side exception (not a death) surfaces as ShardError with
    the remote traceback — never silently swallowed or retried."""
    prog = [("phase",)]                    # malformed: unpack raises
    with pytest.raises(ShardError):
        _cluster(prog, tmp_path)


# ---------------------------------------------------------------------------
# slice snapshots: the checkpoint fan-out building block
# ---------------------------------------------------------------------------

def _run_some(seed=2):
    p = trace_fuzz.cluster_trace_params(seed)
    rng = p["rng"]
    rt = RegCScaleRuntime(p["W"], page_words=p["page_words"],
                          protocol=p["proto"],
                          cache_pages=p["cache_pages"])
    gas = [rt.alloc(p["n_words"]), rt.alloc(p["n_words"])]
    prog = trace_fuzz.gen_span_program(rng, p["W"], p["n_words"],
                                       p["page_words"], p["cache_pages"],
                                       n_phases=4)
    trace_fuzz.run_program(rt, prog, gas, "batched")
    return rt, p["W"]


def test_snapshot_slice_compose_roundtrip():
    """snapshot(rows=...) slices + compose_snapshots must reassemble
    the exact full snapshot — per-key bit-equality, meta included."""
    rt, W = _run_some()
    full_arrays, full_meta = rt.snapshot()
    cut = W // 2
    parts = [rt.snapshot(rows=(0, cut)), rt.snapshot(rows=(cut, W))]
    arrays, meta = RegCScaleRuntime.compose_snapshots(parts)
    assert meta == full_meta
    assert set(arrays) == set(full_arrays)
    for k in full_arrays:
        np.testing.assert_array_equal(arrays[k], full_arrays[k],
                                      err_msg=k)
    rt2 = RegCScaleRuntime.from_snapshot(arrays, meta)
    assert_bit_equal(rt2, rt, "compose-roundtrip")


def test_snapshot_slices_must_tile_worker_axis():
    rt, W = _run_some()
    with pytest.raises(AssertionError):
        RegCScaleRuntime.compose_snapshots(
            [rt.snapshot(rows=(0, 1)), rt.snapshot(rows=(2, W))])


def test_from_snapshot_refuses_partial_slice():
    """A single shard's slice is NOT a restorable checkpoint — only the
    composed full-width snapshot is."""
    rt, W = _run_some()
    arrays, meta = rt.snapshot(rows=(0, W // 2))
    assert meta["slice"] == [0, W // 2]
    with pytest.raises(AssertionError):
        RegCScaleRuntime.from_snapshot(arrays, meta)


# ---------------------------------------------------------------------------
# membership + failure detection units
# ---------------------------------------------------------------------------

def test_membership_rebind_and_owners():
    t = MembershipTable()
    t.add(0, 100, 0, 2)
    t.add(1, 101, 2, 4)
    t.mark(0, ShardState.ALIVE)
    t.mark(1, ShardState.ALIVE)
    assert t.owners() == [(0, 2, 0), (2, 4, 1)]
    assert t.alive_ranks() == [0, 1]
    t.mark(1, ShardState.DEAD)
    assert t.alive_ranks() == [0]
    t.rebind(1, 0)
    t.mark(1, ShardState.QUARANTINED)
    # survivor now serves the whole axis; the dead rank owns nothing
    assert t.owners() == [(0, 2, 0), (2, 4, 0)]


def test_membership_reincarnation_restores_home_slice():
    t = MembershipTable()
    t.add(0, 100, 0, 2)
    t.add(1, 101, 2, 4)
    t.mark(1, ShardState.DEAD)
    t.rebind(1, 0)
    t.reincarnate(1, 202)
    assert t.records[1].incarnation == 1
    assert t.records[1].pid == 202
    assert t.state(1) == ShardState.JOINING
    t.mark(0, ShardState.ALIVE)
    t.mark(1, ShardState.ALIVE)
    # rebind had stacked rank 1's home slice onto rank 0; the
    # reincarnation reclaims it — ownership never double-counts a row
    assert t.owners() == [(0, 2, 0), (2, 4, 1)]


def test_heartbeat_detector_degenerate_window_uses_floor():
    d = HeartbeatDetector(floor_s=0.25, k=6.0)
    assert d.timeout_s() == 0.25          # cold start
    d.observe(0.004)
    assert d.timeout_s() == 0.25          # single sample: still floor
    assert d.n_samples() == 1


def test_heartbeat_detector_adapts_but_never_below_floor():
    d = HeartbeatDetector(floor_s=0.001, k=6.0, window=64)
    for _ in range(64):
        d.observe(0.010)
    # zero-MAD window: threshold collapses to ~median, floored
    assert 0.001 <= d.timeout_s() <= 0.011
    d2 = HeartbeatDetector(floor_s=0.5, k=6.0)
    for _ in range(64):
        d2.observe(0.010)
    assert d2.timeout_s() == 0.5          # floor dominates fast replies
