"""Tests for the RegC training-layer sync policies (repro.regc_sync).

Single-device parts run inline; multi-device semantics (psum vs int8 ring,
lazy vs eager, GSPMD vs shard_map equivalence) run in a subprocess with
``--xla_force_host_platform_device_count=8`` because the main test process
must keep seeing exactly one device (DESIGN.md §6).
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="jax-dependent suite; the no-jax CI leg covers the numpy fallbacks")
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:           # tier-1 env may lack hypothesis
    from _hypothesis_stub import given, settings, st

from repro.regc_sync.policies import (
    RegCSyncPolicy, _dequant, _flatten_to_buckets, _quant, _unflatten_buckets,
)


# ---------------------------------------------------------------------------
# bucketing (page-granularity analogue): lossless round trip
# ---------------------------------------------------------------------------


@st.composite
def tree_shapes(draw):
    n = draw(st.integers(1, 6))
    return [tuple(draw(st.lists(st.integers(1, 7), min_size=1, max_size=3)))
            for _ in range(n)]


def _check_bucket_roundtrip(shapes, bucket_bytes):
    rng = np.random.RandomState(0)
    tree = {f"p{i}": jnp.asarray(rng.randn(*s).astype(np.float32))
            for i, s in enumerate(shapes)}
    buckets, shp, treedef = _flatten_to_buckets(tree, bucket_bytes)
    out = _unflatten_buckets(buckets, shp, treedef)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(a, b)


@given(tree_shapes(), st.integers(8, 512))
@settings(max_examples=25, deadline=None)
def test_bucket_roundtrip_property(shapes, bucket_bytes):
    _check_bucket_roundtrip(shapes, bucket_bytes)


def test_bucket_roundtrip_seeded():
    """Deterministic twin: seeded shape lists across the bucket-size
    range, plus the degenerate single-scalar tree."""
    _check_bucket_roundtrip([(1,)], 8)
    rng = np.random.RandomState(11)
    for bucket_bytes in (8, 64, 200, 512):
        shapes = [tuple(int(rng.randint(1, 8))
                        for _ in range(int(rng.randint(1, 4))))
                  for _ in range(int(rng.randint(1, 7)))]
        _check_bucket_roundtrip(shapes, bucket_bytes)


def test_bucket_sizes_respect_threshold():
    tree = {f"p{i}": jnp.ones((1024,), jnp.float32) for i in range(16)}
    buckets, _, _ = _flatten_to_buckets(tree, 8192)   # 2 leaves per bucket
    assert len(buckets) == 8
    assert all(b.size * 4 >= 8192 for b in buckets[:-1])


# ---------------------------------------------------------------------------
# int8 quantization (compressed-diff analogue)
# ---------------------------------------------------------------------------


def _check_quant_error_bound(n, scale_mag):
    rng = np.random.RandomState(n)
    x = jnp.asarray(rng.randn(n).astype(np.float32) * scale_mag)
    q, s = _quant(x)
    err = np.abs(np.asarray(_dequant(q, s) - x))
    # error bounded by half a quantization step
    assert err.max() <= float(s) * 0.5 + 1e-6


@given(st.integers(1, 2000), st.floats(1e-3, 1e3))
@settings(max_examples=30, deadline=None)
def test_quant_error_bound(n, scale_mag):
    _check_quant_error_bound(n, scale_mag)


def test_quant_error_bound_seeded():
    """Deterministic twin: size/magnitude edges plus seeded draws."""
    for n, mag in [(1, 1e-3), (2000, 1e3), (7, 1.0)]:
        _check_quant_error_bound(n, mag)
    rng = np.random.RandomState(3)
    for _ in range(10):
        _check_quant_error_bound(int(rng.randint(1, 2001)),
                                 float(10.0 ** rng.uniform(-3, 3)))


def test_quant_preserves_zero():
    q, s = _quant(jnp.zeros(16))
    np.testing.assert_array_equal(np.asarray(_dequant(q, s)), 0.0)


def test_policy_validation():
    with pytest.raises(AssertionError):
        RegCSyncPolicy(ordinary_sync="nope")
    with pytest.raises(AssertionError):
        RegCSyncPolicy(granularity="page")


# ---------------------------------------------------------------------------
# multi-device semantics (subprocess, 8 fake host devices)
# ---------------------------------------------------------------------------


def _run_multidev(script: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


MULTIDEV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.regc_sync.policies import (RegCSyncPolicy, barrier_sync_grads,
                                      ring_allreduce_int8, span_reduce)

mesh = jax.make_mesh((8,), ("data",))
x = jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64) / 100.0 - 2.0

# --- int8 ring all-reduce approximates fp32 psum --------------------------
def ring(v):
    return ring_allreduce_int8(v, "data", 8)
from repro.compat import shard_map
ring_out = shard_map(ring, mesh=mesh, in_specs=P("data"),
                         out_specs=P("data"))(x.reshape(-1))
psum_out = np.asarray(x).sum(0)
ring_first = np.asarray(ring_out.reshape(8, 64))[0]
rel = np.abs(ring_first - psum_out) / (np.abs(psum_out) + 1e-3)
assert rel.max() < 0.05, rel.max()
# every shard holds the same reduced vector (all-gather phase correctness)
rr = np.asarray(ring_out.reshape(8, 64))
assert np.allclose(rr, rr[0:1], atol=1e-6)

# --- object vs bucket granularity agree exactly (both are psum) ------------
grads = {"a": x, "b": (x * 3 + 1).reshape(8, 8, 8)}
outs = {}
for gran in ("object", "bucket"):
    pol = RegCSyncPolicy(granularity=gran, bucket_bytes=128)
    f = lambda g: barrier_sync_grads(g, ("data",), pol, axis_sizes={"data": 8})
    o = shard_map(f, mesh=mesh,
                      in_specs=({"a": P("data"), "b": P("data")},),
                      out_specs={"a": P("data"), "b": P("data")})(
        {"a": grads["a"].reshape(8, 1, 64), "b": grads["b"]})
    outs[gran] = o
for k in outs["object"]:
    np.testing.assert_allclose(np.asarray(outs["object"][k]),
                               np.asarray(outs["bucket"][k]), rtol=1e-6)

# --- span_reduce == the reduction extension --------------------------------
val = jnp.arange(8.0)
got = shard_map(lambda v: span_reduce(v, ("data",), "sum"),
                    mesh=mesh, in_specs=P("data"), out_specs=P("data"))(val)
np.testing.assert_allclose(np.asarray(got), 28.0)
print("MULTIDEV_OK")
"""


@pytest.mark.slow
def test_multidevice_sync_semantics():
    out = _run_multidev(MULTIDEV_SCRIPT)
    assert "MULTIDEV_OK" in out


TRAIN_EQUIV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.regc_sync.policies import RegCSyncPolicy
from repro.train.train_step import (TrainHParams, make_train_step,
                                    make_train_step_regc)

cfg = get_reduced("internlm2-1.8b")
params = M.init_model_params(cfg, jax.random.PRNGKey(0), jnp.float32)
opt = init_opt_state(params)
ks = jax.random.split(jax.random.PRNGKey(1), 2)
B, S = 16, 32   # 8-way DP -> local batch 2, divisible by n_micro=2
batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
step0 = jnp.zeros((), jnp.int32)

mesh = jax.make_mesh((8,), ("data",))

# reference: single-device GSPMD step (global batch)
hp = TrainHParams(remat=None, ce_chunk=32)
ref_p, ref_o, ref_m = jax.jit(make_train_step(cfg, hp))(params, opt, batch, step0)

results = {}
for tag, policy, n_micro in (
    ("lazy_object", RegCSyncPolicy("lazy", "object"), 1),
    ("lazy_bucket", RegCSyncPolicy("lazy", "bucket", 1 << 16), 1),
    ("eager_object", RegCSyncPolicy("eager", "object"), 2),
    ("lazy_micro", RegCSyncPolicy("lazy", "object"), 2),
):
    hp2 = TrainHParams(remat=None, ce_chunk=32, n_micro=n_micro,
                       sync=policy)
    step = make_train_step_regc(cfg, hp2, mesh, dp_axes=("data",))
    p2, o2, m2 = step(params, opt, batch, step0)
    results[tag] = (p2, m2)
    assert np.isfinite(float(m2["loss"])), (tag, m2)
    np.testing.assert_allclose(float(m2["loss"]), float(ref_m["loss"]),
                               rtol=2e-4, err_msg=tag)

# RegC lazy and RC eager produce the same update (DRF program: both
# consistent at the step barrier; only traffic schedules differ)
for a, b in zip(jax.tree.leaves(results["eager_object"][0]),
                jax.tree.leaves(results["lazy_micro"][0])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-5)
# shard_map lazy == GSPMD reference update
for a, b in zip(jax.tree.leaves(results["lazy_object"][0]),
                jax.tree.leaves(ref_p)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-5)
print("TRAIN_EQUIV_OK")
"""


@pytest.mark.slow
def test_regc_train_equivalence_8dev():
    """GSPMD vs explicit-RegC shard_map vs eager-RC: same update, different
    collective schedule (the paper's Table I executable at trainer scale)."""
    out = _run_multidev(TRAIN_EQUIV_SCRIPT)
    assert "TRAIN_EQUIV_OK" in out
