"""Shared test configuration.

Makes the hypothesis property suites *visibly* absent instead of silently
skipped: when ``_hypothesis_stub`` stood in for hypothesis (the tier-1
container does not ship it — see requirements-dev.txt), the terminal
summary reports how many property tests were skipped and how to enable
them.  The deterministic oracles in ``tests/test_directory.py`` and the
seeded trace-fuzz suite (``tests/test_trace_fuzz.py``) cover the same
cross-validation either way.

CI's property-suite job (which installs requirements-dev.txt precisely so
the property tests run somewhere) sets ``REQUIRE_PROPERTY_TESTS=1``: a
run that still stub-skips any property test then FAILS instead of going
green with the suites silently absent.
"""
import os


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    try:
        import _hypothesis_stub as stub
    except ImportError:
        return
    if stub.SKIPPED:
        terminalreporter.write_sep(
            "-", "hypothesis property suites")
        terminalreporter.write_line(
            f"{stub.SKIPPED} property test(s) skipped via _hypothesis_stub "
            f"({stub.DECORATED} @given suite(s) collected): install "
            "hypothesis (`pip install -r requirements-dev.txt`) to run "
            "them; the seeded trace-fuzz + directory oracles cover the "
            "same cross-validation deterministically.")
        if os.environ.get("REQUIRE_PROPERTY_TESTS"):
            terminalreporter.write_line(
                "REQUIRE_PROPERTY_TESTS is set: failing the run — this "
                "environment promised to execute the property suites.")


def pytest_sessionfinish(session, exitstatus):
    if not os.environ.get("REQUIRE_PROPERTY_TESTS"):
        return
    try:
        import _hypothesis_stub as stub
    except ImportError:
        return
    if stub.SKIPPED and session.exitstatus == 0:
        session.exitstatus = 1
