"""Shared test configuration.

Makes the hypothesis property suites *visibly* absent instead of silently
skipped: when ``_hypothesis_stub`` stood in for hypothesis (the tier-1
container does not ship it — see requirements-dev.txt), the terminal
summary reports how many property tests were skipped and how to enable
them.  The deterministic oracles in ``tests/test_directory.py`` and the
seeded trace-fuzz suite (``tests/test_trace_fuzz.py``) cover the same
cross-validation either way.

CI's property-suite job (which installs requirements-dev.txt precisely so
the property tests run somewhere) sets ``REQUIRE_PROPERTY_TESTS=1``: a
run that still stub-skips any property test then FAILS instead of going
green with the suites silently absent.
"""
import os


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    """ONE summary line for the stub-skipped property suites — the CI
    hint (REQUIRE_PROPERTY_TESTS) included once, instead of a banner
    block plus per-environment extra lines."""
    try:
        import _hypothesis_stub as stub
    except ImportError:
        return
    if not stub.SKIPPED:
        return
    msg = (f"{stub.SKIPPED} property test(s) stub-skipped (hypothesis "
           f"absent; {stub.DECORATED} @given suite(s)) — CI's property job "
           "runs them under REQUIRE_PROPERTY_TESTS=1")
    if os.environ.get("REQUIRE_PROPERTY_TESTS"):
        msg += ", set here: FAILING the run"
    else:
        msg += "; locally: pip install -r requirements-dev.txt"
    terminalreporter.write_line(msg)


def pytest_sessionfinish(session, exitstatus):
    if not os.environ.get("REQUIRE_PROPERTY_TESTS"):
        return
    try:
        import _hypothesis_stub as stub
    except ImportError:
        return
    if stub.SKIPPED and session.exitstatus == 0:
        session.exitstatus = 1
