"""Shared test configuration.

Makes the hypothesis property suites *visibly* absent instead of silently
skipped: when ``_hypothesis_stub`` stood in for hypothesis (the tier-1
container does not ship it — see requirements-dev.txt), the terminal
summary reports how many property tests were skipped and how to enable
them.  The deterministic oracles in ``tests/test_directory.py`` and the
seeded trace-fuzz suite (``tests/test_trace_fuzz.py``) cover the same
cross-validation either way.
"""


def pytest_terminal_summary(terminalreporter, exitstatus, config):
    try:
        import _hypothesis_stub as stub
    except ImportError:
        return
    if stub.SKIPPED:
        terminalreporter.write_sep(
            "-", "hypothesis property suites")
        terminalreporter.write_line(
            f"{stub.SKIPPED} property test(s) skipped via _hypothesis_stub "
            f"({stub.DECORATED} @given suite(s) collected): install "
            "hypothesis (`pip install -r requirements-dev.txt`) to run "
            "them; the seeded trace-fuzz + directory oracles cover the "
            "same cross-validation deterministically.")
