"""Crash-consistency and robustness tests for ``checkpoint/store.py``:
stray non-``step_NNNNNNNNN`` entries in the checkpoint root (regression
— they used to crash ``latest_step``/``_rotate`` on the int parse),
torn saves killed between the shard write and the manifest rename, the
numpy-only ``save_arrays``/``load_arrays`` path (no jax import), and
``CheckpointManager`` rotation racing an in-flight async save."""
import json
import threading

import numpy as np

from repro.checkpoint import (CheckpointManager, gc_incomplete,
                              latest_step, load_arrays, save_arrays)
from repro.checkpoint.store import _MANIFEST, _step_dir


def _save(root, step, **arrays):
    save_arrays(root, step, arrays or {"x": np.arange(4)},
                extra={"step": step})


def test_latest_step_ignores_stray_entries(tmp_path):
    """Editor backups, NFS debris, and malformed step names must not
    crash or be miscounted (regression: int(p.name.split('_')[1]))."""
    _save(tmp_path, 3)
    _save(tmp_path, 7)
    (tmp_path / "step_zzz").mkdir()                    # malformed dir
    (tmp_path / "step_00000010x").mkdir()              # near-miss name
    (tmp_path / "step_tmp").write_text("")             # stray file
    (tmp_path / "step_000000099").write_text("")       # file, not dir
    assert latest_step(tmp_path) == 7


def test_rotate_ignores_stray_entries(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    (tmp_path / "step_backup~").mkdir()
    (tmp_path / ".nfs000123").write_text("")
    for s in (1, 2, 3):
        mgr.save_arrays(s, {"x": np.arange(3)})
    mgr.wait()
    assert latest_step(tmp_path) == 3
    assert not _step_dir(tmp_path, 1).exists()         # rotated out
    assert (tmp_path / "step_backup~").exists()        # not ours: kept


def test_gc_incomplete_spares_foreign_entries(tmp_path):
    """gc removes only conforming manifest-less step dirs — a stray
    foreign directory matching ``step_*`` loosely is not ours to
    delete."""
    _save(tmp_path, 1)
    torn = _step_dir(tmp_path, 2)
    torn.mkdir()
    (torn / "shard_00000.npz").write_bytes(b"partial")
    foreign = tmp_path / "step_notes"
    foreign.mkdir()
    (foreign / "keep.txt").write_text("mine")
    gc_incomplete(tmp_path)
    assert not torn.exists()
    assert foreign.exists()
    assert latest_step(tmp_path) == 1


def test_torn_save_ignored_then_collected(tmp_path):
    """Kill between the shard write and the manifest rename: the torn
    step is invisible to ``latest_step`` and removed by
    ``gc_incomplete``; a later complete save of the same step wins."""
    _save(tmp_path, 4)
    d = _step_dir(tmp_path, 5)
    d.mkdir()
    with open(d / "shard_00000.npz", "wb") as f:
        np.savez(f, x=np.arange(8))
    # manifest only made it to the tmp name — the commit never happened
    (d / ".manifest.tmp").write_text(json.dumps({"step": 5}))
    assert latest_step(tmp_path) == 4
    gc_incomplete(tmp_path)
    assert not d.exists()
    _save(tmp_path, 5)
    assert latest_step(tmp_path) == 5
    arrays, extra = load_arrays(tmp_path, 5)
    np.testing.assert_array_equal(arrays["x"], np.arange(4))
    assert extra == {"step": 5}


def test_save_arrays_roundtrip_no_jax_path(tmp_path):
    arrays = {"a": np.arange(6).reshape(2, 3),
              "b": np.zeros(0, np.uint64),
              "c": np.array([True, False])}
    save_arrays(tmp_path, 12, arrays, extra={"meta": {"k": [1, 2]}})
    out, extra = load_arrays(tmp_path, 12)
    assert set(out) == set(arrays)
    for k in arrays:
        np.testing.assert_array_equal(out[k], arrays[k])
        assert out[k].dtype == arrays[k].dtype
    assert extra == {"meta": {"k": [1, 2]}}


def test_store_importable_without_jax(tmp_path):
    """The numpy-only path must work with jax UNIMPORTABLE (the nojax
    CI leg imports this module for coherence snapshots) — checked in a
    subprocess where ``import jax`` is poisoned."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    src = Path(__file__).resolve().parents[1] / "src"
    code = (
        "import sys; sys.modules['jax'] = None\n"
        "import numpy as np\n"
        "import repro.checkpoint.store as s\n"
        f"d = {str(tmp_path)!r}\n"
        "s.save_arrays(d, 1, {'x': np.arange(3)}, extra={'ok': True})\n"
        "a, e = s.load_arrays(d, 1)\n"
        "assert a['x'].tolist() == [0, 1, 2] and e == {'ok': True}\n"
        "assert s.latest_step(d) == 1\n")
    env = dict(os.environ, PYTHONPATH=str(src))
    subprocess.run([sys.executable, "-c", code], check=True, env=env)


def test_manager_rotation_races_async_save(tmp_path, monkeypatch):
    """Rotation must count the in-flight (uncommitted) save toward
    ``keep`` and never delete it: with keep=2 and a slow writer, the
    pending step and the newest committed step survive, older ones
    rotate out, and the manifest commits intact after the join."""
    release = threading.Event()
    real_savez = np.savez

    def slow_savez(f, **kw):
        assert release.wait(10)
        return real_savez(f, **kw)

    mgr = CheckpointManager(tmp_path, keep=2, async_write=False)
    for s in (1, 2):
        mgr.save_arrays(s, {"x": np.arange(3)})
    mgr.async_write = True
    monkeypatch.setattr(np, "savez", slow_savez)
    mgr.save_arrays(3, {"x": np.arange(3)})  # async, writer is parked
    # rotation already ran with step 3 uncommitted: it must have
    # counted toward keep (1 rotated out, 2 + pending 3 kept)
    assert not _step_dir(tmp_path, 1).exists()
    assert _step_dir(tmp_path, 2).exists()
    assert _step_dir(tmp_path, 3).exists()
    assert latest_step(tmp_path) == 2        # not yet committed
    release.set()
    mgr.wait()
    monkeypatch.setattr(np, "savez", real_savez)
    assert latest_step(tmp_path) == 3
    assert (_step_dir(tmp_path, 3) / _MANIFEST).exists()
    arrays, _ = load_arrays(tmp_path, 3)
    np.testing.assert_array_equal(arrays["x"], np.arange(3))


def test_save_arrays_fsync_durability_protocol(tmp_path, monkeypatch):
    """Regression: tmp-write + rename alone orders the commit against
    *process* crashes only — against power loss the shard bytes, the
    manifest bytes, and both directory entry tables must each be
    fsync'd.  Records every fsync (resolving fds via /proc/self/fd) and
    asserts the full protocol: shard tmp, manifest tmp, step dir, then
    the root dir — data before directories, step dir before its
    parent."""
    import os

    synced = []
    real_fsync = os.fsync

    def recording_fsync(fd):
        try:
            synced.append(os.readlink(f"/proc/self/fd/{fd}"))
        except OSError:
            synced.append("<unresolvable>")
        return real_fsync(fd)

    monkeypatch.setattr(os, "fsync", recording_fsync)
    save_arrays(tmp_path, 6, {"x": np.arange(5)}, extra={})
    d = os.path.realpath(_step_dir(tmp_path, 6))
    root = os.path.realpath(tmp_path)
    # the file fsyncs happen BEFORE the renames, so /proc recorded the
    # tmp names — which is itself part of the protocol under test
    assert any(p.endswith(".shard_00000.tmp.npz") for p in synced), synced
    assert any(p.endswith(".manifest.tmp") for p in synced), synced
    assert d in synced and root in synced, synced
    shard_i = next(i for i, p in enumerate(synced)
                   if p.endswith(".shard_00000.tmp.npz"))
    man_i = next(i for i, p in enumerate(synced)
                 if p.endswith(".manifest.tmp"))
    assert shard_i < man_i < synced.index(d) < synced.index(root), synced
