"""Blockwise-int8 AdamW (beyond-paper, §Perf C-series) vs the f32 reference:
quantization round-trip bounds, update-direction agreement, and end-to-end
convergence on the tiny overfit task."""
import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="jax-dependent suite; the no-jax CI leg covers the numpy fallbacks")
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:           # tier-1 env may lack hypothesis
    from _hypothesis_stub import given, settings, st

from repro.configs import get_reduced
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.optim.quantized import (
    BLOCK, adamw8bit_update, dequantize_blockwise, init_opt_state_q8,
    quantize_blockwise,
)
from repro.train.train_step import TrainHParams, make_train_step

KEY = jax.random.PRNGKey(0)


def _check_blockwise_roundtrip(n, mag):
    rng = np.random.RandomState(n)
    x = jnp.asarray(rng.randn(n).astype(np.float32)) * mag
    q, s = quantize_blockwise(x)
    back = dequantize_blockwise(q, s)
    # per-block error <= half step = absmax/254
    err = np.abs(np.asarray(back - x))
    pad = (-n) % BLOCK
    xa = np.pad(np.asarray(x), (0, pad)).reshape(-1, BLOCK)
    bound = np.abs(xa).max(1) / 127.0 * 0.5 + 1e-20
    ea = np.pad(err, (0, pad)).reshape(-1, BLOCK)
    assert (ea <= bound[:, None] + 1e-12).all()


@given(st.integers(1, 1000), st.floats(1e-6, 1e4))
@settings(max_examples=25, deadline=None)
def test_blockwise_roundtrip_error_bound(n, mag):
    _check_blockwise_roundtrip(n, mag)


def test_blockwise_roundtrip_error_bound_seeded():
    """Deterministic twin of the hypothesis property above, so tier-1
    exercises the same invariant in environments without hypothesis."""
    rng = np.random.RandomState(7)
    cases = [(1, 1e-6), (BLOCK, 1.0), (BLOCK + 1, 1e4), (1000, 3e-2)]
    cases += [(int(rng.randint(1, 1001)),
               float(10.0 ** rng.uniform(-6, 4))) for _ in range(12)]
    for n, mag in cases:
        _check_blockwise_roundtrip(n, mag)


def test_q8_matches_f32_update_direction():
    """One step from zero state: int8 and f32 AdamW must produce nearly
    identical updates (first step is exactly representable)."""
    rng = np.random.RandomState(0)
    params = {"w": jnp.asarray(rng.randn(64, 128), jnp.float32),
              "b": jnp.asarray(rng.randn(128), jnp.float32)}
    grads = {"w": jnp.asarray(rng.randn(64, 128), jnp.float32),
             "b": jnp.asarray(rng.randn(128), jnp.float32)}
    cfg = AdamWConfig()
    p1, _, g1 = adamw_update(params, grads, init_opt_state(params),
                             jnp.zeros((), jnp.int32), 1e-2, cfg)
    p2, _, g2 = adamw8bit_update(params, grads, init_opt_state_q8(params),
                                 jnp.zeros((), jnp.int32), 1e-2, cfg)
    np.testing.assert_allclose(float(g1), float(g2), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-4)


def test_q8_overfit_converges_like_f32():
    """20 steps on a repeated batch: int8-state AdamW must reach a loss
    within 10% of the f32 run (quantization noise is second-order)."""
    cfg = get_reduced("internlm2-1.8b")
    params0 = M.init_model_params(cfg, KEY, jnp.float32)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    batch = {"tokens": jax.random.randint(ks[0], (2, 64), 0, cfg.vocab_size),
             "targets": jax.random.randint(ks[1], (2, 64), 0, cfg.vocab_size)}
    finals = {}
    for impl in ("adamw", "adamw8bit"):
        hp = TrainHParams(lr=1e-3, warmup=2, total_steps=50, remat=None,
                          ce_chunk=32, opt_impl=impl)
        step = jax.jit(make_train_step(cfg, hp))
        params = params0
        opt = (init_opt_state(params) if impl == "adamw"
               else init_opt_state_q8(params))
        for i in range(20):
            params, opt, m = step(params, opt, batch, jnp.asarray(i))
        finals[impl] = float(m["loss"])
    assert finals["adamw8bit"] < finals["adamw"] * 1.1, finals


def test_q8_state_is_4x_smaller():
    params = {"w": jnp.zeros((1024, 1024), jnp.float32)}
    f32 = init_opt_state(params)
    q8 = init_opt_state_q8(params)
    f32_b = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(f32))
    q8_b = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(q8))
    assert q8_b < f32_b / 3.5


_MULTIDIM_SHAPES = [(7,), (3, 5), (2, 3, 130), (4, 256), (1, 1, 1)]


def _check_blockwise_multidim(shape, seed):
    """Last-axis blocking on arbitrary ranks (the sharding-preserving
    layout): round-trip error bounded, scale shape as documented."""
    from repro.optim.quantized import scale_shape
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(*shape).astype(np.float32))
    q, s = quantize_blockwise(x)
    assert q.shape == x.shape
    assert s.shape == scale_shape(shape)
    back = dequantize_blockwise(q, s)
    step = np.abs(np.asarray(x)).max() / 127.0 + 1e-20
    assert np.abs(np.asarray(back - x)).max() <= step * 0.5 + 1e-12


@given(st.sampled_from(_MULTIDIM_SHAPES), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_blockwise_multidim_roundtrip(shape, seed):
    _check_blockwise_multidim(shape, seed)


def test_blockwise_multidim_roundtrip_seeded():
    """Deterministic twin: every sampled shape, two seeds each."""
    for shape in _MULTIDIM_SHAPES:
        for seed in (0, 37):
            _check_blockwise_multidim(shape, seed)
