"""System smoke + integration tests.

Per-arch REDUCED-config smoke tests (deliverable f): same family/pattern/
feature flags as the full config, tiny widths, one forward/train step and one
decode step on CPU asserting output shapes + finiteness.  Full configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="jax-dependent suite; the no-jax CI leg covers the numpy fallbacks")
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_reduced, shapes_for
from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.train.train_step import TrainHParams, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def tiny_batch(cfg, *, train=True, seq=S):
    ks = jax.random.split(KEY, 3)
    if cfg.input_mode == "embeds":
        batch = {"embeds": jax.random.normal(ks[0], (B, seq, cfg.d_model),
                                             jnp.float32)}
    else:
        batch = {"tokens": jax.random.randint(ks[0], (B, seq), 0,
                                              cfg.vocab_size)}
    if train:
        batch["targets"] = jax.random.randint(ks[1], (B, seq), 0,
                                              cfg.vocab_size)
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (B, seq))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, seq))
    return batch


def _params(cfg):
    return M.init_model_params(cfg, KEY, jnp.float32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_train_step_smoke(arch):
    """One full train step (fwd+bwd+AdamW) on the reduced config: loss is a
    finite scalar, params keep shapes, grads actually change the params."""
    cfg = get_reduced(arch)
    params = _params(cfg)
    opt = init_opt_state(params)
    hp = TrainHParams(remat=None, ce_chunk=32, total_steps=10, warmup=1)
    step = jax.jit(make_train_step(cfg, hp))
    batch = tiny_batch(cfg)
    new_params, new_opt, metrics = step(params, opt, batch,
                                        jnp.zeros((), jnp.int32))
    assert np.isfinite(float(metrics["loss"])), metrics
    assert np.isfinite(float(metrics["grad_norm"]))
    # structure preserved and at least one leaf moved
    moved = False
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b.shape
        moved |= bool(jnp.any(a != b))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_smoke(arch):
    """prefill + one decode step: logits (B, V) and finite; caches advance."""
    cfg = get_reduced(arch)
    params = _params(cfg)
    batch = tiny_batch(cfg, train=False, seq=16)
    hidden, caches, plen = M.prefill(cfg, params, batch, max_len=32,
                                     cache_dtype=jnp.float32)
    assert hidden.shape == (B, 16, cfg.d_model)
    if cfg.input_mode == "embeds":
        step_batch = {"embeds": jax.random.normal(KEY, (B, 1, cfg.d_model))}
    else:
        step_batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    if cfg.mrope:
        step_batch["positions"] = jnp.full((3, B, 1), plen, jnp.int32)
    logits, new_caches = M.decode_step(cfg, params, step_batch, caches, plen)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_full_config_metadata(arch):
    """The FULL config is never allocated in tests, but its metadata must be
    self-consistent: param count in the right ballpark and abstract params
    constructible."""
    cfg = get_config(arch)
    n = cfg.param_count()
    # expected totals DERIVED FROM THE ASSIGNED HYPERPARAMETERS (the names
    # are labels; e.g. the assigned moonshot config — 48L, 64e x 1408 MoE in
    # every layer — totals ~28B, not the marketing 16B)
    expected = {
        "jamba-1.5-large-398b": 398e9, "moonshot-v1-16b-a3b": 28e9,
        "grok-1-314b": 314e9, "musicgen-medium": 1.5e9,
        "qwen2-vl-72b": 72e9, "mamba2-2.7b": 2.8e9,
        "internlm2-1.8b": 1.8e9, "gemma2-27b": 27e9,
        "llama3-405b": 405e9, "granite-20b": 20e9,
    }[arch]
    assert 0.6 * expected < n < 1.6 * expected, (arch, n, expected)
    abstract = M.abstract_model_params(cfg)
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(abstract))
    assert total == n
    assert cfg.active_param_count() <= n
    if cfg.moe is None:
        assert cfg.active_param_count() == n


def test_shape_assignment_coverage():
    """32 runnable cells: long_500k only for sub-quadratic archs (DESIGN.md
    §Arch-applicability)."""
    cells = {a: shapes_for(get_config(a)) for a in ARCH_IDS}
    n = sum(len(v) for v in cells.values())
    assert n == 32
    assert "long_500k" in cells["jamba-1.5-large-398b"]
    assert "long_500k" in cells["mamba2-2.7b"]
    for a in ("llama3-405b", "gemma2-27b", "granite-20b"):
        assert "long_500k" not in cells[a]


def test_train_step_microbatching_equivalence():
    """n_micro=2 gradient accumulation == single-batch step (same loss to
    fp32 tolerance)."""
    cfg = get_reduced("internlm2-1.8b")
    params = _params(cfg)
    opt = init_opt_state(params)
    batch = tiny_batch(cfg)
    outs = {}
    for n_micro in (1, 2):
        hp = TrainHParams(remat=None, ce_chunk=32, n_micro=n_micro)
        step = jax.jit(make_train_step(cfg, hp))
        p2, _, m = step(params, opt, batch, jnp.zeros((), jnp.int32))
        outs[n_micro] = (m["loss"], p2)
    np.testing.assert_allclose(outs[1][0], outs[2][0], rtol=1e-4)
    for a, b in zip(jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[2][1])):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-5)


def test_remat_policies_same_loss():
    cfg = get_reduced("gemma2-27b")
    params = _params(cfg)
    batch = tiny_batch(cfg)
    losses = []
    for remat in (None, "dots", "full"):
        loss, _ = M.loss_fn(cfg, params, batch, remat=remat, ce_chunk=32)
        losses.append(float(loss))
    np.testing.assert_allclose(losses[0], losses[1], rtol=1e-5)
    np.testing.assert_allclose(losses[0], losses[2], rtol=1e-5)


def test_loss_decreases_tiny_overfit():
    """20 steps on one repeated batch must reduce loss (end-to-end sanity of
    model+optimizer+schedule)."""
    cfg = get_reduced("internlm2-1.8b")
    params = _params(cfg)
    opt = init_opt_state(params)
    hp = TrainHParams(lr=1e-3, warmup=2, total_steps=50, remat=None,
                      ce_chunk=32)
    step = jax.jit(make_train_step(cfg, hp))
    batch = tiny_batch(cfg)
    first = last = None
    for i in range(20):
        params, opt, m = step(params, opt, batch, jnp.asarray(i))
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first * 0.9, (first, last)
