"""Examples are part of the public API surface — they must run green."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable] + args, env=env, cwd=ROOT,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stdout[-1500:] + "\n" + out.stderr[-2500:]
    return out.stdout


@pytest.mark.slow
def test_quickstart():
    out = _run(["examples/quickstart.py"])
    assert "fine ships a ~2-word diff" in out
    assert "residual = 28.0" in out


@pytest.mark.slow
def test_dsm_jacobi_converges():
    out = _run(["examples/dsm_jacobi.py", "--n", "24", "--iters", "400",
                "--workers", "2"])
    assert "converged" in out


@pytest.mark.slow
def test_train_lm_with_failure(tmp_path):
    out = _run(["examples/train_lm.py", "--steps", "16",
                "--inject-failure-at", "9",
                "--ckpt-dir", str(tmp_path / "ck")])
    assert "restarts=1" in out


@pytest.mark.slow
def test_serve_batch():
    out = _run(["examples/serve_batch.py", "--n-requests", "4",
                "--batch", "2"])
    assert "served 4 requests" in out
