"""KV-cache serving workload (fig8): the inference-traffic DSM adversary.

Covers the serving trace-fuzz family (skewed/bursty interval programs,
reference vs loop vs batched in lockstep, eviction-counter assertions),
the ``apps.kv_serving`` app itself across drivers/engines/backends, its
data-race-freedom under the detector, and the determinism of the request
stream + latency report the fig8 bench commits.
"""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

import trace_fuzz
from repro.core import RuntimeConfig, make_runtime
from repro.core.regc import Traffic
from repro.dsm.apps import gen_requests, kv_serving

ROOT = Path(__file__).resolve().parents[1]

N_SERVING_TRACES = 60

# slot geometry used by the app tests: 64-word pages, 8-word KV rows,
# 24-row slots -> 3-page slot stride; cache below one prompt's pages
APP_KW = dict(tok_words=8, max_tokens=24, attn_window=8, seed=3)
CFG = RuntimeConfig(page_words=64, cache_pages=2, model_mechanism=False)


def _assert_traffic_equal(a, b, ctx):
    for f in dataclasses.fields(Traffic):
        assert (getattr(a.traffic, f.name)
                == getattr(b.traffic, f.name)), (ctx, f.name)


def _report_key(rep):
    return (rep.steps, rep.prefill_tokens, rep.decode_tokens,
            rep.admit_spans, rep.admitted, rep.idle_slot_steps,
            rep.peak_queue,
            tuple((r.slot, r.admit_step, r.finish_step)
                  for r in rep.requests))


def test_fuzz_serving_traces_cross_runtime():
    """Serving family (masked admission spans, bursty prefill writes,
    Zipf-skewed windowed decode appends under slot-scale caches):
    reference vs loop vs batched in LOCKSTEP on every trace, with the
    aggregate counters proving the eviction machinery actually fired —
    the danger screen on wide prefills, batched eviction rounds on the
    sliding windows, and the span engine on the admission lock."""
    agg = {}
    for seed in range(N_SERVING_TRACES):
        stats = trace_fuzz.crosscheck(seed, family="serving")
        for k, v in stats.items():
            agg[k] = agg.get(k, 0) + v
    assert agg["batched_phases"] > N_SERVING_TRACES, agg
    assert agg["danger_vec_ops"] > 0, agg
    assert agg["danger_scalar_ops"] == 0, agg
    assert agg["evict_batch_rounds"] > 0, agg
    assert agg["span_all_calls"] > N_SERVING_TRACES // 2, agg


def test_fuzz_serving_jit_lockstep():
    """Serving family on 'pallas-jit': the fused flush chain under
    masked admission spans + slot-scale eviction pressure, reference vs
    loop vs batched in LOCKSTEP.  Sampled seeds by default; FUZZ_JIT=1
    runs the full serving corpus."""
    pytest.importorskip("jax")
    for seed in trace_fuzz.jit_seeds(N_SERVING_TRACES, (0, 3, 9)):
        trace_fuzz.crosscheck(seed, family="serving",
                              backends=("pallas-jit",))


def test_kv_serving_app_drivers_bit_equal():
    """The serving app across drivers: traffic field-for-field, clocks
    bit-equal, and the whole ServeReport — request latencies included —
    identical, with the paged-attention pressure counters live."""
    for W, n_req in ((4, 16), (16, 48)):
        runs = {}
        for driver in ("loop", "batched"):
            rt = make_runtime(W, CFG)
            rep = kv_serving(rt, n_req, driver=driver, **APP_KW)
            runs[driver] = (rt, rep)
        rt_l, rep_l = runs["loop"]
        rt_b, rep_b = runs["batched"]
        _assert_traffic_equal(rt_l, rt_b, W)
        np.testing.assert_array_equal(rt_l.clock, rt_b.clock)
        assert _report_key(rep_l) == _report_key(rep_b), W
        np.testing.assert_array_equal(rep_l.latencies(), rep_b.latencies())
        st = rt_b.stats
        assert st["danger_vec_ops"] > 0, (W, st)
        assert st["danger_scalar_ops"] == 0, (W, st)
        assert st["span_all_calls"] > 0, (W, st)
        assert rep_b.latencies().size == n_req


def test_kv_serving_matches_reference():
    """Scale engine vs the per-page reference on the serving app:
    traffic exact, clocks allclose (the exactness contract)."""
    for W in (3, 6):
        rt_s = make_runtime(W, CFG)
        rep_s = kv_serving(rt_s, 18, driver="batched", **APP_KW)
        rt_r = make_runtime(W, CFG, engine="reference", track_values=False)
        rep_r = kv_serving(rt_r, 18, driver="loop", **APP_KW)
        _assert_traffic_equal(rt_s, rt_r, W)
        np.testing.assert_allclose(rt_s.clock, rt_r.clock,
                                   rtol=1e-9, atol=1e-12)
        assert _report_key(rep_s) == _report_key(rep_r), W


def test_kv_serving_race_free():
    """Slot blocks are disjoint and the queue cell is lock-guarded, so
    the serving program is DRF: the detector must flag nothing, and as a
    pure observer must not move traffic or clocks."""
    base = make_runtime(8, CFG)
    kv_serving(base, 24, driver="batched", **APP_KW)
    det = make_runtime(8, CFG, detect_races=True)
    kv_serving(det, 24, driver="batched", **APP_KW)
    assert det.stats["race_ww"] == 0 and det.stats["race_rw"] == 0
    _assert_traffic_equal(base, det, "observer")
    np.testing.assert_array_equal(base.clock, det.clock)


def test_request_stream_deterministic_and_skewed():
    """The synthetic stream is a pure function of its seed, Zipf-skewed
    toward tenant 0, and bursty (some same-step arrival groups)."""
    a = gen_requests(200, n_tenants=8, seed=11)
    b = gen_requests(200, n_tenants=8, seed=11)
    assert [dataclasses.astuple(r) for r in a] == \
        [dataclasses.astuple(r) for r in b]
    counts = np.bincount([r.tenant for r in a], minlength=8)
    assert counts[0] == counts.max() and counts[0] > 200 // 8
    steps = [r.arrival_step for r in a]
    assert any(steps.count(s) > 1 for s in set(steps)), "no bursts"
    assert all(1 <= r.prompt_tokens and r.decode_tokens >= 1
               and r.prompt_tokens + r.decode_tokens <= 96 for r in a)


def test_kv_serving_report_deterministic():
    """Same seed twice -> identical report, down to float latencies."""
    reps = []
    for _ in range(2):
        rt = make_runtime(5, CFG)
        reps.append(kv_serving(rt, 20, driver="batched", **APP_KW))
    assert _report_key(reps[0]) == _report_key(reps[1])
    np.testing.assert_array_equal(reps[0].latencies(), reps[1].latencies())
    assert reps[0].latency_pct(99) >= reps[0].latency_pct(50) > 0
    assert reps[0].tokens_per_s() > 0


def test_committed_fig8_rows_driver_bit_equal():
    """The committed BENCH_scale.json fig8 rows: for every (protocol, W)
    pair the loop and batched rows carry identical modeled time, exact
    traffic, and identical srv_* workload counters — the both-drivers
    half of the bench exactness contract, pinned on the committed
    ground truth itself.  (srv_evict_rounds and the span/danger path
    counters are engine-path telemetry and legitimately differ by
    driver.)"""
    rows = json.loads((ROOT / "BENCH_scale.json").read_text())["rows"]
    fig8 = [r for r in rows if r["section"] == "fig8_kv_serving"]
    assert len(fig8) == 12, len(fig8)
    by_key = {}
    for r in fig8:
        by_key.setdefault((r["protocol"], r["W"]), {})[r["driver"]] = r
    shared = (["t_model_s", "total_bytes", "srv_requests",
               "srv_prefill_tok", "srv_decode_tok", "srv_steps",
               "srv_admit_spans", "srv_admitted", "srv_idle_slot_steps",
               "srv_peak_queue", "danger_vec", "danger_scalar"])
    for key, drv in by_key.items():
        assert set(drv) == {"loop", "batched"}, key
        for f in shared + [f for f in drv["loop"]
                           if f.startswith("tr_")]:
            assert drv["loop"][f] == drv["batched"][f], (key, f)
        assert drv["batched"]["srv_evict_rounds"] > 0, key
        assert drv["batched"]["danger_vec"] > 0, key


def test_kv_serving_backends_agree():
    """numpy vs pallas directory backends on the serving app: traffic
    and clocks identical (integer-exact plane kernels)."""
    pytest.importorskip("jax")
    runs = {}
    for backend in ("numpy", "pallas"):
        rt = make_runtime(4, CFG, backend=backend)
        rep = kv_serving(rt, 12, driver="batched", **APP_KW)
        runs[backend] = (rt, rep)
    _assert_traffic_equal(runs["numpy"][0], runs["pallas"][0], "backend")
    np.testing.assert_array_equal(runs["numpy"][0].clock,
                                  runs["pallas"][0].clock)
    assert _report_key(runs["numpy"][1]) == _report_key(runs["pallas"][1])
