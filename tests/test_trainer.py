"""End-to-end Trainer tests: checkpoint/restart after injected failure,
exact-resume determinism, and serving integration."""
import numpy as np
import pytest

jax = pytest.importorskip(
    "jax", reason="jax-dependent suite; the no-jax CI leg covers the numpy fallbacks")
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data import DataConfig
from repro.ft import FailureInjector
from repro.models import model as M
from repro.serve.decode import generate
from repro.train.train_step import TrainHParams
from repro.train.trainer import Trainer, TrainerConfig


def _mk_trainer(tmp_path, *, steps=12, ckpt_every=4, injector=None, seed=0):
    cfg = get_reduced("internlm2-1.8b")
    hp = TrainHParams(lr=1e-3, warmup=2, total_steps=steps, remat=None,
                      ce_chunk=32)
    tc = TrainerConfig(total_steps=steps, ckpt_every=ckpt_every,
                       ckpt_dir=str(tmp_path / "ckpts"), log_every=1000,
                       ckpt_async=True, seed=seed)
    data = DataConfig(kind="synthetic", vocab_size=cfg.vocab_size,
                      seq_len=32, global_batch=4)
    return Trainer(cfg, hp, tc, data, injector=injector,
                   log_fn=lambda *_: None)


def test_trainer_runs_and_checkpoints(tmp_path):
    out = _mk_trainer(tmp_path).run()
    assert out["step"] == 12
    assert len(out["history"]) == 12
    assert all(np.isfinite(h["loss"]) for h in out["history"])
    ckpts = sorted((tmp_path / "ckpts").glob("step_*"))
    assert ckpts, "no checkpoint written"


def test_trainer_survives_injected_failure(tmp_path):
    """Worker dies at step 9 -> restart from the step-8 checkpoint; the
    replayed history must end at the same step count with finite loss."""
    inj = FailureInjector(at_steps=[9])
    tr = _mk_trainer(tmp_path, injector=inj)
    out = tr.run()
    assert out["restarts"] == 1
    assert out["step"] == 12
    steps_seen = [h["step"] for h in out["history"]]
    assert steps_seen.count(9) == 1      # failed attempt raised BEFORE step 9 ran
    assert 8 in steps_seen


def test_restart_is_exact_replay(tmp_path):
    """Determinism of recovery: an uninterrupted run and a failed+restarted
    run converge to identical parameters (stateless-by-step data + fp32)."""
    ref = _mk_trainer(tmp_path / "a", steps=8, ckpt_every=4).run()
    inj = FailureInjector(at_steps=[6])
    rec = _mk_trainer(tmp_path / "b", steps=8, ckpt_every=4,
                      injector=inj).run()
    assert rec["restarts"] == 1
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(rec["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_trainer_loss_decreases_on_synthetic(tmp_path):
    out = _mk_trainer(tmp_path, steps=30, ckpt_every=100).run()
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert last < first, (first, last)


def test_generate_shapes_and_determinism():
    cfg = get_reduced("granite-20b")
    params = M.init_model_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    prompt = {"tokens": jnp.arange(12, dtype=jnp.int32).reshape(2, 6) % cfg.vocab_size}
    a = generate(cfg, params, prompt, max_new_tokens=5)
    b = generate(cfg, params, prompt, max_new_tokens=5)
    assert a.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
