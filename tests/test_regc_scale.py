"""Cross-validation: the vectorized scale engine must produce EXACTLY the
same protocol traffic as the reference RegCRuntime on random traces (the
scale engine is what the paper-figure benchmarks run at 256 workers)."""
import dataclasses

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:           # tier-1 env may lack hypothesis
    from _hypothesis_stub import given, settings, st

from repro.core import FINE_PROTO, IDEAL_PROTO, PAGE_PROTO, RegCRuntime
from repro.core.regc import Traffic
from repro.core.regc_scale import RegCScaleRuntime


@st.composite
def trace(draw):
    """A random program over 3 workers / 2 locks / 2 arrays."""
    n_ops = draw(st.integers(3, 25))
    ops = []
    depth = {w: [] for w in range(3)}
    for _ in range(n_ops):
        w = draw(st.integers(0, 2))
        kind = draw(st.sampled_from(
            ["read", "write", "acquire", "release", "barrier"]))
        if kind == "release":
            if not depth[w]:
                continue
            ops.append(("release", w, depth[w].pop()))
        elif kind == "acquire":
            if len(depth[w]) >= 2:
                continue
            lock = draw(st.integers(0, 1))
            depth[w].append(lock)
            ops.append(("acquire", w, lock))
        elif kind == "barrier":
            if any(depth.values()):
                continue            # barriers outside spans only
            ops.append(("barrier",))
        else:
            arr = draw(st.integers(0, 1))
            lo = draw(st.integers(0, 250))
            hi = draw(st.integers(lo + 1, min(lo + 120, 256)))
            ops.append((kind, w, arr, lo, hi))
    # close any open spans, final barrier
    for w in range(3):
        while depth[w]:
            ops.append(("release", w, depth[w].pop()))
    ops.append(("barrier",))
    return ops


def run_trace(rt, ops, arrays):
    for op in ops:
        if op[0] == "read":
            rt.read(op[1], arrays[op[2]], op[3], op[4])
        elif op[0] == "write":
            rt.write(op[1], arrays[op[2]], op[3], op[4],
                     np.ones(op[4] - op[3], np.float32)
                     if getattr(rt, "track_values", False) else None)
        elif op[0] == "acquire":
            rt.acquire(op[1], op[2])
        elif op[0] == "release":
            rt.release(op[1], op[2])
        else:
            rt.barrier()
    return rt


def _trace_np(rng) -> list:
    """Numpy-seeded mirror of the ``trace()`` strategy (same op mix and
    span/barrier constraints) for the deterministic twin."""
    ops = []
    depth = {w: [] for w in range(3)}
    kinds = ["read", "write", "acquire", "release", "barrier"]
    for _ in range(int(rng.randint(3, 26))):
        w = int(rng.randint(0, 3))
        kind = kinds[int(rng.randint(len(kinds)))]
        if kind == "release":
            if not depth[w]:
                continue
            ops.append(("release", w, depth[w].pop()))
        elif kind == "acquire":
            if len(depth[w]) >= 2:
                continue
            lock = int(rng.randint(0, 2))
            depth[w].append(lock)
            ops.append(("acquire", w, lock))
        elif kind == "barrier":
            if any(depth.values()):
                continue
            ops.append(("barrier",))
        else:
            arr = int(rng.randint(0, 2))
            lo = int(rng.randint(0, 251))
            hi = int(rng.randint(lo + 1, min(lo + 120, 256) + 1))
            ops.append((kind, w, arr, lo, hi))
    for w in range(3):
        while depth[w]:
            ops.append(("release", w, depth[w].pop()))
    ops.append(("barrier",))
    return ops


def _check_scale_engine_matches_reference(ops, proto, page_words):
    ref = RegCRuntime(3, page_words=page_words, protocol=proto,
                      track_values=False, prefetch=1)
    fast = RegCScaleRuntime(3, page_words=page_words, protocol=proto,
                            prefetch=1, model_mechanism=False)
    ga_r = [ref.alloc(256), ref.alloc(256)]
    ga_f = [fast.alloc(256), fast.alloc(256)]
    run_trace(ref, ops, ga_r)
    run_trace(fast, ops, ga_f)
    for f in dataclasses.fields(Traffic):
        assert getattr(ref.traffic, f.name) == getattr(fast.traffic, f.name), (
            f.name, ref.traffic, fast.traffic)
    # modeled clocks agree too (identical charging rules)
    np.testing.assert_allclose(fast.clock, ref.clock, rtol=1e-9, atol=1e-12)


@given(trace(), st.sampled_from([FINE_PROTO, PAGE_PROTO, IDEAL_PROTO]),
       st.sampled_from([32, 64]))
@settings(max_examples=60, deadline=None)
def test_scale_engine_traffic_matches_reference(ops, proto, page_words):
    _check_scale_engine_matches_reference(ops, proto, page_words)


def test_scale_engine_traffic_matches_reference_seeded():
    """Deterministic twin: seeded traces cycling every protocol and page
    size, so the cross-validation runs under plain pytest too."""
    protos = [FINE_PROTO, PAGE_PROTO, IDEAL_PROTO]
    for seed in range(18):
        ops = _trace_np(np.random.RandomState(seed))
        _check_scale_engine_matches_reference(
            ops, protos[seed % 3], 32 if seed % 2 == 0 else 64)


def test_scale_engine_capacity_eviction_monotone():
    """Smaller cache -> at least as many fetches (capacity misses)."""
    fetches = {}
    for cap in (None, 8, 2):
        rt = RegCScaleRuntime(1, page_words=64, cache_pages=cap,
                              model_mechanism=False, prefetch=0)
        ga = rt.alloc(64 * 16)
        for sweep in range(3):
            for p in range(16):
                rt.read(0, ga, p * 64, p * 64 + 64)
        fetches[cap] = rt.traffic.page_fetches
    assert fetches[None] <= fetches[8] <= fetches[2]
    assert fetches[2] == 3 * 16          # thrashing: every page refetched


def test_mechanism_costs_fine_vs_page():
    """The paper's §IV mechanisms: instrumented stores charge per word
    (fine), write faults charge per page-epoch (page)."""
    def run(proto):
        rt = RegCScaleRuntime(1, page_words=1024, protocol=proto,
                              model_mechanism=True)
        ga = rt.alloc(8 * 1024)
        for it in range(4):
            rt.write(0, ga, 0, 8 * 1024)
            rt.barrier()
        return rt

    fine = run(FINE_PROTO)
    page = run(PAGE_PROTO)
    # fine pays instrumentation on every stored word, all iterations
    from repro.core.regc_scale import FAULT_S, INSTR_S_PER_WORD
    assert fine.time >= 4 * 8 * 1024 * INSTR_S_PER_WORD
    # page pays one fault per page per write epoch (flush re-arms)
    assert page.time >= 4 * 8 * FAULT_S
    # traffic identical (same ordinary-region protocol)
    assert fine.traffic.writeback_bytes == page.traffic.writeback_bytes


def _assert_same_traffic(ref, fast):
    for f in dataclasses.fields(Traffic):
        assert getattr(ref.traffic, f.name) == getattr(fast.traffic, f.name), (
            f.name, ref.traffic, fast.traffic)
    np.testing.assert_allclose(fast.clock, ref.clock, rtol=1e-9, atol=1e-12)


def _pair(proto, page_words=64, cache_pages=None, W=3):
    ref = RegCRuntime(W, page_words=page_words, protocol=proto,
                      track_values=False, prefetch=1, cache_pages=cache_pages)
    fast = RegCScaleRuntime(W, page_words=page_words, protocol=proto,
                            prefetch=1, model_mechanism=False,
                            cache_pages=cache_pages)
    return ref, fast


# ---------------------------------------------------------------------------
# directory-specific deterministic traces (no hypothesis needed): false
# sharing, cache spill, multi-lock — the cross-worker paths the directory
# engine vectorizes.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("proto", [FINE_PROTO, PAGE_PROTO, IDEAL_PROTO])
def test_directory_trace_false_sharing(proto):
    """Three workers write disjoint halves/thirds of the SAME page in
    ordinary regions; flushes must invalidate exactly the reference's
    sharer set (order-sensitive: first flusher sweeps, later flushers hit
    an already-invalidated page)."""
    ref, fast = _pair(proto)
    for rt in (ref, fast):
        ga = rt.alloc(256)
        for it in range(3):
            for w in range(3):
                rt.read(w, ga, 0, 64)          # everyone shares page 0
                rt.write(w, ga, w * 20, w * 20 + 20)   # disjoint words
            rt.barrier()
            rt.write(0, ga, 0, 10)
            rt.acquire(1, 0)                   # acquire-time flush of w1?
            rt.release(1, 0)
            rt.write(1, ga, 10, 20)
            rt.barrier()
    _assert_same_traffic(ref, fast)


@pytest.mark.parametrize("proto", [FINE_PROTO, PAGE_PROTO])
def test_directory_trace_cache_spill(proto):
    """Working set 2x the cache: every epoch re-streams all pages, so the
    batched watermark eviction must reproduce the reference's per-op LRU
    (fetch counts AND dirty-victim writebacks)."""
    ref, fast = _pair(proto, page_words=64, cache_pages=6, W=2)
    for rt in (ref, fast):
        a = rt.alloc(64 * 8)
        b = rt.alloc(64 * 8)
        for sweep in range(3):
            for w in range(2):
                for blk in range(4):
                    rt.read(w, a, blk * 128, blk * 128 + 128)
                    rt.write(w, b, blk * 128, blk * 128 + 128)
            rt.barrier()
    _assert_same_traffic(ref, fast)


@pytest.mark.parametrize("proto", [FINE_PROTO, PAGE_PROTO])
def test_directory_trace_multi_lock(proto):
    """Interleaved spans on three locks with overlapping pages: notice
    logs must coalesce per (lock, version-range, page) exactly like the
    reference's nested dict replay."""
    ref, fast = _pair(proto, page_words=32)
    for rt in (ref, fast):
        ga = rt.alloc(256)
        for it in range(3):
            for w in range(3):
                with rt.span(w, lock_id=w % 2):
                    rt.write(w, ga, 10 * w, 10 * w + 8)
                    rt.write(w, ga, 100, 104)          # contended words
            with rt.span(0, lock_id=2):
                rt.write(0, ga, 200, 230)
            rt.read(1, ga, 96, 110)
            rt.barrier()
    _assert_same_traffic(ref, fast)


# ---------------------------------------------------------------------------
# phase_all (worker-axis batched driver): W-sweep equivalence vs the
# per-worker `phase` path on seeded false-sharing / spill / multi-lock
# phase traces.  Traffic must be field-for-field identical and the modeled
# clocks bit-equal (the batched driver replays the same per-worker charge
# sequence, just op-major — see regc_scale.phase_all).
# ---------------------------------------------------------------------------

W_SWEEP = [2, 4, 16, 64]


def _assert_drivers_equal(loop_rt, batched_rt, ctx=""):
    for f in dataclasses.fields(Traffic):
        assert (getattr(loop_rt.traffic, f.name)
                == getattr(batched_rt.traffic, f.name)), (
            ctx, f.name, loop_rt.traffic, batched_rt.traffic)
    np.testing.assert_allclose(batched_rt.clock, loop_rt.clock,
                               rtol=0, atol=0)


def _drive(rt, phases, driver):
    """phases: list of (reads, writes, spans) where reads/writes are
    (ga_idx, lo(W,), hi(W,)) and spans is a list of (lock, ga_idx, lo, hi)
    per-worker critical-section writes run after the bulk phase."""
    gas = [rt.alloc(64 * 64), rt.alloc(64 * 64)]
    W = rt.W
    for reads, writes, spans in phases:
        r = [(gas[g], lo, hi) for g, lo, hi in reads]
        wr = [(gas[g], lo, hi) for g, lo, hi in writes]
        flops = 7.0 * np.arange(1, W + 1)
        if driver == "batched":
            rt.phase_all(reads=r, writes=wr, flops=flops, mem_bytes=64.0)
        else:
            for w in range(W):
                rt.phase(w,
                         reads=[(ga, int(lo[w]), int(hi[w]))
                                for ga, lo, hi in r],
                         writes=[(ga, int(lo[w]), int(hi[w]))
                                 for ga, lo, hi in wr],
                         flops=float(flops[w]), mem_bytes=64.0)
        for lock, g, lo, hi in spans:
            for w in range(W):
                with rt.span(w, lock):
                    rt.read(w, gas[g], lo, hi)
                    rt.write(w, gas[g], lo, hi)
        rt.barrier()
    return rt


def _seeded_phases(kind, W, seed=0):
    rng = np.random.default_rng(seed)
    n_words = 64 * 64
    phases = []
    for it in range(4):
        if kind == "false_sharing":
            # all workers share low pages; writes are disjoint slivers of
            # the SAME pages (sub-page intervals) + an overlapping halo
            sl = 3 + int(rng.integers(0, 5))
            lo_w = np.arange(W, dtype=np.int64) * sl
            reads = [(0, np.zeros(W, np.int64),
                      np.full(W, 64 + int(rng.integers(0, 64)), np.int64))]
            writes = [(0, lo_w, lo_w + sl)]
            spans = []
        elif kind == "multi_lock":
            blk = n_words // W
            lo_b = np.arange(W, dtype=np.int64) * blk
            reads = [(1, np.maximum(lo_b - 37, 0),
                      np.minimum(lo_b + blk + 41, n_words))]
            writes = [(1, lo_b, lo_b + blk)]
            spans = [(it % 2, 0, 100, 104), (2, 0, 200, 202 + it)]
        else:                      # spill: stream blocks >> cache
            blk = n_words // W
            lo_b = np.arange(W, dtype=np.int64) * blk
            reads = [(0, lo_b, lo_b + blk)]
            writes = [(1, lo_b + int(rng.integers(0, 7)),
                       lo_b + blk - int(rng.integers(0, 5)))]
            spans = []
        phases.append((reads, writes, spans))
    return phases


@pytest.mark.parametrize("W", W_SWEEP)
@pytest.mark.parametrize("proto", [FINE_PROTO, PAGE_PROTO, IDEAL_PROTO])
@pytest.mark.parametrize("kind", ["false_sharing", "multi_lock"])
def test_phase_all_matches_phase(W, proto, kind):
    rts = {}
    for driver in ("loop", "batched"):
        rt = RegCScaleRuntime(W, page_words=64, protocol=proto, prefetch=1,
                              model_mechanism=True)
        _drive(rt, _seeded_phases(kind, W, seed=W), driver)
        rts[driver] = rt
    _assert_drivers_equal(rts["loop"], rts["batched"], (W, proto, kind))


@pytest.mark.parametrize("W", W_SWEEP)
@pytest.mark.parametrize("cache_pages", [6, 16, 10 ** 6])
def test_phase_all_matches_phase_spill(W, cache_pages):
    """Small caches make eviction possible (batched multi-worker eviction
    engine / residual replay); the huge cache exercises the batched
    tick/incache bookkeeping — both must reproduce the per-worker path
    exactly."""
    rts = {}
    for driver in ("loop", "batched"):
        rt = RegCScaleRuntime(W, page_words=64, protocol=FINE_PROTO,
                              prefetch=1, model_mechanism=False,
                              cache_pages=cache_pages)
        _drive(rt, _seeded_phases("spill", W, seed=W), driver)
        rts[driver] = rt
    _assert_drivers_equal(rts["loop"], rts["batched"], (W, cache_pages))


# ---------------------------------------------------------------------------
# spill-regime W-sweep {2..256}: the batched eviction engine (no
# _assume_spill latch — eviction-capable phases stay on the vectorized
# path, residual workers replay tick-ordered) must stay bit-equal to the
# loop driver at every scale, on disjoint-block streaming (fully batched)
# AND rotating-block (residual-replay) spill workloads.
# ---------------------------------------------------------------------------

W_SWEEP_SPILL = [2, 4, 16, 64, 256]


@pytest.mark.parametrize("W", W_SWEEP_SPILL)
@pytest.mark.parametrize("proto", [FINE_PROTO, PAGE_PROTO])
def test_batched_eviction_w_sweep_streaming(W, proto):
    """Disjoint-block streaming spill (working set >> cache): phases stay
    fully batched — no residual replay — with vectorized eviction, and
    traffic/clocks are bit-equal to the loop driver."""
    from repro.dsm.apps import stream_triad
    n = 64 * 8 * W                     # 8 pages/worker/array, cache 13
    rts = {}
    for driver in ("loop", "batched"):
        rt = RegCScaleRuntime(W, page_words=64, protocol=proto, prefetch=1,
                              model_mechanism=False, cache_pages=13)
        stream_triad(rt, n, 3, driver=driver)
        rts[driver] = rt
    _assert_drivers_equal(rts["loop"], rts["batched"], (W, proto))
    if W >= 4:                # tiny row sets take the per-worker shortcut
        assert rts["batched"].stats["evict_batch_rounds"] > 0
    assert rts["batched"].stats["residual_replays"] == 0, \
        "disjoint blocks must not be classed as interacting"


@pytest.mark.parametrize("W", W_SWEEP_SPILL)
def test_batched_eviction_w_sweep_rotating(W):
    """Rotating-block spill: each worker's dirty block lands inside its
    neighbours' reach, so the window-disjointness analysis must route the
    interacting workers through the tick-ordered residual replay — and
    stay bit-equal to the loop driver."""
    from repro.dsm.apps import stream_spill
    rts = {}
    for driver in ("loop", "batched"):
        rt = RegCScaleRuntime(W, page_words=64, protocol=FINE_PROTO,
                              prefetch=1, model_mechanism=False,
                              cache_pages=11)
        stream_spill(rt, 64 * 6 * W, 2, sweeps=2, driver=driver)
        rts[driver] = rt
    _assert_drivers_equal(rts["loop"], rts["batched"], W)
    assert rts["batched"].stats["residual_replays"] > 0


def test_batched_eviction_merged_round_row_order():
    """Regression: mixed front-run lengths split round-1 eviction into
    two lockstep groups whose leftovers concatenate group-major — a
    PERMUTED row set ([0,2,4,6,1,3,5,7]) that spans the whole axis.  The
    merged round-2 group must still align per-row charges with the
    plane's row order (rows re-sorted; ``row_block`` proves unit-step
    contiguity instead of inferring it from size/bounds), or eviction
    writebacks land on the wrong workers' clocks — visible only BEFORE a
    barrier joins the clocks."""
    W, pw, blk = 8, 16, 16
    n = pw * blk * W
    rts = {}
    for driver in ("loop", "batched"):
        rt = RegCScaleRuntime(W, page_words=pw, protocol=FINE_PROTO,
                              prefetch=0, model_mechanism=False,
                              cache_pages=10)
        A = rt.alloc(n)
        ids = np.arange(W, dtype=np.int64)
        base = ids * blk * pw
        L1 = np.where(ids % 2 == 0, 2 * pw, 3 * pw)

        def ph(reads=(), writes=(), rt=rt, driver=driver):
            if driver == "batched":
                rt.phase_all(reads=reads, writes=writes)
            else:
                for w in range(rt.W):
                    rt.phase(w,
                             reads=[(ga, int(lo[w]), int(hi[w]))
                                    for ga, lo, hi in reads],
                             writes=[(ga, int(lo[w]), int(hi[w]))
                                     for ga, lo, hi in writes])

        ph(reads=[(A, base, base + L1)])          # 2-page vs 3-page runs
        ph(writes=[(A, base + 8 * pw, base + 16 * pw)])
        for w in range(1, W, 2):                  # odd rows: dirty flushed
            rt.acquire(w, 0)
            rt.release(w, 0)
        ph(reads=[(A, base + 3 * pw, base + 6 * pw)])   # merged round
        rts[driver] = rt
    # NO barrier: compare raw per-worker clocks
    _assert_drivers_equal(rts["loop"], rts["batched"], "merged-round")


# ---------------------------------------------------------------------------
# no drift vs the committed PR 2 benchmark CSVs: removing the
# _assume_spill latch must not change any modeled time or traffic —
# eviction-free points AND spill points are re-derived here and compared
# against the committed artifacts/bench rows field-for-field.
# ---------------------------------------------------------------------------


def _bench_rows(name):
    import csv
    from pathlib import Path
    path = Path(__file__).resolve().parent.parent / "artifacts/bench" / name
    if not path.exists():
        pytest.skip(f"committed bench CSV {name} not present")
    with open(path) as fh:
        return list(csv.DictReader(fh))


@pytest.mark.parametrize("p,figure,series", [
    (4, "fig2_strong", "samhita"),
    (64, "fig2_strong", "samhita_page"),
    (8, "fig4_spill", "samhita_fits"),
    (8, "fig4_spill", "samhita_spills"),
])
def test_no_drift_vs_committed_stream_csv(p, figure, series):
    """Re-derive committed stream-triad points (iters as recorded in
    BENCH_scale.json meta) on BOTH drivers, through the benchmark's own
    runtime factory and section constants: modeled time and exact traffic
    must match the committed CSVs to the digit."""
    import json
    from pathlib import Path
    from benchmarks import stream_triad as st_bench
    from benchmarks.common import make_rt
    from repro.dsm.apps import stream_triad
    root = Path(__file__).resolve().parent.parent
    meta = json.loads((root / "BENCH_scale.json").read_text())["meta"]
    iters = int(meta.get("iters", 4))
    kw = {}
    if figure == "fig4_spill":
        iters = st_bench.spill_iters(iters)
        kw["cache_pages"] = st_bench.SPILL_CACHE_PAGES
    rows = [r for r in _bench_rows("stream_triad.csv")
            if r["figure"] == figure and r["series"] == series
            and int(r["p"]) == p]
    assert rows, (figure, series, p)
    row = rows[0]
    n = int(row["n"])
    series_key = series if series in ("samhita", "samhita_page") \
        else "samhita"                 # fig4 tags resolve like _point()
    for driver in ("loop", "batched"):
        rt = make_rt(series_key, p, **kw)
        stream_triad(rt, n, iters, driver=driver)
        assert rt.traffic.total_bytes == int(row["net_bytes"]), driver
        assert round(rt.time, 6) == float(row["t_model_s"]), driver


@pytest.mark.parametrize("W", W_SWEEP)
def test_phase_all_apps_end_to_end(W):
    """The three paper apps, batched vs loop driver, traffic identical
    and clocks bit-equal (the benchmark CSV bit-identity guarantee)."""
    from repro.dsm.apps import jacobi, molecular_dynamics, stream_triad
    for app, kw in ((stream_triad, dict(n=64 * 1024, iters=2)),
                    (jacobi, dict(n=256, iters=2, mode="lock")),
                    (molecular_dynamics,
                     dict(n_particles=128, iters=2, mode="reduction"))):
        rts = {}
        for driver in ("loop", "batched"):
            rt = RegCScaleRuntime(W, protocol=FINE_PROTO, prefetch=1,
                                  model_mechanism=True)
            app(rt, driver=driver, **kw)
            rts[driver] = rt
        _assert_drivers_equal(rts["loop"], rts["batched"],
                              (W, app.__name__))


def test_phase_all_rejects_open_spans():
    rt = RegCScaleRuntime(2, page_words=64)
    ga = rt.alloc(256)
    rt.acquire(0, 0)
    with pytest.raises(AssertionError):
        rt.phase_all(reads=[(ga, 0, 64)])
    with pytest.raises(AssertionError):
        rt.span_all(None, 1, reads=[(ga, 0, 64)])
    rt.release(0, 0)


@pytest.mark.parametrize("W", W_SWEEP)
@pytest.mark.parametrize("proto", [FINE_PROTO, PAGE_PROTO, IDEAL_PROTO])
def test_span_all_matches_span_loop(W, proto):
    """span_all vs the per-worker span loop on hot + striped + masked
    lock passes interleaved with dirty-producing bulk phases: traffic
    field-for-field identical, clocks bit-equal — checked after EVERY
    event (barriers would mask per-worker misattribution)."""
    pw = 64
    n = pw * 8 * W
    ids = np.arange(W, dtype=np.int64)
    lo_b, hi_b = ids * pw * 8, (ids + 1) * pw * 8
    stripe = (ids % max(2, W // 4)).astype(np.int64)
    s_lo, s_hi = stripe * pw, stripe * pw + 3
    zero, two = np.zeros(W, np.int64), np.full(W, 2, np.int64)
    odd = (ids % 2 == 1)
    if not odd.any():
        odd[0] = True
    rts, gas = {}, {}
    for driver in ("loop", "batched"):
        rt = RegCScaleRuntime(W, page_words=pw, protocol=proto, prefetch=1,
                              model_mechanism=True)
        rts[driver] = rt
        gas[driver] = (rt.alloc(n), rt.alloc(pw * W), rt.alloc(2))

    def span_pass(driver, locks, ga_i, lo, hi, mask=None):
        rt = rts[driver]
        acc = gas[driver][ga_i]
        if driver == "batched":
            rt.span_all(mask, locks, reads=[(acc, lo, hi)],
                        writes=[(acc, lo, hi)])
            return
        locks = np.broadcast_to(np.asarray(locks, np.int64), (W,))
        for w in range(W):
            if mask is not None and not mask[w]:
                continue
            rt.acquire(w, int(locks[w]))
            rt.read(w, acc, int(lo[w]), int(hi[w]))
            rt.write(w, acc, int(lo[w]), int(hi[w]))
            rt.release(w, int(locks[w]))

    for it in range(3):
        for driver, rt in rts.items():
            A = gas[driver][0]
            rt.phase_all(reads=[(A, lo_b, hi_b)], writes=[(A, lo_b, hi_b)]) \
                if driver == "batched" else [
                rt.phase(w, reads=[(A, int(lo_b[w]), int(hi_b[w]))],
                         writes=[(A, int(lo_b[w]), int(hi_b[w]))])
                for w in range(W)]
        for ev in (("hot",), ("striped",), ("masked",)):
            for driver in ("loop", "batched"):
                if ev[0] == "hot":
                    span_pass(driver, 90, 2, zero, two)
                elif ev[0] == "striped":
                    span_pass(driver, stripe, 1, s_lo, s_hi)
                else:
                    span_pass(driver, 91, 2, zero, two, mask=odd)
            np.testing.assert_allclose(
                rts["batched"].clock, rts["loop"].clock, rtol=0, atol=0,
                err_msg=f"{(W, proto, it)} {ev[0]}")
        for rt in rts.values():
            rt.barrier()
    _assert_drivers_equal(rts["loop"], rts["batched"], (W, proto))
    assert rts["batched"].stats["span_groups_vec"] > 0
    assert rts["batched"].stats["span_serial_workers"] == 0, \
        "uniform groups must resolve on the analytic span path"


def test_scale_fine_beats_page_on_small_span_updates():
    """Paper Table I / §V: consistency-region updates move diffs (fine) vs
    whole pages (page) — 64 workers, steady state (cold fetches amortized)."""
    totals = {}
    for proto in (FINE_PROTO, PAGE_PROTO):
        rt = RegCScaleRuntime(64, page_words=1024, protocol=proto,
                              model_mechanism=False)
        ga = rt.alloc(1024)
        base = None
        for it in range(8):
            for w in range(64):
                with rt.span(w, 0):
                    rt.write(w, ga, 3, 5)   # 2-word critical-section update
                rt.read(w, ga, 3, 5)
            if it == 0:
                base = rt.traffic.total_bytes      # cold-start iteration
        totals[proto] = rt.traffic.total_bytes - base
    assert totals[FINE_PROTO] < totals[PAGE_PROTO] / 5, totals
