"""Public-API redesign suite: ``RuntimeConfig``/``make_runtime`` vs the
legacy keyword constructors (bit-equal traffic/clocks on seeded traces),
validated-choice knob errors, and the ``Session`` façade vs the legacy
underscore drivers.
"""
import dataclasses

import numpy as np
import pytest

import trace_fuzz
from repro.core import (BACKENDS, DANGER_MODES, DRIVERS, ENGINES,
                        FINE_PROTO, PROTOCOLS, RegCRuntime,
                        RegCScaleRuntime, RuntimeConfig, check_choice,
                        make_runtime)
from repro.core.regc import Traffic
from repro.dsm.apps import _phase_driver, _reduce_all, _span_driver
from repro.dsm.session import Session, session


def _assert_traffic_equal(a, b, ctx):
    for f in dataclasses.fields(Traffic):
        assert (getattr(a.traffic, f.name)
                == getattr(b.traffic, f.name)), (ctx, f.name)


def _seeded_trace(seed):
    p = trace_fuzz.trace_params(seed)
    prog = trace_fuzz.gen_program(p["rng"], p["W"], p["n_words"],
                                  p["page_words"])
    return p, prog


def test_make_runtime_backcompat_scale():
    """Old-style keyword construction and RuntimeConfig-built scale
    runtimes produce bit-equal traffic, clocks, and stats on seeded
    fuzz traces."""
    for seed in (0, 1, 2, 5):
        p, prog = _seeded_trace(seed)
        kw = dict(page_words=p["page_words"], protocol=p["proto"],
                  prefetch=1, model_mechanism=False,
                  cache_pages=p["cache_pages"], fetch_batch=4)
        old = RegCScaleRuntime(p["W"], **kw)
        new = make_runtime(p["W"], RuntimeConfig(**kw))
        for rt in (old, new):
            trace_fuzz.run_program(
                rt, prog, [rt.alloc(p["n_words"]) for _ in range(2)],
                "batched")
        _assert_traffic_equal(old, new, seed)
        np.testing.assert_array_equal(old.clock, new.clock)
        assert old.stats == new.stats, seed


def test_make_runtime_backcompat_reference():
    """Same contract for the reference engine (scale-only knobs at
    their defaults are ignored by the factory, not mis-applied)."""
    for seed in (0, 3):
        p, prog = _seeded_trace(seed)
        kw = dict(page_words=p["page_words"], protocol=p["proto"],
                  prefetch=1, cache_pages=p["cache_pages"],
                  track_values=False)
        old = RegCRuntime(p["W"], **kw)
        new = make_runtime(p["W"], RuntimeConfig(**kw),
                           engine="reference")
        for rt in (old, new):
            trace_fuzz.run_program(
                rt, prog, [rt.alloc(p["n_words"]) for _ in range(2)],
                "ref")
        _assert_traffic_equal(old, new, seed)
        np.testing.assert_array_equal(old.clock, new.clock)


def test_make_runtime_overrides_and_errors():
    cfg = RuntimeConfig(page_words=64)
    rt = make_runtime(4, cfg, cache_pages=7, engine="scale")
    assert rt.page_words == 64 and rt.cache_pages == 7
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.page_words = 32
    with pytest.raises(ValueError, match="bogus_knob"):
        make_runtime(4, bogus_knob=1)
    with pytest.raises(ValueError) as ei:
        make_runtime(4, engine="jit")
    assert "'jit'" in str(ei.value) and "'scale'" in str(ei.value) \
        and "'reference'" in str(ei.value)
    # the reference engine refuses behavior-bearing fault hooks
    with pytest.raises(ValueError, match="chaos"):
        make_runtime(4, chaos=object(), engine="reference")


@pytest.mark.parametrize("name,bad,allowed,build", [
    ("protocol", "mesi", PROTOCOLS,
     lambda: RegCScaleRuntime(2, protocol="mesi")),
    ("protocol", "mesi", PROTOCOLS,
     lambda: RegCRuntime(2, protocol="mesi")),
    ("protocol", "mesi", PROTOCOLS,
     lambda: RuntimeConfig(protocol="mesi")),
    ("danger_mode", "fast", DANGER_MODES,
     lambda: RegCScaleRuntime(2, danger_mode="fast")),
    ("backend", "cuda", BACKENDS,
     lambda: RuntimeConfig(backend="cuda")),
    ("backend", "cuda", BACKENDS,
     lambda: RegCScaleRuntime(2, backend="cuda")),
    ("driver", "vector", DRIVERS,
     lambda: session(RegCScaleRuntime(2), driver="vector")),
])
def test_knob_validation_messages(name, bad, allowed, build):
    """Every string knob rejects unknown values with a ValueError that
    names the knob, the bad value, and the full allowed set."""
    with pytest.raises(ValueError) as ei:
        build()
    msg = str(ei.value)
    assert name in msg and repr(bad) in msg, msg
    for choice in allowed:
        assert repr(choice) in msg, (choice, msg)


def test_check_choice_passthrough():
    assert check_choice("engine", "scale", ENGINES) == "scale"


def test_session_vs_legacy_drivers_bit_equal():
    """Driving a runtime through the Session façade and through the
    legacy underscore helpers yields bit-equal traffic and clocks."""
    def run(legacy):
        rt = make_runtime(4, RuntimeConfig(page_words=32, cache_pages=6,
                                           model_mechanism=False))
        A = rt.alloc(32 * 24)
        acc = rt.alloc(2)
        lo = np.arange(4, dtype=np.int64) * 32 * 6
        hi = lo + 32 * 6
        zero, two = np.zeros(4, np.int64), np.full(4, 2, np.int64)
        if legacy:
            phase = _phase_driver(rt, "batched")
            span = _span_driver(rt, "batched")
            red = lambda name: _reduce_all(rt, name)
        else:
            s = session(rt, "batched")
            phase, span, red = s.phase, s.span, s.reduce
        for it in range(3):
            phase(reads=((A, lo, hi),), writes=((A, lo, hi),),
                  flops=2.0 * (hi - lo))
            span(0, reads=((acc, zero, two),), writes=((acc, zero, two),))
            red("resid")
            rt.barrier()
        return rt
    old, new = run(True), run(False)
    _assert_traffic_equal(old, new, "session")
    np.testing.assert_array_equal(old.clock, new.clock)
    assert old.stats == new.stats


def test_session_resolves_driver_and_rejects_impossible():
    ref = make_runtime(2, engine="reference")
    s = session(ref)
    assert isinstance(s, Session) and s.driver == "loop"
    with pytest.raises(ValueError, match="phase_all"):
        session(ref, "batched")
    assert session(RegCScaleRuntime(2)).driver == "batched"


def test_core_public_exports():
    import repro.core as core
    for name in core.__all__:
        assert getattr(core, name) is not None, name
    assert set(PROTOCOLS) == {"fine", "page", "ideal"}
    assert BACKENDS == ("numpy", "pallas", "pallas-jit")
    assert DANGER_MODES == ("vec", "scalar")
    assert DRIVERS == ("auto", "batched", "loop")
    assert ENGINES == ("scale", "reference")
    assert FINE_PROTO in PROTOCOLS
