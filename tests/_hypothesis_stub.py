"""Drop-in fallback for ``hypothesis`` so its absence only skips the
property-style tests, not whole modules (see requirements-dev.txt).

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_stub import given, settings, st

``@given(...)`` replaces the test body with ``pytest.importorskip``, so the
test reports the canonical "could not import 'hypothesis'" skip; strategy
constructors (including ``st.composite``) return inert placeholders that are
only ever evaluated at decoration time.
"""
import pytest

# number of property-style tests this stub skipped in the current run;
# tests/conftest.py reports it in the terminal summary so the absent
# hypothesis suites are visible instead of silently missing
SKIPPED = 0
DECORATED = 0


class _Strategies:
    @staticmethod
    def composite(fn):
        def strategy(*args, **kwargs):
            return None
        return strategy

    def __getattr__(self, name):
        def strategy(*args, **kwargs):
            return None
        return strategy


st = _Strategies()


def given(*_args, **_kwargs):
    def deco(fn):
        global DECORATED
        DECORATED += 1

        # zero-arg on purpose: the original signature holds strategy
        # parameters that pytest would otherwise resolve as fixtures
        def skipper():
            global SKIPPED
            SKIPPED += 1
            pytest.importorskip("hypothesis")
        skipper.__name__ = getattr(fn, "__name__", "test_skipped")
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn
