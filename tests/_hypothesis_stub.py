"""Drop-in fallback for ``hypothesis`` so its absence only skips the
property-style tests, not whole modules (see requirements-dev.txt).

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hypothesis_stub import given, settings, st

``@given(...)`` replaces the test body with ``pytest.importorskip``, so the
test reports the canonical "could not import 'hypothesis'" skip; strategy
constructors (including ``st.composite``) return inert placeholders that are
only ever evaluated at decoration time.
"""
import pytest


class _Strategies:
    @staticmethod
    def composite(fn):
        def strategy(*args, **kwargs):
            return None
        return strategy

    def __getattr__(self, name):
        def strategy(*args, **kwargs):
            return None
        return strategy


st = _Strategies()


def given(*_args, **_kwargs):
    def deco(fn):
        # zero-arg on purpose: the original signature holds strategy
        # parameters that pytest would otherwise resolve as fixtures
        def skipper():
            pytest.importorskip("hypothesis")
        skipper.__name__ = getattr(fn, "__name__", "test_skipped")
        skipper.__doc__ = fn.__doc__
        return skipper
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn
