"""Chaos trace-fuzz family (see ``trace_fuzz.chaos_crosscheck``): ≥100
seeded phase programs under deterministic message loss and injected
worker crashes, asserting the crash-recovery exactness contract on every
trace — uninjected loop vs batched in lockstep (traffic field-for-field,
clocks bit-equal, chaos counters identical), and each driver's
crash → restore-last-barrier-checkpoint → replay run bit-equal to its
uninjected baseline, including the full stats dict (the replayed suffix
re-takes the same engine paths and retry charges, not merely the same
totals).

The aggregate counters guard against silently-idle chaos: crashes,
dropped messages, invalidation retransmissions, replayed events, and
straggler flags must all fire across the corpus.
"""
import numpy as np
import pytest

import trace_fuzz
from repro.dsm.costmodel import ChaosNet
from repro.ft import FailureInjector, StragglerMonitor, WorkerFailure

N_CHAOS_TRACES = 104


def test_chaos_fuzz_traces_recovery_exact():
    agg = {}
    for seed in range(N_CHAOS_TRACES):
        stats = trace_fuzz.chaos_crosscheck(seed)
        for k, v in stats.items():
            agg[k] = agg.get(k, 0) + v
    # every trace injects >= 1 crash per driver...
    assert agg["crashes"] >= 2 * N_CHAOS_TRACES, agg
    # ... and the chaos paths must actually fire, not silently idle
    assert agg["chaos_msgs"] > 0, agg
    assert agg["chaos_drops"] > 0, agg
    assert agg["chaos_inval_retries"] > 0, agg
    assert agg["replayed_events"] > 0, agg
    assert agg["checkpoints"] > 2 * N_CHAOS_TRACES, agg
    assert agg["straggler_checks"] > 0, agg
    assert agg["straggler_flags"] > 0, agg
    # the corpus must cross the engine's hard paths under chaos too
    assert agg["span_all_calls"] > 0, agg
    assert agg["evict_batch_rounds"] > 0, agg
    assert agg["danger_ops"] > 0, agg


def test_chaos_fuzz_backends_agree():
    """numpy vs pallas directory backends under chaos + recovery (the
    kernels are integer-exact; retry charges depend only on counters, so
    both backends must stay in the same lockstep)."""
    pytest.importorskip("jax")
    for seed in (0, 1, 2, 5):
        trace_fuzz.chaos_crosscheck(seed, backends=("numpy", "pallas"))


def test_chaos_fuzz_jit_lockstep():
    """The fused flush chain ('pallas-jit') under chaos + checkpoint
    replay: crash recovery must land on the identical traffic/clocks as
    the uninjected jit baseline (jit_* dispatch counters sit outside the
    exactness bar — replay topology differs).  Sampled seeds by default;
    FUZZ_JIT=1 runs the full chaos corpus."""
    pytest.importorskip("jax")
    for seed in trace_fuzz.jit_seeds(N_CHAOS_TRACES, (0, 1, 4, 7)):
        trace_fuzz.chaos_crosscheck(seed, backends=("pallas-jit",))


def test_chaosnet_deterministic_and_seed_sensitive():
    stats_a, stats_b, stats_c = {}, {}, {}
    a = ChaosNet(seed=7, drop_rate=0.3)
    b = ChaosNet(seed=7, drop_rate=0.3)
    c = ChaosNet(seed=8, drop_rate=0.3)
    for net, st in ((a, stats_a), (b, stats_b), (c, stats_c)):
        net.bind(4, st)
    rows = np.arange(4)
    ea = np.concatenate([a.retry_rows(rows) for _ in range(50)])
    eb = np.concatenate([b.retry_rows(rows) for _ in range(50)])
    ec = np.concatenate([c.retry_rows(rows) for _ in range(50)])
    np.testing.assert_array_equal(ea, eb)
    assert stats_a == stats_b
    assert not np.array_equal(ea, ec), "seed must matter"
    assert stats_a["chaos_drops"] > 0
    # scalar path delegates to the vector path bit-for-bit
    d = ChaosNet(seed=7, drop_rate=0.3)
    d.bind(4, {})
    es = np.array([[d.retry1(int(w)) for w in rows] for _ in range(50)])
    np.testing.assert_array_equal(ea, es.ravel())


def test_chaosnet_state_roundtrip():
    """A restored ChaosNet continues the exact drop sequence — the
    property recovery-by-replay rests on."""
    a = ChaosNet(seed=3, drop_rate=0.25)
    a.bind(3, {})
    for _ in range(17):
        a.retry_rows(np.arange(3))
    a.inval_msgs(29)
    state = a.state_arrays()
    b = ChaosNet(**a.config())
    st_b = {}
    b.bind(3, st_b)
    b.load_state(state)
    st_a = {}
    a.bind(3, st_a)          # rebind to fresh stats for a clean diff
    for _ in range(9):
        np.testing.assert_array_equal(a.retry_rows(np.arange(3)),
                                      b.retry_rows(np.arange(3)))
    a.inval_msgs(13)
    b.inval_msgs(13)
    assert st_a == st_b


def test_failure_injector_targeting():
    # bare step: fires once, for whichever worker probes first
    inj = FailureInjector(at_steps=[3])
    inj.check(2, worker=0)
    with pytest.raises(WorkerFailure) as ei:
        inj.check(3, worker=1)
    assert (ei.value.step, ei.value.worker) == (3, 1)
    inj.check(3, worker=2)        # consumed — no refire

    # untargeted probe keeps the old behavior (worker 0)
    inj = FailureInjector(at_steps=[3])
    with pytest.raises(WorkerFailure) as ei:
        inj.check(3)
    assert ei.value.worker == 0

    # targeted entry only fires for its worker ...
    inj = FailureInjector(at_steps=[(4, 2)])
    inj.check(4, worker=1)
    with pytest.raises(WorkerFailure) as ei:
        inj.check(4, worker=2)
    assert ei.value.worker == 2
    # ... but an untargeted probe of a targeted step fires it too (the
    # step-driven chaos_tick path, where the runtime tracks no worker)
    inj = FailureInjector(at_steps=[(4, 2)])
    with pytest.raises(WorkerFailure) as ei:
        inj.check(4)
    assert ei.value.worker == 2

    # targeted beats bare when both match the probing worker
    inj = FailureInjector(at_steps=[(5, 1), 5])
    with pytest.raises(WorkerFailure) as ei:
        inj.check(5, worker=1)
    assert ei.value.worker == 1
    with pytest.raises(WorkerFailure) as ei:
        inj.check(5, worker=3)    # bare entry still pending
    assert ei.value.worker == 3


def test_straggler_monitor_state_roundtrip():
    rng = np.random.default_rng(0)
    a = StragglerMonitor(4, window=6, k=3.0, patience=2)
    for _ in range(10):
        d = rng.random(4) * 1e-3
        d[2] += 5e-3          # worker 2 drags
        a.observe(d)
    b = StragglerMonitor.from_state(a.state_arrays(), a.config())
    assert b.flagged_total == a.flagged_total
    for _ in range(6):
        d = rng.random(4) * 1e-3
        d[2] += 5e-3
        assert a.observe(d.copy()) == b.observe(d.copy())
    assert a.flagged_total == b.flagged_total > 0


def test_chaosnet_backoff_cap_exact_charge():
    """The cap bounds the per-level exponent: with every level forced
    to drop, each element exhausts all max_retries levels, so the
    charge is exactly sum_{k<R} timeout * backoff**min(k, cap)."""
    def charge(cap):
        net = ChaosNet(seed=0, drop_rate=0.5, timeout_s=1.0,
                       backoff=2.0, max_retries=10, backoff_cap=cap)
        net.bind(3, {})
        net._dropped = lambda lane, seq, level: np.ones(lane.shape, bool)
        return net.retry_rows(np.arange(3))

    # cap=3: 1+2+4+8 then six more capped 8s = 63
    np.testing.assert_array_equal(charge(3), np.full(3, 63.0))
    # default cap=6: 1+2+4+8+16+32+64 then three more 64s = 319
    np.testing.assert_array_equal(charge(6), np.full(3, 319.0))
    # cap=0: flat retransmission, 10 * timeout
    np.testing.assert_array_equal(charge(0), np.full(3, 10.0))


def test_chaosnet_default_cap_never_binds_stock_config():
    """Stock configs (max_retries=3 < cap=6) charge exactly the uncapped
    geometric sum — committed benches and checkpoints are unchanged."""
    assert ChaosNet().config()["backoff_cap"] == 6
    net = ChaosNet(seed=0, drop_rate=0.5, timeout_s=1.0, backoff=2.0,
                   max_retries=3)
    net.bind(2, {})
    net._dropped = lambda lane, seq, level: np.ones(lane.shape, bool)
    np.testing.assert_array_equal(net.retry_rows(np.arange(2)),
                                  np.full(2, 7.0))   # 1 + 2 + 4


def test_chaosnet_backoff_seconds_matches_retry_charge():
    """The static helper the cluster control plane charges real RPC
    retries through is the same capped term retry_rows applies."""
    assert ChaosNet.backoff_seconds(1.0, 2.0, 0) == 0.0
    assert ChaosNet.backoff_seconds(1.0, 2.0, 10, cap=3) == 63.0
    assert ChaosNet.backoff_seconds(1.0, 2.0, 10, cap=6) == 319.0
    net = ChaosNet(seed=0, drop_rate=0.5, timeout_s=0.25, backoff=3.0,
                   max_retries=5, backoff_cap=2)
    net.bind(1, {})
    net._dropped = lambda lane, seq, level: np.ones(lane.shape, bool)
    assert float(net.retry_rows(np.array([0]))[0]) == \
        ChaosNet.backoff_seconds(0.25, 3.0, 5, cap=2)


def test_mad_threshold_degenerate_window_guard():
    from repro.ft.runtime import mad_threshold
    import math
    # <2 samples: no spread to estimate -> floor (inf with no floor)
    assert mad_threshold([], 4.0, 0.5) == 0.5
    assert mad_threshold([0.3], 4.0, 0.5) == 0.5
    assert mad_threshold([], 4.0, 0.0) == math.inf
    assert mad_threshold([0.3], 4.0, 0.0) == math.inf
    # healthy window: median + k * MAD
    assert mad_threshold([1.0, 2.0, 3.0], 3.0, 0.0) == 2.0 + 3.0 * 1.0
    # zero-spread window: MAD floors at epsilon, not 0
    t = mad_threshold([2.0] * 9, 4.0, 0.0)
    assert 2.0 < t <= 2.0 + 4e-12


def test_straggler_monitor_tiny_window_no_flags():
    """A window=1 monitor (pool below the warm-up gate) must neither
    raise nor flag — the degenerate guard in action end-to-end."""
    m = StragglerMonitor(1, window=1, k=4.0, patience=1)
    for d in (1e-3, 5.0, 1e-3):
        assert m.observe([d]) == []
    assert m.flagged_total == 0
