"""Cross-runtime trace-fuzz suite (see ``trace_fuzz``): ≥200 seeded
random phase programs — skewed/shrinking/rotating intervals, multi-lock
spans, forced spill — asserting the full exactness contract on every
trace: reference vs scale traffic field-for-field, scale loop vs batched
clocks bit-equal, and (jax present) numpy vs pallas backends identical.

The aggregate path counters guard against the suite silently testing
nothing: the batched eviction engine, the per-op danger screen, and the
residual tick-ordered replay must all fire across the corpus.
"""
import dataclasses

import numpy as np
import pytest

import trace_fuzz
from repro.core.regc import Traffic

N_TRACES = 220


def test_fuzz_traces_cross_runtime():
    agg = {}
    for seed in range(N_TRACES):
        stats = trace_fuzz.crosscheck(seed)
        for k, v in stats.items():
            agg[k] = agg.get(k, 0) + v
    # the corpus must exercise every engine path, not silently bypass it
    assert agg["batched_phases"] > N_TRACES, agg
    assert agg["evict_batch_rounds"] > 0, agg
    assert agg["residual_replays"] > 0, agg
    assert agg["danger_ops"] > 0, agg


N_DANGER_TRACES = 80


def test_fuzz_danger_traces_cross_runtime():
    """Danger-dense family (rotating/sliding windows sized to force
    mid-op eviction): reference vs loop vs batched in LOCKSTEP, plus the
    vectorized refetch replay cross-validated against the forced scalar
    page walk on every trace.  The corpus must be absorbed by the
    vectorized schedule — the scalar fallback firing would mean the
    engine silently degraded."""
    agg = {}
    for seed in range(N_DANGER_TRACES):
        stats = trace_fuzz.crosscheck(seed, family="danger")
        for k, v in stats.items():
            agg[k] = agg.get(k, 0) + v
    assert agg["danger_vec_ops"] > N_DANGER_TRACES, agg
    assert agg["danger_scalar_ops"] == 0, agg
    assert agg["evict_batch_rounds"] > 0, agg
    assert agg["residual_replays"] > 0, agg
    # lockstep-uniform danger workers must share schedules somewhere in
    # the corpus (the rotating steady state), without absorbing the
    # whole corpus (isomorphism must actually be checked, not assumed)
    assert agg["danger_shared_ops"] > 0, agg
    assert agg["danger_shared_ops"] < agg["danger_vec_ops"], agg
    # the packed multi-row victim scan: near-isomorphic groups (one
    # clamped row breaking an otherwise-lockstep phase) must still
    # share — strictly more absorption than the all-or-nothing
    # whole-group check alone, which measured 431 on this corpus
    assert agg["danger_shared_ops"] > 431, agg
    assert agg["danger_subgroup_ops"] > 0, agg


N_SPAN_TRACES = 120


def test_fuzz_span_traces_cross_runtime():
    """Span-dense family (hot/striped/nested locks, masked subsets,
    spill forced inside spans): reference vs loop vs span_all in
    LOCKSTEP on every trace.  The corpus must drive every span-engine
    path: the analytic uniform-group pass (``span_groups_vec``), the
    per-worker Tier-B body, and the full-serial fallbacks — none may
    silently absorb the others' share."""
    agg = {}
    for seed in range(N_SPAN_TRACES):
        stats = trace_fuzz.crosscheck(seed, family="span")
        for k, v in stats.items():
            agg[k] = agg.get(k, 0) + v
    assert agg["span_all_calls"] > N_SPAN_TRACES, agg
    assert agg["span_groups_vec"] > N_SPAN_TRACES, agg
    assert agg["span_workers_vec"] > agg["span_groups_vec"], agg
    assert agg["span_serial_workers"] > 0, agg
    assert agg["span_serial_calls"] > 0, agg
    # the mixed-payload backlog rejection (see DIRECTORY.md "Why the
    # mixed-payload backlog stays serial") must actually be taken — the
    # counter proves the documented serial path is live, not dead code
    assert agg["span_backlog_serial"] > 0, agg
    # multi-region uniform groups (read one array, write another) must
    # be absorbed by the analytic path — these shapes counted
    # span_serial before the region-by-region grant-group algebra
    assert agg["span_multi_region_groups"] > 0, agg


def test_span_multi_region_groups_vectorize():
    """Uniform span groups whose ops touch MULTIPLE regions (read one
    array, write another) must resolve on the analytic grant-group
    path: these shapes fell back to the serial span walk before the
    region-by-region grant-group algebra, counting
    ``span_serial_workers``."""
    from repro.core import FINE_PROTO, PAGE_PROTO
    from repro.core.regc_scale import RegCScaleRuntime
    ids_cache = ((4, FINE_PROTO, None), (8, PAGE_PROTO, None),
                 (8, FINE_PROTO, 64))
    for W, proto, cache in ids_cache:
        runs = {}
        for driver in ("loop", "batched"):
            rt = RegCScaleRuntime(W, page_words=16, protocol=proto,
                                  prefetch=1, model_mechanism=False,
                                  cache_pages=cache)
            gas = [rt.alloc(16 * 64) for _ in range(3)]
            ids = np.arange(W, dtype=np.int64)
            locks = ids % 2
            # a second lock pair for the second span shape: each lock
            # must see the SAME payload on every re-acquire (the
            # repeated-uniform backlog relaxation), so the two
            # multi-region shapes may not share locks
            locks2 = 2 + ids % 2
            lo = np.where(locks == 0, 32, 96).astype(np.int64)
            hi = lo + 8
            prog = []
            for _ in range(4):
                prog.append(("phase", [],
                             [(0, ids * 64, ids * 64 + 32)], 0.0, 0.0))
                prog.append(("span_phase", None, locks,
                             [(1, lo, hi)], [(2, lo.copy(), hi.copy())]))
                prog.append(("span_phase", None, locks2,
                             [(1, lo, hi), (2, lo.copy(), hi.copy())],
                             [(1, lo.copy(), hi.copy())]))
                prog.append(("barrier",))
            trace_fuzz.run_program(rt, prog, gas, driver)
            runs[driver] = rt
        for f in dataclasses.fields(Traffic):
            assert (getattr(runs["loop"].traffic, f.name)
                    == getattr(runs["batched"].traffic, f.name)), \
                (W, proto, f.name)
        np.testing.assert_array_equal(runs["loop"].clock,
                                      runs["batched"].clock)
        st = runs["batched"].stats
        assert st["span_groups_vec"] > 0, (W, proto, st)
        assert st["span_serial_workers"] == 0, \
            "multi-region uniform groups must stay on the analytic path"
        assert st["span_serial_calls"] == 0, (W, proto, st)


def test_lock_contention_app_drivers_bit_equal():
    """The span-engine adversary app (hot lock + disjoint striping):
    the batched driver must absorb every span pass through the analytic
    group path — bit-equal to the per-worker loop, with zero serialized
    span workers."""
    from repro.core import FINE_PROTO, PAGE_PROTO
    from repro.core.regc_scale import RegCScaleRuntime
    from repro.dsm.apps import lock_contention
    for W, proto in ((4, FINE_PROTO), (16, PAGE_PROTO), (16, FINE_PROTO)):
        runs = {}
        for driver in ("loop", "batched"):
            rt = RegCScaleRuntime(W, page_words=64, protocol=proto,
                                  prefetch=1, model_mechanism=True)
            # sweeps=2: the second sweep re-acquires with unreplayed
            # backlog — the repeated-payload relaxation must absorb it
            lock_contention(rt, 64 * 16 * W, 3, n_locks=4, sweeps=2,
                            driver=driver)
            runs[driver] = rt
        for f in dataclasses.fields(Traffic):
            assert (getattr(runs["loop"].traffic, f.name)
                    == getattr(runs["batched"].traffic, f.name)), (W, f.name)
        np.testing.assert_array_equal(runs["loop"].clock,
                                      runs["batched"].clock)
        st = runs["batched"].stats
        assert st["span_groups_vec"] > 0, (W, proto)
        assert st["span_serial_workers"] == 0, \
            "uniform lock groups must stay on the analytic span path"
        assert st["span_serial_calls"] == 0, (W, proto)


def test_stream_refetch_app_drivers_bit_equal():
    """The mid-op refetch torture app (disjoint sliding windows): every
    op danger-flagged, zero residual replays — the batched driver must
    absorb it all through the vectorized schedule, bit-equal to loop."""
    from repro.core import FINE_PROTO
    from repro.core.regc_scale import RegCScaleRuntime
    from repro.dsm.apps import stream_refetch
    for W, cache in ((2, 9), (8, 20), (16, 13)):
        runs = {}
        for driver in ("loop", "batched"):
            rt = RegCScaleRuntime(W, page_words=64, protocol=FINE_PROTO,
                                  prefetch=1, model_mechanism=False,
                                  cache_pages=cache)
            stream_refetch(rt, 64 * 64 * W, 3, driver=driver)
            runs[driver] = rt
        for f in dataclasses.fields(Traffic):
            assert (getattr(runs["loop"].traffic, f.name)
                    == getattr(runs["batched"].traffic, f.name)), (W, f.name)
        np.testing.assert_array_equal(runs["loop"].clock,
                                      runs["batched"].clock)
        assert runs["batched"].stats["danger_vec_ops"] > 0, (W, cache)
        assert runs["batched"].stats["danger_scalar_ops"] == 0, (W, cache)
        assert runs["batched"].stats["residual_replays"] == 0, \
            "disjoint sliding windows must stay on the batched path"
        st = runs["batched"].stats
        assert st["danger_shared_ops"] == st["danger_ops"], \
            "lockstep-uniform windows must share one schedule"


def test_fuzz_traces_backends_agree():
    """numpy vs pallas directory backends on a fuzz subset: the packed
    bitmask kernels are integer-exact, so traffic and clocks must be
    identical (interpret mode on CPU makes this slow — subset only)."""
    pytest.importorskip("jax")
    for seed in (1, 3, 5, 7):
        p = trace_fuzz.trace_params(seed)
        prog = trace_fuzz.gen_program(p["rng"], p["W"], p["n_words"],
                                      p["page_words"], n_phases=4)
        runs = {}
        for backend in ("numpy", "pallas"):
            from repro.core.regc_scale import RegCScaleRuntime
            rt = RegCScaleRuntime(p["W"], page_words=p["page_words"],
                                  protocol=p["proto"], prefetch=1,
                                  model_mechanism=False,
                                  cache_pages=p["cache_pages"],
                                  backend=backend)
            trace_fuzz.run_program(
                rt, prog, [rt.alloc(p["n_words"]), rt.alloc(p["n_words"])],
                "batched")
            runs[backend] = rt
        for f in dataclasses.fields(Traffic):
            assert (getattr(runs["numpy"].traffic, f.name)
                    == getattr(runs["pallas"].traffic, f.name)), f.name
        np.testing.assert_array_equal(runs["numpy"].clock,
                                      runs["pallas"].clock)


def test_fuzz_traces_jit_lockstep():
    """'pallas-jit' (the fused flush chain + jitted rank-select) in full
    LOCKSTEP on the core trace families: loop vs batched clocks
    bit-equal after every event, traffic field-for-field vs the
    per-page reference oracle.  Sampled seeds per family by default;
    ``FUZZ_JIT=1`` runs each family's full corpus.  The aggregate
    dispatch counter proves the fused device program actually ran —
    zero dispatches would mean a silent numpy fallback."""
    pytest.importorskip("jax")
    agg = {}
    fams = (("mixed", N_TRACES, (1, 3, 6, 11)),
            ("danger", N_DANGER_TRACES, (0, 2, 7, 13)),
            ("span", N_SPAN_TRACES, (1, 4, 9, 17)))
    for fam, n, sample in fams:
        for seed in trace_fuzz.jit_seeds(n, sample):
            stats = trace_fuzz.crosscheck(seed, family=fam,
                                          backends=("pallas-jit",))
            for k, v in stats.items():
                agg[k] = agg.get(k, 0) + v
    assert agg["jit_dispatches"] > 0, agg


def test_fuzz_spill_app_drivers_bit_equal():
    """The spill-heavy app variant (rotating blocks — residual replay
    territory) stays bit-exact across drivers at several scales."""
    from repro.core import FINE_PROTO
    from repro.core.regc_scale import RegCScaleRuntime
    from repro.dsm.apps import stream_spill
    for W, cache in ((2, 5), (8, 9), (16, 17)):
        runs = {}
        for driver in ("loop", "batched"):
            rt = RegCScaleRuntime(W, page_words=32, protocol=FINE_PROTO,
                                  prefetch=1, model_mechanism=False,
                                  cache_pages=cache)
            stream_spill(rt, 32 * 16 * W, 3, driver=driver)
            runs[driver] = rt
        for f in dataclasses.fields(Traffic):
            assert (getattr(runs["loop"].traffic, f.name)
                    == getattr(runs["batched"].traffic, f.name)), (W, f.name)
        np.testing.assert_array_equal(runs["loop"].clock,
                                      runs["batched"].clock)
        assert runs["batched"].stats["residual_replays"] > 0, (W, cache)
