"""Race-detection fuzz suite (see ``trace_fuzz``): seeded racy/clean
programs cross-validated on every runtime/driver pairing with
``detect_races=True``.

The contract under test (see DIRECTORY.md "Race-detection contract"):

* every seeded-race trace is flagged, every clean trace is silent;
* loop vs batched report the IDENTICAL race set after every event, with
  traffic field-for-field and clocks bit-equal;
* the scalar per-event oracle (``RegCRuntime``) agrees with both;
* detection is a pure observer — a detection-off run is bit-equal in
  traffic and clocks;
* the race set survives mid-run chaos crash/recovery unchanged.
"""
import numpy as np
import pytest

import trace_fuzz

N_RACE_TRACES = 120


def test_fuzz_race_traces_detection():
    agg = {}
    for seed in range(N_RACE_TRACES):
        stats = trace_fuzz.race_crosscheck(seed)
        for k, v in stats.items():
            agg[k] = agg.get(k, 0) + v
    # both race kinds must be exercised across the corpus, and the
    # engine paths under detection must not silently idle
    assert agg["race_ww"] > 0, agg
    assert agg["race_rw"] > 0, agg
    assert agg["batched_phases"] > N_RACE_TRACES, agg
    assert agg["span_all_calls"] > 0, agg
    assert agg["danger_ops"] > 0, agg


def test_fuzz_race_traces_backends_agree():
    """numpy vs pallas directory backends under detection: the detector
    reads the same planes the protocol writes, so race sets, traffic
    and clocks must be identical (interpret mode is slow — subset)."""
    pytest.importorskip("jax")
    for seed in (1, 2, 5, 8):
        trace_fuzz.race_crosscheck(seed, backends=("numpy", "pallas"))


def test_fuzz_race_jit_lockstep():
    """Race detection over the fused flush chain ('pallas-jit'): the
    detector reads the same planes the jit-backed protocol writes, so
    race sets, traffic and clocks must stay in the same lockstep.
    Sampled seeds by default; FUZZ_JIT=1 runs the full race corpus."""
    pytest.importorskip("jax")
    for seed in trace_fuzz.jit_seeds(N_RACE_TRACES, (1, 4, 8, 13)):
        trace_fuzz.race_crosscheck(seed, backends=("pallas-jit",))


N_RACE_CHAOS_TRACES = 24


def test_fuzz_race_chaos_recovery():
    """Mid-run worker crashes + barrier-checkpoint replay must finish
    with the identical race set as the uninjected detection-on run —
    detector state (vector clocks, lock clocks, the race set itself)
    rides snapshot/from_snapshot."""
    agg = {}
    for seed in range(N_RACE_CHAOS_TRACES):
        stats = trace_fuzz.race_chaos_crosscheck(seed)
        for k, v in stats.items():
            agg[k] = agg.get(k, 0) + v
    assert agg["crashes"] >= N_RACE_CHAOS_TRACES, agg
    assert agg["race_ww"] + agg["race_rw"] > 0, agg


def _mk(W=2, **kw):
    from repro.core.regc_scale import RegCScaleRuntime
    kw.setdefault("page_words", 4)
    kw.setdefault("protocol", "fine")
    kw.setdefault("prefetch", 1)
    kw.setdefault("model_mechanism", False)
    kw.setdefault("detect_races", True)
    return RegCScaleRuntime(W, **kw)


def test_race_exact_tuples_scale_and_oracle():
    """Canonical race-tuple semantics, pinned on both runtimes: pages
    are flagged as ``(page, a, b, kind)`` with a < b, ``ww`` for
    write/write and ``rw`` for any read/write order, exactly once per
    (page, pair, kind)."""
    from repro.core import RegCRuntime

    def scenario(rt, ga):
        P = ga.page_lo
        rt.write(0, ga, 0, 4)
        rt.write(1, ga, 2, 6)          # pages 0 (W/W) and 1
        rt.read(0, ga, 4, 8)           # page 1: unordered vs w1's write
        rt.barrier()
        rt.write(0, ga, 32, 36)        # page 8 ...
        rt.barrier()
        rt.read(1, ga, 32, 36)         # ... read AFTER a barrier: clean
        return P

    rt = _mk()
    P = scenario(rt, rt.alloc(64))
    ref = RegCRuntime(2, page_words=4, protocol="fine", prefetch=1,
                      track_values=False, detect_races=True)
    P2 = scenario(ref, ref.alloc(64))
    want = {(P + 0, 0, 1, "ww"), (P + 1, 0, 1, "rw")}
    assert rt.races == want, rt.races
    assert ref.races == {(P2 + 0, 0, 1, "ww"), (P2 + 1, 0, 1, "rw")}
    assert rt.race_counts == {"race_ww": 1, "race_rw": 1}
    assert ref.race_counts == rt.race_counts


def test_race_lock_ordering():
    """The same page under the SAME lock is ordered (acquire joins the
    lock's clock); under DIFFERENT locks it races."""
    rt = _mk()
    ga = rt.alloc(32)
    P = ga.page_lo
    for w in (0, 1):
        rt.acquire(w, 0)
        rt.write(w, ga, 0, 4)
        rt.release(w, 0)
    assert not rt.races, rt.races
    for w, lk in ((0, 1), (1, 2)):
        rt.acquire(w, lk)
        rt.write(w, ga, 4, 8)
        rt.release(w, lk)
    assert rt.races == {(P + 1, 0, 1, "ww")}, rt.races


def test_race_detection_survives_eviction():
    """With a tiny cache the racing page is evicted and refetched
    between the two accesses — the vector-clock planes live in the
    directory window (which only grows), so the race is still exact."""
    rt = _mk(cache_pages=2)
    ga = rt.alloc(256)
    P = ga.page_lo
    rt.write(1, ga, 0, 4)              # page 0
    for k in range(8):                 # churn w1's cache: page 0 evicts
        rt.read(1, ga, 32 + 16 * k, 32 + 16 * k + 8)
    rt.read(0, ga, 0, 4)               # still unordered vs w1's write
    assert (P + 0, 0, 1, "rw") in rt.races, rt.races


def test_race_detection_pure_observer_batched():
    """phase_all with detection on vs off: traffic and clocks bit-equal
    (the acceptance-criteria observer check, in unit form)."""
    import dataclasses

    from repro.core.regc import Traffic
    runs = {}
    for detect in (False, True):
        rt = _mk(W=4, cache_pages=3, detect_races=detect)
        ga = rt.alloc(512)
        ids = np.arange(4, dtype=np.int64)
        for it in range(4):
            lo = ((ids + it) % 4) * 128
            # NO barrier between rotations: each handoff is unordered
            rt.phase_all(reads=[(ga, lo, lo + 64)],
                         writes=[(ga, lo, lo + 32)])
        rt.barrier()
        runs[detect] = rt
    for f in dataclasses.fields(Traffic):
        assert (getattr(runs[True].traffic, f.name)
                == getattr(runs[False].traffic, f.name)), f.name
    np.testing.assert_array_equal(runs[True].clock, runs[False].clock)
    assert runs[True].races, "rotating unsynchronized blocks must race"
