"""End-to-end training driver: a ~100M-parameter-class decoder trained for a
few hundred steps with the full substrate — data pipeline, RegC sync policy,
async checkpointing, failure injection + restart, straggler monitor.

The default size is CPU-container friendly (--profile tiny). On a real pod:

  python examples/train_lm.py --profile 100m --steps 300

trains the ~100M config; the step function is the same GSPMD train_step the
multi-pod dry-run lowers for the assigned architectures.

Run (CI size):  PYTHONPATH=src python examples/train_lm.py
"""
import argparse
import dataclasses

from repro.configs import get_reduced
from repro.configs.base import LayerSpec, ModelConfig
from repro.data import DataConfig
from repro.ft import FailureInjector
from repro.train.train_step import TrainHParams
from repro.train.trainer import Trainer, TrainerConfig

# a llama-family ~108M config (12L x 768d), runnable on one host
CONFIG_100M = ModelConfig(
    name="repro-108m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32_000,
    pattern=(LayerSpec("attn", "global", "dense"),),
    rope_theta=10_000.0, source="llama-arch scaled down",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--profile", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure-at", type=int, default=-1,
                    help="simulate a worker loss at this step (recovery demo)")
    args = ap.parse_args()

    if args.profile == "100m":
        cfg = CONFIG_100M
    else:
        cfg = dataclasses.replace(get_reduced("internlm2-1.8b", n_periods=2),
                                  name="repro-tiny")
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    hp = TrainHParams(lr=3e-4, warmup=max(2, args.steps // 10),
                      total_steps=args.steps, remat=None,
                      ce_chunk=min(512, args.seq_len))
    tc = TrainerConfig(total_steps=args.steps,
                       ckpt_every=max(10, args.steps // 4),
                       ckpt_dir=args.ckpt_dir, log_every=10)
    data = DataConfig(kind="synthetic", vocab_size=cfg.vocab_size,
                      seq_len=args.seq_len, global_batch=args.global_batch)
    injector = (FailureInjector(at_steps=[args.inject_failure_at])
                if args.inject_failure_at >= 0 else None)

    out = Trainer(cfg, hp, tc, data, injector=injector).run()
    losses = [h["loss"] for h in out["history"]]
    print(f"\nsteps={out['step']} restarts={out['restarts']}")
    print(f"loss: first5={sum(losses[:5])/5:.4f} "
          f"last5={sum(losses[-5:])/5:.4f}")
    stragglers = sum(1 for h in out["history"] if h["straggler"])
    print(f"straggler flags: {stragglers}")
    assert losses[-1] < losses[0], "training diverged"


if __name__ == "__main__":
    main()
