"""Quickstart: the RegC public API in five minutes.

1. The consistency model itself (spans, barriers, the two protocols),
   built through the one public entry point: ``RuntimeConfig`` +
   ``make_runtime``.
2. The paper's reduction extension.
3. The ``Session`` façade — the portable way to drive SPMD phases and
   spans (same program text on the reference oracle and the vectorized
   scale engine), shown on the KV-cache serving workload.
4. RegC as a training-sync policy on a real model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import FINE_PROTO, PAGE_PROTO, RuntimeConfig, make_runtime
from repro.dsm.apps import kv_serving
from repro.dsm.session import session
from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.train.train_step import TrainHParams, make_train_step


def demo_consistency_model():
    print("== 1. regional consistency: spans make critical-section stores "
          "visible ==")
    for proto in (FINE_PROTO, PAGE_PROTO):
        cfg = RuntimeConfig(page_words=1024, protocol=proto)
        rt = make_runtime(2, cfg, engine="reference")
        shared = rt.alloc(4096)             # 4 pages in the global space

        # worker 0 updates two words inside a critical section (a span)
        with rt.span(0, lock_id=7):
            rt.write(0, shared, 100, 102, np.array([3.5, 4.5], np.float32))

        # worker 1 enters a span of the SAME lock -> rule 2: the update is
        # already visible, no barrier needed
        with rt.span(1, lock_id=7):
            got = rt.read(1, shared, 100, 102)
        assert np.allclose(got, [3.5, 4.5])

        t = rt.traffic
        print(f"  protocol={proto:5s}: moved {t.total_bytes:6d} bytes "
              f"(diffs={t.diff_bytes}, whole pages={t.writeback_bytes + t.fetch_bytes})")
    print("  -> fine ships a ~2-word diff; page moves 4 KiB pages\n")


def demo_reduction_extension():
    print("== 2. the reduction extension (paper V-B) ==")
    rt = make_runtime(8, engine="reference")
    for w in range(8):
        rt.reduce(w, "residual", float(w))   # replaces mutex-accumulate
    rt.barrier()
    print(f"  residual = {rt.reduction_result('residual')} "
          f"(runtime log-tree, never a lock)\n")


def demo_session_serving():
    print("== 3. the Session façade + the KV-cache serving workload ==")
    # the scale engine resolves driver='auto' to the worker-axis-batched
    # phase_all/span_all path; the reference oracle resolves to the
    # per-worker loop — SAME program text, bit-equal traffic
    for engine in ("scale", "reference"):
        # traffic/clock modeling only (track_values=False): the serving
        # program is an interval workload, values never flow through it
        rt = make_runtime(4, RuntimeConfig(page_words=64, cache_pages=2,
                                           model_mechanism=False,
                                           track_values=False),
                          engine=engine)
        s = session(rt)                     # driver='auto'
        rep = kv_serving(rt, 12, tok_words=8, max_tokens=24, attn_window=8,
                         seed=3)
        print(f"  engine={engine:9s} driver={s.driver:7s}: "
              f"{rep.latencies().size} requests, "
              f"p50={rep.latency_pct(50) * 1e3:.3f}ms "
              f"p99={rep.latency_pct(99) * 1e3:.3f}ms "
              f"bytes={rt.traffic.total_bytes}")
    print("  -> continuous batching as a RegC program: prefill = bulk "
          "writes, decode = windowed\n     reads + appends, admission = "
          "lock spans; eviction pressure is the adversary\n")


def demo_training_sync():
    print("== 4. RegC as the gradient-sync policy of a trainer ==")
    cfg = get_reduced("internlm2-1.8b")
    params = M.init_model_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = init_opt_state(params)
    hp = TrainHParams(remat=None, ce_chunk=32, total_steps=10, warmup=1)
    step = jax.jit(make_train_step(cfg, hp))
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    batch = {"tokens": jax.random.randint(ks[0], (2, 64), 0, cfg.vocab_size),
             "targets": jax.random.randint(ks[1], (2, 64), 0, cfg.vocab_size)}
    for i in range(3):
        params, opt, m = step(params, opt, batch, jnp.asarray(i))
        print(f"  step {i}: loss={float(m['loss']):.4f} "
              f"grad_norm={float(m['grad_norm']):.3f}")
    print("  (gradients = ordinary region, barrier-synced; loss/grad-norm = "
          "consistency region, span_reduce'd)")


if __name__ == "__main__":
    demo_consistency_model()
    demo_reduction_extension()
    demo_session_serving()
    demo_training_sync()
