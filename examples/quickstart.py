"""Quickstart: the RegC public API in five minutes.

1. The consistency model itself (spans, barriers, the two protocols).
2. The paper's reduction extension.
3. RegC as a training-sync policy on a real model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FINE_PROTO, PAGE_PROTO, RegCRuntime
from repro.configs import get_reduced
from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.train.train_step import TrainHParams, make_train_step


def demo_consistency_model():
    print("== 1. regional consistency: spans make critical-section stores "
          "visible ==")
    for proto in (FINE_PROTO, PAGE_PROTO):
        rt = RegCRuntime(2, page_words=1024, protocol=proto,
                         track_values=True)
        shared = rt.alloc(4096)             # 4 pages in the global space

        # worker 0 updates two words inside a critical section (a span)
        with rt.span(0, lock_id=7):
            rt.write(0, shared, 100, 102, np.array([3.5, 4.5], np.float32))

        # worker 1 enters a span of the SAME lock -> rule 2: the update is
        # already visible, no barrier needed
        with rt.span(1, lock_id=7):
            got = rt.read(1, shared, 100, 102)
        assert np.allclose(got, [3.5, 4.5])

        t = rt.traffic
        print(f"  protocol={proto:5s}: moved {t.total_bytes:6d} bytes "
              f"(diffs={t.diff_bytes}, whole pages={t.writeback_bytes + t.fetch_bytes})")
    print("  -> fine ships a ~2-word diff; page moves 4 KiB pages\n")


def demo_reduction_extension():
    print("== 2. the reduction extension (paper V-B) ==")
    rt = RegCRuntime(8, protocol=FINE_PROTO)
    for w in range(8):
        rt.reduce(w, "residual", float(w))   # replaces mutex-accumulate
    rt.barrier()
    print(f"  residual = {rt.reduction_result('residual')} "
          f"(runtime log-tree, never a lock)\n")


def demo_training_sync():
    print("== 3. RegC as the gradient-sync policy of a trainer ==")
    cfg = get_reduced("internlm2-1.8b")
    params = M.init_model_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = init_opt_state(params)
    hp = TrainHParams(remat=None, ce_chunk=32, total_steps=10, warmup=1)
    step = jax.jit(make_train_step(cfg, hp))
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    batch = {"tokens": jax.random.randint(ks[0], (2, 64), 0, cfg.vocab_size),
             "targets": jax.random.randint(ks[1], (2, 64), 0, cfg.vocab_size)}
    for i in range(3):
        params, opt, m = step(params, opt, batch, jnp.asarray(i))
        print(f"  step {i}: loss={float(m['loss']):.4f} "
              f"grad_norm={float(m['grad_norm']):.3f}")
    print("  (gradients = ordinary region, barrier-synced; loss/grad-norm = "
          "consistency region, span_reduce'd)")


if __name__ == "__main__":
    demo_consistency_model()
    demo_reduction_extension()
    demo_training_sync()
