"""The paper's Jacobi application on the RegC DSM runtime — with VALUES
(track_values=True): the solver actually converges, and the protocol's
correctness is visible end to end.

Solves the 2-D Poisson problem  -lap(u) = f  on an n x n grid with a known
manufactured solution, partitioned across W simulated workers, residual
accumulated through a mutex span (or the reduction extension).

Run:  PYTHONPATH=src python examples/dsm_jacobi.py [--mode reduction]
"""
import argparse

import numpy as np

from repro.core import FINE_PROTO, PAGE_PROTO, RuntimeConfig, make_runtime

RES_LOCK = 0


def run(n=32, workers=4, iters=700, mode="lock", protocol=FINE_PROTO):
    # the reference engine is the one that carries VALUES end to end
    rt = make_runtime(workers,
                      RuntimeConfig(page_words=256, protocol=protocol),
                      engine="reference")
    u = rt.alloc(n * n)
    uold = rt.alloc(n * n)
    fga = rt.alloc(n * n)
    res = rt.alloc(1)

    # manufactured problem: u* = sin(pi x) sin(pi y), f = 2 pi^2 u*
    xs = np.linspace(0, 1, n)
    uu, vv = np.meshgrid(xs, xs)
    u_star = np.sin(np.pi * uu) * np.sin(np.pi * vv)
    h = 1.0 / (n - 1)
    f_np = (2 * np.pi ** 2 * u_star).astype(np.float32)

    # worker 0 initializes f in the GAS (ordinary stores + barrier)
    rt.write(0, fga, 0, n * n, f_np.ravel())
    rt.barrier()

    rows = n // workers
    for it in range(iters):
        # uold = u
        for w in range(workers):
            lo = w * rows * n
            hi = ((w + 1) * rows if w < workers - 1 else n) * n
            vals = rt.read(w, u, lo, hi)
            rt.write(w, uold, lo, hi, vals)
        rt.barrier()

        # stencil + residual
        for w in range(workers):
            r0 = max(w * rows, 1)
            r1 = min((w + 1) * rows if w < workers - 1 else n, n - 1)
            lo_h, hi_h = (r0 - 1) * n, (r1 + 1) * n
            block = np.array(rt.read(w, uold, lo_h, hi_h)).reshape(-1, n)
            fblk = np.array(rt.read(w, fga, r0 * n, r1 * n)).reshape(-1, n)
            new = block[1:-1].copy()
            new[:, 1:-1] = 0.25 * (block[:-2, 1:-1] + block[2:, 1:-1]
                                   + block[1:-1, :-2] + block[1:-1, 2:]
                                   + h * h * fblk[:, 1:-1])
            local_res = float(np.abs(new - block[1:-1]).sum())
            rt.write(w, u, r0 * n, r1 * n, new.ravel())
            if mode == "lock":
                with rt.span(w, RES_LOCK):
                    cur = rt.read(w, res, 0, 1)
                    rt.write(w, res, 0, 1,
                             np.array([float(cur[0]) + local_res], np.float32))
            else:
                rt.reduce(w, "residual", local_res)
        rt.barrier()

        if mode == "lock":
            total = float(rt.read(0, res, 0, 1)[0])
            with rt.span(0, RES_LOCK):      # reset for next iteration
                rt.write(0, res, 0, 1, np.zeros(1, np.float32))
        else:
            total = rt.reduction_result("residual")
        rt.barrier()
        if it % 50 == 0:
            print(f"  iter {it:4d}  residual={total:.4e}")

    final = np.array(rt.read(0, u, 0, n * n)).reshape(n, n)
    err = np.abs(final - u_star).max()
    print(f"  final max error vs analytic solution: {err:.4f}")
    t = rt.traffic
    print(f"  traffic: fetched={t.fetch_bytes >> 10}KiB "
          f"writeback={t.writeback_bytes >> 10}KiB "
          f"diffs={t.diff_bytes}B invalidations={t.invalidations}")
    return err


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lock", "reduction"], default="lock")
    ap.add_argument("--protocol", choices=["fine", "page"], default="fine")
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--iters", type=int, default=700,
                    help="plain Jacobi needs O(n^2) iterations")
    args = ap.parse_args()
    proto = FINE_PROTO if args.protocol == "fine" else PAGE_PROTO
    print(f"Jacobi on RegC DSM (protocol={args.protocol}, mode={args.mode})")
    err = run(args.n, args.workers, args.iters, args.mode, proto)
    assert err < 0.05, "solver failed to converge - protocol bug!"
    print("converged: the RegC protocol preserved program semantics.")
