"""Batched serving example: continuous batching over a request queue with a
shared KV cache — the serve-side counterpart of the dry-run's decode cells.

Requests arrive with different prompt lengths and different generation
budgets; the scheduler packs up to --batch active sequences, decodes them in
lockstep, and refills slots as sequences finish.

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import model as M
from repro.serve.decode import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b")
    ap.add_argument("--n-requests", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    params = M.init_model_params(cfg, jax.random.PRNGKey(args.seed),
                                 jnp.float32)
    rng = np.random.RandomState(args.seed)
    queue = [{"id": i,
              "prompt": rng.randint(0, cfg.vocab_size,
                                    size=int(rng.randint(4, 24))),
              "budget": int(rng.randint(4, 12))}
             for i in range(args.n_requests)]

    B = args.batch
    serve_step = jax.jit(make_serve_step(cfg))
    # one shared cache of B slots
    caches = M.init_caches(cfg, B, args.max_len, jnp.float32)
    active = [None] * B
    cur_tok = np.zeros((B, 1), np.int32)
    done, t0, steps = [], time.perf_counter(), 0

    def admit(slot):
        """Prefill a new request into `slot` (single-row prefill)."""
        nonlocal caches, cur_tok
        req = queue.pop(0)
        toks = jnp.asarray(req["prompt"][None, :], jnp.int32)
        hidden, row_caches, plen = M.prefill(cfg, params, {"tokens": toks},
                                             max_len=args.max_len,
                                             cache_dtype=jnp.float32)
        # copy the single-row cache into the shared batch cache at `slot`
        caches = jax.tree.map(
            lambda big, row: big.at[:, slot:slot + 1, :row.shape[2]].set(
                row[:, :, :big.shape[2]] if row.shape[2] <= big.shape[2]
                else row[:, :, :big.shape[2]]),
            caches, row_caches)
        w = M._lm_matrix(cfg, params)
        logits = jnp.einsum("d,dv->v", hidden[0, -1], w)
        cur_tok[slot, 0] = int(jnp.argmax(logits))
        active[slot] = {**req, "generated": [int(cur_tok[slot, 0])],
                        "pos": plen}

    # NOTE: single shared cur_len across slots keeps the example simple: we
    # admit in waves (all slots share the max position).
    while queue or any(a is not None for a in active):
        for s in range(B):
            if active[s] is None and queue:
                admit(s)
        cur_len = max(a["pos"] for a in active if a is not None)
        tok, logits, caches = serve_step(
            params, {"tokens": jnp.asarray(cur_tok)}, caches,
            jnp.asarray(cur_len))
        steps += 1
        tok = np.asarray(tok)
        for s in range(B):
            a = active[s]
            if a is None:
                continue
            a["generated"].append(int(tok[s]))
            a["pos"] += 1
            cur_tok[s, 0] = int(tok[s])
            if len(a["generated"]) >= a["budget"] \
                    or a["pos"] >= args.max_len - 1:
                done.append(a)
                print(f"  req {a['id']:2d}: prompt_len={len(a['prompt'])} "
                      f"generated={a['generated'][:6]}...")
                active[s] = None

    dt = time.perf_counter() - t0
    total_tok = sum(len(d["generated"]) for d in done)
    print(f"\nserved {len(done)} requests / {total_tok} tokens in {dt:.2f}s "
          f"({steps} decode steps, {total_tok/dt:.1f} tok/s on 1 CPU)")


if __name__ == "__main__":
    main()
