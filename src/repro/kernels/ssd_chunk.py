"""Pallas TPU kernel for the Mamba-2 SSD *intra-chunk* block (the quadratic,
MXU-friendly part of the chunked SSD algorithm; arXiv:2405.21060 §6).

For one (batch, head, chunk) grid cell with chunk length Q, head dim P,
state dim N:

  scores_ij = (C_i . B_j) * exp(cum_i - cum_j) * dt_j   (j <= i)
  y_intra_i = sum_j scores_ij x_j
  state     = sum_j exp(cum_last - cum_j) * dt_j * (B_j (x) x_j)   # (P, N)

The inter-chunk recurrence (combining per-chunk states) is O(S/Q) sequential
and stays in XLA (`lax.scan`) — it is latency-, not compute-bound.  VMEM per
cell at (Q=256, P=64, N=128) fp32: x 64KB + B/C 128KB each + scores 256KB +
outputs ~96KB — comfortably under the ~16MB VMEM budget, MXU dims all
multiples of the 128 lane width (Q, N) or the 8 sublane width (P).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1)
    cum = cum_ref[0].astype(jnp.float32)      # (Q, 1)
    B = b_ref[0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0].astype(jnp.float32)          # (Q, N)
    Q = x.shape[0]

    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    causal = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    # mask BEFORE exp: off-causal cum_i - cum_j > 0 would overflow to inf
    delta = jnp.where(causal, cum - cum.reshape(1, Q), -jnp.inf)
    decay = jnp.exp(delta)                                        # 0 off-causal
    scores = cb * decay * dt.reshape(1, Q)
    y_ref[0] = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)   # (Q, P)

    w_in = jnp.exp(cum[Q - 1] - cum) * dt                          # (Q, 1)
    st_ref[0] = jax.lax.dot_general(
        x * w_in, B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(st_ref.dtype)  # (P, N)


def ssd_chunk(x, dt, cum, B_, C_, *, interpret: bool = False):
    """Intra-chunk SSD.

    x:   (M, Q, P)  — M = batch*heads*chunks flattened grid dim
    dt:  (M, Q, 1)  (discretized, >0)
    cum: (M, Q, 1)  (within-chunk cumsum of dt*A)
    B_:  (M, Q, N), C_: (M, Q, N)
    Returns y (M, Q, P) f32, state (M, P, N) f32.
    """
    M, Q, P = x.shape
    N = B_.shape[-1]
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(M,),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, P, N), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((M, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, cum, B_, C_)
