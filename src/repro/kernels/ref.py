"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# page_diff
# ---------------------------------------------------------------------------


def diff_encode_ref(curr, twin):
    changed = jax.lax.bitcast_convert_type(curr, jnp.int32) != \
        jax.lax.bitcast_convert_type(twin, jnp.int32)   # memcmp semantics
    mask = changed.astype(jnp.int8)
    vals = jnp.where(changed, curr, 0.0)
    count = jnp.sum(changed, axis=1).astype(jnp.int32)
    return mask, vals, count


def diff_apply_ref(dst, mask, vals):
    return jnp.where(mask != 0, vals, dst)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def flash_attention_ref(q, k, v, *, scale=None, causal=True, window=None,
                        softcap=None):
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D)."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kr,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= qp - kp < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr,
                      preferred_element_type=jnp.float32).astype(q.dtype)


# ---------------------------------------------------------------------------
# ssd_chunk
# ---------------------------------------------------------------------------


def ssd_chunk_ref(x, dt, cum, B_, C_):
    """Shapes as in kernels.ssd_chunk."""
    xf = x.astype(jnp.float32)
    dtf = dt[..., 0].astype(jnp.float32)       # (M, Q)
    cumf = cum[..., 0].astype(jnp.float32)
    Bf = B_.astype(jnp.float32)
    Cf = C_.astype(jnp.float32)
    M, Q, P = x.shape
    cb = jnp.einsum("mqn,mkn->mqk", Cf, Bf)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    delta = jnp.where(causal[None], cumf[:, :, None] - cumf[:, None, :],
                      -jnp.inf)   # mask BEFORE exp (off-causal overflows)
    scores = cb * jnp.exp(delta) * dtf[:, None, :]
    y = jnp.einsum("mqk,mkp->mqp", scores, xf)
    w_in = jnp.exp(cumf[:, -1:] - cumf) * dtf   # (M, Q)
    state = jnp.einsum("mq,mqp,mqn->mpn", w_in, xf, Bf)
    return y, state
