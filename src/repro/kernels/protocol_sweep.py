"""Bitmask protocol-sweep kernels for the RegC sharing directory.

The directory's boolean page-state planes (valid/dirty/wprot, one row per
worker — see ``core.directory.RegionDirectory``) pack 32 pages per lane as
little-endian ``uint32`` bitmasks: bit ``j`` of word ``k`` in row ``w`` is
directory column ``32*k + j`` of worker ``w``.  At 256 workers x millions
of pages that turns the two whole-plane reductions the barrier flush needs
into dense integer kernels that run on the accelerator:

* ``popcount_rows``  — per-worker dirty-page counts (the barrier-flush
  writeback charge), a SWAR popcount + row reduction over the packed plane;
* ``coverage_multi`` — the shared-interval sweep's coverage cumsum over the
  2W sorted window bounds (pages under >= 2 worker windows are the only
  candidates for sharer invalidation).

Both are integer-exact, so protocol traffic is identical on every backend
(``tests/test_directory.py`` oracles the packed kernels against the boolean
planes).  The kernels follow the repo convention (``kernels/ops.py``):
identical kernel bodies run compiled on TPU and in interpret mode on CPU.
When jax itself is unavailable the module degrades to the numpy paths and
``resolve_backend`` reports that 'pallas' is unavailable.
"""
from __future__ import annotations

import warnings

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    HAVE_PALLAS = True
except Exception:                                  # jax absent / broken
    HAVE_PALLAS = False

ROWS_PER_BLOCK = 8
_LANE = 128


def resolve_backend(backend: str) -> str:
    """Map a requested backend to an available one ('pallas' needs jax)."""
    if backend not in ("numpy", "pallas"):
        raise ValueError(f"unknown protocol-sweep backend: {backend!r}")
    if backend == "pallas" and not HAVE_PALLAS:
        warnings.warn("protocol_sweep: jax/pallas unavailable, "
                      "falling back to numpy", RuntimeWarning, stacklevel=2)
        return "numpy"
    return backend


# ---------------------------------------------------------------------------
# bitmask packing (host side, numpy)
# ---------------------------------------------------------------------------


def pack_mask_rows(plane: np.ndarray) -> np.ndarray:
    """(W, C) bool -> (W, ceil(C/32)) uint32, little-endian bit order:
    bit j of word k is column 32*k + j."""
    W, C = plane.shape
    n_words = -(-C // 32) if C else 0
    pad = n_words * 32 - C
    if pad:
        plane = np.pad(plane, ((0, 0), (0, pad)))
    if n_words == 0:
        return np.zeros((W, 0), np.uint32)
    by = np.packbits(plane.reshape(W, n_words * 4, 8), axis=-1,
                     bitorder="little")            # (W, n_words*4, 1) uint8
    return np.ascontiguousarray(by.reshape(W, n_words, 4)).view(
        np.uint32).reshape(W, n_words)


def unpack_mask_rows(bits: np.ndarray, n_cols: int) -> np.ndarray:
    """Inverse of ``pack_mask_rows`` (oracle/tests)."""
    W, n_words = bits.shape
    by = np.ascontiguousarray(bits).view(np.uint8).reshape(W, n_words * 4, 1)
    cols = np.unpackbits(by, axis=-1, bitorder="little").reshape(W, -1)
    return cols[:, :n_cols].astype(bool)


# ---------------------------------------------------------------------------
# row popcount: numpy SWAR / Pallas kernel (same bit-twiddle)
# ---------------------------------------------------------------------------


def _popcount_rows_np(bits: np.ndarray) -> np.ndarray:
    v = bits.astype(np.uint32, copy=True)
    v -= (v >> 1) & np.uint32(0x55555555)
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    v = (v * np.uint32(0x01010101)) >> 24
    return v.sum(axis=1, dtype=np.int64)


if HAVE_PALLAS:

    def _popcount_kernel(bits_ref, out_ref):
        v = bits_ref[...]
        v = v - ((v >> 1) & jnp.uint32(0x55555555))
        v = ((v & jnp.uint32(0x33333333))
             + ((v >> 2) & jnp.uint32(0x33333333)))
        v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
        v = (v * jnp.uint32(0x01010101)) >> 24
        out_ref[...] = jnp.sum(v.astype(jnp.int32), axis=1)

    def _popcount_rows_pallas(bits: np.ndarray) -> np.ndarray:
        W, n_words = bits.shape
        Wp = -(-W // ROWS_PER_BLOCK) * ROWS_PER_BLOCK
        Cp = max(-(-n_words // _LANE) * _LANE, _LANE)
        padded = np.zeros((Wp, Cp), np.uint32)     # zero words add 0 bits
        padded[:W, :n_words] = bits
        out = pl.pallas_call(
            _popcount_kernel,
            grid=(Wp // ROWS_PER_BLOCK,),
            in_specs=[pl.BlockSpec((ROWS_PER_BLOCK, Cp), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((ROWS_PER_BLOCK,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((Wp,), jnp.int32),
            interpret=jax.default_backend() != "tpu",
        )(jnp.asarray(padded))
        return np.asarray(out[:W]).astype(np.int64)

    def _coverage_kernel(delta_ref, multi_ref):
        cover = jnp.cumsum(delta_ref[...], axis=1)
        multi_ref[...] = (cover >= 2).astype(jnp.int8)

    def _coverage_multi_pallas(delta: np.ndarray) -> np.ndarray:
        n = delta.size
        npad = max(-(-n // _LANE) * _LANE, _LANE)
        padded = np.zeros((1, npad), np.int32)
        padded[0, :n] = delta
        out = pl.pallas_call(
            _coverage_kernel,
            in_specs=[pl.BlockSpec((1, npad), lambda: (0, 0))],
            out_specs=pl.BlockSpec((1, npad), lambda: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, npad), jnp.int8),
            interpret=jax.default_backend() != "tpu",
        )(jnp.asarray(padded))
        return np.asarray(out[0, :n]).astype(bool)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def popcount_rows(bits: np.ndarray, *, backend: str = "numpy") -> np.ndarray:
    """(W, n_words) uint32 -> (W,) int64 per-row set-bit counts."""
    if bits.shape[1] == 0:
        return np.zeros(bits.shape[0], np.int64)
    if resolve_backend(backend) == "pallas":
        return _popcount_rows_pallas(bits)
    return _popcount_rows_np(bits)


def coverage_multi(delta: np.ndarray, *, backend: str = "numpy") -> np.ndarray:
    """Sorted-bound deltas (+1 window start / -1 window end) -> boolean
    mask of sweep points where the running cover count is >= 2."""
    if resolve_backend(backend) == "pallas":
        return _coverage_multi_pallas(delta.astype(np.int32))
    return np.cumsum(delta) >= 2
