"""Bitmask protocol-sweep kernels for the RegC sharing directory.

The directory's boolean page-state planes (valid/dirty/wprot, one row per
worker — see ``core.directory.RegionDirectory``) pack 32 pages per lane as
little-endian ``uint32`` bitmasks: bit ``j`` of word ``k`` in row ``w`` is
directory column ``32*k + j`` of worker ``w``.  At 256 workers x millions
of pages that turns the whole-plane reductions the barrier flush and the
batched eviction engine need into dense integer kernels that run on the
accelerator:

* ``popcount_rows``  — per-worker dirty-page counts (the barrier-flush
  writeback charge and the eviction engine's dirty-victim counts), a SWAR
  popcount + row reduction over the packed plane;
* ``coverage_multi`` — the shared-interval sweep's coverage cumsum over the
  2W sorted window bounds (pages under >= 2 worker windows are the only
  candidates for sharer invalidation);
* ``take_first_k``   — per-row rank-select (each row's first k set bits in
  little-endian column order): the batched eviction engine's segment-LRU
  victim selection over packed run-liveness masks;
* ``kth_set_index``  — per-row rank query (column of the k-th set bit):
  the mid-op refetch replay engine's scan cut — how far a victim run's
  live mask must be consumed to satisfy an eviction demand.

Both are integer-exact, so protocol traffic is identical on every backend
(``tests/test_directory.py`` oracles the packed kernels against the boolean
planes).  The kernels follow the repo convention (``kernels/ops.py``):
identical kernel bodies run compiled on TPU and in interpret mode on CPU.
When jax itself is unavailable the module degrades to the numpy paths and
``resolve_backend`` reports that 'pallas' is unavailable.
"""
from __future__ import annotations

import warnings

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    HAVE_PALLAS = True
except Exception:                                  # jax absent / broken
    HAVE_PALLAS = False

ROWS_PER_BLOCK = 8
_LANE = 128


def resolve_backend(backend: str) -> str:
    """Map a requested backend to an available one ('pallas' needs jax)."""
    from repro.core.config import BACKENDS, check_choice
    check_choice("backend", backend, BACKENDS)
    if backend == "pallas" and not HAVE_PALLAS:
        warnings.warn("protocol_sweep: jax/pallas unavailable, "
                      "falling back to numpy", RuntimeWarning, stacklevel=2)
        return "numpy"
    return backend


# ---------------------------------------------------------------------------
# bitmask packing (host side, numpy)
# ---------------------------------------------------------------------------


def pack_mask_rows(plane: np.ndarray) -> np.ndarray:
    """(W, C) bool -> (W, ceil(C/32)) uint32, little-endian bit order:
    bit j of word k is column 32*k + j."""
    W, C = plane.shape
    n_words = -(-C // 32) if C else 0
    pad = n_words * 32 - C
    if pad:
        plane = np.pad(plane, ((0, 0), (0, pad)))
    if n_words == 0:
        return np.zeros((W, 0), np.uint32)
    by = np.packbits(plane.reshape(W, n_words * 4, 8), axis=-1,
                     bitorder="little")            # (W, n_words*4, 1) uint8
    return np.ascontiguousarray(by.reshape(W, n_words, 4)).view(
        np.uint32).reshape(W, n_words)


def unpack_mask_rows(bits: np.ndarray, n_cols: int) -> np.ndarray:
    """Inverse of ``pack_mask_rows`` (oracle/tests)."""
    W, n_words = bits.shape
    by = np.ascontiguousarray(bits).view(np.uint8).reshape(W, n_words * 4, 1)
    cols = np.unpackbits(by, axis=-1, bitorder="little").reshape(W, -1)
    return cols[:, :n_cols].astype(bool)


# ---------------------------------------------------------------------------
# row popcount: numpy SWAR / Pallas kernel (same bit-twiddle)
# ---------------------------------------------------------------------------


def _popcount_words(v: np.ndarray) -> np.ndarray:
    """Per-word SWAR popcount, (R, n_words) uint32 -> uint32 counts."""
    v = v.astype(np.uint32, copy=True)
    v -= (v >> 1) & np.uint32(0x55555555)
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    return (v * np.uint32(0x01010101)) >> 24


def _popcount_rows_np(bits: np.ndarray) -> np.ndarray:
    return _popcount_words(bits).sum(axis=1, dtype=np.int64)


def _take_first_k_np(bits: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Per-row rank-select: keep only the first (lowest-column) k[i] set
    bits of row i.  Word-level prefix popcounts bound how many bits each
    word still needs; within a word, bit j survives iff its rank among the
    word's set bits is below that need — 32 static shift steps over the
    packed plane (the eviction plane's segment-LRU 'take' mask)."""
    pc = _popcount_words(bits)
    excl = np.cumsum(pc, axis=1, dtype=np.int64) - pc       # bits before word
    need = np.clip(k[:, None] - excl, 0, 32).astype(np.uint32)
    out = np.zeros_like(bits, np.uint32)
    run = np.zeros_like(bits, np.uint32)                    # rank within word
    for j in range(32):
        bit = (bits >> np.uint32(j)) & np.uint32(1)
        sel = (bit != 0) & (run < need)
        out |= sel.astype(np.uint32) << np.uint32(j)
        run += bit
    return out


def _kth_set_index_np(bits: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Per-row rank query: little-endian column index of the k[i]-th
    (1-based) set bit of row i, or -1 when the row has fewer than k[i]
    set bits (or k[i] <= 0).  Word-level prefix popcounts locate the
    word; 32 static shift steps locate the bit within it."""
    R, n_words = bits.shape
    pc = _popcount_words(bits).astype(np.int64)
    cum = np.cumsum(pc, axis=1)
    total = cum[:, -1]
    kk = np.asarray(k, np.int64)
    # first word whose cumulative popcount reaches k (k > total handled
    # by the final mask; argmax of an all-False row is 0, also masked)
    wi = np.argmax(cum >= kk[:, None], axis=1)
    rows = np.arange(R)
    need = (kk - (cum[rows, wi] - pc[rows, wi])).astype(np.int64)
    word = bits[rows, wi]
    run = np.zeros(R, np.int64)
    idx = np.full(R, -1, np.int64)
    for j in range(32):
        bit = ((word >> np.uint32(j)) & np.uint32(1)).astype(np.int64)
        run += bit
        hit = (idx < 0) & (bit == 1) & (run == need)
        idx = np.where(hit, 32 * wi + j, idx)
    return np.where((kk >= 1) & (total >= kk), idx, -1)


if HAVE_PALLAS:

    def _popcount_kernel(bits_ref, out_ref):
        v = bits_ref[...]
        v = v - ((v >> 1) & jnp.uint32(0x55555555))
        v = ((v & jnp.uint32(0x33333333))
             + ((v >> 2) & jnp.uint32(0x33333333)))
        v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
        v = (v * jnp.uint32(0x01010101)) >> 24
        out_ref[...] = jnp.sum(v.astype(jnp.int32), axis=1)

    def _popcount_rows_pallas(bits: np.ndarray) -> np.ndarray:
        W, n_words = bits.shape
        Wp = -(-W // ROWS_PER_BLOCK) * ROWS_PER_BLOCK
        Cp = max(-(-n_words // _LANE) * _LANE, _LANE)
        padded = np.zeros((Wp, Cp), np.uint32)     # zero words add 0 bits
        padded[:W, :n_words] = bits
        out = pl.pallas_call(
            _popcount_kernel,
            grid=(Wp // ROWS_PER_BLOCK,),
            in_specs=[pl.BlockSpec((ROWS_PER_BLOCK, Cp), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((ROWS_PER_BLOCK,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((Wp,), jnp.int32),
            interpret=jax.default_backend() != "tpu",
        )(jnp.asarray(padded))
        return np.asarray(out[:W]).astype(np.int64)

    def _take_first_k_kernel(bits_ref, k_ref, out_ref):
        v = bits_ref[...]
        pc = v - ((v >> 1) & jnp.uint32(0x55555555))
        pc = ((pc & jnp.uint32(0x33333333))
              + ((pc >> 2) & jnp.uint32(0x33333333)))
        pc = (pc + (pc >> 4)) & jnp.uint32(0x0F0F0F0F)
        pc = (pc * jnp.uint32(0x01010101)) >> 24
        excl = jnp.cumsum(pc.astype(jnp.int32), axis=1) - pc.astype(jnp.int32)
        need = jnp.clip(k_ref[...] - excl, 0, 32).astype(jnp.uint32)
        out = jnp.zeros_like(v)
        run = jnp.zeros_like(v)
        for j in range(32):                      # static rank-select steps
            bit = (v >> j) & jnp.uint32(1)
            sel = (bit != 0) & (run < need)
            out = out | (sel.astype(jnp.uint32) << j)
            run = run + bit
        out_ref[...] = out

    def _take_first_k_pallas(bits: np.ndarray, k: np.ndarray) -> np.ndarray:
        R, n_words = bits.shape
        Rp = -(-R // ROWS_PER_BLOCK) * ROWS_PER_BLOCK
        Cp = max(-(-n_words // _LANE) * _LANE, _LANE)
        padded = np.zeros((Rp, Cp), np.uint32)
        padded[:R, :n_words] = bits
        kp = np.zeros((Rp, 1), np.int32)
        kp[:R, 0] = np.minimum(k, np.iinfo(np.int32).max)
        out = pl.pallas_call(
            _take_first_k_kernel,
            grid=(Rp // ROWS_PER_BLOCK,),
            in_specs=[pl.BlockSpec((ROWS_PER_BLOCK, Cp), lambda i: (i, 0)),
                      pl.BlockSpec((ROWS_PER_BLOCK, 1), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((ROWS_PER_BLOCK, Cp), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((Rp, Cp), jnp.uint32),
            interpret=jax.default_backend() != "tpu",
        )(jnp.asarray(padded), jnp.asarray(kp))
        return np.asarray(out[:R, :n_words])

    def _kth_set_index_kernel(bits_ref, k_ref, out_ref):
        v = bits_ref[...]
        pc = v - ((v >> 1) & jnp.uint32(0x55555555))
        pc = ((pc & jnp.uint32(0x33333333))
              + ((pc >> 2) & jnp.uint32(0x33333333)))
        pc = (pc + (pc >> 4)) & jnp.uint32(0x0F0F0F0F)
        pc = ((pc * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
        cum = jnp.cumsum(pc, axis=1)
        total = cum[:, -1:]
        k = k_ref[...]
        reach = cum >= k
        wi = jnp.argmax(reach, axis=1, keepdims=True)
        excl = jnp.take_along_axis(cum - pc, wi, axis=1)
        need = k - excl
        word = jnp.take_along_axis(v, wi, axis=1)
        run = jnp.zeros_like(need)
        idx = jnp.full_like(need, -1)
        for j in range(32):                  # static rank steps
            bit = ((word >> j) & jnp.uint32(1)).astype(jnp.int32)
            run = run + bit
            hit = (idx < 0) & (bit == 1) & (run == need)
            idx = jnp.where(hit, 32 * wi + j, idx)
        ok = (k >= 1) & (total >= k)
        out_ref[...] = jnp.where(ok, idx, -1)

    def _kth_set_index_pallas(bits: np.ndarray, k: np.ndarray) -> np.ndarray:
        R, n_words = bits.shape
        Rp = -(-R // ROWS_PER_BLOCK) * ROWS_PER_BLOCK
        Cp = max(-(-n_words // _LANE) * _LANE, _LANE)
        padded = np.zeros((Rp, Cp), np.uint32)
        padded[:R, :n_words] = bits
        kp = np.zeros((Rp, 1), np.int32)
        kp[:R, 0] = np.minimum(k, np.iinfo(np.int32).max)
        out = pl.pallas_call(
            _kth_set_index_kernel,
            grid=(Rp // ROWS_PER_BLOCK,),
            in_specs=[pl.BlockSpec((ROWS_PER_BLOCK, Cp), lambda i: (i, 0)),
                      pl.BlockSpec((ROWS_PER_BLOCK, 1), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((ROWS_PER_BLOCK, 1), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
            interpret=jax.default_backend() != "tpu",
        )(jnp.asarray(padded), jnp.asarray(kp))
        return np.asarray(out[:R, 0]).astype(np.int64)

    def _coverage_kernel(delta_ref, multi_ref):
        cover = jnp.cumsum(delta_ref[...], axis=1)
        multi_ref[...] = (cover >= 2).astype(jnp.int8)

    def _coverage_multi_pallas(delta: np.ndarray) -> np.ndarray:
        n = delta.size
        npad = max(-(-n // _LANE) * _LANE, _LANE)
        padded = np.zeros((1, npad), np.int32)
        padded[0, :n] = delta
        out = pl.pallas_call(
            _coverage_kernel,
            in_specs=[pl.BlockSpec((1, npad), lambda: (0, 0))],
            out_specs=pl.BlockSpec((1, npad), lambda: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, npad), jnp.int8),
            interpret=jax.default_backend() != "tpu",
        )(jnp.asarray(padded))
        return np.asarray(out[0, :n]).astype(bool)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def popcount_rows(bits: np.ndarray, *, backend: str = "numpy") -> np.ndarray:
    """(W, n_words) uint32 -> (W,) int64 per-row set-bit counts."""
    if bits.shape[1] == 0:
        return np.zeros(bits.shape[0], np.int64)
    if resolve_backend(backend) == "pallas":
        return _popcount_rows_pallas(bits)
    return _popcount_rows_np(bits)


def take_first_k(bits: np.ndarray, k: np.ndarray, *,
                 backend: str = "numpy") -> np.ndarray:
    """(R, n_words) uint32 + (R,) counts -> packed mask of each row's first
    k[i] set bits in little-endian column order (the batched eviction
    engine's segment-LRU victim selection)."""
    if bits.shape[1] == 0:
        return np.zeros_like(bits, np.uint32)
    if resolve_backend(backend) == "pallas":
        return _take_first_k_pallas(bits, k)
    return _take_first_k_np(bits, np.asarray(k, np.int64))


def kth_set_index(bits: np.ndarray, k: np.ndarray, *,
                  backend: str = "numpy") -> np.ndarray:
    """(R, n_words) uint32 + (R,) ranks -> (R,) little-endian column index
    of each row's k[i]-th (1-based) set bit, -1 when out of range (the
    refetch replay engine's victim-scan cut)."""
    if bits.shape[1] == 0:
        return np.full(bits.shape[0], -1, np.int64)
    if resolve_backend(backend) == "pallas":
        return _kth_set_index_pallas(bits, np.asarray(k, np.int64))
    return _kth_set_index_np(bits, np.asarray(k, np.int64))


def coverage_multi(delta: np.ndarray, *, backend: str = "numpy") -> np.ndarray:
    """Sorted-bound deltas (+1 window start / -1 window end) -> boolean
    mask of sweep points where the running cover count is >= 2."""
    if resolve_backend(backend) == "pallas":
        return _coverage_multi_pallas(delta.astype(np.int32))
    return np.cumsum(delta) >= 2
