"""Bitmask protocol-sweep kernels for the RegC sharing directory.

The directory's boolean page-state planes (valid/dirty/wprot, one row per
worker — see ``core.directory.RegionDirectory``) pack 32 pages per lane as
little-endian ``uint32`` bitmasks: bit ``j`` of word ``k`` in row ``w`` is
directory column ``32*k + j`` of worker ``w``.  At 256 workers x millions
of pages that turns the whole-plane reductions the barrier flush and the
batched eviction engine need into dense integer kernels that run on the
accelerator:

* ``popcount_rows``  — per-worker dirty-page counts (the barrier-flush
  writeback charge and the eviction engine's dirty-victim counts), a SWAR
  popcount + row reduction over the packed plane;
* ``coverage_multi`` — the shared-interval sweep's coverage cumsum over the
  2W sorted window bounds (pages under >= 2 worker windows are the only
  candidates for sharer invalidation);
* ``take_first_k``   — per-row rank-select (each row's first k set bits in
  little-endian column order): the batched eviction engine's segment-LRU
  victim selection over packed run-liveness masks;
* ``kth_set_index``  — per-row rank query (column of the k-th set bit):
  the mid-op refetch replay engine's scan cut — how far a victim run's
  live mask must be consumed to satisfy an eviction demand.

All tiers are integer-exact, so protocol traffic is identical on every
backend (``tests/test_directory.py`` oracles the packed kernels against the
boolean planes).  Three execution tiers share the kernel algebra:

* ``numpy``      — boolean-plane / SWAR reductions (the reference tier);
* ``pallas``     — per-op ``pallas_call`` kernels, compiled on TPU and
  interpret-mode on CPU (the validation twin);
* ``pallas-jit`` — the same kernels as jnp programs under ``jax.jit``
  (XLA-fused, so the SWAR multi-pass runs without numpy's temporaries),
  plus the FUSED chains: ``phase_step`` runs the whole barrier-flush
  reduction set (popcount + shared-coverage sweep + sharer-invalidation
  candidate mask) for every dirty region as ONE device dispatch with
  ``lax.scan`` carrying the per-region loop, and ``take_and_cut`` fuses
  the eviction rank-select + rank-query into one dispatch.  Packed
  planes stay device-resident across the chained ops inside a dispatch
  instead of round-tripping per kernel (see DIRECTORY.md
  "Compiled-phase contract").

When jax itself is unavailable (or ``REPRO_FORCE_NUMPY=1`` is set) the
module degrades to the numpy paths; availability is probed ONCE and
cached (``available_backends``), not re-checked per call.
"""
from __future__ import annotations

import os
import warnings
from typing import Optional, Tuple

import numpy as np

try:
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    HAVE_PALLAS = True
except Exception:                                  # jax absent / broken
    HAVE_PALLAS = False

ROWS_PER_BLOCK = 8
_LANE = 128
_FORCE_ENV = "REPRO_FORCE_NUMPY"

# one cached module-level availability probe (the env override and the
# jax import are both process-stable, so per-call re-checking was pure
# overhead); tests reset it via _reset_backend_probe after monkeypatching
# the environment
_AVAILABLE: Optional[Tuple[str, ...]] = None
_WARNED: set = set()


def available_backends() -> Tuple[str, ...]:
    """The backends this process can actually run, probed once and
    cached: numpy always; 'pallas'/'pallas-jit' when jax imported and
    ``REPRO_FORCE_NUMPY=1`` is not set (the debugging override that
    forces every kernel onto the numpy tier)."""
    global _AVAILABLE
    if _AVAILABLE is None:
        if not HAVE_PALLAS or os.environ.get(_FORCE_ENV) == "1":
            _AVAILABLE = ("numpy",)
        else:
            _AVAILABLE = ("numpy", "pallas", "pallas-jit")
    return _AVAILABLE


def _reset_backend_probe():
    """Drop the cached probe (tests that monkeypatch REPRO_FORCE_NUMPY)."""
    global _AVAILABLE
    _AVAILABLE = None
    _WARNED.clear()


def resolve_backend(backend: str) -> str:
    """Map a requested backend to an available one (cached probe; warns
    once per unavailable backend, not per call)."""
    from repro.core.config import BACKENDS, check_choice
    check_choice("backend", backend, BACKENDS)
    if backend not in available_backends():
        if backend not in _WARNED:
            _WARNED.add(backend)
            why = (f"{_FORCE_ENV}=1" if os.environ.get(_FORCE_ENV) == "1"
                   else "jax/pallas unavailable")
            warnings.warn(f"protocol_sweep: {why}, backend {backend!r} "
                          "falling back to numpy", RuntimeWarning,
                          stacklevel=2)
        return "numpy"
    return backend


# jit-dispatch accounting: every fused/jitted kernel call notes itself in
# the caller's stats dict (the runtime's ``jit_dispatches`` counter — CI
# fails when a bench leg silently falls back to numpy and the counter
# stays 0).  ``jit_cache_misses`` counts first-seen (kernel, shape) keys,
# mirroring jax's process-wide compilation cache.
_JIT_SEEN: set = set()


def _note_dispatch(stats: Optional[dict], key):
    if stats is None:
        return
    stats["jit_dispatches"] = stats.get("jit_dispatches", 0) + 1
    if key not in _JIT_SEEN:
        _JIT_SEEN.add(key)
        stats["jit_cache_misses"] = stats.get("jit_cache_misses", 0) + 1


# ---------------------------------------------------------------------------
# bitmask packing (host side, numpy)
# ---------------------------------------------------------------------------


def pack_mask_rows(plane: np.ndarray) -> np.ndarray:
    """(W, C) bool -> (W, ceil(C/32)) uint32, little-endian bit order:
    bit j of word k is column 32*k + j."""
    W, C = plane.shape
    n_words = -(-C // 32) if C else 0
    pad = n_words * 32 - C
    if pad:
        plane = np.pad(plane, ((0, 0), (0, pad)))
    if n_words == 0:
        return np.zeros((W, 0), np.uint32)
    by = np.packbits(plane.reshape(W, n_words * 4, 8), axis=-1,
                     bitorder="little")            # (W, n_words*4, 1) uint8
    return np.ascontiguousarray(by.reshape(W, n_words, 4)).view(
        np.uint32).reshape(W, n_words)


def unpack_mask_rows(bits: np.ndarray, n_cols: int) -> np.ndarray:
    """Inverse of ``pack_mask_rows`` (oracle/tests)."""
    W, n_words = bits.shape
    by = np.ascontiguousarray(bits).view(np.uint8).reshape(W, n_words * 4, 1)
    cols = np.unpackbits(by, axis=-1, bitorder="little").reshape(W, -1)
    return cols[:, :n_cols].astype(bool)


# ---------------------------------------------------------------------------
# row popcount: numpy SWAR / Pallas kernel (same bit-twiddle)
# ---------------------------------------------------------------------------


def _popcount_words(v: np.ndarray) -> np.ndarray:
    """Per-word SWAR popcount, (R, n_words) uint32 -> uint32 counts."""
    v = v.astype(np.uint32, copy=True)
    v -= (v >> 1) & np.uint32(0x55555555)
    v = (v & np.uint32(0x33333333)) + ((v >> 2) & np.uint32(0x33333333))
    v = (v + (v >> 4)) & np.uint32(0x0F0F0F0F)
    return (v * np.uint32(0x01010101)) >> 24


def _popcount_rows_np(bits: np.ndarray) -> np.ndarray:
    return _popcount_words(bits).sum(axis=1, dtype=np.int64)


def _take_first_k_np(bits: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Per-row rank-select: keep only the first (lowest-column) k[i] set
    bits of row i.  Word-level prefix popcounts bound how many bits each
    word still needs; within a word, bit j survives iff its rank among the
    word's set bits is below that need — 32 static shift steps over the
    packed plane (the eviction plane's segment-LRU 'take' mask)."""
    pc = _popcount_words(bits)
    excl = np.cumsum(pc, axis=1, dtype=np.int64) - pc       # bits before word
    need = np.clip(k[:, None] - excl, 0, 32).astype(np.uint32)
    out = np.zeros_like(bits, np.uint32)
    run = np.zeros_like(bits, np.uint32)                    # rank within word
    for j in range(32):
        bit = (bits >> np.uint32(j)) & np.uint32(1)
        sel = (bit != 0) & (run < need)
        out |= sel.astype(np.uint32) << np.uint32(j)
        run += bit
    return out


def _kth_set_index_np(bits: np.ndarray, k: np.ndarray) -> np.ndarray:
    """Per-row rank query: little-endian column index of the k[i]-th
    (1-based) set bit of row i, or -1 when the row has fewer than k[i]
    set bits (or k[i] <= 0).  Word-level prefix popcounts locate the
    word; 32 static shift steps locate the bit within it."""
    R, n_words = bits.shape
    pc = _popcount_words(bits).astype(np.int64)
    cum = np.cumsum(pc, axis=1)
    total = cum[:, -1]
    kk = np.asarray(k, np.int64)
    # first word whose cumulative popcount reaches k (k > total handled
    # by the final mask; argmax of an all-False row is 0, also masked)
    wi = np.argmax(cum >= kk[:, None], axis=1)
    rows = np.arange(R)
    need = (kk - (cum[rows, wi] - pc[rows, wi])).astype(np.int64)
    word = bits[rows, wi]
    run = np.zeros(R, np.int64)
    idx = np.full(R, -1, np.int64)
    for j in range(32):
        bit = ((word >> np.uint32(j)) & np.uint32(1)).astype(np.int64)
        run += bit
        hit = (idx < 0) & (bit == 1) & (run == need)
        idx = np.where(hit, 32 * wi + j, idx)
    return np.where((kk >= 1) & (total >= kk), idx, -1)


if HAVE_PALLAS:

    def _popcount_kernel(bits_ref, out_ref):
        v = bits_ref[...]
        v = v - ((v >> 1) & jnp.uint32(0x55555555))
        v = ((v & jnp.uint32(0x33333333))
             + ((v >> 2) & jnp.uint32(0x33333333)))
        v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
        v = (v * jnp.uint32(0x01010101)) >> 24
        out_ref[...] = jnp.sum(v.astype(jnp.int32), axis=1)

    def _popcount_rows_pallas(bits: np.ndarray) -> np.ndarray:
        W, n_words = bits.shape
        Wp = -(-W // ROWS_PER_BLOCK) * ROWS_PER_BLOCK
        Cp = max(-(-n_words // _LANE) * _LANE, _LANE)
        padded = np.zeros((Wp, Cp), np.uint32)     # zero words add 0 bits
        padded[:W, :n_words] = bits
        out = pl.pallas_call(
            _popcount_kernel,
            grid=(Wp // ROWS_PER_BLOCK,),
            in_specs=[pl.BlockSpec((ROWS_PER_BLOCK, Cp), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((ROWS_PER_BLOCK,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((Wp,), jnp.int32),
            interpret=jax.default_backend() != "tpu",
        )(jnp.asarray(padded))
        return np.asarray(out[:W]).astype(np.int64)

    def _take_first_k_kernel(bits_ref, k_ref, out_ref):
        v = bits_ref[...]
        pc = v - ((v >> 1) & jnp.uint32(0x55555555))
        pc = ((pc & jnp.uint32(0x33333333))
              + ((pc >> 2) & jnp.uint32(0x33333333)))
        pc = (pc + (pc >> 4)) & jnp.uint32(0x0F0F0F0F)
        pc = (pc * jnp.uint32(0x01010101)) >> 24
        excl = jnp.cumsum(pc.astype(jnp.int32), axis=1) - pc.astype(jnp.int32)
        need = jnp.clip(k_ref[...] - excl, 0, 32).astype(jnp.uint32)
        out = jnp.zeros_like(v)
        run = jnp.zeros_like(v)
        for j in range(32):                      # static rank-select steps
            bit = (v >> j) & jnp.uint32(1)
            sel = (bit != 0) & (run < need)
            out = out | (sel.astype(jnp.uint32) << j)
            run = run + bit
        out_ref[...] = out

    def _take_first_k_pallas(bits: np.ndarray, k: np.ndarray) -> np.ndarray:
        R, n_words = bits.shape
        Rp = -(-R // ROWS_PER_BLOCK) * ROWS_PER_BLOCK
        Cp = max(-(-n_words // _LANE) * _LANE, _LANE)
        padded = np.zeros((Rp, Cp), np.uint32)
        padded[:R, :n_words] = bits
        kp = np.zeros((Rp, 1), np.int32)
        kp[:R, 0] = np.minimum(k, np.iinfo(np.int32).max)
        out = pl.pallas_call(
            _take_first_k_kernel,
            grid=(Rp // ROWS_PER_BLOCK,),
            in_specs=[pl.BlockSpec((ROWS_PER_BLOCK, Cp), lambda i: (i, 0)),
                      pl.BlockSpec((ROWS_PER_BLOCK, 1), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((ROWS_PER_BLOCK, Cp), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((Rp, Cp), jnp.uint32),
            interpret=jax.default_backend() != "tpu",
        )(jnp.asarray(padded), jnp.asarray(kp))
        return np.asarray(out[:R, :n_words])

    def _kth_set_index_kernel(bits_ref, k_ref, out_ref):
        v = bits_ref[...]
        pc = v - ((v >> 1) & jnp.uint32(0x55555555))
        pc = ((pc & jnp.uint32(0x33333333))
              + ((pc >> 2) & jnp.uint32(0x33333333)))
        pc = (pc + (pc >> 4)) & jnp.uint32(0x0F0F0F0F)
        pc = ((pc * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
        cum = jnp.cumsum(pc, axis=1)
        total = cum[:, -1:]
        k = k_ref[...]
        reach = cum >= k
        wi = jnp.argmax(reach, axis=1, keepdims=True)
        excl = jnp.take_along_axis(cum - pc, wi, axis=1)
        need = k - excl
        word = jnp.take_along_axis(v, wi, axis=1)
        run = jnp.zeros_like(need)
        idx = jnp.full_like(need, -1)
        for j in range(32):                  # static rank steps
            bit = ((word >> j) & jnp.uint32(1)).astype(jnp.int32)
            run = run + bit
            hit = (idx < 0) & (bit == 1) & (run == need)
            idx = jnp.where(hit, 32 * wi + j, idx)
        ok = (k >= 1) & (total >= k)
        out_ref[...] = jnp.where(ok, idx, -1)

    def _kth_set_index_pallas(bits: np.ndarray, k: np.ndarray) -> np.ndarray:
        R, n_words = bits.shape
        Rp = -(-R // ROWS_PER_BLOCK) * ROWS_PER_BLOCK
        Cp = max(-(-n_words // _LANE) * _LANE, _LANE)
        padded = np.zeros((Rp, Cp), np.uint32)
        padded[:R, :n_words] = bits
        kp = np.zeros((Rp, 1), np.int32)
        kp[:R, 0] = np.minimum(k, np.iinfo(np.int32).max)
        out = pl.pallas_call(
            _kth_set_index_kernel,
            grid=(Rp // ROWS_PER_BLOCK,),
            in_specs=[pl.BlockSpec((ROWS_PER_BLOCK, Cp), lambda i: (i, 0)),
                      pl.BlockSpec((ROWS_PER_BLOCK, 1), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((ROWS_PER_BLOCK, 1), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((Rp, 1), jnp.int32),
            interpret=jax.default_backend() != "tpu",
        )(jnp.asarray(padded), jnp.asarray(kp))
        return np.asarray(out[:R, 0]).astype(np.int64)

    def _coverage_kernel(delta_ref, multi_ref):
        cover = jnp.cumsum(delta_ref[...], axis=1)
        multi_ref[...] = (cover >= 2).astype(jnp.int8)

    def _coverage_multi_pallas(delta: np.ndarray) -> np.ndarray:
        n = delta.size
        npad = max(-(-n // _LANE) * _LANE, _LANE)
        padded = np.zeros((1, npad), np.int32)
        padded[0, :n] = delta
        out = pl.pallas_call(
            _coverage_kernel,
            in_specs=[pl.BlockSpec((1, npad), lambda: (0, 0))],
            out_specs=pl.BlockSpec((1, npad), lambda: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((1, npad), jnp.int8),
            interpret=jax.default_backend() != "tpu",
        )(jnp.asarray(padded))
        return np.asarray(out[0, :n]).astype(bool)

    # -----------------------------------------------------------------
    # 'pallas-jit' tier: the same kernel algebra as jnp programs under
    # jax.jit — XLA fuses the SWAR passes into one traversal, and the
    # fused chains run several protocol ops per dispatch with the packed
    # planes staying device-resident in between.
    # -----------------------------------------------------------------

    def _swar_pop_j(v):
        v = v - ((v >> 1) & jnp.uint32(0x55555555))
        v = ((v & jnp.uint32(0x33333333))
             + ((v >> 2) & jnp.uint32(0x33333333)))
        v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
        return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)

    def _rank_select_j(bits, k):
        """Packed per-row rank-select (first k[i] set bits), traced: the
        word-prefix popcount bound + 32 bit steps via fori_loop."""
        pc = _swar_pop_j(bits)
        excl = jnp.cumsum(pc, axis=1) - pc
        need = jnp.clip(k[:, None] - excl, 0, 32).astype(jnp.uint32)

        def step(j, carry):
            out, run = carry
            bit = (bits >> j) & jnp.uint32(1)
            sel = (bit != 0) & (run < need)
            out = out | (sel.astype(jnp.uint32) << j)
            return out, run + bit

        out, _ = jax.lax.fori_loop(
            0, 32, step, (jnp.zeros_like(bits), jnp.zeros_like(bits)))
        return out

    def _rank_query_j(bits, k):
        """Packed per-row rank query (column of the k[i]-th set bit, -1
        out of range), traced."""
        pc = _swar_pop_j(bits)
        cum = jnp.cumsum(pc, axis=1)
        total = cum[:, -1]
        wi = jnp.argmax(cum >= k[:, None], axis=1)
        rows = jnp.arange(bits.shape[0])
        need = k - (cum[rows, wi] - pc[rows, wi])
        word = bits[rows, wi]

        def step(j, carry):
            run, idx = carry
            bit = ((word >> j) & jnp.uint32(1)).astype(jnp.int32)
            run = run + bit
            hit = (idx < 0) & (bit == 1) & (run == need)
            return run, jnp.where(hit, 32 * wi.astype(jnp.int32) + j, idx)

        _, idx = jax.lax.fori_loop(
            0, 32, step,
            (jnp.zeros_like(need), jnp.full_like(need, -1)))
        return jnp.where((k >= 1) & (total >= k), idx, -1)

    @jax.jit
    def _popcount_rows_jit(bits):
        return jnp.sum(_swar_pop_j(bits), axis=1)

    @jax.jit
    def _take_first_k_jit(bits, k):
        return _rank_select_j(bits, k)

    @jax.jit
    def _kth_set_index_jit(bits, k):
        return _rank_query_j(bits, k)

    @jax.jit
    def _take_and_cut_jit(bits, k):
        # fused eviction rank-select + rank-query: ONE dispatch yields
        # both the take mask and the scan cut, the packed run staying
        # device-resident between the two ops
        return _rank_select_j(bits, k), _rank_query_j(bits, k)

    @jax.jit
    def _coverage_multi_jit(delta):
        return jnp.cumsum(delta) >= 2

    @jax.jit
    def _phase_step_jit(bits, base, rowmask, sbases, sends):
        """Fused barrier-flush chain over R stacked regions — ONE device
        dispatch per protocol phase, ``lax.scan`` carrying the per-region
        loop.  Per region: per-row dirty popcount (the writeback charge),
        the shared-coverage test (a page is a sharer-invalidation
        candidate iff covered by >= 2 live worker windows — evaluated
        per cell as a searchsorted stab of the sorted window bounds,
        equivalent to the numpy path's interval sweep), and the
        shared-dirty candidate mask (dirty ∧ multi-covered ∧ active row)
        packed back to uint32.  The packed planes never leave the device
        between the chained ops.

        bits (R, W, nw) uint32; base (R, W) int32 row window offsets
        (-1 rows have all-zero bits); rowmask (R, W) bool flush mask;
        sbases/sends (R, W) int32 sorted live window bounds padded with
        INT32_MAX (a pad entry stabs nothing).  Returns
        (counts (R, W) int32, shared (R, W, nw) uint32).
        """
        nw = bits.shape[2]
        col = (jnp.arange(nw, dtype=jnp.int32)[:, None] * 32
               + jnp.arange(32, dtype=jnp.int32)[None, :])   # (nw, 32)
        lanes = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)

        def step(_, xs):
            b, base_r, rowm, sb, se = xs
            counts = jnp.sum(_swar_pop_j(b), axis=1)         # (W,)
            active = rowm & (counts > 0)
            page = base_r[:, None, None] + col[None]         # (W, nw, 32)
            flat = page.reshape(-1)
            cov = (jnp.searchsorted(sb, flat, side="right")
                   - jnp.searchsorted(se, flat, side="right"))
            multi = (cov >= 2).reshape(page.shape)
            mbits = jnp.sum(jnp.where(multi, lanes, jnp.uint32(0)),
                            axis=-1, dtype=jnp.uint32)       # (W, nw)
            shared = jnp.where(active[:, None], b & mbits, jnp.uint32(0))
            return None, (counts, shared)

        _, (counts, shared) = jax.lax.scan(
            step, None, (bits, base, rowmask, sbases, sends))
        return counts, shared


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def _k32(k) -> np.ndarray:
    return np.minimum(np.asarray(k, np.int64),
                      np.iinfo(np.int32).max).astype(np.int32)


def popcount_rows(bits: np.ndarray, *, backend: str = "numpy",
                  stats: Optional[dict] = None) -> np.ndarray:
    """(W, n_words) uint32 -> (W,) int64 per-row set-bit counts."""
    if bits.shape[1] == 0:
        return np.zeros(bits.shape[0], np.int64)
    b = resolve_backend(backend)
    if b == "pallas-jit":
        out = np.asarray(_popcount_rows_jit(jnp.asarray(bits)))
        _note_dispatch(stats, ("popcount", bits.shape))
        return out.astype(np.int64)
    if b == "pallas":
        return _popcount_rows_pallas(bits)
    return _popcount_rows_np(bits)


def take_first_k(bits: np.ndarray, k: np.ndarray, *,
                 backend: str = "numpy",
                 stats: Optional[dict] = None) -> np.ndarray:
    """(R, n_words) uint32 + (R,) counts -> packed mask of each row's first
    k[i] set bits in little-endian column order (the batched eviction
    engine's segment-LRU victim selection)."""
    if bits.shape[1] == 0:
        return np.zeros_like(bits, np.uint32)
    b = resolve_backend(backend)
    if b == "pallas-jit":
        out = np.asarray(_take_first_k_jit(jnp.asarray(bits),
                                           jnp.asarray(_k32(k))))
        _note_dispatch(stats, ("take_first_k", bits.shape))
        return out
    if b == "pallas":
        return _take_first_k_pallas(bits, k)
    return _take_first_k_np(bits, np.asarray(k, np.int64))


def kth_set_index(bits: np.ndarray, k: np.ndarray, *,
                  backend: str = "numpy",
                  stats: Optional[dict] = None) -> np.ndarray:
    """(R, n_words) uint32 + (R,) ranks -> (R,) little-endian column index
    of each row's k[i]-th (1-based) set bit, -1 when out of range (the
    refetch replay engine's victim-scan cut)."""
    if bits.shape[1] == 0:
        return np.full(bits.shape[0], -1, np.int64)
    b = resolve_backend(backend)
    if b == "pallas-jit":
        out = np.asarray(_kth_set_index_jit(jnp.asarray(bits),
                                            jnp.asarray(_k32(k))))
        _note_dispatch(stats, ("kth_set_index", bits.shape))
        return out.astype(np.int64)
    if b == "pallas":
        return _kth_set_index_pallas(bits, np.asarray(k, np.int64))
    return _kth_set_index_np(bits, np.asarray(k, np.int64))


def coverage_multi(delta: np.ndarray, *, backend: str = "numpy",
                   stats: Optional[dict] = None) -> np.ndarray:
    """Sorted-bound deltas (+1 window start / -1 window end) -> boolean
    mask of sweep points where the running cover count is >= 2."""
    b = resolve_backend(backend)
    if b == "pallas-jit":
        out = np.asarray(_coverage_multi_jit(
            jnp.asarray(delta.astype(np.int32))))
        _note_dispatch(stats, ("coverage", delta.shape))
        return out
    if b == "pallas":
        return _coverage_multi_pallas(delta.astype(np.int32))
    return np.cumsum(delta) >= 2


def take_and_cut(bits: np.ndarray, k: np.ndarray, *,
                 backend: str = "numpy",
                 stats: Optional[dict] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Fused eviction rank-select + rank-query: the packed first-k take
    mask AND the per-row scan cut (index of the k[i]-th set bit) in one
    call — ONE device dispatch on 'pallas-jit' (the refetch replay
    engine's victim scan); two numpy passes otherwise."""
    if bits.shape[1] == 0:
        return (np.zeros_like(bits, np.uint32),
                np.full(bits.shape[0], -1, np.int64))
    b = resolve_backend(backend)
    if b == "pallas-jit":
        take, cut = _take_and_cut_jit(jnp.asarray(bits),
                                      jnp.asarray(_k32(k)))
        _note_dispatch(stats, ("take_and_cut", bits.shape))
        return np.asarray(take), np.asarray(cut).astype(np.int64)
    kk = np.asarray(k, np.int64)
    if b == "pallas":
        return (_take_first_k_pallas(bits, kk),
                _kth_set_index_pallas(bits, kk))
    return _take_first_k_np(bits, kk), _kth_set_index_np(bits, kk)


def phase_step(bits: np.ndarray, base: np.ndarray, rowmask: np.ndarray,
               sbases: np.ndarray, sends: np.ndarray, *,
               stats: Optional[dict] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
    """The fused barrier-flush chain ('pallas-jit' only): R stacked
    regions' packed dirty planes in, per-row dirty counts + packed
    shared-dirty candidate masks out, as ONE jitted device dispatch
    (``lax.scan`` over the region axis).  Inputs per
    ``_phase_step_jit``; numpy fallback exists only for the oracle
    tests — the runtime routes non-jit backends through the unfused
    path."""
    if resolve_backend("pallas-jit") == "pallas-jit":
        counts, shared = _phase_step_jit(
            jnp.asarray(bits), jnp.asarray(base), jnp.asarray(rowmask),
            jnp.asarray(sbases), jnp.asarray(sends))
        _note_dispatch(stats, ("phase_step", bits.shape))
        return np.asarray(counts).astype(np.int64), np.asarray(shared)
    return _phase_step_np(bits, base, rowmask, sbases, sends)


def _phase_step_np(bits, base, rowmask, sbases, sends):
    """Numpy oracle of the fused flush chain (tests + no-jax fallback)."""
    R, W, nw = bits.shape
    counts = np.stack([_popcount_rows_np(bits[r]) for r in range(R)])
    shared = np.zeros_like(bits)
    col = (np.arange(nw, dtype=np.int64)[:, None] * 32
           + np.arange(32, dtype=np.int64)[None, :])
    lanes = np.uint32(1) << np.arange(32, dtype=np.uint32)
    for r in range(R):
        active = rowmask[r] & (counts[r] > 0)
        page = base[r].astype(np.int64)[:, None, None] + col[None]
        cov = (np.searchsorted(sbases[r], page.ravel(), side="right")
               - np.searchsorted(sends[r], page.ravel(), side="right"))
        multi = (cov >= 2).reshape(page.shape)
        mbits = np.where(multi, lanes, np.uint32(0)).sum(
            axis=-1, dtype=np.uint32)
        shared[r] = np.where(active[:, None], bits[r] & mbits, 0)
    return counts.astype(np.int64), shared
