try:
    from repro.kernels.ops import (diff_apply, diff_encode, flash_attention,
                                   ssd_chunk)
except ImportError:
    try:
        import jax  # noqa: F401 — jax imports fine: the failure is a real
        # defect in the kernel modules and must propagate, not be masked
        # as a missing-dependency fallback
    except ImportError:
        # jax absent: the Pallas kernel surface is unavailable, but the
        # numpy-backed modules (protocol_sweep fallbacks, the scale
        # runtime's directory engine) must stay importable — they gate
        # jax themselves.
        diff_apply = diff_encode = flash_attention = ssd_chunk = None
    else:
        raise
