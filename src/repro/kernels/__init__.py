"""One import surface for every kernel (the lite_llama idiom): protocol
plane-sweep kernels, fused jit chains, and the model-layer Pallas ops all
resolve from ``repro.kernels`` directly.  Backend availability is probed
once (``available_backends`` — honors ``REPRO_FORCE_NUMPY=1``); the
protocol kernels degrade to their numpy tiers when jax is absent, while
the model-layer ops (which have no numpy twin) surface as ``None``.
"""
from repro.kernels.protocol_sweep import (HAVE_PALLAS,  # noqa: F401
                                          available_backends,
                                          coverage_multi, kth_set_index,
                                          pack_mask_rows, phase_step,
                                          popcount_rows, resolve_backend,
                                          take_and_cut, take_first_k,
                                          unpack_mask_rows)

try:
    from repro.kernels.ops import (diff_apply, diff_encode, flash_attention,
                                   ssd_chunk)
except ImportError:
    try:
        import jax  # noqa: F401 — jax imports fine: the failure is a real
        # defect in the kernel modules and must propagate, not be masked
        # as a missing-dependency fallback
    except ImportError:
        # jax absent: the Pallas kernel surface is unavailable, but the
        # numpy-backed modules (protocol_sweep fallbacks, the scale
        # runtime's directory engine) must stay importable — they gate
        # jax themselves.
        diff_apply = diff_encode = flash_attention = ssd_chunk = None
    else:
        raise

__all__ = ["HAVE_PALLAS", "available_backends", "resolve_backend",
           "pack_mask_rows", "unpack_mask_rows", "popcount_rows",
           "coverage_multi", "take_first_k", "kth_set_index",
           "take_and_cut", "phase_step",
           "diff_apply", "diff_encode", "flash_attention", "ssd_chunk"]
