from repro.kernels.ops import diff_apply, diff_encode, flash_attention, ssd_chunk
