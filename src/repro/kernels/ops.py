"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on real TPU
— the kernel *code* is identical; interpret mode executes the same kernel
body with pure-JAX semantics for validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import page_diff as _pd
from repro.kernels import ssd_chunk as _sc


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def diff_encode(curr, twin, *, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _pd.diff_encode(curr, twin, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def diff_apply(dst, mask, vals, *, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _pd.diff_apply(dst, mask, vals, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "softcap", "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, *, scale=None, causal=True, window=None,
                    softcap=None, q_block=128, kv_block=128, interpret=None):
    if interpret is None:
        interpret = _default_interpret()
    return _fa.flash_attention(
        q, k, v, scale=scale, causal=causal, window=window, softcap=softcap,
        q_block=q_block, kv_block=kv_block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, dt, cum, B_, C_, *, interpret: bool = None):
    if interpret is None:
        interpret = _default_interpret()
    return _sc.ssd_chunk(x, dt, cum, B_, C_, interpret=interpret)
