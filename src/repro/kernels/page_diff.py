"""Pallas TPU kernels for RegC page twin-diffing — the consistency-region
hot spot (DESIGN.md §4.2).

The paper instruments every store with an LLVM pass to track consistency-
region modifications.  On TPU there are no store traps; instead, a span
snapshots *twins* of the pages it may write and, at release, diffs the
current page content against the twin at word granularity:

* ``diff_encode``  — mask = (curr != twin); vals = curr*mask; count per page.
  The protocol layer transmits ``count*4 + W/8`` bytes per dirty page
  (packed values + bitmask) instead of the full page — the fine-grained
  update of the `samhita` protocol.
* ``diff_apply``   — applies (mask, vals) onto the home copy at the memory
  server (or onto a stale cached copy at an acquiring worker).

Pages are (page_words,) fp32 rows; a page of 4 KiB = 1024 words maps onto
(8, 128) VMEM tiles exactly.  Grid tiles PAGES_PER_BLOCK pages per step so
arbitrary page counts stream through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

PAGES_PER_BLOCK = 8


def _diff_encode_kernel(curr_ref, twin_ref, mask_ref, vals_ref, count_ref):
    curr = curr_ref[...]
    twin = twin_ref[...]
    # bitwise comparison (memcmp semantics): float equality would miss
    # denormals under FTZ and mis-handle -0.0 / NaN
    changed = jax.lax.bitcast_convert_type(curr, jnp.int32) != \
        jax.lax.bitcast_convert_type(twin, jnp.int32)
    mask_ref[...] = changed.astype(jnp.int8)
    vals_ref[...] = jnp.where(changed, curr, 0.0)
    count_ref[...] = jnp.sum(changed.astype(jnp.int32), axis=1)


def _diff_apply_kernel(dst_ref, mask_ref, vals_ref, out_ref):
    mask = mask_ref[...] != 0
    out_ref[...] = jnp.where(mask, vals_ref[...], dst_ref[...])


def _grid_for(n_pages: int):
    ppb = min(PAGES_PER_BLOCK, n_pages)
    assert n_pages % ppb == 0, (n_pages, ppb)
    return n_pages // ppb, ppb


def diff_encode(curr, twin, *, interpret: bool = False):
    """curr/twin: (n_pages, page_words) f32.
    Returns (mask i8 (n,W), vals f32 (n,W), count i32 (n,))."""
    n, w = curr.shape
    g, ppb = _grid_for(n)
    page_spec = pl.BlockSpec((ppb, w), lambda i: (i, 0))
    return pl.pallas_call(
        _diff_encode_kernel,
        grid=(g,),
        in_specs=[page_spec, page_spec],
        out_specs=[
            pl.BlockSpec((ppb, w), lambda i: (i, 0)),
            pl.BlockSpec((ppb, w), lambda i: (i, 0)),
            pl.BlockSpec((ppb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, w), jnp.int8),
            jax.ShapeDtypeStruct((n, w), curr.dtype),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(curr, twin)


def diff_apply(dst, mask, vals, *, interpret: bool = False):
    """dst (n,W) f32; mask (n,W) i8; vals (n,W) f32 -> updated dst."""
    n, w = dst.shape
    g, ppb = _grid_for(n)
    spec = pl.BlockSpec((ppb, w), lambda i: (i, 0))
    return pl.pallas_call(
        _diff_apply_kernel,
        grid=(g,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n, w), dst.dtype),
        interpret=interpret,
    )(dst, mask, vals)
