"""Pallas TPU flash attention (GQA, causal, sliding-window, logit softcap).

Grid: (batch * q_heads, nq, nk) with the kv axis innermost so the online-
softmax accumulators live in VMEM scratch across kv steps.  BlockSpec index
maps pick the right (q block, kv block, kv head) tile; GQA is native — the
kv index map divides the head index by the group size, so KV is never
repeated in HBM.  Fully-masked (future) kv blocks are skipped with
``pl.when``, so causal attention does ~half the FLOPs of the XLA blocked
path — this is the kernel-level hillclimb lever for the compute term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, softcap, q_block, kv_block, nk):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * q_block
    k_start = ki * kv_block

    # skip kv blocks that are entirely masked
    live = True
    if causal:
        live = k_start <= q_start + q_block - 1
    if window is not None:
        live = jnp.logical_and(
            live, k_start + kv_block - 1 >= q_start - window + 1)

    @pl.when(live)
    def _body():
        q = q_ref[0]                                   # (qb, D)
        k = k_ref[0]                                   # (kb, D)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (qb, kb)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, scale=None, causal=True, window=None,
                    softcap=None, q_block=128, kv_block=128,
                    interpret: bool = False):
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D) -> (B, Hq, S, D)."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = D ** -0.5 if scale is None else scale
    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    assert S % q_block == 0 and S % kv_block == 0
    nq, nk = S // q_block, S // kv_block

    qr = q.reshape(B * Hq, S, D)
    kr = k.reshape(B * Hkv, S, D)
    vr = v.reshape(B * Hkv, S, D)

    def kv_map(h, qi, ki):
        return (h // (Hq // Hkv) % Hkv + (h // Hq) * Hkv, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, q_block=q_block, kv_block=kv_block, nk=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, kv_block, D), kv_map),
            pl.BlockSpec((1, kv_block, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, q_block, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu_scratch((q_block, 1), jnp.float32),
            pltpu_scratch((q_block, 1), jnp.float32),
            pltpu_scratch((q_block, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, S, D)


def pltpu_scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
