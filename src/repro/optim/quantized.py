"""Blockwise-int8 AdamW state (beyond-paper optimization, §Perf C-series).

Why: at 405B on a 256-chip pod, f32 AdamW m+v alone is 11.8 GiB/device of
the 16 GiB HBM — training cannot fit regardless of activation policy.  The
fix (bitsandbytes-style) stores both moments as int8 with per-block absmax
scales: 8 bytes/param -> ~2.06 bytes/param.

Two representation choices that matter at scale:

* blocks run along the LAST axis only (shape (..., ceil(last/128)) scales) —
  a flatten-the-leaf layout would destroy the parameter's GSPMD sharding
  and force a full f32 gather of every moment at dequantize time (measured:
  6.7 TB/device on llama3-405b — §Perf C4 refuted iteration);
* v is stored as sqrt(v): linear absmax int8 on raw v zeroes small entries
  whose block-mate is large while their m survives -> m/(0+eps) update
  explosions.  sqrt halves the dynamic range and makes m and sigma quantize
  to zero together (|m| <~ sigma), which is benign.

The update dequantizes, applies AdamW, re-quantizes; quantization noise is
bounded by absmax/127 per block and is second-order for Adam.  Convergence
is asserted by ``tests/test_quantized_opt.py`` against the f32 reference.

This is the training-layer twin of the paper's fine-grained *diffs*: store /
ship the compressed representation of slowly-varying state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, clip_by_global_norm
from repro.utils.tree import global_sq_norm

BLOCK = 128


def _last_pad(last: int) -> int:
    return (-last) % BLOCK


def scale_shape(shape) -> Tuple[int, ...]:
    if not shape:
        return (1,)
    last = int(shape[-1])
    return tuple(shape[:-1]) + ((last + BLOCK - 1) // BLOCK,)


def quantize_blockwise(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., L) f32 -> (q int8 same shape, scales f32 (..., ceil(L/128))).

    Blocks along the last axis ONLY: leading dims (and their shardings)
    pass through untouched."""
    if x.ndim == 0:
        x = x[None]
        q, s = quantize_blockwise(x)
        return q[0], s
    last = x.shape[-1]
    pad = _last_pad(last)
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xp.reshape(*x.shape[:-1], -1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    q = q.reshape(*x.shape[:-1], last + pad)[..., :last]
    return q, scale


def dequantize_blockwise(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    if q.ndim == 0:
        return dequantize_blockwise(q[None], scale)[0]
    last = q.shape[-1]
    pad = _last_pad(last)
    qp = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    qb = qp.reshape(*q.shape[:-1], -1, BLOCK).astype(jnp.float32)
    out = qb * scale[..., None]
    return out.reshape(*q.shape[:-1], last + pad)[..., :last]


def init_opt_state_q8(params):
    def leaf(p):
        return {
            "m_q": jnp.zeros(p.shape, jnp.int8),
            "m_s": jnp.zeros(scale_shape(p.shape), jnp.float32),
            "v_q": jnp.zeros(p.shape, jnp.int8),
            "v_s": jnp.zeros(scale_shape(p.shape), jnp.float32),
        }
    return jax.tree.map(leaf, params)


def adamw8bit_update(params, grads, state, step, lr, cfg: AdamWConfig):
    """Drop-in replacement for adamw_update with int8 m / sqrt-v."""
    sq = global_sq_norm(grads)
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm, sq_norm=sq)
    else:
        gnorm = jnp.sqrt(sq)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def leaf(p, g, s):
        g = g.astype(jnp.float32)
        m = dequantize_blockwise(s["m_q"], s["m_s"])
        sigma = dequantize_blockwise(s["v_q"], s["v_s"])
        v = sigma * sigma
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = (p.astype(jnp.float32)
                 - lr * (upd + wd * p.astype(jnp.float32))).astype(p.dtype)
        m_q, m_s = quantize_blockwise(m)
        v_q, v_s = quantize_blockwise(jnp.sqrt(v))
        return new_p, {"m_q": m_q, "m_s": m_s, "v_q": v_q, "v_s": v_s}

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_s = tdef.flatten_up_to(state)
    outs = [leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_state = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_params, new_state, gnorm


def opt_bytes_per_param() -> float:
    """int8 q (x2) + f32 scale per 128 block (x2) = 2.0625 B/param."""
    return 2.0 + 8.0 / BLOCK
