"""AdamW with fp32 master moments, decoupled weight decay and global-norm
clipping.  Optimizer state is a pytree with the same structure (and logical
sharding) as the parameters, so FSDP/ZeRO sharding of m/v falls out of the
params' ``embed_fsdp`` axes for free — the 'memory server striping' of the
paper, applied to optimizer state."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.utils.tree import global_sq_norm, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def init_opt_state(params):
    return {
        "m": tree_zeros_like(params, jnp.float32),
        "v": tree_zeros_like(params, jnp.float32),
    }


def _decay_mask(p):
    return jnp.asarray(1.0 if p.ndim >= 2 else 0.0, jnp.float32)


def clip_by_global_norm(grads, max_norm, *, sq_norm=None):
    """sq_norm may be supplied externally (RegC path: psum of local sq-norms
    via the reduction extension)."""
    if sq_norm is None:
        sq_norm = global_sq_norm(grads)
    norm = jnp.sqrt(sq_norm)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, state, step, lr, cfg: AdamWConfig):
    """Returns (new_params, new_state, grad_norm)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = jnp.sqrt(global_sq_norm(grads))
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * g
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * (g * g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * _decay_mask(p) * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v}, gnorm


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1) -> Callable:
    def sched(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = base_lr * (step + 1.0) / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return sched
