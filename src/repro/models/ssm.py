"""Mamba-2 SSD (state-space duality) mixer — arXiv:2405.21060.

Train/prefill uses the chunked SSD algorithm: quadratic *within* a chunk
(maps onto the MXU), recurrent *across* chunks (``lax.scan`` carrying the
(B, H, P, N) state).  Decode is the O(1)-per-token recurrence.  The
intra-chunk part has a Pallas kernel (``repro.kernels.ssd_chunk``); this file
is the pure-XLA implementation used for CPU tests and dry-run lowering.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.sharding import ShardingCtx, constrain


def ssd_chunked(x, dt, A, B_, C_, *, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x: (B, S, H, P)   dt: (B, S, H)  (already softplus'd, >0)
    A: (H,)           (negative)
    B_, C_: (B, S, G, N), H % G == 0
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # pad with dt=0 tokens: zero input weight, unit decay -> state-neutral
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q
    hg = H // G  # heads per B/C group

    xc = x.reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H).astype(jnp.float32)
    Bc = B_.reshape(Bb, nc, Q, G, N)
    Cc = C_.reshape(Bb, nc, Q, G, N)

    dA = dtc * A.astype(jnp.float32)                     # (B,nc,Q,H) negative
    cum = jnp.cumsum(dA, axis=2)                         # within-chunk cumsum

    # ---- intra-chunk (quadratic, MXU-friendly) ----------------------------
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc,
                    preferred_element_type=jnp.float32)  # (B,nc,G,Q,Q)
    CB = jnp.repeat(CB, hg, axis=2)                      # (B,nc,H,Q,Q)
    # decay_ij = exp(cum_i - cum_j), causal.  Mask BEFORE the exp: in the
    # non-causal triangle cum_i - cum_j > 0 and exp overflows to inf, which
    # the where() would hide in the forward but turn into 0*inf = NaN in the
    # backward (where-grad still differentiates the dead branch).
    cum_h = cum.transpose(0, 1, 3, 2)                    # (B,nc,H,Q)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    delta = cum_h[..., :, None] - cum_h[..., None, :]
    decay = jnp.exp(jnp.where(causal, delta, -jnp.inf))  # exact 0 off-causal
    scores = CB * decay
    scores = scores * dtc.transpose(0, 1, 3, 2)[..., None, :]  # * dt_j
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, xc,
                         preferred_element_type=jnp.float32)

    # ---- per-chunk input states ------------------------------------------
    last = cum_h[..., -1:]                               # (B,nc,H,1)
    w_in = jnp.exp(last - cum_h) * dtc.transpose(0, 1, 3, 2)  # (B,nc,H,Q)
    B_heads = jnp.repeat(Bc, hg, axis=3)                 # (B,nc,Q,H,N)
    chunk_states = jnp.einsum("bchq,bcqhn,bcqhp->bchpn",
                              w_in, B_heads, xc,
                              preferred_element_type=jnp.float32)

    # ---- inter-chunk recurrence ------------------------------------------
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))           # (B,nc,H)
    if initial_state is None:
        initial_state = jnp.zeros((Bb, H, P, N), jnp.float32)
    else:
        initial_state = initial_state.astype(jnp.float32)

    def step(h, inp):
        dec, s_in = inp                                  # (B,H), (B,H,P,N)
        h_prev = h
        h_new = h * dec[..., None, None] + s_in
        return h_new, h_prev

    dec_s = chunk_decay.transpose(1, 0, 2)               # (nc,B,H)
    st_s = chunk_states.transpose(1, 0, 2, 3, 4)         # (nc,B,H,P,N)
    final_state, prev_states = lax.scan(step, initial_state, (dec_s, st_s))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (B,nc,H,P,N)

    # ---- inter-chunk output contribution ----------------------------------
    C_heads = jnp.repeat(Cc, hg, axis=3)                 # (B,nc,Q,H,N)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", C_heads, prev_states,
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y[:, :S_orig], final_state


def ssd_reference(x, dt, A, B_, C_, *, initial_state=None):
    """O(S) sequential oracle (tests only)."""
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    hg = H // G
    h0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B_, hg, axis=2).astype(jnp.float32)  # (B,S,H,N)
    Cf = jnp.repeat(C_, hg, axis=2).astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                            # (B,H,P),(B,H),(B,H,N)x2
        dec = jnp.exp(dtt * A.astype(jnp.float32))       # (B,H)
        h = h * dec[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dtt, bt, xt)
        y = jnp.einsum("bhn,bhpn->bhp", ct, h)
        return h, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dtf.transpose(1, 0, 2), Bf.transpose(1, 0, 2, 3),
          Cf.transpose(1, 0, 2, 3))
    hT, ys = lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), hT


# ---------------------------------------------------------------------------
# Full Mamba-2 block
# ---------------------------------------------------------------------------


def _causal_conv(seq, w, b, tail=None):
    """Depthwise causal conv1d.  seq: (B, S, Cdim); w: (K, Cdim); b: (Cdim,).
    tail: (B, K-1, Cdim) carried context (decode / prefill continuation)."""
    K = w.shape[0]
    Bb = seq.shape[0]
    if tail is None:
        tail = jnp.zeros((Bb, K - 1, seq.shape[-1]), seq.dtype)
    full = jnp.concatenate([tail, seq], axis=1)
    out = sum(
        full[:, i : i + seq.shape[1]] * w[i][None, None, :] for i in range(K)
    )
    new_tail = full[:, -(K - 1):] if K > 1 else tail
    return jax.nn.silu(out + b[None, None, :]), new_tail


def mamba2_block(params, x, cfg, ctx: Optional[ShardingCtx], *,
                 cache=None, mode: str = "train"):
    """mode: 'train' | 'prefill' | 'decode'.
    cache (decode): (conv_tail (B,K-1,conv_dim), ssm_state (B,H,P,N)).
    Returns (out, new_cache) — new_cache is None for train."""
    s = cfg.ssm
    B, S, d = x.shape
    d_in = s.expand * cfg.d_model
    gn = s.n_groups * s.d_state
    H = d_in // s.head_dim
    P = s.head_dim
    N = s.d_state
    G = s.n_groups
    # separate projections (clean TP sharding; no post-matmul slicing)
    z = jnp.einsum("bsd,de->bse", x, params["in_z"])
    xr = jnp.einsum("bsd,de->bse", x, params["in_x"])
    Br = jnp.einsum("bsd,de->bse", x, params["in_B"])
    Cr = jnp.einsum("bsd,de->bse", x, params["in_C"])
    dtr = jnp.einsum("bsd,dh->bsh", x, params["in_dt"])

    # the packed x/B/C conv activation must stay replicated on its feature
    # dim: the concat/split boundaries (d_in, d_in+gn) don't align with a
    # 'model' sharding of the packed dim, and GSPMD (jax 0.4.37) miscompiles
    # the straddling concat/split when the batch dim is replicated
    # (DECODE_2D_RULES) — wrong VALUES, not just extra collectives.  xh
    # re-shards over 'ssm_in' right after the split, so TP sharding of the
    # SSD math is unaffected; the replicated tensor is only (B, S, conv_dim).
    conv_in = jnp.concatenate([xr, Br, Cr], axis=-1)
    conv_in = constrain(conv_in, ("batch", "seq", None), ctx)
    tail_in = cache[0] if (cache is not None) else None
    conv_out, new_tail = _causal_conv(conv_in, params["conv_w"], params["conv_b"],
                                      tail=tail_in)
    conv_out = constrain(conv_out, ("batch", "seq", None), ctx)
    xr, Br, Cr = jnp.split(conv_out, [d_in, d_in + gn], axis=-1)

    xh = xr.reshape(B, S, H, P)
    xh = constrain(xh, ("batch", "seq", "ssm_in", None), ctx)
    Bm = Br.reshape(B, S, G, N)
    Cm = Cr.reshape(B, S, G, N)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    init_state = cache[1] if (cache is not None) else None
    if mode == "decode" and S == 1:
        # O(1) recurrence
        h = init_state.astype(jnp.float32)
        hg = H // G
        Bh = jnp.repeat(Bm, hg, axis=2)[:, 0]            # (B,H,N)
        Ch = jnp.repeat(Cm, hg, axis=2)[:, 0]
        dt0 = dt[:, 0]                                   # (B,H)
        dec = jnp.exp(dt0 * A)
        h = h * dec[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt0, Bh, xh[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", Ch, h)[:, None]  # (B,1,H,P)
        new_state = h
    else:
        y, new_state = ssd_chunked(xh, dt, A, Bm, Cm, chunk=s.chunk,
                                   initial_state=init_state)

    y = y + xh.astype(jnp.float32) * params["D"].astype(jnp.float32)[:, None]
    y = y.reshape(B, S, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    # gated RMSNorm
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * lax.rsqrt(var + cfg.norm_eps) * (1.0 + params["gate_ln"].astype(jnp.float32))
    y = y.astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])

    new_cache = None if mode == "train" else (new_tail, new_state)
    return out, new_cache
