"""Logical-axis sharding: every parameter/activation carries logical axis
names; a rules table maps logical axes -> mesh axes per parallelism config.

The rules engine only applies a mesh axis when the dimension is divisible by
the product of mesh-axis sizes (GSPMD requires equal shards); otherwise the
dimension falls back to replication.  This is what makes e.g. grok-1's 8
experts work on a 16-way `model` axis (experts replicate, d_ff shards) and
granite's MQA kv=1 head replicate while q heads shard.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis vocabulary -----------------------------------------------------
#   batch      global batch dim                      -> DP (pod, data)
#   seq        sequence dim (activations)            -> SP ('model') optionally
#   kv_seq     KV-cache sequence dim                 -> context parallel ('data')
#   embed      d_model                               -> replicated (activations)
#   embed_fsdp d_model on *params*                   -> FSDP ('data')
#   heads      q heads                               -> TP ('model')
#   kv_heads   kv heads                              -> TP if divisible
#   mlp        d_ff                                  -> TP ('model')
#   vocab      vocabulary                            -> TP ('model')
#   expert     MoE expert dim                        -> EP ('model')
#   layers     stacked super-block dim               -> never sharded
#   ssm_in     SSD inner dim (expand*d_model)        -> TP ('model')
#   conv / state / groups / misc                     -> replicated

Rules = Dict[str, Optional[Tuple[str, ...]]]

# Production default: DP over (pod, data), FSDP params over data, TP over model.
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": None,     # sequence sharding of SAVED layer boundaries only
    "kv_seq": None,
    "embed": None,
    "embed_fsdp": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "ssm_in": ("model",),
    "layers": None,
    "conv": None,
    "state": None,
    "groups": None,
    None: None,
}

# Small-model training (d_model <= ~3k): Megatron-style TP=16 is collective-
# bound (4 all-reduces of (B,S,d) per layer vs O(d^2) flops), so the 'model'
# axis is spent on extra data parallelism instead; params/opt shard over
# 'data' (FSDP) which keeps optimizer state under HBM.
SMALL_MODEL_RULES: Rules = dict(
    DEFAULT_RULES,
    batch=("pod", "data", "model"),
    heads=None, kv_heads=None, mlp=None, expert=None, ssm_in=None,
    vocab=("model",),   # CE logits stay sharded (the big activation)
)

# (defined after SERVE_RULES below)

# Serving (decode): KV cache is the dominant state.  GQA kv-head counts (8)
# don't divide the 16-way model axis, so the cache shards its *seq* dim over
# 'model' (distributed-softmax decode; GSPMD inserts the psum combine).
SERVE_RULES: Rules = dict(
    DEFAULT_RULES,
    kv_seq=("model",),
    kv_heads=None,
)

# Small models at decode: TP stays (1-token activations make its all-reduce
# negligible) but FSDP is dropped — replicating a few GB of weights beats a
# per-layer all-gather that moves 15/16 of the weights per generated token.
SMALL_SERVE_RULES: Rules = dict(SERVE_RULES, embed_fsdp=None)

# Long-context decode (global_batch=1): context-parallel KV over every
# available axis (batch=1 cannot use them otherwise).
LONG_CONTEXT_RULES: Rules = dict(
    DEFAULT_RULES,
    batch=None,
    kv_seq=("pod", "data", "model"),
    kv_heads=None,
    seq=None,
)

# Decode for big dense models (§Perf B-series): baseline SERVE_RULES
# re-gathers the FSDP-sharded weights every generated token (~100 GB/device
# of all-gather per step on llama3-405b).  Here weights stay 2-D sharded
# (embed_fsdp x TP) and are NEVER gathered (pair with gather_fsdp=False);
# instead the *batch* is replicated and activations shard their d_model dim
# over 'data', so every matmul is a local partial dot + a psum of one
# activation row.  Decode FLOPs are tiny (memory-bound), so the replicated
# batch compute is free; the KV cache context-shards over BOTH axes.
DECODE_2D_RULES: Rules = dict(
    DEFAULT_RULES,
    batch=None,
    embed=("data",),
    kv_seq=("data", "model"),
    kv_heads=None,
)

# Sequence-parallel boundaries (§Perf C-series): the residual carry saved at
# every super-block boundary for the backward pass is resharded over 'model'
# along seq — 16x less live activation memory, at the cost of one
# (re)gather per super-block in forward and recompute.
TRAIN_SP_RULES: Rules = dict(DEFAULT_RULES, seq_sp=("model",))

# ZeRO across pods (§Perf C5): params/optimizer/grads shard over BOTH the
# pod and data axes (32-way FSDP x 16-way TP = 512-way state sharding on the
# multi-pod mesh).  Weight gathers then cross the inter-pod links too.
FSDP_POD_RULES: Rules = dict(DEFAULT_RULES, embed_fsdp=("pod", "data"))

# long-context decode with the 2-D no-regather treatment (pair with
# gather_fsdp=False): activations shard d_model over 'data'; weights never
# regathered per token (§Perf B-series generalized to long_500k)
LONG_2D_RULES: Rules = dict(LONG_CONTEXT_RULES, embed=("data",))

NAMED_RULES = {
    "default": None,
    "decode2d": DECODE_2D_RULES,
    "long": LONG_CONTEXT_RULES,
    "long2d": LONG_2D_RULES,
    "serve": SERVE_RULES,
    "small": SMALL_MODEL_RULES,
    "train_sp": TRAIN_SP_RULES,
    "fsdp_pod": FSDP_POD_RULES,
}


@dataclasses.dataclass
class ShardingCtx:
    """Mesh + rules; ``None`` ctx means single-device (tests).

    gather_fsdp: constrain FSDP-sharded weights to replicated before each
    layer (gather-weights semantics — right for training).  False keeps the
    'embed_fsdp' shard on the weights and pays a small activation psum per
    matmul instead — right for decode, where regathering the full weight set
    per generated token dominates the collective term (§Perf).
    moe_impl: 'dense' (GSPMD dense dispatch) | 'ep' (shard_map expert
    parallelism, one activation psum per layer)."""

    mesh: Mesh
    rules: Rules
    gather_fsdp: bool = True
    moe_impl: str = "dense"

    def axis_size(self, names: Tuple[str, ...]) -> int:
        n = 1
        for name in names:
            n *= self.mesh.shape[name]
        return n

    def spec_for(self, shape: Sequence[int], axes: Sequence[Optional[str]]) -> P:
        assert len(shape) == len(axes), (shape, axes)
        parts = []
        for dim, ax in zip(shape, axes):
            mesh_axes = self.rules.get(ax)
            if not mesh_axes:
                parts.append(None)
                continue
            mesh_axes = tuple(m for m in mesh_axes if m in self.mesh.shape)
            # divisibility fallback: longest prefix of the axis tuple that
            # divides the dim (e.g. batch=(pod,data,model) -> (pod,data))
            while mesh_axes and dim % self.axis_size(mesh_axes) != 0:
                mesh_axes = mesh_axes[:-1]
            if mesh_axes:
                parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
            else:
                parts.append(None)
        # PartitionSpec must not reuse a mesh axis twice; later dims lose.
        used = set()
        clean = []
        for p in parts:
            tup = (p,) if isinstance(p, str) else (p or ())
            if any(t in used for t in tup):
                clean.append(None)
            else:
                used.update(tup)
                clean.append(p)
        return P(*clean)

    def sharding_for(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, axes))


def constrain(x, axes: Sequence[Optional[str]], ctx: Optional[ShardingCtx]):
    """with_sharding_constraint by logical axes (no-op when ctx is None)."""
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, ctx.sharding_for(x.shape, axes))


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # 'normal' | 'zeros' | 'ones' | 'scaled'
    scale: float = 1.0         # stddev for 'normal'; fan-in applied for 'scaled'

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_param(spec: ParamSpec, key, dtype):
    import jax.numpy as jnp

    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "scaled":  # fan-in scaled normal (last-but-one dim = fan_in)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale * (fan_in ** -0.5)
        return (jax.random.normal(key, spec.shape) * std).astype(dtype)
    return (jax.random.normal(key, spec.shape) * spec.scale).astype(dtype)


def init_params(spec_tree, rng, dtype):
    """Initialize a pytree of ParamSpec -> pytree of arrays (single device)."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(rng, len(leaves))
    arrs = [init_param(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def param_shardings(spec_tree, ctx: ShardingCtx):
    """Pytree of NamedSharding matching a ParamSpec tree."""
    return jax.tree.map(
        lambda s: ctx.sharding_for(s.shape, s.axes),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def abstract_params(spec_tree, dtype):
    """ShapeDtypeStruct tree (for dry-run lowering, no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
