"""Model layers: RMSNorm, RoPE/M-RoPE, GQA attention (blocked online-softmax
for train/prefill, fused single-token path for decode), SwiGLU/GeGLU MLP and
gather-based top-k MoE dispatch.

Attention notes
---------------
``blocked_attention`` is the pure-XLA flash-attention analogue: a double
``lax.scan`` over (q-block, kv-block) tiles with online-softmax accumulators.
Memory is O(block^2) instead of O(S^2) so 32k prefill lowers without
materializing score matrices.  Causal masking is applied inside the tile;
fully-masked tiles still burn FLOPs in HLO — this shows up explicitly in the
roofline's MODEL_FLOPS/HLO_FLOPS ratio and is one of the hillclimb levers
(the Pallas kernel in ``repro.kernels.flash_attention`` skips them on TPU).
Local (sliding-window) layers dynamic-slice a window of K/V per q-block, so
window attention is sub-quadratic in HLO FLOPs too.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.sharding import ShardingCtx, constrain

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norm
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps: float = 1e-5):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE (standard + multimodal 3D)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _apply_rot(x, cos, sin):
    # x: (..., D); cos/sin broadcastable (..., D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) int32."""
    inv = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv       # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _apply_rot(x, cos, sin)


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    """Qwen2-VL style (t, h, w) split of the D/2 frequency dims.

    head_dim=128 -> (16, 24, 24), matching the published mrope_section."""
    half = head_dim // 2
    t = head_dim // 8
    h = (half - t) // 2
    return (t, h, half - t - h)


def apply_mrope(x, positions_thw, theta: float):
    """x: (B, S, H, D); positions_thw: (3, B, S) int32 (temporal/height/width)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                 # (D/2,)
    secs = mrope_sections(d)
    ang_all = positions_thw[..., None].astype(jnp.float32) * inv  # (3, B, S, D/2)
    pieces, start = [], 0
    for i, s in enumerate(secs):
        pieces.append(ang_all[i, :, :, start:start + s])
        start += s
    ang = jnp.concatenate(pieces, axis=-1)                     # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _apply_rot(x, cos, sin)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _softcap(scores, cap: Optional[float]):
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


def repeat_kv(k, n_rep: int):
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D).  Under TP this is a device-
    local gather (each shard of the repeated 'heads' dim reads one kv head);
    XLA fuses it into the attention dots, so no HBM blow-up on TPU."""
    if n_rep == 1:
        return k
    B, S, Hkv, D = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (B, S, Hkv, n_rep, D)
    ).reshape(B, S, Hkv * n_rep, D)


def blocked_attention(
    q, k, v,
    *,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    q_block: int = 512,
    kv_block: int = 512,
):
    """Online-softmax tiled attention (MHA layout; repeat_kv applied by the
    caller so the 'heads' dim TP-shards directly).

    q, k, v: (B, S, H, D).  Returns (B, S, H, D).
    """
    B, S, H, D = q.shape
    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    assert S % q_block == 0 and S % kv_block == 0
    nq, nk = S // q_block, S // kv_block

    # (nq, B, qb, H, D) — scan over leading dim.
    qs = q.reshape(B, nq, q_block, H, D).transpose(1, 0, 2, 3, 4)

    if window is not None:
        # local layers: slice a static-size window of K/V per q block
        win_len = min(S, -(-(window + q_block) // kv_block) * kv_block)

    def q_step(_, qi_qblk):
        qi, q_blk = qi_qblk  # q_blk: (B, qb, H, D)
        q_pos = qi * q_block + jnp.arange(q_block)

        if window is None:
            k_use, v_use, k_start = k, v, 0
            nk_use = nk
        else:
            start = jnp.clip(qi * q_block + q_block - win_len, 0, S - win_len)
            k_use = lax.dynamic_slice_in_dim(k, start, win_len, axis=1)
            v_use = lax.dynamic_slice_in_dim(v, start, win_len, axis=1)
            k_start = start
            nk_use = win_len // kv_block

        ks = k_use.reshape(B, nk_use, kv_block, H, D).transpose(1, 0, 2, 3, 4)
        vs = v_use.reshape(B, nk_use, kv_block, H, D).transpose(1, 0, 2, 3, 4)

        m0 = jnp.full((B, H, q_block), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        o0 = jnp.zeros((B, H, q_block, D), jnp.float32)

        def kv_step(carry, ki_kv):
            m, l_, o = carry
            ki, k_blk, v_blk = ki_kv
            k_pos = k_start + ki * kv_block + jnp.arange(kv_block)
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            s = _softcap(s, softcap)
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            s = jnp.where(mask, s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l_ * alpha + p.sum(axis=-1)
            pv = jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_blk,
                preferred_element_type=jnp.float32,
            )
            o_new = o * alpha[..., None] + pv
            return (m_new, l_new, o_new), None

        (m, l_, o), _ = lax.scan(
            kv_step, (m0, l0, o0), (jnp.arange(nk_use), ks, vs)
        )
        o = o / jnp.maximum(l_, 1e-30)[..., None]
        # (B, H, qb, D) -> (B, qb, H, D)
        return None, o.transpose(0, 2, 1, 3).astype(q.dtype)

    _, outs = lax.scan(q_step, None, (jnp.arange(nq), qs))
    # (nq, B, qb, H, D) -> (B, S, H, D)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)


def reference_attention(q, k, v, *, scale, causal=True, window=None, softcap=None):
    """Naive O(S^2)-memory oracle (tests only).  q,k,v: (B,S,H,D)."""
    B, S, H, D = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = _softcap(s * scale, softcap)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bhqd", p, v, preferred_element_type=jnp.float32)
    return o.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *, scale, window=None,
                     softcap=None):
    """Single-token attention against a KV cache — GQA-native (no repeat_kv:
    the cache is the dominant state in decode; repeating it G-fold would
    multiply the memory term).

    q: (B, 1, Hq, D); k_cache/v_cache: (B, S, Hkv, D); cur_len: () or (B,)
    — number of valid cache positions.  Returns (B, 1, Hq, D)."""
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, G, D)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    s = _softcap(s, softcap)
    pos = jnp.arange(S)
    cur = jnp.asarray(cur_len)
    cur_b = cur if cur.ndim else jnp.full((B,), cur)
    mask = pos[None, :] < cur_b[:, None]                       # (B, S)
    if window is not None:
        mask &= pos[None, :] >= (cur_b[:, None] - window)
    s = jnp.where(mask[:, None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgqk,bkhd->bhgqd", p, v_cache, preferred_element_type=jnp.float32
    )
    # (B, Hkv, G, 1, D) -> (B, 1, Hq, D)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + attention + out proj)
# ---------------------------------------------------------------------------


def attention_block(
    params, x, positions, cfg, spec, ctx: Optional[ShardingCtx],
    *, kv_cache=None, cur_len=None, attn_impl: str = "blocked",
    mode: str = "train",
):
    """Full attention layer. x: (B, S, d).

    mode='train'   : no cache I/O, blocked causal attention.
    mode='prefill' : kv_cache = (k_buf, v_buf) sized (B, max_len, Hkv, D);
                     writes the S fresh KV at cur_len, attends within the
                     prompt, returns updated buffers.
    mode='decode'  : S==1; writes at cur_len, attends against the cache."""
    B, S, d = x.shape
    Hq, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = Hq // Hkv
    scale = cfg.query_scale if cfg.query_scale is not None else D ** -0.5
    window = cfg.window if spec.attn_type == "local" else None

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])           # (B,S,Hq,D)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])           # (B,S,Hkv,D)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])

    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    q = constrain(q, ("batch", "seq", "heads", None), ctx)
    k = constrain(k, ("batch", "seq", "kv_heads", None), ctx)
    v = constrain(v, ("batch", "seq", "kv_heads", None), ctx)

    if mode in ("train", "prefill"):
        kr, vr = repeat_kv(k, G), repeat_kv(v, G)
        if attn_impl == "reference":
            o = reference_attention(q, kr, vr, scale=scale, causal=True,
                                    window=window, softcap=cfg.attn_softcap)
        else:
            o = blocked_attention(q, kr, vr, scale=scale, causal=True,
                                  window=window, softcap=cfg.attn_softcap)
        if mode == "train" or kv_cache is None:
            new_cache = None
        else:
            k_cache, v_cache = kv_cache
            off = 0 if cur_len is None else cur_len
            k_cache = lax.dynamic_update_slice_in_dim(
                k_cache, k.astype(k_cache.dtype), off, axis=1)
            v_cache = lax.dynamic_update_slice_in_dim(
                v_cache, v.astype(v_cache.dtype), off, axis=1)
            new_cache = (k_cache, v_cache)
    else:  # decode
        k_cache, v_cache = kv_cache
        k_cache = lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cur_len, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cur_len, axis=1)
        o = decode_attention(q, k_cache, v_cache,
                             cur_len + S, scale=scale,
                             window=window, softcap=cfg.attn_softcap)
        new_cache = (k_cache, v_cache)

    # cast the (f32-accumulated) attention output back to the residual dtype
    # BEFORE the out projection: the TP partial-sum of this dot is what GSPMD
    # all-reduces, and an f32 operand doubles that collective's bytes (the
    # biggest single AR in the moonshot/gemma2 train HLO — §Perf A2)
    o = o.astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, params["wo"])
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_block(params, x, cfg, ctx: Optional[ShardingCtx]):
    act = jax.nn.gelu if cfg.geglu else jax.nn.silu
    h = act(jnp.einsum("bsd,df->bsf", x, params["w1"]))
    h = h * jnp.einsum("bsd,df->bsf", x, params["w3"])
    h = constrain(h, ("batch", "seq", "mlp"), ctx)
    return jnp.einsum("bsf,fd->bsd", h, params["w2"])


# ---------------------------------------------------------------------------
# MoE (token-choice top-k, gather/scatter dispatch with capacity dropping)
# ---------------------------------------------------------------------------


def _moe_groups(cfg, ctx: Optional[ShardingCtx], T: int) -> int:
    """Dispatch groups aligned to the DP shards so sort/cumsum/scatter are
    shard-local (a *global* argsort over the batch-sharded token dim would
    force a distributed sort — hundreds of collectives per layer)."""
    if ctx is None:
        return 1
    axes = ctx.rules.get("batch") or ()
    axes = tuple(a for a in axes if a in ctx.mesh.shape)
    g = ctx.axis_size(axes) if axes else 1
    while g > 1 and T % g:
        g //= 2
    return max(g, 1)


def moe_block(params, x, cfg, ctx: Optional[ShardingCtx]):
    """Token-choice top-k MoE, group-local dropping dispatch (GShard-style).

    Tokens are reshaped (Gg, Tg, d) with the group dim sharded like 'batch';
    per-group argsort/capacity/scatter are device-local.  Expert weights are
    EP-sharded over 'model'; the combine contracts the expert-sharded dim so
    GSPMD inserts exactly one (T, d) psum per layer — the same collective
    shape as a Megatron TP MLP.

    Returns (out, stats); stats feed regc.reduce (consistency-region state,
    fine-grained psum — the paper's reduction extension)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    act = jax.nn.gelu if cfg.geglu else jax.nn.silu

    Gg = _moe_groups(cfg, ctx, T)
    Tg = T // Gg
    C = max(1, int(Tg * K * m.capacity_factor) // E)

    xt = x.reshape(Gg, Tg, d)
    xt = constrain(xt, ("batch", None, None), ctx)
    logits = jnp.einsum("gtd,de->gte", xt, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, K)                         # (Gg, Tg, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    ids_1hot = jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32)
    f_e = ids_1hot.mean((0, 1))
    p_e = probs.mean((0, 1))
    aux_loss = E * jnp.sum(f_e * p_e)

    # ---- group-local dispatch: sort (token,k) pairs by expert ------------
    e_flat = top_e.reshape(Gg, Tg * K)
    w_flat = top_w.reshape(Gg, Tg * K).astype(x.dtype)
    perm = jnp.argsort(e_flat, axis=-1)                        # per-group, stable
    e_sorted = jnp.take_along_axis(e_flat, perm, axis=-1)
    w_sorted = jnp.take_along_axis(w_flat, perm, axis=-1)
    tok_sorted = perm // K                                     # (Gg, Tg*K)
    group_start = jax.vmap(
        lambda es: jnp.searchsorted(es, jnp.arange(E)))(e_sorted)  # (Gg, E)
    pos_in_e = jnp.arange(Tg * K)[None, :] - jnp.take_along_axis(
        group_start, e_sorted, axis=-1)
    keep = pos_in_e < C
    slot = jnp.where(keep, e_sorted * C + pos_in_e, E * C)     # drop -> scratch

    gathered_in = jnp.take_along_axis(xt, tok_sorted[..., None], axis=1)
    xe = jnp.zeros((Gg, E * C + 1, d), x.dtype)
    xe = jax.vmap(lambda b, s, v: b.at[s].set(v))(xe, slot, gathered_in)
    xe = xe[:, : E * C].reshape(Gg, E, C, d)
    xe = constrain(xe, ("batch", "expert", None, None), ctx)

    h = act(jnp.einsum("gecd,edf->gecf", xe, params["w1"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, params["w3"])
    h = constrain(h, ("batch", "expert", None, "mlp"), ctx)
    ye = jnp.einsum("gecf,efd->gecd", h, params["w2"])         # (Gg, E, C, d)

    ye_flat = ye.reshape(Gg, E * C, d)
    picked = jnp.take_along_axis(
        ye_flat, jnp.clip(slot, 0, E * C - 1)[..., None], axis=1)
    picked = jnp.where(keep[..., None], picked, 0.0)           # (Gg, Tg*K, d)
    contrib = picked * w_sorted[..., None]
    out = jax.vmap(
        lambda t, c: jnp.zeros((Tg, d), x.dtype).at[t].add(c)
    )(tok_sorted, contrib)
    out = constrain(out, ("batch", None, None), ctx)

    if m.n_shared:
        hs = act(jnp.einsum("gtd,sdf->gtsf", xt, params["shared_w1"]))
        hs = hs * jnp.einsum("gtd,sdf->gtsf", xt, params["shared_w3"])
        out = out + jnp.einsum("gtsf,sfd->gtd", hs, params["shared_w2"])

    load = jnp.zeros((E,), jnp.float32).at[e_sorted.reshape(-1)].add(
        keep.reshape(-1).astype(jnp.float32))
    stats = {"aux_loss": aux_loss, "expert_load": load}
    return out.reshape(B, S, d), stats


# ---------------------------------------------------------------------------
# EP MoE via shard_map (hillclimb variant; see EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------


def moe_block_ep(params, x, cfg, ctx: ShardingCtx):
    """Expert-parallel MoE, manual shard_map over (batch axes + 'model').

    Why: the GSPMD dense-dispatch path reshapes the expert-sharded (E, C, d)
    tensor through E*C for the combine gather, which breaks expert locality
    — the partitioner replicates the ~GB dispatched tensor and all-reduces
    it across 'model' every layer (704 GB/device/step on moonshot train_4k).

    Here every device routes its OWN data shard's tokens and dispatches only
    to its OWN E/ep experts (tokens are replicated across 'model', experts
    across data — dispatch and expert compute are fully local); the combine
    is a partial sum of local-expert outputs, merged by ONE (B_local, S, d)
    psum over 'model' per layer — the same collective shape as a Megatron TP
    MLP.  Fully manual (not partial-auto) because bf16 boundaries through
    partial-auto shard_map grads hit an XLA-CPU fatal bug ("Invalid binary
    instruction opcode copy"); manual-everything sidesteps it and is also
    the explicit-RegC-style code path.
    """
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    mesh = ctx.mesh
    if "model" not in mesh.shape or E % mesh.shape["model"] or m.n_shared:
        return moe_block(params, x, cfg, ctx)     # fallback: dense GSPMD
    ep = mesh.shape["model"]
    E_loc = E // ep
    act = jax.nn.gelu if cfg.geglu else jax.nn.silu
    B, S, d = x.shape
    batch_axes = tuple(a for a in (ctx.rules.get("batch") or ())
                       if a in mesh.shape and a != "model")
    if B % max(1, ctx.axis_size(batch_axes)):
        batch_axes = ()
    cf = m.capacity_factor

    def inner(xb, router, w1, w2, w3):
        # xb: (B_loc, S, d); router: (d, E); w*: (E_loc, d, f) — all local
        shard = lax.axis_index("model")
        Bb, Sb, dd = xb.shape
        T = Bb * Sb
        xt = xb.reshape(T, dd)
        logits = jnp.einsum("td,de->te", xt, router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = lax.top_k(probs, K)                    # (T, K)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        ids_1hot = jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32)
        aux_loss = E * jnp.sum(ids_1hot.mean(0) * probs.mean(0))
        if batch_axes:
            aux_loss = lax.pmean(aux_loss, batch_axes)

        # local dispatch: sort (token, k) pairs by expert, keep my slice
        C = max(1, int(T * K * cf) // E)
        e_flat = top_e.reshape(T * K)
        w_flat = top_w.reshape(T * K).astype(xb.dtype)
        perm = jnp.argsort(e_flat)
        e_sorted = e_flat[perm]
        w_sorted = w_flat[perm]
        tok_sorted = perm // K
        start = jnp.searchsorted(e_sorted, jnp.arange(E))
        pos_in_e = jnp.arange(T * K) - start[e_sorted]
        e_local = e_sorted - shard * E_loc
        mine = (e_local >= 0) & (e_local < E_loc) & (pos_in_e < C)
        slot = jnp.where(mine, e_local * C + pos_in_e, E_loc * C)

        gathered = xt[tok_sorted]                              # (T*K, d)
        xe = jnp.zeros((E_loc * C + 1, dd), xb.dtype).at[slot].set(gathered)
        xe = xe[: E_loc * C].reshape(E_loc, C, dd)

        h = act(jnp.einsum("ecd,edf->ecf", xe, w1))
        h = h * jnp.einsum("ecd,edf->ecf", xe, w3)
        ye = jnp.einsum("ecf,efd->ecd", h, w2)                 # (E_loc, C, d)

        ye_flat = ye.reshape(E_loc * C, dd)
        picked = ye_flat[jnp.clip(slot, 0, E_loc * C - 1)]
        picked = jnp.where(mine[:, None], picked, 0.0)
        contrib = picked * w_sorted[:, None]
        out = jnp.zeros((T, dd), xb.dtype).at[tok_sorted].add(contrib)
        out = lax.psum(out, "model")                           # THE combine

        load_loc = jnp.zeros((E_loc,), jnp.float32).at[
            jnp.clip(e_local, 0, E_loc - 1)].add(mine.astype(jnp.float32))
        load = lax.all_gather(load_loc, "model", tiled=True)   # (E,) tiny
        if batch_axes:
            load = lax.psum(load, batch_axes)
        return out.reshape(Bb, Sb, dd), aux_loss, load

    from jax.sharding import PartitionSpec as P
    bspec = P(batch_axes if batch_axes else None)
    manual = set(batch_axes) | {"model"}
    from repro.compat import shard_map
    fn = shard_map(
        inner, mesh=mesh,
        in_specs=(bspec, P(), P("model"), P("model"), P("model")),
        out_specs=(bspec, P(), P()),
        axis_names=manual, check_vma=False)
    out, aux, load = fn(x, params["router"], params["w1"], params["w2"],
                        params["w3"])
    return out, {"aux_loss": aux, "expert_load": load}
