"""Composable decoder model: param specs, super-block scan, loss, decode.

The layer stack is ``cfg.pattern`` repeated ``cfg.n_superblocks`` times; all
super-blocks share code and are driven by one ``lax.scan`` whose xs are the
parameter (and cache) pytrees stacked on a leading 'layers' dim.  HLO size is
therefore independent of depth — llama3-405B (126L) lowers as fast as a 2L
toy.  Cross-entropy is computed in sequence chunks (scan) so the full
(B, S, vocab) logits tensor is never materialized.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, LayerSpec
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.sharding import (
    ParamSpec, ShardingCtx, abstract_params, constrain, init_params,
)

# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, Hq, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = d ** -0.5
    return {
        "wq": ParamSpec((d, Hq, D), ("embed_fsdp", "heads", None), "normal", s),
        "wk": ParamSpec((d, Hkv, D), ("embed_fsdp", "kv_heads", None), "normal", s),
        "wv": ParamSpec((d, Hkv, D), ("embed_fsdp", "kv_heads", None), "normal", s),
        "wo": ParamSpec((Hq, D, d), ("heads", None, "embed_fsdp"), "normal",
                        (Hq * D) ** -0.5),
    }


def _ssm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    gn = s.n_groups * s.d_state
    H = d_in // s.head_dim
    sc = d ** -0.5
    return {
        "in_z": ParamSpec((d, d_in), ("embed_fsdp", "ssm_in"), "normal", sc),
        "in_x": ParamSpec((d, d_in), ("embed_fsdp", "ssm_in"), "normal", sc),
        "in_B": ParamSpec((d, gn), ("embed_fsdp", None), "normal", sc),
        "in_C": ParamSpec((d, gn), ("embed_fsdp", None), "normal", sc),
        "in_dt": ParamSpec((d, H), ("embed_fsdp", None), "normal", sc),
        "conv_w": ParamSpec((s.d_conv, d_in + 2 * gn), (None, "ssm_in"),
                            "normal", 0.2),
        "conv_b": ParamSpec((d_in + 2 * gn,), ("ssm_in",), "zeros"),
        "A_log": ParamSpec((H,), (None,), "ones"),
        "D": ParamSpec((H,), (None,), "ones"),
        "dt_bias": ParamSpec((H,), (None,), "zeros"),
        "gate_ln": ParamSpec((d_in,), ("ssm_in",), "zeros"),
        "out_proj": ParamSpec((d_in, d), ("ssm_in", "embed_fsdp"), "normal",
                              d_in ** -0.5),
    }


def _mlp_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w1": ParamSpec((d, f), ("embed_fsdp", "mlp"), "normal", d ** -0.5),
        "w3": ParamSpec((d, f), ("embed_fsdp", "mlp"), "normal", d ** -0.5),
        "w2": ParamSpec((f, d), ("mlp", "embed_fsdp"), "normal", f ** -0.5),
    }


def _moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    sp = {
        "router": ParamSpec((d, E), ("embed_fsdp", None), "normal", d ** -0.5),
        "w1": ParamSpec((E, d, f), ("expert", "embed_fsdp", "mlp"), "normal", d ** -0.5),
        "w3": ParamSpec((E, d, f), ("expert", "embed_fsdp", "mlp"), "normal", d ** -0.5),
        "w2": ParamSpec((E, f, d), ("expert", "mlp", "embed_fsdp"), "normal", f ** -0.5),
    }
    if m.n_shared:
        sp["shared_w1"] = ParamSpec((m.n_shared, d, f), (None, "embed_fsdp", "mlp"),
                                    "normal", d ** -0.5)
        sp["shared_w3"] = ParamSpec((m.n_shared, d, f), (None, "embed_fsdp", "mlp"),
                                    "normal", d ** -0.5)
        sp["shared_w2"] = ParamSpec((m.n_shared, f, d), (None, "mlp", "embed_fsdp"),
                                    "normal", f ** -0.5)
    return sp


def _layer_specs(cfg: ModelConfig, spec: LayerSpec) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    out: Dict[str, ParamSpec] = {"ln": ParamSpec((d,), (None,), "zeros")}
    if spec.kind == "attn":
        out.update(_attn_specs(cfg))
    else:
        out.update(_ssm_specs(cfg))
    if cfg.use_post_norm:
        out["ln_post"] = ParamSpec((d,), (None,), "zeros")
    if spec.mlp != "none":
        out["ln_mlp"] = ParamSpec((d,), (None,), "zeros")
        if cfg.use_post_norm:
            out["ln_mlp_post"] = ParamSpec((d,), (None,), "zeros")
        out.update({f"mlp_{k}": v for k, v in
                    (_mlp_specs(cfg) if spec.mlp == "dense" else _moe_specs(cfg)).items()})
    return out


def _stack(spec_dict: Dict[str, ParamSpec], n: int) -> Dict[str, ParamSpec]:
    return {
        k: ParamSpec((n,) + v.shape, ("layers",) + v.axes, v.init, v.scale)
        for k, v in spec_dict.items()
    }


def param_specs(cfg: ModelConfig):
    tree: Dict[str, Any] = {
        # vocab-only sharding: a 2-axis-sharded table makes the token gather
        # reshard pathologically under SPMD (full remat warning); the table
        # is small (<300MB/shard at 405B) so d_model stays replicated.
        "embed": ParamSpec((cfg.vocab_size, cfg.d_model),
                           ("vocab", None), "normal", 1.0),
        "final_ln": ParamSpec((cfg.d_model,), (None,), "zeros"),
        "blocks": [
            _stack(_layer_specs(cfg, spec), cfg.n_superblocks)
            for spec in cfg.pattern
        ],
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                    ("embed_fsdp", "vocab"), "normal",
                                    cfg.d_model ** -0.5)
    return tree


# ---------------------------------------------------------------------------
# Layer / super-block application
# ---------------------------------------------------------------------------


def _apply_layer(cfg, spec: LayerSpec, p, x, positions, ctx, *,
                 mode, cache, cur_len, attn_impl):
    h = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    if spec.kind == "attn":
        out, new_cache = L.attention_block(
            p, h, positions, cfg, spec, ctx,
            kv_cache=cache, cur_len=cur_len, attn_impl=attn_impl, mode=mode)
    else:
        out, new_cache = S.mamba2_block(p, h, cfg, ctx, cache=cache, mode=mode)
    if cfg.use_post_norm:
        out = L.rmsnorm(out, p["ln_post"], cfg.norm_eps)
    x = x + out
    stats = {"aux_loss": jnp.zeros((), jnp.float32)}
    if cfg.moe is not None:
        stats["expert_load"] = jnp.zeros((cfg.moe.n_experts,), jnp.float32)
    if spec.mlp != "none":
        h2 = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
        if spec.mlp == "dense":
            mp = {k[4:]: v for k, v in p.items() if k.startswith("mlp_")}
            out2 = L.mlp_block(mp, h2, cfg, ctx)
        else:
            mp = {k[4:]: v for k, v in p.items() if k.startswith("mlp_")}
            if ctx is not None and ctx.moe_impl == "ep":
                out2, mstats = L.moe_block_ep(mp, h2, cfg, ctx)
            else:
                out2, mstats = L.moe_block(mp, h2, cfg, ctx)
            stats.update(mstats)
        if cfg.use_post_norm:
            out2 = L.rmsnorm(out2, p["ln_mlp_post"], cfg.norm_eps)
        x = x + out2
    return x, new_cache, stats


def run_stack(cfg: ModelConfig, params, x, positions, ctx, *,
              mode: str = "train", caches=None, cur_len=None,
              attn_impl: str = "blocked", remat: Optional[str] = None,
              remat_segment: int = 0):
    """Apply all layers.  Returns (hidden, new_caches, stats_sum).

    remat_segment > 0 segments the super-block scan into (outer, inner) with
    checkpointing at BOTH levels (sqrt-N remat): live boundary activations
    drop from n_superblocks x act to (outer + inner) x act at the cost of
    one extra forward inside each segment's backward."""

    # FSDP gather-weights semantics: re-constrain each sliced layer param to
    # its logical axes with 'embed_fsdp' replicated.  Without this, GSPMD may
    # contract over the data-sharded dim instead — a partial dot followed by
    # an all-reduce of the (much larger) activation, which is the wrong side
    # of the FSDP trade for training these models.  Decode flips the trade
    # (ctx.gather_fsdp=False): regathering all weights per generated token
    # costs ~params bytes of all-gather per step, while the partial-dot
    # all-reduce is only an activation row (§Perf llama3-405b decode).
    if ctx is not None and not ctx.gather_fsdp:
        gather_axes = [
            {k: s.axes for k, s in _layer_specs(cfg, spec).items()}
            for spec in cfg.pattern
        ]
    else:
        gather_axes = [
            {k: tuple(None if a == "embed_fsdp" else a for a in s.axes)
             for k, s in _layer_specs(cfg, spec).items()}
            for spec in cfg.pattern
        ]

    def superblock(carry_x, xs):
        p_blocks, cache_blocks = xs
        stats_acc = None
        new_caches = []
        xx = carry_x
        for pos, spec in enumerate(cfg.pattern):
            cache = None if cache_blocks is None else cache_blocks[pos]
            p_gathered = {
                k: constrain(v, gather_axes[pos][k], ctx)
                for k, v in p_blocks[pos].items()
            }
            xx, ncache, stats = _apply_layer(
                cfg, spec, p_gathered, xx, positions, ctx,
                mode=mode, cache=cache, cur_len=cur_len, attn_impl=attn_impl)
            new_caches.append(ncache)
            stats_acc = stats if stats_acc is None else jax.tree.map(
                jnp.add, stats_acc, stats)
        if mode == "train":
            new_caches = None
            # the carry is what the scan SAVES for backward; seq_sp-shard it
            # (rules decide; None rule == current batch-only sharding)
            xx = constrain(xx, ("batch", "seq_sp", "embed"), ctx)
        return xx, (new_caches, stats_acc)

    body = superblock
    if remat and mode == "train":
        policy = {
            "full": None,
            "dots": jax.checkpoint_policies.checkpoint_dots,
            "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        }[remat]
        body = jax.checkpoint(superblock, policy=policy, prevent_cse=False) \
            if policy else jax.checkpoint(superblock, prevent_cse=False)

    n_sb = cfg.n_superblocks
    if (remat_segment and mode == "train" and remat_segment > 1
            and n_sb % remat_segment == 0 and n_sb // remat_segment > 1):
        inner = remat_segment
        outer = n_sb // inner

        def segment(carry_x, seg_xs):
            xx, (ncaches, stats) = lax.scan(body, carry_x, seg_xs)
            return xx, (ncaches, stats)

        seg_body = jax.checkpoint(segment, prevent_cse=False)
        blocks_r = jax.tree.map(
            lambda a: a.reshape(outer, inner, *a.shape[1:]),
            params["blocks"])
        # train mode: caches is None (scan over None leaves is fine)
        x, (new_caches, stats) = lax.scan(seg_body, x, (blocks_r, caches))
        stats = jax.tree.map(lambda a: a.sum((0, 1)), stats)
        return x, new_caches, stats

    x, (new_caches, stats) = lax.scan(body, x, (params["blocks"], caches))
    stats = jax.tree.map(lambda a: a.sum(0), stats)  # sum over super-blocks
    return x, new_caches, stats


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_inputs(cfg: ModelConfig, params, batch, ctx):
    if cfg.input_mode == "embeds":
        x = batch["embeds"]
    else:
        x = params["embed"][batch["tokens"]]
        if cfg.tie_embeddings:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma scaling
    return constrain(x, ("batch", "seq", "embed"), ctx)


def _lm_matrix(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].T      # (d, V)
    return params["lm_head"]


def chunked_ce_loss(cfg: ModelConfig, params, hidden, targets, ctx, *,
                    chunk: int = 1024, mask=None):
    """Cross-entropy without materializing (B, S, V) logits."""
    B, S_, d = hidden.shape
    c = min(chunk, S_)
    assert S_ % c == 0
    nc = S_ // c
    w = _lm_matrix(cfg, params)
    hs = hidden.reshape(B, nc, c, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, nc, c).transpose(1, 0, 2)
    if mask is None:
        ms = jnp.ones((nc, B, c), jnp.float32)
    else:
        ms = mask.reshape(B, nc, c).transpose(1, 0, 2).astype(jnp.float32)

    def step(acc, inp):
        hc, tc, mc = inp
        logits = jnp.einsum("bcd,dv->bcv", hc, w,
                            preferred_element_type=jnp.float32)
        if cfg.final_softcap is not None:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        loss = ((lse - ll) * mc).sum()
        ntok = mc.sum()
        return (acc[0] + loss, acc[1] + ntok), None

    (loss, ntok), _ = lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hs, ts, ms))
    return loss / jnp.maximum(ntok, 1.0)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def make_positions(cfg: ModelConfig, B: int, S: int, offset=0):
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.mrope:
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def loss_fn(cfg: ModelConfig, params, batch, ctx=None, *,
            attn_impl="blocked", remat=None, ce_chunk=1024,
            remat_segment=0):
    """Training loss. batch: tokens/embeds (B,S[,d]), targets (B,S),
    optional positions, optional loss_mask."""
    x = embed_inputs(cfg, params, batch, ctx)
    B, S_ = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = make_positions(cfg, B, S_)
    hidden, _, stats = run_stack(cfg, params, x, positions, ctx,
                                 mode="train", attn_impl=attn_impl,
                                 remat=remat, remat_segment=remat_segment)
    hidden = L.rmsnorm(hidden, params["final_ln"], cfg.norm_eps)
    ce = chunked_ce_loss(cfg, params, hidden, batch["targets"], ctx,
                         chunk=ce_chunk, mask=batch.get("loss_mask"))
    aux = stats["aux_loss"]
    aux = aux.sum() if getattr(aux, "ndim", 0) else aux
    total = ce
    if cfg.moe is not None:
        total = total + cfg.moe.router_aux_weight * aux / cfg.n_layers
    metrics = {"ce": ce, "aux_loss": aux}
    if cfg.moe is not None:
        load = stats["expert_load"]
        metrics["expert_load"] = load.sum(0) if load.ndim > 1 else load
    return total, metrics


def init_caches(cfg: ModelConfig, B: int, max_len: int, dtype=jnp.bfloat16):
    """Per-position stacked cache buffers (leading dim n_superblocks)."""
    n = cfg.n_superblocks
    caches = []
    for spec in cfg.pattern:
        if spec.kind == "attn":
            kv_shape = (n, B, max_len, cfg.n_kv_heads, cfg.head_dim)
            caches.append((jnp.zeros(kv_shape, dtype), jnp.zeros(kv_shape, dtype)))
        else:
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            H = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            caches.append((
                jnp.zeros((n, B, s.d_conv - 1, conv_dim), dtype),
                jnp.zeros((n, B, H, s.head_dim, s.d_state), jnp.float32),
            ))
    return caches


def cache_logical_axes(cfg: ModelConfig):
    """Logical axes matching init_caches structure (for dry-run shardings)."""
    axes = []
    for spec in cfg.pattern:
        if spec.kind == "attn":
            a = ("layers", "batch", "kv_seq", "kv_heads", None)
            axes.append((a, a))
        else:
            axes.append((
                ("layers", "batch", None, "ssm_in"),
                ("layers", "batch", "ssm_in", None, None),
            ))
    return axes


def forward_hidden(cfg, params, batch, ctx=None, *, mode, caches, cur_len,
                   attn_impl="blocked"):
    x = embed_inputs(cfg, params, batch, ctx)
    B, S_ = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = make_positions(cfg, B, S_, offset=cur_len if cur_len is not None else 0)
    hidden, new_caches, _ = run_stack(
        cfg, params, x, positions, ctx, mode=mode, caches=caches,
        cur_len=cur_len, attn_impl=attn_impl)
    return L.rmsnorm(hidden, params["final_ln"], cfg.norm_eps), new_caches


def decode_step(cfg: ModelConfig, params, batch, caches, cur_len, ctx=None):
    """One-token decode. batch: tokens (B,1) or embeds (B,1,d).
    Returns (next_token_logits (B, V), new_caches)."""
    hidden, new_caches = forward_hidden(
        cfg, params, batch, ctx, mode="decode", caches=caches, cur_len=cur_len)
    w = _lm_matrix(cfg, params)
    logits = jnp.einsum("bd,dv->bv", hidden[:, -1], w,
                        preferred_element_type=jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits, new_caches


def prefill(cfg: ModelConfig, params, batch, max_len: int, ctx=None,
            attn_impl="blocked", cache_dtype=jnp.bfloat16):
    """Run the prompt, returning (last_hidden, primed caches, prompt_len)."""
    x = batch["tokens"] if cfg.input_mode == "tokens" else batch["embeds"]
    B, S_ = x.shape[0], x.shape[1]
    caches = init_caches(cfg, B, max_len, cache_dtype)
    hidden, new_caches = forward_hidden(
        cfg, params, batch, ctx, mode="prefill", caches=caches, cur_len=0,
        attn_impl=attn_impl)
    return hidden, new_caches, S_


def init_model_params(cfg: ModelConfig, rng, dtype=jnp.float32):
    return init_params(param_specs(cfg), rng, dtype)


def abstract_model_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return abstract_params(param_specs(cfg), dtype)
