"""Public SPMD driver façade over a RegC runtime.

``session(rt, driver=...)`` returns a :class:`Session` whose named
callables drive whole declared-access phases — the programming surface
every app, benchmark, and example uses (the old underscore helpers in
``dsm.apps`` are now thin shims over this module):

* ``s.phase(reads=..., writes=..., flops=..., ...)`` — one bulk ordinary
  phase.  Interval tuples are ``(ga, lo, hi)`` with (W,) int arrays;
  flops/mem_bytes/seconds/instr_words scalars or (W,) arrays.
* ``s.span(lock_ids, reads=..., writes=..., w_mask=None)`` — one whole
  consistency-region pass: every masked worker acquires its lock, runs
  the declared interval ops inside the span, and releases.
* ``s.reduce(name, value=1.0)`` — per-worker reduction contribution
  (the paper's §V-B extension).
* ``s.barrier()`` — delegate to ``rt.barrier()``.

Drivers: ``batched`` routes through the scale engine's worker-axis
vectorized entry points (``phase_all``/``span_all``/``reduce_all``);
``loop`` issues per-worker ops in worker order — the only choice for the
reference runtime, which ``auto`` detects.  The two drivers are bit-exact
against each other (the exactness contract, lockstep-checked by the
trace-fuzz suite): spans always serialize through their grant chain, so
op order is identical whichever driver executes the bulk part.
"""
from __future__ import annotations

import numpy as np

from repro.core.config import DRIVERS, check_choice


def _phase_callable(rt, driver: str):
    batched = getattr(rt, "phase_all", None)
    if driver == "auto":
        driver = "batched" if batched is not None else "loop"
    if driver == "batched":
        if batched is None:
            raise ValueError(
                "session(driver='batched'): runtime has no phase_all "
                "(use driver='loop' for the reference engine)")
        return batched

    W = rt.W
    per_worker = getattr(rt, "phase", None)

    def at(v, w):
        return float(v[w]) if np.ndim(v) else float(v)

    def loop(reads=(), writes=(), *, flops=0.0, mem_bytes=0.0, seconds=0.0,
             instr_words=0.0):
        for w in range(W):
            r = [(ga, int(lo[w]), int(hi[w])) for ga, lo, hi in reads]
            wr = [(ga, int(lo[w]), int(hi[w])) for ga, lo, hi in writes]
            fl, mb = at(flops, w), at(mem_bytes, w)
            sec, iw = at(seconds, w), at(instr_words, w)
            if per_worker is not None:
                per_worker(w, reads=r, writes=wr, flops=fl, mem_bytes=mb,
                           seconds=sec, instr_words=iw)
                continue
            for ga, lo, hi in r:
                rt.read(w, ga, lo, hi)
            for ga, lo, hi in wr:
                rt.write(w, ga, lo, hi)
            if fl or mb or sec:
                rt.compute(w, flops=fl, mem_bytes=mb, seconds=sec)
            if iw:
                rt.instr_stores(w, iw)
    return loop


def _span_callable(rt, driver: str):
    batched = getattr(rt, "span_all", None)
    if driver == "auto":
        driver = "batched" if batched is not None else "loop"
    if driver == "batched":
        if batched is None:
            raise ValueError(
                "session(driver='batched'): runtime has no span_all "
                "(use driver='loop' for the reference engine)")

        def span_batched(lock_ids, reads=(), writes=(), w_mask=None):
            batched(w_mask, lock_ids, reads=reads, writes=writes)
        return span_batched

    W = rt.W

    def span_loop(lock_ids, reads=(), writes=(), w_mask=None):
        locks = np.broadcast_to(np.asarray(lock_ids, np.int64), (W,))
        for w in range(W):
            if w_mask is not None and not w_mask[w]:
                continue
            rt.acquire(w, int(locks[w]))
            for ga, lo, hi in reads:
                rt.read(w, ga, int(lo[w]), int(hi[w]))
            for ga, lo, hi in writes:
                rt.write(w, ga, int(lo[w]), int(hi[w]))
            rt.release(w, int(locks[w]))
    return span_loop


class Session:
    """Named phase/span/reduce drivers bound to one runtime.

    ``driver`` is resolved once at construction (``auto`` picks
    ``batched`` iff the runtime exposes the worker-axis entry points);
    the resolved name is available as ``s.driver``."""

    def __init__(self, rt, driver: str = "auto"):
        check_choice("driver", driver, DRIVERS)
        self.rt = rt
        if driver == "auto":
            driver = ("batched" if getattr(rt, "phase_all", None) is not None
                      else "loop")
        self.driver = driver
        self.phase = _phase_callable(rt, driver)
        self.span = _span_callable(rt, driver)

    def reduce(self, name: str, value: float = 1.0):
        """Per-worker reduction contribution, batched when the runtime
        offers ``reduce_all`` (identical combine and traffic either way,
        whichever driver runs the phases)."""
        ra = getattr(self.rt, "reduce_all", None)
        if ra is not None:
            ra(name, value)
        else:
            for w in range(self.rt.W):
                self.rt.reduce(w, name, value)

    def barrier(self):
        self.rt.barrier()


def session(rt, driver: str = "auto") -> Session:
    """Factory spelling of :class:`Session` (the public entry point)."""
    return Session(rt, driver)
