"""Linear (alpha-beta) interconnect + node cost models.

Traffic counts in the DSM runtime are EXACT (every byte is accounted as the
protocol moves it); only *time* is modeled, as latency + bytes/bandwidth,
because this container has no cluster.  Two parameter sets ship:

* ``IB_2013``  — the paper's System G: QDR InfiniBand (32 Gbit/s effective,
  ~1.3 us), dual quad-core 2.8 GHz Harpertown nodes (8 cores/node), measured
  STREAM-class node memory bandwidth ~6.4 GB/s shared across the node's
  cores (matches the paper's Fig. 2 Pthreads plateau).
* ``ICI_V5E``  — the TPU-adaptation target: ~50 GB/s/link, ~1 us, HBM
  819 GB/s per chip (chips don't share HBM — node_size=1).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CostModel:
    name: str
    net_latency_s: float          # per message
    net_bw_Bps: float             # per link
    node_mem_bw_Bps: float        # all sockets of a node combined
    node_size: int                # workers per node (placement fills nodes)
    flops_per_worker: float       # SUSTAINED scalar flops per worker
    socket_size: int = 0          # 0 = no socket effect; else cores/socket.
    #   The paper's placement fills socket 0 first (its Fig. 2 note: 1-4
    #   core bandwidth is similar): <= socket_size workers see only one
    #   socket's memory bandwidth (node_mem_bw / n_sockets).

    def node_bw(self, workers_sharing: int) -> float:
        if self.socket_size and workers_sharing <= self.socket_size:
            n_sockets = max(1, self.node_size // self.socket_size)
            return self.node_mem_bw_Bps / n_sockets
        return self.node_mem_bw_Bps

    def xfer_s(self, n_bytes: float, n_msgs: int = 1) -> float:
        return self.net_latency_s * n_msgs + n_bytes / self.net_bw_Bps

    def mem_s(self, n_bytes: float, workers_sharing: int = 1) -> float:
        bw = self.node_bw(workers_sharing) / max(1, workers_sharing)
        return n_bytes / bw

    def compute_s(self, flops: float = 0.0, mem_bytes: float = 0.0,
                  workers_sharing: int = 1) -> float:
        return max(flops / self.flops_per_worker,
                   self.mem_s(mem_bytes, workers_sharing))

    def workers_on_node(self, n_workers: int) -> int:
        return min(n_workers, self.node_size)


IB_2013 = CostModel(
    name="ib2013",
    net_latency_s=1.3e-6,
    net_bw_Bps=4.0e9,             # QDR 32 Gbit/s
    node_mem_bw_Bps=6.4e9,        # Penryn Harpertown node (STREAM-class)
    node_size=8,
    socket_size=4,                # dual quad-core, fill-first placement
    flops_per_worker=2.8e9,       # 2.8 GHz, ~1 sustained flop/cycle —
    #   the paper's kernels are scalar C with divisions/transcendentals in
    #   the inner loops (OmpSCR), nowhere near 4-wide SSE peak
)

ICI_V5E = CostModel(
    name="ici_v5e",
    net_latency_s=1.0e-6,
    net_bw_Bps=50.0e9,
    node_mem_bw_Bps=819.0e9,
    node_size=1,
    flops_per_worker=197e12,
)


# ---------------------------------------------------------------------------
# message loss (chaos tier)
# ---------------------------------------------------------------------------

_SM_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_SM_M1 = np.uint64(0xBF58476D1CE4E5B9)
_SM_M2 = np.uint64(0x94D049BB133111EB)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer over a uint64 array (array ops only — numpy
    scalar uint64 arithmetic warns on the intended wraparound)."""
    x = (x + _SM_GAMMA)
    x ^= x >> np.uint64(30)
    x *= _SM_M1
    x ^= x >> np.uint64(27)
    x *= _SM_M2
    x ^= x >> np.uint64(31)
    return x


class ChaosNet:
    """Deterministic message-loss model layered on a :class:`CostModel`.

    Every clock-charged message-group event on the protocol path consumes
    exactly one per-worker sequence tick; the (seed, worker, seq) triple
    hashes to a drop decision per retry level, so losses are a pure
    function of each worker's own event history — independent of how a
    driver batches workers together.  That is what keeps the loop and
    batched drivers bit-equal under chaos: both produce the same
    per-worker sequence of charge events (the engine's exactness
    invariant), hence the same ticks, hence the same retry charges.

    A dropped message is retransmitted after ``timeout_s`` with
    exponential backoff: r consecutive drops charge
    ``sum_{k<r} timeout_s * backoff**min(k, backoff_cap)`` extra seconds
    (capped at ``max_retries`` levels — the last retransmission always
    succeeds, so the protocol outcome and traffic counters never change,
    only time).  ``backoff_cap`` bounds the per-level exponent so deep
    retry chains (large ``max_retries``) charge linearly past the cap
    instead of geometrically without bound; the default cap (6) is above
    the default chain depth, so stock configurations are unchanged.

    Invalidation messages charge no clock in the base model, so their
    losses are accounted on a separate GLOBAL sequence counter as
    stats-only retransmissions (``inval_retries``): the total over N
    consumed indices is partition-independent, preserving driver
    equality from the cumulative invalidation-count equality.
    """

    def __init__(self, *, seed: int = 0, drop_rate: float = 0.05,
                 timeout_s: float = 5e-6, backoff: float = 2.0,
                 max_retries: int = 3, backoff_cap: int = 6):
        assert 0.0 <= drop_rate < 1.0, drop_rate
        assert max_retries >= 1, max_retries
        assert backoff_cap >= 0, backoff_cap
        self.seed = int(seed)
        self.drop_rate = float(drop_rate)
        self.timeout_s = float(timeout_s)
        self.backoff = float(backoff)
        self.max_retries = int(max_retries)
        self.backoff_cap = int(backoff_cap)
        self.W = 0
        self.msg_seq = np.zeros(0, np.uint64)       # per-worker event count
        self.inval_seq = np.zeros(1, np.uint64)     # global inval msg count
        self._stats: dict = {}
        self._seed_u = np.uint64(np.int64(self.seed))

    # -- wiring ---------------------------------------------------------
    def bind(self, n_workers: int, stats: dict):
        """Attach to a runtime: allocate per-worker counters and route the
        chaos_* counters into the runtime's ``stats`` dict."""
        if self.W != n_workers:
            self.W = n_workers
            self.msg_seq = np.zeros(n_workers, np.uint64)
            self.inval_seq = np.zeros(1, np.uint64)
        self._stats = stats
        for k in ("chaos_msgs", "chaos_drops", "chaos_inval_retries"):
            stats.setdefault(k, 0)

    def config(self) -> dict:
        return {"seed": self.seed, "drop_rate": self.drop_rate,
                "timeout_s": self.timeout_s, "backoff": self.backoff,
                "max_retries": self.max_retries,
                "backoff_cap": self.backoff_cap}

    def state_arrays(self) -> dict:
        return {"chaos_msg_seq": self.msg_seq.copy(),
                "chaos_inval_seq": self.inval_seq.copy()}

    def load_state(self, arrays: dict):
        self.msg_seq = np.asarray(arrays["chaos_msg_seq"],
                                  np.uint64).copy()
        self.inval_seq = np.asarray(arrays["chaos_inval_seq"],
                                    np.uint64).copy()
        self.W = self.msg_seq.size

    # -- drop decisions -------------------------------------------------
    def _dropped(self, lane: np.ndarray, seq: np.ndarray,
                 level: int) -> np.ndarray:
        h = _splitmix64(_splitmix64(_splitmix64(
            lane + self._seed_u) ^ seq) + np.uint64(level))
        u = (h >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)
        return u < self.drop_rate

    def _consecutive_drops(self, lane: np.ndarray,
                           seq: np.ndarray) -> np.ndarray:
        """Number of consecutive drops (0..max_retries) per element."""
        r = np.zeros(lane.shape, np.int64)
        alive = np.ones(lane.shape, bool)
        for k in range(self.max_retries):
            d = alive & self._dropped(lane, seq, k)
            if not d.any():
                break
            r[d] += 1
            alive = d
        return r

    # -- charged-path API -----------------------------------------------
    def retry_rows(self, rows: np.ndarray) -> np.ndarray:
        """Consume one message tick per worker in ``rows`` (distinct
        worker ids) and return the extra retransmission seconds each owes.
        Charged-path only: the caller adds the result to the clock as a
        SEPARATE ``+=`` right after the base charge, so loop and batched
        drivers execute identical float-op sequences."""
        rows = np.asarray(rows, np.int64)
        if rows.size == 0:
            return np.zeros(0, np.float64)
        lane = rows.astype(np.uint64)
        seq = self.msg_seq[rows]
        r = self._consecutive_drops(lane, seq)
        self.msg_seq[rows] += np.uint64(1)
        st = self._stats
        st["chaos_msgs"] = st.get("chaos_msgs", 0) + int(rows.size)
        ndrop = int(r.sum())
        if ndrop:
            st["chaos_drops"] = st.get("chaos_drops", 0) + ndrop
        # sum_{k<r} timeout * backoff^min(k, cap), elementwise
        # (r <= max_retries; the cap keeps deep chains linear past it)
        extra = np.zeros(rows.size, np.float64)
        for k in range(self.max_retries):
            m = r > k
            if not m.any():
                break
            extra[m] += self.timeout_s * (
                self.backoff ** min(k, self.backoff_cap))
        return extra

    @staticmethod
    def backoff_seconds(timeout_s: float, backoff: float, levels: int,
                        cap: int = 6) -> float:
        """The retry charge for ``levels`` consecutive timeouts — the same
        capped-exponent term :meth:`retry_rows` charges per element.  The
        cluster control plane uses this to account real RPC retries in
        its availability report without touching the modeled clocks."""
        return float(sum(timeout_s * backoff ** min(k, cap)
                         for k in range(levels)))

    def retry1(self, w: int) -> float:
        """Scalar path: delegates to :meth:`retry_rows` on a 1-element
        array so the charge is bit-identical to the vector path."""
        return float(self.retry_rows(np.array([w], np.int64))[0])

    # -- invalidation (uncharged) path ----------------------------------
    def inval_msgs(self, n: int):
        """Consume ``n`` global invalidation-message indices and account
        their retransmissions (stats only — the base model charges no
        clock for invalidations, so neither does their loss)."""
        if n <= 0:
            return
        start = self.inval_seq[0:1]
        idx = start + np.arange(n, dtype=np.uint64)
        lane = np.full(n, 0xA5A5A5A5A5A5A5A5, np.uint64)
        r = self._consecutive_drops(lane, idx)
        self.inval_seq += np.uint64(n)
        nr = int(r.sum())
        if nr:
            st = self._stats
            st["chaos_inval_retries"] = (
                st.get("chaos_inval_retries", 0) + nr)
