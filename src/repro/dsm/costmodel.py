"""Linear (alpha-beta) interconnect + node cost models.

Traffic counts in the DSM runtime are EXACT (every byte is accounted as the
protocol moves it); only *time* is modeled, as latency + bytes/bandwidth,
because this container has no cluster.  Two parameter sets ship:

* ``IB_2013``  — the paper's System G: QDR InfiniBand (32 Gbit/s effective,
  ~1.3 us), dual quad-core 2.8 GHz Harpertown nodes (8 cores/node), measured
  STREAM-class node memory bandwidth ~6.4 GB/s shared across the node's
  cores (matches the paper's Fig. 2 Pthreads plateau).
* ``ICI_V5E``  — the TPU-adaptation target: ~50 GB/s/link, ~1 us, HBM
  819 GB/s per chip (chips don't share HBM — node_size=1).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CostModel:
    name: str
    net_latency_s: float          # per message
    net_bw_Bps: float             # per link
    node_mem_bw_Bps: float        # all sockets of a node combined
    node_size: int                # workers per node (placement fills nodes)
    flops_per_worker: float       # SUSTAINED scalar flops per worker
    socket_size: int = 0          # 0 = no socket effect; else cores/socket.
    #   The paper's placement fills socket 0 first (its Fig. 2 note: 1-4
    #   core bandwidth is similar): <= socket_size workers see only one
    #   socket's memory bandwidth (node_mem_bw / n_sockets).

    def node_bw(self, workers_sharing: int) -> float:
        if self.socket_size and workers_sharing <= self.socket_size:
            n_sockets = max(1, self.node_size // self.socket_size)
            return self.node_mem_bw_Bps / n_sockets
        return self.node_mem_bw_Bps

    def xfer_s(self, n_bytes: float, n_msgs: int = 1) -> float:
        return self.net_latency_s * n_msgs + n_bytes / self.net_bw_Bps

    def mem_s(self, n_bytes: float, workers_sharing: int = 1) -> float:
        bw = self.node_bw(workers_sharing) / max(1, workers_sharing)
        return n_bytes / bw

    def compute_s(self, flops: float = 0.0, mem_bytes: float = 0.0,
                  workers_sharing: int = 1) -> float:
        return max(flops / self.flops_per_worker,
                   self.mem_s(mem_bytes, workers_sharing))

    def workers_on_node(self, n_workers: int) -> int:
        return min(n_workers, self.node_size)


IB_2013 = CostModel(
    name="ib2013",
    net_latency_s=1.3e-6,
    net_bw_Bps=4.0e9,             # QDR 32 Gbit/s
    node_mem_bw_Bps=6.4e9,        # Penryn Harpertown node (STREAM-class)
    node_size=8,
    socket_size=4,                # dual quad-core, fill-first placement
    flops_per_worker=2.8e9,       # 2.8 GHz, ~1 sustained flop/cycle —
    #   the paper's kernels are scalar C with divisions/transcendentals in
    #   the inner loops (OmpSCR), nowhere near 4-wide SSE peak
)

ICI_V5E = CostModel(
    name="ici_v5e",
    net_latency_s=1.0e-6,
    net_bw_Bps=50.0e9,
    node_mem_bw_Bps=819.0e9,
    node_size=1,
    flops_per_worker=197e12,
)
