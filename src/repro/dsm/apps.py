"""The paper's three applications, ported onto the RegC runtime API.

These are the Samhita programs of §V — STREAM TRIAD, Jacobi (OmpSCR), and
molecular dynamics (OmpSCR) — expressed as phase-structured SPMD over a
RegC runtime (reference or scale engine; both expose the same API).

Each app takes ``mode``:
* ``lock``       — global accumulators protected by a mutex (consistency
  region), exactly the paper's threaded port;
* ``reduction``  — the paper's §V-B programming-model extension:
  ``rt.reduce`` replaces the mutex-accumulate pattern.

Compute costs are charged via ``rt.compute`` from per-phase flop/byte
counts (the runtime's node model turns them into time); ALL protocol
traffic is exact.
"""
from __future__ import annotations

from typing import Callable, Optional

RES_LOCK = 0
ENERGY_LOCK = 1


def _phase_fn(rt):
    """Drive one worker-phase per call: runtimes exposing ``rt.phase``
    (the scale engine — its seam for worker-axis batching, see ROADMAP)
    get the phase as a single call; others (the reference runtime) get
    the equivalent sequence of read/write/compute calls."""
    ph = getattr(rt, "phase", None)
    if ph is not None:
        return ph

    def fallback(w, reads=(), writes=(), *, flops=0.0, mem_bytes=0.0,
                 seconds=0.0, instr_words=0.0):
        for ga, lo, hi in reads:
            rt.read(w, ga, lo, hi)
        for ga, lo, hi in writes:
            rt.write(w, ga, lo, hi)
        if flops or mem_bytes or seconds:
            rt.compute(w, flops=flops, mem_bytes=mem_bytes, seconds=seconds)
        if instr_words:
            rt.instr_stores(w, instr_words)
    return fallback


# ---------------------------------------------------------------------------
# STREAM TRIAD (paper §V-A, Figs. 2-4)
# ---------------------------------------------------------------------------


def stream_triad(rt, n: int, iters: int, *,
                 on_iter: Optional[Callable] = None):
    """A = B + alpha*C, one barrier per iteration (400 in the paper)."""
    A, B, C = rt.alloc(n), rt.alloc(n), rt.alloc(n)
    W = rt.W
    chunk = n // W
    phase = _phase_fn(rt)
    for it in range(iters):
        for w in range(W):
            lo = w * chunk
            hi = (w + 1) * chunk if w < W - 1 else n
            phase(w, reads=((B, lo, hi), (C, lo, hi)),
                  writes=((A, lo, hi),),
                  flops=2.0 * (hi - lo), mem_bytes=3.0 * 4 * (hi - lo))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def triad_bytes_per_iter(n: int) -> float:
    return 3.0 * 4 * n


# ---------------------------------------------------------------------------
# Jacobi iterative solver (paper §V-B, Figs. 5-6; OmpSCR c_jacobi01)
# ---------------------------------------------------------------------------


def jacobi(rt, n: int, iters: int, *, mode: str = "lock",
           on_iter: Optional[Callable] = None):
    """5-point stencil on an n x n grid; per-iteration global residual.

    Phases per iteration (3 barriers, as in the paper):
      1. uold = u                  (ordinary stores, own block)
      2. u = stencil(uold, f); local residual; global accumulate
         (consistency region in 'lock' mode / runtime reduction otherwise)
      3. all workers read the residual (convergence test)
    """
    assert mode in ("lock", "reduction")
    W = rt.W
    u = rt.alloc(n * n)
    uold = rt.alloc(n * n)
    f = rt.alloc(n * n)
    res = rt.alloc(1)          # global residual accumulator (one word)
    rows = n // W
    phase = _phase_fn(rt)

    for it in range(iters):
        # phase 1: copy own block u -> uold
        for w in range(W):
            lo, hi = w * rows * n, ((w + 1) * rows if w < W - 1 else n) * n
            phase(w, reads=((u, lo, hi),), writes=((uold, lo, hi),),
                  mem_bytes=2.0 * 4 * (hi - lo))
        rt.barrier()

        # phase 2: stencil + residual
        for w in range(W):
            r0 = w * rows
            r1 = (w + 1) * rows if w < W - 1 else n
            lo_h = max(r0 - 1, 0) * n            # halo rows from neighbours
            hi_h = min(r1 + 1, n) * n
            pts = (r1 - r0) * n
            # OmpSCR stencil: ~13 adds/muls + one fp DIVISION per point
            # (the residual normalization) — ~50 flop-equivalents scalar
            phase(w, reads=((uold, lo_h, hi_h), (f, r0 * n, r1 * n)),
                  writes=((u, r0 * n, r1 * n),),
                  flops=50.0 * pts, mem_bytes=4.0 * 4 * pts)
            if mode == "lock":
                with rt.span(w, RES_LOCK):
                    rt.read(w, res, 0, 1)
                    rt.write(w, res, 0, 1)
            else:
                rt.reduce(w, "residual", 1.0)
        rt.barrier()

        # phase 3: convergence test — everyone reads the residual
        for w in range(W):
            if mode == "lock":
                rt.read(w, res, 0, 1)
            else:
                pass                              # reduction result is local
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def jacobi_flops_per_iter(n: int) -> float:
    return 50.0 * n * n


# ---------------------------------------------------------------------------
# Molecular dynamics (paper §V-C, Fig. 7; OmpSCR c_md)
# ---------------------------------------------------------------------------


def molecular_dynamics(rt, n_particles: int, iters: int, *,
                       mode: str = "lock", ndim: int = 3,
                       on_iter: Optional[Callable] = None):
    """Velocity-Verlet n-body with a central pair potential.

    Phase A (forces): every worker reads ALL positions, writes the force
    rows of its own particles, and accumulates potential+kinetic energy
    into globals (mutex / reduction).  O(n^2/W) interactions per worker.
    Phase B (update): positions/velocities/accelerations of own particles.
    """
    assert mode in ("lock", "reduction")
    W = rt.W
    nw = n_particles * ndim
    pos = rt.alloc(nw)
    vel = rt.alloc(nw)
    acc = rt.alloc(nw)
    force = rt.alloc(nw)
    energy = rt.alloc(2)       # [potential, kinetic]
    chunk = n_particles // W
    phase = _phase_fn(rt)

    for it in range(iters):
        # phase A: forces + energies
        for w in range(W):
            p0 = w * chunk
            p1 = (w + 1) * chunk if w < W - 1 else n_particles
            inter = (p1 - p0) * n_particles
            # ~18 flops + sqrt + pow per pair (OmpSCR central potential):
            # ~60 flop-equivalents scalar; the pair loop accumulates the
            # 3-vector force per pair — instrumented stores under `fine`
            # (the paper's §V-C overhead)
            phase(w,
                  reads=((pos, 0, nw),                       # all positions
                         (vel, p0 * ndim, p1 * ndim)),       # own vel (KE)
                  writes=((force, p0 * ndim, p1 * ndim),),
                  flops=60.0 * inter,
                  mem_bytes=4.0 * (nw + 2 * (p1 - p0) * ndim),
                  instr_words=3.0 * inter)
            if mode == "lock":
                with rt.span(w, ENERGY_LOCK):
                    rt.read(w, energy, 0, 2)
                    rt.write(w, energy, 0, 2)
            else:
                rt.reduce(w, "potential", 1.0)
                rt.reduce(w, "kinetic", 1.0)
        rt.barrier()

        # phase B: velocity-Verlet update of own particles
        for w in range(W):
            p0, p1 = w * chunk * ndim, ((w + 1) * chunk if w < W - 1
                                        else n_particles) * ndim
            phase(w,
                  reads=((pos, p0, p1), (vel, p0, p1),
                         (acc, p0, p1), (force, p0, p1)),
                  writes=((pos, p0, p1), (vel, p0, p1), (acc, p0, p1)),
                  flops=12.0 * (p1 - p0), mem_bytes=7.0 * 4 * (p1 - p0))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def md_flops_per_iter(n_particles: int) -> float:
    return 60.0 * n_particles * n_particles
