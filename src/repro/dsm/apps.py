"""The paper's three applications, ported onto the RegC runtime API.

These are the Samhita programs of §V — STREAM TRIAD, Jacobi (OmpSCR), and
molecular dynamics (OmpSCR) — expressed as phase-structured SPMD over a
RegC runtime (reference or scale engine; both expose the same API).

Each bulk phase is described once as (W,) interval arrays — the worker's
read/write sets declared up front, which is what makes whole-phase batched
coherence resolution possible — and handed to a *driver*:

* ``batched`` — one ``rt.phase_all`` call per phase (the scale engine's
  worker-axis vectorized path);
* ``loop``    — one ``rt.phase`` (or read/write/compute sequence, for the
  reference runtime) call per worker, in worker order.

The two drivers are bit-exact against each other: consistency-region spans
(lock mode) always run in a per-worker pass AFTER the bulk phase, so the
op order is identical whichever driver executes the bulk part.

Each app takes ``mode``:
* ``lock``       — global accumulators protected by a mutex (consistency
  region), exactly the paper's threaded port;
* ``reduction``  — the paper's §V-B programming-model extension:
  ``rt.reduce`` replaces the mutex-accumulate pattern.

Compute costs are charged via per-phase flop/byte counts (the runtime's
node model turns them into time); ALL protocol traffic is exact.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

RES_LOCK = 0
ENERGY_LOCK = 1


def _phase_driver(rt, driver: str = "auto"):
    """Return ``phase(reads=..., writes=..., flops=..., ...)`` executing one
    whole SPMD phase.  Interval tuples are ``(ga, lo, hi)`` with (W,) int
    arrays; flops/mem_bytes/seconds/instr_words scalars or (W,) arrays."""
    assert driver in ("auto", "batched", "loop"), driver
    batched = getattr(rt, "phase_all", None)
    if driver == "auto":
        driver = "batched" if batched is not None else "loop"
    if driver == "batched":
        assert batched is not None, "runtime has no phase_all (use loop)"
        return batched

    W = rt.W
    per_worker = getattr(rt, "phase", None)

    def at(v, w):
        return float(v[w]) if np.ndim(v) else float(v)

    def loop(reads=(), writes=(), *, flops=0.0, mem_bytes=0.0, seconds=0.0,
             instr_words=0.0):
        for w in range(W):
            r = [(ga, int(lo[w]), int(hi[w])) for ga, lo, hi in reads]
            wr = [(ga, int(lo[w]), int(hi[w])) for ga, lo, hi in writes]
            fl, mb = at(flops, w), at(mem_bytes, w)
            sec, iw = at(seconds, w), at(instr_words, w)
            if per_worker is not None:
                per_worker(w, reads=r, writes=wr, flops=fl, mem_bytes=mb,
                           seconds=sec, instr_words=iw)
                continue
            for ga, lo, hi in r:
                rt.read(w, ga, lo, hi)
            for ga, lo, hi in wr:
                rt.write(w, ga, lo, hi)
            if fl or mb or sec:
                rt.compute(w, flops=fl, mem_bytes=mb, seconds=sec)
            if iw:
                rt.instr_stores(w, iw)
    return loop


def _span_driver(rt, driver: str = "auto"):
    """Return ``span_phase(lock_ids, reads=..., writes=..., w_mask=None)``
    executing one whole consistency-region pass: every masked worker
    acquires its lock, runs the declared interval ops inside the span,
    and releases.  ``batched`` drives ``rt.span_all`` (grant order
    serialized, flush+notice pipelined); ``loop`` — and any runtime
    without span_all, e.g. the reference — runs the per-worker span loop
    in worker order.  The two are bit-exact against each other (the
    span_all contract, lockstep-checked by the trace-fuzz suite)."""
    assert driver in ("auto", "batched", "loop"), driver
    batched = getattr(rt, "span_all", None)
    if driver == "auto":
        driver = "batched" if batched is not None else "loop"
    if driver == "batched":
        assert batched is not None, "runtime has no span_all (use loop)"

        def span_batched(lock_ids, reads=(), writes=(), w_mask=None):
            batched(w_mask, lock_ids, reads=reads, writes=writes)
        return span_batched

    W = rt.W

    def span_loop(lock_ids, reads=(), writes=(), w_mask=None):
        locks = np.broadcast_to(np.asarray(lock_ids, np.int64), (W,))
        for w in range(W):
            if w_mask is not None and not w_mask[w]:
                continue
            rt.acquire(w, int(locks[w]))
            for ga, lo, hi in reads:
                rt.read(w, ga, int(lo[w]), int(hi[w]))
            for ga, lo, hi in writes:
                rt.write(w, ga, int(lo[w]), int(hi[w]))
            rt.release(w, int(locks[w]))
    return span_loop


def _reduce_all(rt, name: str, value: float = 1.0):
    """Per-worker reduction contribution, batched when the runtime offers
    ``reduce_all`` (identical combine either way)."""
    ra = getattr(rt, "reduce_all", None)
    if ra is not None:
        ra(name, value)
    else:
        for w in range(rt.W):
            rt.reduce(w, name, value)


def _blocks(n: int, W: int):
    """Block partition of [0, n): (W,) lo/hi arrays, last worker takes the
    remainder (the paper's static OpenMP-style schedule)."""
    chunk = n // W
    lo = np.arange(W, dtype=np.int64) * chunk
    hi = lo + chunk
    hi[-1] = n
    return lo, hi


# ---------------------------------------------------------------------------
# STREAM TRIAD (paper §V-A, Figs. 2-4)
# ---------------------------------------------------------------------------


def stream_triad(rt, n: int, iters: int, *, driver: str = "auto",
                 on_iter: Optional[Callable] = None):
    """A = B + alpha*C, one barrier per iteration (400 in the paper)."""
    A, B, C = rt.alloc(n), rt.alloc(n), rt.alloc(n)
    W = rt.W
    lo, hi = _blocks(n, W)
    phase = _phase_driver(rt, driver)
    flops = 2.0 * (hi - lo)
    mem_bytes = 3.0 * 4 * (hi - lo)
    for it in range(iters):
        phase(reads=((B, lo, hi), (C, lo, hi)), writes=((A, lo, hi),),
              flops=flops, mem_bytes=mem_bytes)
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def triad_bytes_per_iter(n: int) -> float:
    return 3.0 * 4 * n


def stream_spill(rt, n: int, iters: int, *, sweeps: int = 2,
                 rotate: bool = True, driver: str = "auto",
                 on_iter: Optional[Callable] = None):
    """Capacity-pressure STREAM variant: every barrier epoch runs
    ``sweeps`` read+write passes, and with ``rotate`` each pass shifts the
    block assignment by one (worker w takes block ``(w + pass) % W``), so
    per-worker windows creep across the array and each worker's dirty
    block lands inside its neighbours' reach.  Under a small cache this is
    the adversarial spill regime for the batched eviction engine: rotation
    makes the window-disjointness analysis mark workers as interacting
    (tick-ordered residual replay), while ``rotate=False`` keeps blocks
    disjoint (fully batched eviction).  Bit-exact across drivers either
    way — that is the point."""
    A, B = rt.alloc(n), rt.alloc(n)
    W = rt.W
    chunk = n // W
    ids = np.arange(W, dtype=np.int64)
    phase = _phase_driver(rt, driver)
    for it in range(iters):
        for s in range(sweeps):
            r = (ids + it * sweeps + s) % W if rotate else ids
            lo = r * chunk
            hi = np.where(r == W - 1, n, lo + chunk)
            phase(reads=((B, lo, hi),), writes=((A, lo, hi),),
                  flops=2.0 * (hi - lo), mem_bytes=2.0 * 4 * (hi - lo))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def stream_refetch(rt, n: int, iters: int, *, sweeps: int = 2,
                   width_pages: int = 8, driver: str = "auto",
                   on_iter: Optional[Callable] = None):
    """Mid-op refetch torture (the ``_danger`` adversary): each worker
    owns a disjoint block and every pass slides a read+write window
    across it by HALF the window width, under a cache that holds barely
    more than one window pair.  Every op's range therefore half-overlaps
    pages still in cache (its own previous window) while the cold half
    pushes occupancy over the watermark — the exact mid-op
    evict-then-refetch interleave the reference resolves page by page.
    Blocks stay disjoint, so the batched driver keeps every worker on
    the vectorized path and the per-op danger screen (not the residual
    tick-ordered replay) must absorb the pattern: ``stats`` should show
    ``danger_vec_ops`` rising with W while ``residual_replays`` stays 0.
    Bit-exact across drivers, like every app here."""
    A, B = rt.alloc(n), rt.alloc(n)
    W = rt.W
    pw = rt.page_words
    chunk = n // W
    Lw = width_pages * pw                   # window width in words
    assert chunk >= 2 * Lw, "blocks must fit a sliding window"
    step = Lw // 2
    n_offs = (chunk - Lw) // step + 1       # window positions per block
    ids = np.arange(W, dtype=np.int64)
    phase = _phase_driver(rt, driver)
    k = 0
    for it in range(iters):
        for s in range(sweeps):
            off = (k * step) % (n_offs * step)
            k += 1
            lo = ids * chunk + off
            hi = lo + Lw
            phase(reads=((B, lo, hi),), writes=((A, lo, hi),),
                  flops=2.0 * (hi - lo), mem_bytes=2.0 * 4 * (hi - lo))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


# ---------------------------------------------------------------------------
# Jacobi iterative solver (paper §V-B, Figs. 5-6; OmpSCR c_jacobi01)
# ---------------------------------------------------------------------------


def jacobi(rt, n: int, iters: int, *, mode: str = "lock",
           driver: str = "auto", on_iter: Optional[Callable] = None):
    """5-point stencil on an n x n grid; per-iteration global residual.

    Phases per iteration (3 barriers, as in the paper):
      1. uold = u                  (ordinary stores, own block)
      2. u = stencil(uold, f); local residual; global accumulate
         (consistency region in 'lock' mode / runtime reduction otherwise)
      3. all workers read the residual (convergence test)
    """
    assert mode in ("lock", "reduction")
    W = rt.W
    u = rt.alloc(n * n)
    uold = rt.alloc(n * n)
    f = rt.alloc(n * n)
    res = rt.alloc(1)          # global residual accumulator (one word)
    r0, r1 = _blocks(n, W)     # row blocks
    lo_b, hi_b = r0 * n, r1 * n
    lo_h = np.maximum(r0 - 1, 0) * n         # halo rows from neighbours
    hi_h = np.minimum(r1 + 1, n) * n
    pts = (r1 - r0) * n
    zero = np.zeros(W, np.int64)
    one = np.ones(W, np.int64)
    phase = _phase_driver(rt, driver)
    span_phase = _span_driver(rt, driver)

    for it in range(iters):
        # phase 1: copy own block u -> uold
        phase(reads=((u, lo_b, hi_b),), writes=((uold, lo_b, hi_b),),
              mem_bytes=2.0 * 4 * (hi_b - lo_b))
        rt.barrier()

        # phase 2: stencil + residual.  OmpSCR stencil: ~13 adds/muls +
        # one fp DIVISION per point (the residual normalization) — ~50
        # flop-equivalents scalar.  The global accumulate runs as a
        # per-worker span pass after the bulk phase (see module docstring).
        phase(reads=((uold, lo_h, hi_h), (f, lo_b, hi_b)),
              writes=((u, lo_b, hi_b),),
              flops=50.0 * pts, mem_bytes=4.0 * 4 * pts)
        if mode == "lock":
            span_phase(RES_LOCK, reads=((res, zero, one),),
                       writes=((res, zero, one),))
        else:
            _reduce_all(rt, "residual")
        rt.barrier()

        # phase 3: convergence test — everyone reads the residual
        if mode == "lock":
            phase(reads=((res, zero, one),))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def jacobi_flops_per_iter(n: int) -> float:
    return 50.0 * n * n


# ---------------------------------------------------------------------------
# Molecular dynamics (paper §V-C, Fig. 7; OmpSCR c_md)
# ---------------------------------------------------------------------------


def molecular_dynamics(rt, n_particles: int, iters: int, *,
                       mode: str = "lock", ndim: int = 3,
                       driver: str = "auto",
                       on_iter: Optional[Callable] = None):
    """Velocity-Verlet n-body with a central pair potential.

    Phase A (forces): every worker reads ALL positions, writes the force
    rows of its own particles, and accumulates potential+kinetic energy
    into globals (mutex / reduction).  O(n^2/W) interactions per worker.
    Phase B (update): positions/velocities/accelerations of own particles.
    """
    assert mode in ("lock", "reduction")
    W = rt.W
    nw = n_particles * ndim
    pos = rt.alloc(nw)
    vel = rt.alloc(nw)
    acc = rt.alloc(nw)
    force = rt.alloc(nw)
    energy = rt.alloc(2)       # [potential, kinetic]
    p0, p1 = _blocks(n_particles, W)
    lo_w, hi_w = p0 * ndim, p1 * ndim        # own word blocks
    inter = (p1 - p0) * n_particles
    zero = np.zeros(W, np.int64)
    two = np.full(W, 2, np.int64)
    all_w = np.full(W, nw, np.int64)
    phase = _phase_driver(rt, driver)
    span_phase = _span_driver(rt, driver)

    for it in range(iters):
        # phase A: forces + energies.  ~18 flops + sqrt + pow per pair
        # (OmpSCR central potential): ~60 flop-equivalents scalar; the
        # pair loop accumulates the 3-vector force per pair —
        # instrumented stores under `fine` (the paper's §V-C overhead).
        phase(reads=((pos, zero, all_w),                 # all positions
                     (vel, lo_w, hi_w)),                 # own vel (KE)
              writes=((force, lo_w, hi_w),),
              flops=60.0 * inter,
              mem_bytes=4.0 * (nw + 2.0 * (hi_w - lo_w)),
              instr_words=3.0 * inter)
        if mode == "lock":
            span_phase(ENERGY_LOCK, reads=((energy, zero, two),),
                       writes=((energy, zero, two),))
        else:
            _reduce_all(rt, "potential")
            _reduce_all(rt, "kinetic")
        rt.barrier()

        # phase B: velocity-Verlet update of own particles
        phase(reads=((pos, lo_w, hi_w), (vel, lo_w, hi_w),
                     (acc, lo_w, hi_w), (force, lo_w, hi_w)),
              writes=((pos, lo_w, hi_w), (vel, lo_w, hi_w),
                      (acc, lo_w, hi_w)),
              flops=12.0 * (hi_w - lo_w), mem_bytes=7.0 * 4 * (hi_w - lo_w))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def md_flops_per_iter(n_particles: int) -> float:
    return 60.0 * n_particles * n_particles


# ---------------------------------------------------------------------------
# Lock contention (span-engine adversary: hot lock + disjoint lock striping)
# ---------------------------------------------------------------------------


def lock_contention(rt, n: int, iters: int, *, n_locks: int = 8,
                    sweeps: int = 1, driver: str = "auto",
                    on_iter: Optional[Callable] = None):
    """Adversarial consistency-region workload for the span engine.

    Each iteration runs one bulk ordinary phase (read+write of the
    worker's own block — so every span pass starts with real flush work
    to pipeline), then ``sweeps`` x two span passes:

    * **striped** — worker w serializes on lock ``w % n_locks``,
      accumulating into that lock's private page: ``n_locks`` independent
      grant chains of W/n_locks holders each, the regime where distinct
      locks' flush+notice work can fully pipeline;
    * **hot** — every worker serializes through ONE global lock updating
      one shared accumulator pair: the worst-case grant chain, where
      only the per-holder work around the grant can batch.

    Both passes are uniform per lock group, so the batched driver's
    analytic group path (``span_all``/``_span_group_vec``) must absorb
    them entirely; ``stats['span_groups_vec']`` counts it.  Bit-exact
    across drivers, like every app here."""
    assert n_locks >= 1
    W = rt.W
    pw = rt.page_words
    A = rt.alloc(n)
    acc = rt.alloc(n_locks * pw)       # one private page per striped lock
    hot = rt.alloc(2)                  # the global accumulator pair
    ids = np.arange(W, dtype=np.int64)
    lo, hi = _blocks(n, W)
    stripe = (ids % n_locks).astype(np.int64)
    s_lo = stripe * pw
    s_hi = s_lo + 2
    zero = np.zeros(W, np.int64)
    two = np.full(W, 2, np.int64)
    hot_lock = n_locks                 # distinct from every striped lock
    phase = _phase_driver(rt, driver)
    span_phase = _span_driver(rt, driver)
    for it in range(iters):
        phase(reads=((A, lo, hi),), writes=((A, lo, hi),),
              flops=4.0 * (hi - lo), mem_bytes=2.0 * 4 * (hi - lo))
        for _ in range(sweeps):
            span_phase(stripe, reads=((acc, s_lo, s_hi),),
                       writes=((acc, s_lo, s_hi),))
            span_phase(hot_lock, reads=((hot, zero, two),),
                       writes=((hot, zero, two),))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def race_audit(rt, n: int, iters: int, *, n_locks: int = 4,
               driver: str = "auto", on_iter: Optional[Callable] = None):
    """Mixed clean/racy workload for the race-detection bench
    (fig11_races): real protocol traffic with a known, deterministic
    set of data races for the detector to flag.

    Each iteration runs

    * a bulk ordinary phase on the worker's own block (clean);
    * a striped span pass — lock ``w % n_locks`` guarding that lock's
      private accumulator page (clean: same-lock accesses are ordered);
    * the audit targets: a write of the own block followed — with the
      barrier deliberately omitted — by a read of the NEXT worker's
      block (an unordered W→R handoff: one ``rw`` race per shared
      page), and pairwise writes to a shared scratch page with no lock
      at all (one ``ww`` race per worker pair);
    * a barrier closing the iteration.

    The flagged race set saturates after the first iteration (tuples
    are counted once), so ``race_ww``/``race_rw`` are deterministic and
    the committed bench rows gate them like the ``span_*`` counters.
    With ``detect_races=False`` the program is the detector-off
    overhead baseline — traffic and clocks must be bit-equal (the
    pure-observer contract)."""
    assert n_locks >= 1
    W = rt.W
    pw = rt.page_words
    A = rt.alloc(n)
    acc = rt.alloc(n_locks * pw)       # one private page per striped lock
    pairs = rt.alloc(((W + 1) // 2) * pw)  # one shared page per pair
    ids = np.arange(W, dtype=np.int64)
    lo, hi = _blocks(n, W)
    nb_lo, nb_hi = np.roll(lo, -1), np.roll(hi, -1)   # block of (w+1)%W
    stripe = (ids % n_locks).astype(np.int64)
    s_lo = stripe * pw
    s_hi = s_lo + 2
    pr_lo = (ids // 2) * pw
    pr_hi = pr_lo + 2
    phase = _phase_driver(rt, driver)
    span_phase = _span_driver(rt, driver)
    for it in range(iters):
        phase(reads=((A, lo, hi),), writes=((A, lo, hi),),
              flops=2.0 * (hi - lo))
        span_phase(stripe, reads=((acc, s_lo, s_hi),),
                   writes=((acc, s_lo, s_hi),))
        phase(writes=((A, lo, hi),))
        phase(reads=((A, nb_lo, nb_hi),))   # no barrier: unordered handoff
        phase(writes=((pairs, pr_lo, pr_hi),))  # no lock: pairwise W/W
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt
