"""The paper's three applications, ported onto the RegC runtime API.

These are the Samhita programs of §V — STREAM TRIAD, Jacobi (OmpSCR), and
molecular dynamics (OmpSCR) — expressed as phase-structured SPMD over a
RegC runtime (reference or scale engine; both expose the same API).

Each bulk phase is described once as (W,) interval arrays — the worker's
read/write sets declared up front, which is what makes whole-phase batched
coherence resolution possible — and handed to a ``repro.dsm.session``
driver (``batched`` = the scale engine's worker-axis vectorized
``phase_all`` path; ``loop`` = per-worker ops in worker order).  The two
drivers are bit-exact against each other: consistency-region spans (lock
mode) always run in a per-worker pass AFTER the bulk phase, so the op
order is identical whichever driver executes the bulk part.

Each app takes ``mode``:
* ``lock``       — global accumulators protected by a mutex (consistency
  region), exactly the paper's threaded port;
* ``reduction``  — the paper's §V-B programming-model extension:
  ``rt.reduce`` replaces the mutex-accumulate pattern.

Compute costs are charged via per-phase flop/byte counts (the runtime's
node model turns them into time); ALL protocol traffic is exact.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.dsm.session import session

RES_LOCK = 0
ENERGY_LOCK = 1


# --- back-compat shims (pre-Session API) -----------------------------------
# The driver implementations live in ``repro.dsm.session``; these wrappers
# keep old ``from repro.dsm.apps import _phase_driver`` call sites working
# and are what tests/test_api.py proves equivalent to the Session surface.


def _phase_driver(rt, driver: str = "auto"):
    """Deprecated: use ``session(rt, driver).phase``."""
    return session(rt, driver).phase


def _span_driver(rt, driver: str = "auto"):
    """Deprecated: use ``session(rt, driver).span``."""
    return session(rt, driver).span


def _reduce_all(rt, name: str, value: float = 1.0):
    """Deprecated: use ``session(rt).reduce(name, value)``."""
    session(rt, "auto").reduce(name, value)


def _blocks(n: int, W: int):
    """Block partition of [0, n): (W,) lo/hi arrays, last worker takes the
    remainder (the paper's static OpenMP-style schedule)."""
    chunk = n // W
    lo = np.arange(W, dtype=np.int64) * chunk
    hi = lo + chunk
    hi[-1] = n
    return lo, hi


# ---------------------------------------------------------------------------
# STREAM TRIAD (paper §V-A, Figs. 2-4)
# ---------------------------------------------------------------------------


def stream_triad(rt, n: int, iters: int, *, driver: str = "auto",
                 on_iter: Optional[Callable] = None):
    """A = B + alpha*C, one barrier per iteration (400 in the paper)."""
    A, B, C = rt.alloc(n), rt.alloc(n), rt.alloc(n)
    W = rt.W
    lo, hi = _blocks(n, W)
    phase = session(rt, driver).phase
    flops = 2.0 * (hi - lo)
    mem_bytes = 3.0 * 4 * (hi - lo)
    for it in range(iters):
        phase(reads=((B, lo, hi), (C, lo, hi)), writes=((A, lo, hi),),
              flops=flops, mem_bytes=mem_bytes)
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def triad_bytes_per_iter(n: int) -> float:
    return 3.0 * 4 * n


def stream_spill(rt, n: int, iters: int, *, sweeps: int = 2,
                 rotate: bool = True, driver: str = "auto",
                 on_iter: Optional[Callable] = None):
    """Capacity-pressure STREAM variant: every barrier epoch runs
    ``sweeps`` read+write passes, and with ``rotate`` each pass shifts the
    block assignment by one (worker w takes block ``(w + pass) % W``), so
    per-worker windows creep across the array and each worker's dirty
    block lands inside its neighbours' reach.  Under a small cache this is
    the adversarial spill regime for the batched eviction engine: rotation
    makes the window-disjointness analysis mark workers as interacting
    (tick-ordered residual replay), while ``rotate=False`` keeps blocks
    disjoint (fully batched eviction).  Bit-exact across drivers either
    way — that is the point."""
    A, B = rt.alloc(n), rt.alloc(n)
    W = rt.W
    chunk = n // W
    ids = np.arange(W, dtype=np.int64)
    phase = session(rt, driver).phase
    for it in range(iters):
        for s in range(sweeps):
            r = (ids + it * sweeps + s) % W if rotate else ids
            lo = r * chunk
            hi = np.where(r == W - 1, n, lo + chunk)
            phase(reads=((B, lo, hi),), writes=((A, lo, hi),),
                  flops=2.0 * (hi - lo), mem_bytes=2.0 * 4 * (hi - lo))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def stream_refetch(rt, n: int, iters: int, *, sweeps: int = 2,
                   width_pages: int = 8, driver: str = "auto",
                   on_iter: Optional[Callable] = None):
    """Mid-op refetch torture (the ``_danger`` adversary): each worker
    owns a disjoint block and every pass slides a read+write window
    across it by HALF the window width, under a cache that holds barely
    more than one window pair.  Every op's range therefore half-overlaps
    pages still in cache (its own previous window) while the cold half
    pushes occupancy over the watermark — the exact mid-op
    evict-then-refetch interleave the reference resolves page by page.
    Blocks stay disjoint, so the batched driver keeps every worker on
    the vectorized path and the per-op danger screen (not the residual
    tick-ordered replay) must absorb the pattern: ``stats`` should show
    ``danger_vec_ops`` rising with W while ``residual_replays`` stays 0.
    Bit-exact across drivers, like every app here."""
    A, B = rt.alloc(n), rt.alloc(n)
    W = rt.W
    pw = rt.page_words
    chunk = n // W
    Lw = width_pages * pw                   # window width in words
    assert chunk >= 2 * Lw, "blocks must fit a sliding window"
    step = Lw // 2
    n_offs = (chunk - Lw) // step + 1       # window positions per block
    ids = np.arange(W, dtype=np.int64)
    phase = session(rt, driver).phase
    k = 0
    for it in range(iters):
        for s in range(sweeps):
            off = (k * step) % (n_offs * step)
            k += 1
            lo = ids * chunk + off
            hi = lo + Lw
            phase(reads=((B, lo, hi),), writes=((A, lo, hi),),
                  flops=2.0 * (hi - lo), mem_bytes=2.0 * 4 * (hi - lo))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


# ---------------------------------------------------------------------------
# Jacobi iterative solver (paper §V-B, Figs. 5-6; OmpSCR c_jacobi01)
# ---------------------------------------------------------------------------


def jacobi(rt, n: int, iters: int, *, mode: str = "lock",
           driver: str = "auto", on_iter: Optional[Callable] = None):
    """5-point stencil on an n x n grid; per-iteration global residual.

    Phases per iteration (3 barriers, as in the paper):
      1. uold = u                  (ordinary stores, own block)
      2. u = stencil(uold, f); local residual; global accumulate
         (consistency region in 'lock' mode / runtime reduction otherwise)
      3. all workers read the residual (convergence test)
    """
    assert mode in ("lock", "reduction")
    W = rt.W
    u = rt.alloc(n * n)
    uold = rt.alloc(n * n)
    f = rt.alloc(n * n)
    res = rt.alloc(1)          # global residual accumulator (one word)
    r0, r1 = _blocks(n, W)     # row blocks
    lo_b, hi_b = r0 * n, r1 * n
    lo_h = np.maximum(r0 - 1, 0) * n         # halo rows from neighbours
    hi_h = np.minimum(r1 + 1, n) * n
    pts = (r1 - r0) * n
    zero = np.zeros(W, np.int64)
    one = np.ones(W, np.int64)
    s = session(rt, driver)
    phase, span_phase = s.phase, s.span

    for it in range(iters):
        # phase 1: copy own block u -> uold
        phase(reads=((u, lo_b, hi_b),), writes=((uold, lo_b, hi_b),),
              mem_bytes=2.0 * 4 * (hi_b - lo_b))
        rt.barrier()

        # phase 2: stencil + residual.  OmpSCR stencil: ~13 adds/muls +
        # one fp DIVISION per point (the residual normalization) — ~50
        # flop-equivalents scalar.  The global accumulate runs as a
        # per-worker span pass after the bulk phase (see module docstring).
        phase(reads=((uold, lo_h, hi_h), (f, lo_b, hi_b)),
              writes=((u, lo_b, hi_b),),
              flops=50.0 * pts, mem_bytes=4.0 * 4 * pts)
        if mode == "lock":
            span_phase(RES_LOCK, reads=((res, zero, one),),
                       writes=((res, zero, one),))
        else:
            s.reduce("residual")
        rt.barrier()

        # phase 3: convergence test — everyone reads the residual
        if mode == "lock":
            phase(reads=((res, zero, one),))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def jacobi_flops_per_iter(n: int) -> float:
    return 50.0 * n * n


# ---------------------------------------------------------------------------
# Molecular dynamics (paper §V-C, Fig. 7; OmpSCR c_md)
# ---------------------------------------------------------------------------


def molecular_dynamics(rt, n_particles: int, iters: int, *,
                       mode: str = "lock", ndim: int = 3,
                       driver: str = "auto",
                       on_iter: Optional[Callable] = None):
    """Velocity-Verlet n-body with a central pair potential.

    Phase A (forces): every worker reads ALL positions, writes the force
    rows of its own particles, and accumulates potential+kinetic energy
    into globals (mutex / reduction).  O(n^2/W) interactions per worker.
    Phase B (update): positions/velocities/accelerations of own particles.
    """
    assert mode in ("lock", "reduction")
    W = rt.W
    nw = n_particles * ndim
    pos = rt.alloc(nw)
    vel = rt.alloc(nw)
    acc = rt.alloc(nw)
    force = rt.alloc(nw)
    energy = rt.alloc(2)       # [potential, kinetic]
    p0, p1 = _blocks(n_particles, W)
    lo_w, hi_w = p0 * ndim, p1 * ndim        # own word blocks
    inter = (p1 - p0) * n_particles
    zero = np.zeros(W, np.int64)
    two = np.full(W, 2, np.int64)
    all_w = np.full(W, nw, np.int64)
    s = session(rt, driver)
    phase, span_phase = s.phase, s.span

    for it in range(iters):
        # phase A: forces + energies.  ~18 flops + sqrt + pow per pair
        # (OmpSCR central potential): ~60 flop-equivalents scalar; the
        # pair loop accumulates the 3-vector force per pair —
        # instrumented stores under `fine` (the paper's §V-C overhead).
        phase(reads=((pos, zero, all_w),                 # all positions
                     (vel, lo_w, hi_w)),                 # own vel (KE)
              writes=((force, lo_w, hi_w),),
              flops=60.0 * inter,
              mem_bytes=4.0 * (nw + 2.0 * (hi_w - lo_w)),
              instr_words=3.0 * inter)
        if mode == "lock":
            span_phase(ENERGY_LOCK, reads=((energy, zero, two),),
                       writes=((energy, zero, two),))
        else:
            s.reduce("potential")
            s.reduce("kinetic")
        rt.barrier()

        # phase B: velocity-Verlet update of own particles
        phase(reads=((pos, lo_w, hi_w), (vel, lo_w, hi_w),
                     (acc, lo_w, hi_w), (force, lo_w, hi_w)),
              writes=((pos, lo_w, hi_w), (vel, lo_w, hi_w),
                      (acc, lo_w, hi_w)),
              flops=12.0 * (hi_w - lo_w), mem_bytes=7.0 * 4 * (hi_w - lo_w))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def md_flops_per_iter(n_particles: int) -> float:
    return 60.0 * n_particles * n_particles


# ---------------------------------------------------------------------------
# Lock contention (span-engine adversary: hot lock + disjoint lock striping)
# ---------------------------------------------------------------------------


def lock_contention(rt, n: int, iters: int, *, n_locks: int = 8,
                    sweeps: int = 1, driver: str = "auto",
                    on_iter: Optional[Callable] = None):
    """Adversarial consistency-region workload for the span engine.

    Each iteration runs one bulk ordinary phase (read+write of the
    worker's own block — so every span pass starts with real flush work
    to pipeline), then ``sweeps`` x two span passes:

    * **striped** — worker w serializes on lock ``w % n_locks``,
      accumulating into that lock's private page: ``n_locks`` independent
      grant chains of W/n_locks holders each, the regime where distinct
      locks' flush+notice work can fully pipeline;
    * **hot** — every worker serializes through ONE global lock updating
      one shared accumulator pair: the worst-case grant chain, where
      only the per-holder work around the grant can batch.

    Both passes are uniform per lock group, so the batched driver's
    analytic group path (``span_all``/``_span_group_vec``) must absorb
    them entirely; ``stats['span_groups_vec']`` counts it.  Bit-exact
    across drivers, like every app here."""
    assert n_locks >= 1
    W = rt.W
    pw = rt.page_words
    A = rt.alloc(n)
    acc = rt.alloc(n_locks * pw)       # one private page per striped lock
    hot = rt.alloc(2)                  # the global accumulator pair
    ids = np.arange(W, dtype=np.int64)
    lo, hi = _blocks(n, W)
    stripe = (ids % n_locks).astype(np.int64)
    s_lo = stripe * pw
    s_hi = s_lo + 2
    zero = np.zeros(W, np.int64)
    two = np.full(W, 2, np.int64)
    hot_lock = n_locks                 # distinct from every striped lock
    s = session(rt, driver)
    phase, span_phase = s.phase, s.span
    for it in range(iters):
        phase(reads=((A, lo, hi),), writes=((A, lo, hi),),
              flops=4.0 * (hi - lo), mem_bytes=2.0 * 4 * (hi - lo))
        for _ in range(sweeps):
            span_phase(stripe, reads=((acc, s_lo, s_hi),),
                       writes=((acc, s_lo, s_hi),))
            span_phase(hot_lock, reads=((hot, zero, two),),
                       writes=((hot, zero, two),))
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


def race_audit(rt, n: int, iters: int, *, n_locks: int = 4,
               driver: str = "auto", on_iter: Optional[Callable] = None):
    """Mixed clean/racy workload for the race-detection bench
    (fig11_races): real protocol traffic with a known, deterministic
    set of data races for the detector to flag.

    Each iteration runs

    * a bulk ordinary phase on the worker's own block (clean);
    * a striped span pass — lock ``w % n_locks`` guarding that lock's
      private accumulator page (clean: same-lock accesses are ordered);
    * the audit targets: a write of the own block followed — with the
      barrier deliberately omitted — by a read of the NEXT worker's
      block (an unordered W→R handoff: one ``rw`` race per shared
      page), and pairwise writes to a shared scratch page with no lock
      at all (one ``ww`` race per worker pair);
    * a barrier closing the iteration.

    The flagged race set saturates after the first iteration (tuples
    are counted once), so ``race_ww``/``race_rw`` are deterministic and
    the committed bench rows gate them like the ``span_*`` counters.
    With ``detect_races=False`` the program is the detector-off
    overhead baseline — traffic and clocks must be bit-equal (the
    pure-observer contract)."""
    assert n_locks >= 1
    W = rt.W
    pw = rt.page_words
    A = rt.alloc(n)
    acc = rt.alloc(n_locks * pw)       # one private page per striped lock
    pairs = rt.alloc(((W + 1) // 2) * pw)  # one shared page per pair
    ids = np.arange(W, dtype=np.int64)
    lo, hi = _blocks(n, W)
    nb_lo, nb_hi = np.roll(lo, -1), np.roll(hi, -1)   # block of (w+1)%W
    stripe = (ids % n_locks).astype(np.int64)
    s_lo = stripe * pw
    s_hi = s_lo + 2
    pr_lo = (ids // 2) * pw
    pr_hi = pr_lo + 2
    s = session(rt, driver)
    phase, span_phase = s.phase, s.span
    for it in range(iters):
        phase(reads=((A, lo, hi),), writes=((A, lo, hi),),
              flops=2.0 * (hi - lo))
        span_phase(stripe, reads=((acc, s_lo, s_hi),),
                   writes=((acc, s_lo, s_hi),))
        phase(writes=((A, lo, hi),))
        phase(reads=((A, nb_lo, nb_hi),))   # no barrier: unordered handoff
        phase(writes=((pairs, pr_lo, pr_hi),))  # no lock: pairwise W/W
        rt.barrier()
        if on_iter is not None:
            on_iter(it, rt)
    return rt


# ---------------------------------------------------------------------------
# KV-cache serving (fig8_kv_serving): inference traffic as a DSM workload
# ---------------------------------------------------------------------------


ADMIT_LOCK = 2


@dataclasses.dataclass
class ServeRequest:
    """One inference request in the synthetic multi-tenant stream."""
    tenant: int
    prompt_tokens: int
    decode_tokens: int
    arrival_step: int
    slot: int = -1
    admit_step: int = -1
    finish_step: int = -1
    arrival_time: float = 0.0
    finish_time: float = 0.0

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival_time


@dataclasses.dataclass
class ServeReport:
    """Deterministic outcome of one ``kv_serving`` run.

    Everything here is a pure function of the request stream and the
    runtime's modeled clocks, so the drivers' bit-equal-clock contract
    makes the whole report — latencies included — bit-equal across
    ``loop``/``batched`` and both backends."""
    requests: List[ServeRequest]
    steps: int = 0
    prefill_tokens: int = 0
    decode_tokens: int = 0
    admit_spans: int = 0
    admitted: int = 0
    idle_slot_steps: int = 0
    peak_queue: int = 0

    def latencies(self) -> np.ndarray:
        done = [r.latency for r in self.requests if r.finish_step >= 0]
        return np.asarray(sorted(done), dtype=np.float64)

    def latency_pct(self, q: float) -> float:
        lat = self.latencies()
        if not lat.size:
            raise ValueError("latency_pct(): no completed requests")
        return float(np.percentile(lat, q))

    @property
    def span_time(self) -> float:
        """Modeled makespan: last finish time across completed requests."""
        return max((r.finish_time for r in self.requests
                    if r.finish_step >= 0), default=0.0)

    def tokens_per_s(self) -> float:
        t = self.span_time
        return (self.prefill_tokens + self.decode_tokens) / t if t else 0.0


def gen_requests(n_requests: int, *, n_tenants: int = 8,
                 zipf_s: float = 1.3, max_tokens: int = 96,
                 burst_mean: int = 4, gap_max: int = 3,
                 seed: int = 0) -> List[ServeRequest]:
    """Synthetic multi-tenant request stream: Zipf-skewed tenant draws
    (tenant 0 hottest), per-tenant length profiles (hot tenants chatty —
    short prompts/decodes; cold tenants long-context), and bursty
    arrivals (geometric burst sizes separated by uniform step gaps,
    arrival step = decode-step index as the time axis).  Deterministic
    in ``seed``."""
    rng = np.random.default_rng(seed)
    # Zipf over tenant ranks via inverse-CDF on the truncated harmonic
    ranks = np.arange(1, n_tenants + 1, dtype=np.float64)
    pmf = ranks ** -zipf_s
    pmf /= pmf.sum()
    tenants = rng.choice(n_tenants, size=n_requests, p=pmf)
    # per-tenant profiles: prompt/decode budgets scale with tenant rank
    p_base = np.minimum(4 + 6 * np.arange(n_tenants), (3 * max_tokens) // 4)
    d_base = np.minimum(3 + 2 * np.arange(n_tenants), max_tokens // 4)
    reqs: List[ServeRequest] = []
    step = 0
    emitted = 0
    while emitted < n_requests:
        burst = min(int(rng.geometric(1.0 / burst_mean)),
                    n_requests - emitted)
        for _ in range(burst):
            t = int(tenants[emitted])
            dec = max(1, int(d_base[t]) + int(rng.integers(-2, 3)))
            pro = max(1, int(p_base[t]) + int(rng.integers(-3, 4)))
            pro = min(pro, max_tokens - dec)   # fits the slot KV budget
            reqs.append(ServeRequest(tenant=t, prompt_tokens=pro,
                                     decode_tokens=dec, arrival_step=step))
            emitted += 1
        step += int(rng.integers(1, gap_max + 1))
    return reqs


def kv_serving(rt, n_requests: int, *, tok_words: int = 64,
               max_tokens: int = 96, attn_window: int = 32,
               n_tenants: int = 8, zipf_s: float = 1.3,
               burst_mean: int = 4, gap_max: int = 3, seed: int = 0,
               driver: str = "auto", max_steps: int = 200_000,
               on_step: Optional[Callable] = None) -> ServeReport:
    """Continuous-batching inference fleet as a RegC program.

    Workers are decode slots; the KV cache is one GAS region of W
    page-aligned slot blocks, each ``max_tokens`` rows of ``tok_words``
    words (a slot's stacked per-layer K/V rows — the layout of
    ``serve/decode.py``'s caches, flattened time-major).  Each decode
    step runs:

    1. **admission** — queued requests claim free slots inside a span on
       ``ADMIT_LOCK`` (the continuous-batching scheduler's critical
       section; slot reuse is ordered by the lock's grant chain);
    2. **prefill** — a bulk write phase: admitting slots write their
       whole prompt's KV rows at once (idle/running slots touch one word
       of their own block — every worker participates in the SPMD
       phase);
    3. **decode** — active slots read their trailing ``attn_window`` KV
       rows (paged attention) and append one new row; idle slots touch
       one word.  One barrier per step (the batch-wide sync point).

    Slot blocks are disjoint and the queue cell is lock-guarded, so the
    program is data-race-free (``detect_races=True`` flags nothing).
    Under a ``cache_pages`` budget below a slot's working set, prefill
    ranges wider than the cache drive the mid-op danger path and the
    sliding attention window keeps batched eviction live — the
    paged-attention pressure regime the fig8 bench asserts via
    ``stats`` counters.  Requests, latencies (modeled arrival→finish
    time), and every counter are bit-equal across drivers and backends.
    """
    W = rt.W
    pw = rt.page_words
    assert attn_window <= max_tokens
    slot_words = max_tokens * tok_words
    stride = -(-slot_words // pw) * pw       # page-aligned slot pitch
    kv = rt.alloc(W * stride)
    q = rt.alloc(2)                          # queue head/tail cell
    s = session(rt, driver)

    reqs = gen_requests(n_requests, n_tenants=n_tenants, zipf_s=zipf_s,
                        max_tokens=max_tokens, burst_mean=burst_mean,
                        gap_max=gap_max, seed=seed)
    rep = ServeReport(requests=reqs)

    base = np.arange(W, dtype=np.int64) * stride
    zero = np.zeros(W, np.int64)
    two = np.full(W, 2, np.int64)
    active = np.full(W, -1, np.int64)        # request index per slot
    length = np.zeros(W, np.int64)           # KV rows materialized
    remaining = np.zeros(W, np.int64)        # decode tokens left
    queue: List[int] = []
    next_arrival = 0
    completed = 0
    step = 0
    while completed < n_requests:
        if step >= max_steps:
            raise RuntimeError(f"kv_serving: no progress in {max_steps} "
                               "steps (stream starved?)")
        t_now = rt.time
        while (next_arrival < n_requests
               and reqs[next_arrival].arrival_step <= step):
            reqs[next_arrival].arrival_time = t_now
            queue.append(next_arrival)
            next_arrival += 1
        rep.peak_queue = max(rep.peak_queue, len(queue))

        # admission: free slots claim queued requests in slot order,
        # serialized through the admission lock's grant chain
        admit = np.zeros(W, bool)
        for w in range(W):
            if active[w] < 0 and queue:
                i = queue.pop(0)
                r = reqs[i]
                r.slot, r.admit_step = w, step
                active[w] = i
                length[w] = 0
                remaining[w] = r.decode_tokens
                admit[w] = True
        if admit.any():
            s.span(ADMIT_LOCK, reads=((q, zero, two),),
                   writes=((q, zero, two),), w_mask=admit)
            rep.admit_spans += 1
            rep.admitted += int(admit.sum())
            # prefill: bulk KV write of the whole prompt, one phase
            plen = np.where(
                admit,
                np.array([reqs[i].prompt_tokens if i >= 0 else 0
                          for i in active], np.int64), 0)
            w_lo = base
            w_hi = base + np.where(admit, plen * tok_words, 1)
            s.phase(writes=((kv, w_lo, w_hi),),
                    flops=2.0 * plen * tok_words,
                    mem_bytes=4.0 * plen * tok_words)
            length[admit] = plen[admit]
            rep.prefill_tokens += int(plen.sum())

        running = active >= 0
        if running.any():
            # decode: windowed attention read + one appended KV row
            win = np.where(running, np.minimum(length, attn_window), 0)
            r_lo = base + np.where(running, (length - win) * tok_words, 0)
            r_hi = r_lo + np.where(running, win * tok_words, 1)
            w_lo = base + np.where(running, length * tok_words, 0)
            w_hi = w_lo + np.where(running, tok_words, 1)
            s.phase(reads=((kv, r_lo, r_hi),), writes=((kv, w_lo, w_hi),),
                    flops=2.0 * win * tok_words,
                    mem_bytes=4.0 * (win + 1) * tok_words)
            length[running] += 1
            remaining[running] -= 1
            rep.decode_tokens += int(running.sum())
            rep.idle_slot_steps += int(W - running.sum())
        rt.barrier()
        t_end = rt.time
        done = running & (remaining == 0)
        for w in np.flatnonzero(done):
            r = reqs[int(active[w])]
            r.finish_step, r.finish_time = step, t_end
            active[w] = -1
            completed += 1
        step += 1
        rep.steps = step
        if on_step is not None:
            on_step(step, rt)
    return rep
