"""Fault-tolerance runtime: failure injection, straggler detection, elastic
rescale planning.

On a real multi-pod deployment these hooks attach to the control plane
(jax.distributed heartbeats / GCP maintenance events); in this container
failures are *injected* so the recovery paths are exercised end-to-end by
tests: Trainer catches ``WorkerFailure``, restores the last committed
checkpoint (possibly onto a smaller/larger mesh — the checkpoint reshards),
jumps the data pipeline to the restored step, and continues.

Straggler mitigation is the scale-out analogue of the paper's observation
that one slow worker serializes every barrier (RegC rule 3 makes *all*
workers wait): we track per-step wall time, flag outliers against a robust
baseline (median + k*MAD over a sliding window), and the launcher's policy
replaces/bypasses the slow host at the next checkpoint boundary.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple


class WorkerFailure(RuntimeError):
    """Simulated loss of a worker/host (network partition, preemption)."""

    def __init__(self, step: int, worker: int = 0, kind: str = "preemption"):
        super().__init__(f"worker {worker} failed at step {step} ({kind})")
        self.step, self.worker, self.kind = step, worker, kind


@dataclasses.dataclass
class FailureInjector:
    """Raise WorkerFailure at configured steps (each fires once)."""

    at_steps: Sequence[int] = ()
    kind: str = "preemption"

    def __post_init__(self):
        self._pending = set(self.at_steps)

    def check(self, step: int, worker: int = 0):
        if step in self._pending:
            self._pending.discard(step)
            raise WorkerFailure(step, worker, self.kind)


class StragglerMonitor:
    """Sliding-window robust outlier detection on per-step durations.

    ``observe`` returns the list of flagged worker ids (empty when healthy).
    Detection: duration > median + k * MAD (and > abs_floor) over the last
    ``window`` steps, requiring ``patience`` consecutive flags before a
    worker is reported — a single GC pause is not a straggler.
    """

    def __init__(self, n_workers: int = 1, *, window: int = 32,
                 k: float = 4.0, abs_floor_s: float = 1e-4,
                 patience: int = 3):
        self.n = n_workers
        self.window = window
        self.k = k
        self.abs_floor = abs_floor_s
        self.patience = patience
        self._hist: List[deque] = [deque(maxlen=window) for _ in range(n_workers)]
        self._streak = [0] * n_workers
        self.flagged_total = 0

    @staticmethod
    def _median(xs: List[float]) -> float:
        s = sorted(xs)
        m = len(s) // 2
        return s[m] if len(s) % 2 else 0.5 * (s[m - 1] + s[m])

    def observe(self, durations_s: Sequence[float]) -> List[int]:
        assert len(durations_s) == self.n
        for w, d in enumerate(durations_s):
            self._hist[w].append(float(d))
        pool = [d for h in self._hist for d in h]
        if len(pool) < max(8, self.n * 2):
            return []
        med = self._median(pool)
        mad = self._median([abs(d - med) for d in pool]) or 1e-12
        out = []
        for w, d in enumerate(durations_s):
            slow = d > med + self.k * mad and d > self.abs_floor
            self._streak[w] = self._streak[w] + 1 if slow else 0
            if self._streak[w] >= self.patience:
                out.append(w)
        self.flagged_total += len(out)
        return out


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """A rescale decision: new data-parallel world and per-rank batch.

    The global batch is preserved exactly when divisible; otherwise it is
    rounded DOWN to a multiple of the new world (recorded in
    ``dropped_samples`` — optimizer scale stays correct because gradients
    are averaged, not summed)."""

    old_world: int
    new_world: int
    global_batch: int

    @property
    def new_global_batch(self) -> int:
        return (self.global_batch // self.new_world) * self.new_world

    @property
    def dropped_samples(self) -> int:
        return self.global_batch - self.new_global_batch

    @property
    def local_batch(self) -> int:
        return self.new_global_batch // self.new_world

    def describe(self) -> str:
        return (f"rescale {self.old_world}->{self.new_world} workers, "
                f"global_batch {self.global_batch}->{self.new_global_batch} "
                f"(local {self.local_batch})")


def plan_rescale(old_world: int, failed: Sequence[int], global_batch: int,
                 *, spares: int = 0) -> ElasticPlan:
    """Shrink (or refill from spares) after failures."""
    new_world = old_world - len(set(failed)) + spares
    assert new_world >= 1, "no workers left"
    return ElasticPlan(old_world, new_world, global_batch)
