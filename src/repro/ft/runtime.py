"""Fault-tolerance runtime: failure injection, straggler detection, elastic
rescale planning.

On a real multi-pod deployment these hooks attach to the control plane
(jax.distributed heartbeats / GCP maintenance events); in this container
failures are *injected* so the recovery paths are exercised end-to-end by
tests: Trainer catches ``WorkerFailure``, restores the last committed
checkpoint (possibly onto a smaller/larger mesh — the checkpoint reshards),
jumps the data pipeline to the restored step, and continues.

Straggler mitigation is the scale-out analogue of the paper's observation
that one slow worker serializes every barrier (RegC rule 3 makes *all*
workers wait): we track per-step wall time, flag outliers against a robust
baseline (median + k*MAD over a sliding window), and the launcher's policy
replaces/bypasses the slow host at the next checkpoint boundary.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class WorkerFailure(RuntimeError):
    """Simulated loss of a worker/host (network partition, preemption)."""

    def __init__(self, step: int, worker: int = 0, kind: str = "preemption"):
        super().__init__(f"worker {worker} failed at step {step} ({kind})")
        self.step, self.worker, self.kind = step, worker, kind


@dataclasses.dataclass
class FailureInjector:
    """Raise WorkerFailure at configured steps (each fires once).

    ``at_steps`` entries are either bare steps (``int``) — fire for
    whichever worker reaches the step first, any worker — or targeted
    ``(step, worker)`` pairs.  A bare step is stored as ``(step, None)``;
    callers that don't track workers (``check(step)``) still fire it
    exactly once, preserving the pre-targeting behavior.

    ``cluster_at`` carries *process-level* faults for the sharded runtime
    (``repro.cluster``): ``(kind, step, rank)`` entries where kind is
    ``"kill"`` (SIGKILL the shard process), ``"partition_c2s"`` (drop the
    control->shard link direction) or ``"partition_s2c"`` (drop the
    shard->control direction).  These do not raise — the control plane
    polls :meth:`cluster_actions` at the top of each event round and
    *performs* the fault, then must detect and recover from it through
    its own membership machinery.  Each entry fires once."""

    at_steps: Sequence = ()
    kind: str = "preemption"
    cluster_at: Sequence = ()

    CLUSTER_KINDS = ("kill", "partition_c2s", "partition_s2c")

    def __post_init__(self):
        self._pending = set()
        for e in self.at_steps:
            if isinstance(e, tuple):
                s, w = e
                self._pending.add((int(s), None if w is None else int(w)))
            else:
                self._pending.add((int(e), None))
        self._cluster_pending = set()
        for kind, step, rank in self.cluster_at:
            assert kind in self.CLUSTER_KINDS, kind
            self._cluster_pending.add((str(kind), int(step), int(rank)))

    def cluster_actions(self, step: int) -> List[Tuple[str, int]]:
        """Fire-once ``(kind, rank)`` process faults scheduled for
        ``step`` (sorted for determinism)."""
        hits = sorted(p for p in self._cluster_pending if p[1] == step)
        self._cluster_pending -= set(hits)
        return [(k, r) for k, _s, r in hits]

    def check(self, step: int, worker: Optional[int] = None):
        if not self._pending:
            return
        if worker is not None:
            hit = ((step, worker) if (step, worker) in self._pending
                   else (step, None) if (step, None) in self._pending
                   else None)
        else:
            # untargeted probe: a bare step fires for worker 0 (the old
            # behavior); a targeted entry at this step fires for its
            # worker (lowest id wins when several target the same step)
            cands = [p for p in self._pending if p[0] == step]
            if not cands:
                return
            bare = [p for p in cands if p[1] is None]
            hit = bare[0] if bare else min(
                cands, key=lambda p: p[1])
        if hit is None:
            return
        self._pending.discard(hit)
        w = hit[1]
        if w is None:
            w = worker if worker is not None else 0
        raise WorkerFailure(step, w, self.kind)


def mad_threshold(samples: Sequence[float], k: float,
                  floor: float) -> float:
    """Robust outlier threshold ``median + k * MAD`` over ``samples``,
    guarded against degenerate windows: with fewer than 2 samples there
    is no spread to estimate, so the fallback is ``floor`` (infinite
    when no floor is given) rather than a threshold derived from a
    meaningless MAD of 0.  Shared by :class:`StragglerMonitor` (barrier
    walls) and the cluster heartbeat detector (RPC latencies)."""
    xs = [float(x) for x in samples]
    if len(xs) < 2:
        return float(floor) if floor > 0 else math.inf
    med = StragglerMonitor._median(xs)
    mad = StragglerMonitor._median([abs(x - med) for x in xs]) or 1e-12
    return med + k * mad


class StragglerMonitor:
    """Sliding-window robust outlier detection on per-step durations.

    ``observe`` returns the list of flagged worker ids (empty when healthy).
    Detection: duration > median + k * MAD (and > abs_floor) over the last
    ``window`` steps, requiring ``patience`` consecutive flags before a
    worker is reported — a single GC pause is not a straggler.
    """

    def __init__(self, n_workers: int = 1, *, window: int = 32,
                 k: float = 4.0, abs_floor_s: float = 1e-4,
                 patience: int = 3):
        self.n = n_workers
        self.window = window
        self.k = k
        self.abs_floor = abs_floor_s
        self.patience = patience
        self._hist: List[deque] = [deque(maxlen=window) for _ in range(n_workers)]
        self._streak = [0] * n_workers
        self.flagged_total = 0

    @staticmethod
    def _median(xs: List[float]) -> float:
        s = sorted(xs)
        m = len(s) // 2
        return s[m] if len(s) % 2 else 0.5 * (s[m - 1] + s[m])

    def observe(self, durations_s: Sequence[float]) -> List[int]:
        assert len(durations_s) == self.n
        for w, d in enumerate(durations_s):
            self._hist[w].append(float(d))
        pool = [d for h in self._hist for d in h]
        if len(pool) < max(8, self.n * 2):
            return []
        # mad_threshold carries the degenerate-window guard (<2 samples
        # -> no spread estimate); unreachable through the warm-up gate
        # above, but direct callers with window=1 configs hit it
        thresh = mad_threshold(pool, self.k, self.abs_floor)
        out = []
        for w, d in enumerate(durations_s):
            slow = d > thresh and d > self.abs_floor
            self._streak[w] = self._streak[w] + 1 if slow else 0
            if self._streak[w] >= self.patience:
                out.append(w)
        self.flagged_total += len(out)
        return out

    # -- snapshot support (ft/coherence.py) -----------------------------
    def config(self) -> dict:
        return {"n_workers": self.n, "window": self.window, "k": self.k,
                "abs_floor_s": self.abs_floor, "patience": self.patience}

    def state_arrays(self) -> dict:
        """Mutable detection state (windows, streaks, totals) as numpy
        arrays — the checkpoint payload alongside :meth:`config`."""
        counts = np.array([len(h) for h in self._hist], np.int64)
        flat = np.array([d for h in self._hist for d in h], np.float64)
        return {"hist": flat, "hist_counts": counts,
                "streak": np.asarray(self._streak, np.int64),
                "flagged_total": np.array([self.flagged_total], np.int64)}

    @classmethod
    def from_state(cls, arrays: dict, config: dict) -> "StragglerMonitor":
        m = cls(int(config["n_workers"]), window=int(config["window"]),
                k=float(config["k"]),
                abs_floor_s=float(config["abs_floor_s"]),
                patience=int(config["patience"]))
        counts = np.asarray(arrays["hist_counts"], np.int64)
        flat = np.asarray(arrays["hist"], np.float64)
        off = 0
        for w in range(m.n):
            n = int(counts[w])
            m._hist[w].extend(float(x) for x in flat[off:off + n])
            off += n
        m._streak = [int(x) for x in np.asarray(arrays["streak"],
                                                np.int64)]
        m.flagged_total = int(np.asarray(arrays["flagged_total"])[0])
        return m


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """A rescale decision: new data-parallel world and per-rank batch.

    The global batch is preserved exactly when divisible; otherwise it is
    rounded DOWN to a multiple of the new world (recorded in
    ``dropped_samples`` — optimizer scale stays correct because gradients
    are averaged, not summed)."""

    old_world: int
    new_world: int
    global_batch: int

    @property
    def new_global_batch(self) -> int:
        return (self.global_batch // self.new_world) * self.new_world

    @property
    def dropped_samples(self) -> int:
        return self.global_batch - self.new_global_batch

    @property
    def local_batch(self) -> int:
        return self.new_global_batch // self.new_world

    def describe(self) -> str:
        return (f"rescale {self.old_world}->{self.new_world} workers, "
                f"global_batch {self.global_batch}->{self.new_global_batch} "
                f"(local {self.local_batch})")


def plan_rescale(old_world: int, failed: Sequence[int], global_batch: int,
                 *, spares: int = 0) -> ElasticPlan:
    """Shrink (or refill from spares) after failures."""
    new_world = old_world - len(set(failed)) + spares
    assert new_world >= 1, "no workers left"
    return ElasticPlan(old_world, new_world, global_batch)
