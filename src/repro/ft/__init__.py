from repro.ft.runtime import (
    ElasticPlan, FailureInjector, StragglerMonitor, WorkerFailure,
)

__all__ = ["ElasticPlan", "FailureInjector", "StragglerMonitor",
           "WorkerFailure"]
