from repro.ft.runtime import (
    ElasticPlan, FailureInjector, StragglerMonitor, WorkerFailure,
)
from repro.ft.coherence import (
    ChaosHarness, RecoveryReport, assert_bit_equal, harness_ticks,
    load_runtime, run_uninjected, save_runtime,
)

__all__ = ["ChaosHarness", "ElasticPlan", "FailureInjector",
           "RecoveryReport", "StragglerMonitor", "WorkerFailure",
           "assert_bit_equal", "harness_ticks", "load_runtime",
           "run_uninjected", "save_runtime"]
