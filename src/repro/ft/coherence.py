"""Fault tolerance for the RegC coherence engine: barrier-consistent
checkpoints, chaos-driven crash recovery, and the exactness bar.

The paper's rules 2-3 make region and barrier boundaries the ONLY points
where coherence state is globally reconciled — which also makes them
natural *consistent cuts*: at a barrier every span is closed, every
reduction resolved, every dirty page flushed, every lock log replayed.
``RegCScaleRuntime.snapshot()`` serializes the complete protocol state
at such a cut; this module glues it to the sharded-npz + atomic-manifest
checkpoint store (numpy-only — no jax on the recovery path) and runs the
crash-recovery analogue of the trace-fuzz lockstep:

    run with failures -> crash -> restore last barrier checkpoint ->
    replay the suffix -> traffic field-for-field and clocks bit-equal
    with the run that never failed.

The guarantee is *exact replay*, not approximate resumption: message
loss (``dsm.costmodel.ChaosNet``) is deterministic in each worker's own
event counters — part of the checkpointed state — so the replayed suffix
re-experiences the same drops and retry charges the uninjected run did.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.checkpoint.store import load_arrays, save_arrays
from repro.core.regc_scale import RegCScaleRuntime
from repro.ft.runtime import WorkerFailure


def save_runtime(rt: RegCScaleRuntime, root, step: int):
    """Checkpoint a runtime at a barrier-consistent cut into the store's
    npz-shard + atomic-manifest layout (``step`` is the caller's resume
    cursor, e.g. the index of the next program event)."""
    arrays, meta = rt.snapshot()
    save_arrays(root, step, arrays, extra=meta)


def load_runtime(root, step: int, *, injector=None) -> RegCScaleRuntime:
    """Rebuild a bit-identical runtime from a :func:`save_runtime`
    checkpoint.  ``injector`` (typically the SAME, partially-fired
    FailureInjector) rearms crash injection on the replayed suffix."""
    arrays, meta = load_arrays(root, step)
    return RegCScaleRuntime.from_snapshot(arrays, meta, injector=injector)


def harness_ticks(ev, driver: str) -> bool:
    """Whether the harness must call ``rt.chaos_tick()`` for this event.

    The batched driver's bulk entry points (``phase_all``/``span_all``)
    and ``barrier`` (both drivers) tick internally; per-worker loop
    events and the scalar span walks have no single runtime entry, so
    the harness ticks once per event — giving both drivers the same
    per-event injection schedule."""
    kind = ev[0]
    if kind == "barrier":
        return False
    if driver == "batched":
        return kind not in ("phase", "span_phase")
    return True


@dataclasses.dataclass
class RecoveryReport:
    """What a :class:`ChaosHarness` run went through."""

    n_events: int = 0
    n_crashes: int = 0
    n_checkpoints: int = 0
    n_replayed_events: int = 0
    crashed_workers: List[int] = dataclasses.field(default_factory=list)


class ChaosHarness:
    """Run a trace-fuzz phase program under failure injection with
    checkpoint-at-barrier recovery.

    ``make_rt`` builds a fresh runtime (chaos / straggler already
    attached); allocation sizes are replayed through
    ``gas_for_region`` after a restore, so callers keep indexing the
    same region handles across crashes.  On ``WorkerFailure`` the
    harness restores the LAST barrier checkpoint — reattaching the same
    (now partially fired) injector so one configured crash fires once —
    and resumes from the checkpointed event cursor.  ``apply_event`` is
    the trace-fuzz executor (injected to avoid a src->tests import)."""

    def __init__(self, make_rt: Callable[[], RegCScaleRuntime],
                 gas_words: Sequence[int], driver: str, root,
                 apply_event: Callable, *, injector=None):
        self.make_rt = make_rt
        self.gas_words = list(gas_words)
        self.driver = driver
        self.root = root
        self.apply_event = apply_event
        self.injector = injector

    def _alloc(self, rt):
        return [rt.alloc(n) for n in self.gas_words]

    def _regas(self, rt):
        return [rt.gas_for_region(r, n)
                for r, n in enumerate(self.gas_words)]

    def run(self, prog) -> "tuple[RegCScaleRuntime, RecoveryReport]":
        rep = RecoveryReport(n_events=len(prog))
        rt = self.make_rt()
        rt.injector = self.injector
        gas = self._alloc(rt)
        save_runtime(rt, self.root, 0)          # the t=0 cut
        rep.n_checkpoints += 1
        last_ckpt = 0
        i = 0
        while i < len(prog):
            ev = prog[i]
            try:
                if harness_ticks(ev, self.driver):
                    rt.chaos_tick()
                self.apply_event(rt, ev, gas, self.driver)
            except WorkerFailure as e:
                rep.n_crashes += 1
                rep.crashed_workers.append(e.worker)
                rep.n_replayed_events += i - last_ckpt
                rt = load_runtime(self.root, last_ckpt,
                                  injector=self.injector)
                gas = self._regas(rt)
                i = last_ckpt
                continue
            i += 1
            if ev[0] == "barrier":
                # post-barrier state is a consistent cut; cursor = next
                # event index, so recovery replays exactly the suffix
                save_runtime(rt, self.root, i)
                rep.n_checkpoints += 1
                last_ckpt = i
        return rt, rep


class ClusterChaosHarness:
    """:class:`ChaosHarness`'s process-level sibling: run a trace program
    on the sharded multi-process runtime (``repro.cluster``) under
    *process* faults — SIGKILL and one-directional link partitions from
    ``FailureInjector.cluster_at`` — with the same contract: recover
    through the last barrier checkpoint and finish traffic
    field-for-field and clock bit-equal to the unfailed single-process
    run.  The control plane performs detection/quarantine/re-shard
    itself; this wrapper only gives tests the familiar
    construct-run-report shape (and keeps ``repro.cluster`` a lazy
    import so the ft module stays importable everywhere)."""

    def __init__(self, cfg: dict, gas_words: Sequence[int], driver: str,
                 root, apply_ref: "tuple[str, str]", *, n_shards: int,
                 injector=None, recovery: str = "respawn",
                 rpc_timeout_s: float = 0.25, rpc_attempts: int = 4):
        self.cfg = dict(cfg)
        self.gas_words = list(gas_words)
        self.driver = driver
        self.root = root
        self.apply_ref = tuple(apply_ref)
        self.n_shards = int(n_shards)
        self.injector = injector
        self.recovery = recovery
        self.rpc_timeout_s = float(rpc_timeout_s)
        self.rpc_attempts = int(rpc_attempts)

    def run(self, prog):
        """Returns ``(ClusterResult, ClusterReport, digests)`` where
        ``digests`` maps event index -> the digest every shard agreed
        on (the lockstep trace a single-process run must reproduce)."""
        from repro.cluster.control import ClusterRuntime
        with ClusterRuntime(self.cfg, self.gas_words,
                            n_shards=self.n_shards, driver=self.driver,
                            apply_ref=self.apply_ref, root=self.root,
                            recovery=self.recovery,
                            injector=self.injector,
                            rpc_timeout_s=self.rpc_timeout_s,
                            rpc_attempts=self.rpc_attempts) as cluster:
            result = cluster.run(prog)
            return result, result.report, dict(cluster.digests)


def run_uninjected(make_rt: Callable[[], RegCScaleRuntime],
                   gas_words: Sequence[int], driver: str, prog,
                   apply_event: Callable) -> RegCScaleRuntime:
    """The no-failures baseline a recovered run must match bit-for-bit.
    Ticks the same per-event schedule as :class:`ChaosHarness` (ticks
    carry no cost — this just keeps ``_phase_idx`` comparable)."""
    rt = make_rt()
    gas = [rt.alloc(n) for n in gas_words]
    for ev in prog:
        if harness_ticks(ev, driver):
            rt.chaos_tick()
        apply_event(rt, ev, gas, driver)
    return rt


def assert_bit_equal(a: RegCScaleRuntime, b: RegCScaleRuntime, ctx=""):
    """The recovery exactness bar: traffic field-for-field, clocks
    bit-equal, stats counters identical."""
    from repro.core.regc import Traffic
    for f in dataclasses.fields(Traffic):
        av, bv = getattr(a.traffic, f.name), getattr(b.traffic, f.name)
        assert av == bv, (ctx, f.name, av, bv)
    np.testing.assert_array_equal(a.clock, b.clock, err_msg=str(ctx))
    # jit_* counters record dispatch topology (how many fused device
    # programs ran), which legitimately differs between a sharded run and
    # its single-process baseline, and jit_cache_misses mirrors the
    # process-wide compile cache — neither is protocol state, so they sit
    # outside the exactness bar (traffic/clocks/protocol counters).
    sa = {k: v for k, v in a.stats.items() if not k.startswith("jit_")}
    sb = {k: v for k, v in b.stats.items() if not k.startswith("jit_")}
    assert sa == sb, (ctx, sa, sb)
