"""RegC-as-gradient-synchronization: the paper's consistency machinery mapped
onto distributed training (DESIGN.md §2.2).

The dichotomy the paper introduces:

* **ordinary-region state** — bulk gradients.  Propagated *lazily*: local
  accumulation across microbatches, one barrier sync per step
  (``ordinary_sync='lazy'``).  The contrast mode ``'eager'`` syncs at every
  microbatch — release-consistency-like, no region distinction — and is kept
  as the measurable baseline (the paper's RC column of Table I).
* **consistency-region state** — small hot objects (loss metrics, global
  grad-norm, MoE router load stats).  Synced *fine-grained* via
  ``span_reduce`` — the paper's §V-B *reduction extension*, which on a TPU
  mesh is exactly ``lax.psum`` of the object, never a page/bucket.

Granularity of the barrier sync mirrors samhita vs samhita_page:

* ``granularity='object'``  — per-parameter psum (fine-grained updates),
* ``granularity='bucket'``  — parameters concatenated into page-like buckets;
  a whole bucket moves even if one element changed.  Fewer, larger messages —
  cheaper per byte on latency-bound links, wasteful when updates are sparse.

``compression='int8_ring'`` is the beyond-paper optimization: a ring
all-reduce (ppermute) that re-quantizes each hop to int8 — the training-layer
analogue of the paper's fine-grained *diffs* (move only compressed deltas).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


@dataclasses.dataclass(frozen=True)
class RegCSyncPolicy:
    ordinary_sync: str = "lazy"          # 'lazy' (RegC) | 'eager' (RC baseline)
    granularity: str = "bucket"          # 'bucket' (page-like) | 'object' (fine)
    bucket_bytes: int = 64 << 20
    compression: Optional[str] = None    # None | 'int8_ring'

    def __post_init__(self):
        assert self.ordinary_sync in ("lazy", "eager")
        assert self.granularity in ("bucket", "object")
        assert self.compression in (None, "int8_ring")


# ---------------------------------------------------------------------------
# The reduction extension (paper §V-B): consistency-region objects
# ---------------------------------------------------------------------------


def span_reduce(value, dp_axes: Sequence[str], op: str = "sum"):
    """Fine-grained (object-granularity) reduction of a small shared object.

    Replaces the mutex-accumulate pattern; must be called inside a
    ``shard_map`` manual over ``dp_axes``."""
    axes = tuple(dp_axes)
    if op == "sum":
        return lax.psum(value, axes)
    if op == "mean":
        return lax.pmean(value, axes)
    if op == "max":
        return lax.pmax(value, axes)
    raise ValueError(op)


# ---------------------------------------------------------------------------
# Bucketing (page-granularity analogue)
# ---------------------------------------------------------------------------


def _flatten_to_buckets(tree, bucket_bytes: int):
    leaves, treedef = jax.tree.flatten(tree)
    flat = [l.reshape(-1).astype(jnp.float32) for l in leaves]
    buckets: List[jnp.ndarray] = []
    cur: List[jnp.ndarray] = []
    cur_b = 0
    for f in flat:
        cur.append(f)
        cur_b += f.size * 4
        if cur_b >= bucket_bytes:
            buckets.append(jnp.concatenate(cur))
            cur, cur_b = [], 0
    if cur:
        buckets.append(jnp.concatenate(cur))
    shapes = [(l.shape, l.dtype) for l in leaves]
    return buckets, shapes, treedef


def _unflatten_buckets(buckets, shapes, treedef):
    flat = jnp.concatenate([b.reshape(-1) for b in buckets])
    leaves, off = [], 0
    for shape, dtype in shapes:
        n = 1
        for s in shape:
            n *= s
        leaves.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# int8 ring all-reduce (compressed fine-grained diffs; beyond-paper)
# ---------------------------------------------------------------------------


def _quant(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8), scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def ring_allreduce_int8(flat, axis: str, world: int):
    """Ring all-reduce with per-hop int8 re-quantization.

    Moves ~N bytes/device/direction vs ~8N for fp32 psum.  ``world`` (the
    static axis size) must be passed in because ppermute's permutation is a
    static argument."""
    if world == 1:
        return flat
    n = flat.size
    pad = (-n) % world
    x = jnp.pad(flat, (0, pad)).reshape(world, -1)
    idx = lax.axis_index(axis)
    fwd = [(i, (i + 1) % world) for i in range(world)]

    # reduce-scatter phase: after w-1 hops, chunk (idx+1)%w fully reduced
    def rs_step(k, chunks):
        send_ix = (idx - k) % world
        buf = jnp.take(chunks, send_ix, axis=0)
        q, s = _quant(buf)
        q = lax.ppermute(q, axis, fwd)
        s = lax.ppermute(s, axis, fwd)
        recv_ix = (idx - k - 1) % world
        return chunks.at[recv_ix].add(_dequant(q, s))

    chunks = lax.fori_loop(0, world - 1, rs_step, x)

    # all-gather phase: each owner quantizes its fully-reduced chunk ONCE and
    # the payload circulates verbatim — every rank dequantizes the identical
    # (q, scale) pair, so all ranks end bitwise-equal (re-quantizing per hop
    # would compound error and desynchronize replicas)
    own_ix = (idx + 1) % world
    q0, s0 = _quant(jnp.take(chunks, own_ix, axis=0))
    chunks = chunks.at[own_ix].set(_dequant(q0, s0))

    def ag_step(k, carry):
        chunks, q, s = carry
        q = lax.ppermute(q, axis, fwd)
        s = lax.ppermute(s, axis, fwd)
        recv_ix = (idx - k) % world
        return chunks.at[recv_ix].set(_dequant(q, s)), q, s

    chunks, _, _ = lax.fori_loop(0, world - 1, ag_step, (chunks, q0, s0))
    return chunks.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# Barrier sync of ordinary-region state (bulk gradients)
# ---------------------------------------------------------------------------


def barrier_sync_grads(grads, dp_axes: Sequence[str], policy: RegCSyncPolicy,
                       *, axis_sizes: Optional[dict] = None, mean: bool = True):
    """RegC rule 3 at the step barrier: make every ordinary STORE (gradient
    contribution) performed with respect to all participants.

    axis_sizes: static {axis: size}; required for 'int8_ring' (ppermute
    permutations are static)."""
    axes = tuple(dp_axes)

    def _reduce_flat(flat):
        if policy.compression == "int8_ring":
            assert axis_sizes is not None, "int8_ring needs static axis sizes"
            out = flat
            # ring over the *last* dp axis; preceding axes use psum
            if len(axes) > 1:
                out = lax.psum(out, axes[:-1])
            return ring_allreduce_int8(out, axes[-1], axis_sizes[axes[-1]])
        return lax.psum(flat, axes)

    if policy.granularity == "object":
        synced = jax.tree.map(
            lambda g: _reduce_flat(g.astype(jnp.float32).reshape(-1)).reshape(g.shape),
            grads)
    else:
        buckets, shapes, treedef = _flatten_to_buckets(grads, policy.bucket_bytes)
        buckets = [_reduce_flat(b) for b in buckets]
        synced = _unflatten_buckets(buckets, shapes, treedef)

    if mean:
        if axis_sizes is not None:
            denom = 1.0
            for ax in axes:
                denom *= float(axis_sizes[ax])
        else:
            # lax.psum of 1 gives the live axis size under shard_map
            denom = lax.psum(jnp.ones(()), axes)
        synced = jax.tree.map(lambda g: g / denom, synced)
    return synced
