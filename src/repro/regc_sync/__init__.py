from repro.regc_sync.policies import (
    RegCSyncPolicy, barrier_sync_grads, ring_allreduce_int8, span_reduce,
)
