from repro.core.directory import IntervalLog, RegionDirectory
from repro.core.regc import (
    FINE_PROTO, GasArray, IDEAL_PROTO, PAGE_PROTO, RegCRuntime, Traffic,
)
from repro.core.regc_scale import RegCScaleRuntime
