from repro.core.regc import (
    FINE_PROTO, GasArray, IDEAL_PROTO, PAGE_PROTO, RegCRuntime, Traffic,
)
