"""Public surface of the RegC protocol core.

Build runtimes through ``make_runtime``/``RuntimeConfig`` and drive them
through ``repro.dsm.session`` — the engine constructors remain importable
as back-compat shims (same semantics, proven bit-equal by
``tests/test_api.py``), but new code should not spell their keyword lists
out by hand.
"""
from repro.core.config import (
    BACKENDS, DANGER_MODES, DRIVERS, ENGINES, FINE_PROTO, IDEAL_PROTO,
    PAGE_PROTO, PROTOCOLS, RuntimeConfig, check_choice, make_runtime,
)
from repro.core.directory import IntervalLog, RegionDirectory
from repro.core.regc import GasArray, RegCRuntime, Traffic
from repro.core.regc_scale import RegCScaleRuntime

__all__ = [
    # config / factory
    "RuntimeConfig", "make_runtime", "check_choice",
    # canonical string-knob vocabularies
    "PROTOCOLS", "BACKENDS", "DANGER_MODES", "DRIVERS", "ENGINES",
    "FINE_PROTO", "PAGE_PROTO", "IDEAL_PROTO",
    # engines + data types
    "RegCRuntime", "RegCScaleRuntime", "GasArray", "Traffic",
    "IntervalLog", "RegionDirectory",
]
