"""Region-level sharing directory for the vectorized RegC protocol engine.

The reference ``RegCRuntime`` and the original scale engine both kept page
state per (worker, region) — a dict of per-worker ``_Window`` arrays.  Every
cross-worker protocol event (sharer invalidation on an ordinary flush, lock
notice replay, barrier sync) then became a Python loop over all workers,
which is what made 256-worker runs protocol-bound in the *simulator* rather
than in the modeled network.

``RegionDirectory`` turns the worker axis into an array axis: one object per
allocation region holds ``valid`` / ``dirty`` / ``wprot`` / ``touch`` as 2D
``(W, window)`` arrays.  Rows are workers.  Because the paper's benchmarks
block-partition each array (own block + halo), rows cover *different* page
intervals of the region; storing the union window densely would cost
W x region_pages.  Instead every row carries its own base offset: column
``j`` of row ``w`` is absolute page ``base[w] + j``, and all rows share one
column capacity (the max touched-window size).  Memory stays O(pages
actually touched), like the old per-worker windows, while cross-worker
operations become single gather/scatter numpy ops over the worker axis:

* ``invalidate_sharers`` — one boolean-mask op over all overlapping rows
  instead of a Python loop over ``range(W)``;
* ``dirty_cells``       — enumerate every (worker, page) dirty pair of the
  region at once, in worker-major order (== the sequential flush order);
* ``window_cover``      — interval-stabbing count of how many worker
  windows contain each page (lets the barrier flush skip the unshared
  majority of pages analytically);
* ``gather_valid``      — the (rows x pages) validity matrix for an
  arbitrary page set, used by both invalidation and notice replay.

``IntervalLog`` is the companion structure for lock notices: a flat,
amortized-growth ``(page, lo, hi)`` array segmented by release version, so
replaying "all notices since this worker last acquired" is an O(1) slice
plus a vectorized per-page segment-min/max coalesce instead of nested dict
loops over versions x notices.

Exactness invariant (see DIRECTORY.md): these are pure representation
changes — the protocol rules and the traffic ledger are byte-identical to
the reference runtime, which ``tests/test_regc_scale.py`` and
``tests/test_directory.py`` cross-validate.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

_I64_MAX = np.iinfo(np.int64).max
_I64_MIN = np.iinfo(np.int64).min


def use_dense(n_rows: int, l_max: int) -> bool:
    """Strategy pick for per-op batched plane updates: dense (rows x Lmax)
    gather/scatter matrices win when per-row work is too small to amortize
    a Python-level row loop (many workers, narrow intervals — the regime
    that made 256-worker runs driver-bound), or when the whole op is tiny;
    wide intervals are slice-throughput bound, where per-row contiguous
    slice ops are ~100x cheaper per cell than gather matrices.  Both
    strategies charge identically, so the cutoff is invisible to traffic
    and clocks (cross-validated in tests/test_regc_scale.py)."""
    return l_max <= 512 or n_rows * l_max <= (1 << 16)


class RegionDirectory:
    """2D per-worker page state of one allocation region.

    Cells outside a row's live window ``[0, length[w])`` always hold the
    init values (valid=False, dirty=False, wprot=True, touch=0), so window
    extension to the right is free and whole-array reductions are safe.
    """

    __slots__ = ("W", "region", "page_lo", "page_hi", "base", "length",
                 "cap", "valid", "dirty", "wprot", "touch", "incache",
                 "shift", "maybe_dirty", "_cov_stale", "_sorted_bases",
                 "_sorted_ends", "backend", "dirty_lo", "dirty_hi",
                 "span_lo", "span_hi", "race_w", "race_r",
                 "race_maxw", "race_maxr", "jit_stats", "_jit_geom")

    def __init__(self, n_workers: int, region: int, page_lo: int,
                 page_hi: int, *, track_wprot: bool = False,
                 track_touch: bool = False, backend: str = "numpy"):
        self.W = n_workers
        self.region = region
        self.page_lo = page_lo
        self.page_hi = page_hi
        self.base = np.full(n_workers, -1, np.int64)
        self.length = np.zeros(n_workers, np.int64)
        self.cap = 0
        self.valid = np.zeros((n_workers, 0), bool)
        self.dirty = np.zeros((n_workers, 0), bool)
        self.wprot = np.zeros((n_workers, 0), bool) if track_wprot else None
        # LRU bookkeeping (cache_pages runs only).  ``incache`` is cache
        # *occupancy*, distinct from ``valid``: the reference runtime keeps
        # invalidated pages in its LRU dict until they are evicted, so a
        # page can occupy a cache slot while invalid.
        self.touch = np.zeros((n_workers, 0), np.int64) if track_touch else None
        self.incache = np.zeros((n_workers, 0), bool) if track_touch else None
        # cumulative left-extension shift per row: lets LRU-queue entries
        # recorded before a window grew leftwards map to current columns
        self.shift = np.zeros(n_workers, np.int64)
        # span-touch planes (consistency regions): per-cell word-interval
        # accumulator [span_lo, span_hi) of the worker's OPEN span — the
        # vectorized replacement for the per-page ``_Span.touched`` dict.
        # Untouched cells hold (I64_MAX, I64_MIN); lazily allocated on the
        # first span write (``ensure_span``) since most regions never see
        # a consistency region.
        self.span_lo = None
        self.span_hi = None
        # race-detection vector-clock planes (detect_races runs only):
        # cell (u, p) of ``race_w`` is component u of page p's *write*
        # vector clock — the epoch (worker u's own clock value) at u's
        # last recorded write to page p; ``race_r`` is the read twin.
        # 0 means "never accessed" (epochs start at 1), so out-of-window
        # cells read as ordered and window growth stays free.  Lazily
        # allocated (``ensure_race``) since detection is an opt-in mode.
        self.race_w = None
        self.race_r = None
        # per-row running max of every epoch ever recorded in this
        # region's race planes (cells only ever grow, so this equals the
        # plane row max) — the batched detector's O(W) screen: when every
        # row's max is happens-before-ordered under the phase's minimum
        # vector-clock view, no cross-phase race check can fire anywhere
        # in the region and recording can skip the per-worker scan.
        self.race_maxw = None
        self.race_maxr = None
        # conservative per-row bounding interval of possibly-dirty pages
        # (absolute page numbers; empty when lo >= hi).  Widened on ordinary
        # writes, reset on flush; eviction clears cells without narrowing
        # them, so the interval over-approximates — which is the sound
        # direction for the phase_all window-disjointness analysis.
        self.dirty_lo = np.full(n_workers, _I64_MAX, np.int64)
        self.dirty_hi = np.full(n_workers, _I64_MIN, np.int64)
        self.maybe_dirty = False
        self._cov_stale = True
        self._sorted_bases: Optional[np.ndarray] = None
        self._sorted_ends: Optional[np.ndarray] = None
        # 'numpy' | 'pallas' | 'pallas-jit': execution backend for the
        # whole-plane reductions (barrier-flush popcount, shared-interval
        # sweep, eviction rank-select).  All tiers are integer-exact, so
        # traffic is backend-independent; 'pallas-jit' additionally fuses
        # the flush reductions into one device dispatch per phase (see
        # DIRECTORY.md "Compiled-phase contract").
        self.backend = backend
        # jit-tier state: the runtime's stats dict (jit_dispatches /
        # jit_cache_misses accounting, attached by ``alloc``) and the
        # cached int32 window-geometry operands of the fused flush chain
        # (rebuilt only when a window changes — _refresh_bounds drops it)
        self.jit_stats: Optional[dict] = None
        self._jit_geom = None

    # ------------------------------------------------------------------
    # window management
    # ------------------------------------------------------------------

    def _grow_cap(self, need: int):
        new_cap = max(need, 2 * self.cap)
        pad = new_cap - self.cap
        self.valid = np.pad(self.valid, ((0, 0), (0, pad)))
        self.dirty = np.pad(self.dirty, ((0, 0), (0, pad)))
        if self.wprot is not None:
            self.wprot = np.pad(self.wprot, ((0, 0), (0, pad)),
                                constant_values=True)
        if self.touch is not None:
            self.touch = np.pad(self.touch, ((0, 0), (0, pad)))
            self.incache = np.pad(self.incache, ((0, 0), (0, pad)))
        if self.span_lo is not None:
            self.span_lo = np.pad(self.span_lo, ((0, 0), (0, pad)),
                                  constant_values=_I64_MAX)
            self.span_hi = np.pad(self.span_hi, ((0, 0), (0, pad)),
                                  constant_values=_I64_MIN)
        if self.race_w is not None:
            self.race_w = np.pad(self.race_w, ((0, 0), (0, pad)))
            self.race_r = np.pad(self.race_r, ((0, 0), (0, pad)))
        self.cap = new_cap

    def ensure_span(self):
        """Allocate the span-touch planes on first use."""
        if self.span_lo is None:
            self.span_lo = np.full((self.W, self.cap), _I64_MAX, np.int64)
            self.span_hi = np.full((self.W, self.cap), _I64_MIN, np.int64)

    def ensure_race(self):
        """Allocate the race vector-clock planes on first use."""
        if self.race_w is None:
            self.race_w = np.zeros((self.W, self.cap), np.int64)
            self.race_r = np.zeros((self.W, self.cap), np.int64)
            self.race_maxw = np.zeros(self.W, np.int64)
            self.race_maxr = np.zeros(self.W, np.int64)

    def ensure(self, w: int, lo: int, hi: int):
        """Grow row w's window to cover absolute pages [lo, hi)."""
        b = self.base[w]
        if b < 0:
            if hi - lo > self.cap:
                self._grow_cap(hi - lo)
            self.base[w] = lo
            self.length[w] = hi - lo
            self._cov_stale = True
            return
        changed = False
        if lo < b:
            pad = int(b - lo)
            n = int(self.length[w])
            if n + pad > self.cap:
                self._grow_cap(n + pad)
            for arr, init in ((self.valid, False), (self.dirty, False),
                              (self.wprot, True), (self.touch, 0),
                              (self.incache, False),
                              (self.span_lo, _I64_MAX),
                              (self.span_hi, _I64_MIN),
                              (self.race_w, 0), (self.race_r, 0)):
                if arr is None:
                    continue
                row = arr[w]
                row[pad:pad + n] = row[:n]
                row[:pad] = init
            self.base[w] = lo
            self.length[w] = n + pad
            self.shift[w] += pad
            b = lo
            changed = True
        if hi > b + self.length[w]:
            n = int(hi - b)
            if n > self.cap:
                self._grow_cap(n)
            self.length[w] = n
            changed = True
        if changed:
            self._cov_stale = True

    def sl(self, w: int, lo: int, hi: int) -> slice:
        b = int(self.base[w])
        return slice(lo - b, hi - b)

    def ensure_rows(self, lo: np.ndarray, hi: np.ndarray,
                    rows: np.ndarray):
        """Vectorized ``ensure`` over ``rows``: grow row rows[i]'s window
        to cover [lo[i], hi[i]).  Python-loops only over rows that actually
        need to grow — zero in the steady state of phase-structured apps."""
        base = self.base[rows]
        need = (base < 0) | (lo < base) | (hi > base + self.length[rows])
        for i in np.nonzero(need)[0]:
            self.ensure(int(rows[i]), int(lo[i]), int(hi[i]))

    # ------------------------------------------------------------------
    # cross-worker vector primitives
    # ------------------------------------------------------------------

    def range_cols(self, lo: np.ndarray, hi: np.ndarray,
                   rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Per-row column-index matrix for the absolute page intervals
        [lo[i], hi[i]) of rows[i] — windows must already cover them
        (``ensure_rows``).  Returns (cols (R, Lmax), mask (R, Lmax));
        mask is False past each row's interval length."""
        L = hi - lo
        j = np.arange(int(L.max()) if L.size else 0)
        cols = (lo - self.base[rows])[:, None] + j[None, :]
        return cols, j[None, :] < L[:, None]

    def count_range(self, plane: np.ndarray, lo: np.ndarray,
                    hi: np.ndarray,
                    rows: Optional[np.ndarray] = None) -> np.ndarray:
        """Per-row counts of True cells of ``plane`` inside [lo[i], hi[i]),
        reading out-of-window cells as False (windows need NOT cover the
        intervals — used by the phase_all eviction precheck).  ``rows``
        restricts the count to a row subset (``lo``/``hi`` then align with
        ``rows``); default is all W rows."""
        rows = np.arange(self.W) if rows is None else rows
        if plane.shape[1] == 0:
            return np.zeros(rows.size, np.int64)
        L = hi - lo
        Lmax = int(L.max()) if L.size else 0
        base = self.base[rows]
        length = self.length[rows]
        if not use_dense(rows.size, Lmax):
            # wide intervals: contiguous slice sums beat building the
            # (R, Lmax) gather matrices (see use_dense).  Rows sharing a
            # clipped window span (block-partitioned phases are uniform up
            # to edge rows) reduce together as one 2D slice-view sum.
            livem = base >= 0
            c0 = np.where(livem, np.maximum(lo - base, 0), 0)
            c1 = np.maximum(np.where(livem, np.minimum(hi - base, length),
                                     0), c0)
            out = np.zeros(rows.size, np.int64)
            if rows.size > 8:
                uk, inv = np.unique(np.stack([c0, c1], axis=1), axis=0,
                                    return_inverse=True)
                for g in range(uk.shape[0]):
                    a, b = int(uk[g, 0]), int(uk[g, 1])
                    if b <= a:
                        continue
                    sel = np.nonzero(inv == g)[0]
                    rb = self.row_block(rows[sel])
                    out[sel] = plane[rb, a:b].sum(axis=1, dtype=np.int64)
                return out
            for i, w in enumerate(rows):
                a, b = int(c0[i]), int(c1[i])
                if b > a:
                    out[i] = int(plane[w, a:b].sum())
            return out
        j = np.arange(Lmax)
        cols = (lo - base)[:, None] + j[None, :]
        m = ((j[None, :] < L[:, None]) & (cols >= 0)
             & (cols < length[:, None]) & (base >= 0)[:, None])
        sub = plane[rows[:, None], np.where(m, cols, 0)] & m
        return sub.sum(axis=1)

    # ------------------------------------------------------------------
    # dirty bounding intervals (phase_all window-disjointness analysis)
    # ------------------------------------------------------------------

    def note_dirty(self, rows, lo, hi):
        """Widen the conservative dirty bounding interval of ``rows`` to
        cover absolute pages [lo, hi) (scalars or aligned arrays)."""
        self.dirty_lo[rows] = np.minimum(self.dirty_lo[rows], lo)
        self.dirty_hi[rows] = np.maximum(self.dirty_hi[rows], hi)

    def clear_dirty_bounds(self, rows=None):
        """Reset dirty bounds after a flush (``rows=None`` resets all)."""
        if rows is None:
            self.dirty_lo[:] = _I64_MAX
            self.dirty_hi[:] = _I64_MIN
        else:
            self.dirty_lo[rows] = _I64_MAX
            self.dirty_hi[rows] = _I64_MIN

    # ------------------------------------------------------------------
    # span-touch planes (consistency regions)
    # ------------------------------------------------------------------

    def span_note(self, w: int, p_lo: int, p_hi: int,
                  wlo, whi):
        """Accumulate one span write's per-page word intervals into row
        w's span planes: cell p gets (min, max)-merged with [wlo[p-p_lo],
        whi[p-p_lo]) — the vectorized replacement for the reference's
        per-page ``span.touched`` dict merge.  ``wlo``/``whi`` are scalars
        (single-page ops, the accumulator steady state) or aligned
        arrays; the window must already cover [p_lo, p_hi)."""
        self.ensure_span()
        if p_hi - p_lo == 1:
            c = int(p_lo) - int(self.base[w])
            row_lo, row_hi = self.span_lo[w], self.span_hi[w]
            lo_s = int(wlo) if np.ndim(wlo) == 0 else int(wlo[0])
            hi_s = int(whi) if np.ndim(whi) == 0 else int(whi[0])
            if lo_s < row_lo[c]:
                row_lo[c] = lo_s
            if hi_s > row_hi[c]:
                row_hi[c] = hi_s
            return
        s = self.sl(w, p_lo, p_hi)
        np.minimum(self.span_lo[w, s], wlo, out=self.span_lo[w, s])
        np.maximum(self.span_hi[w, s], whi, out=self.span_hi[w, s])

    def span_harvest(self, w: int, p_lo: int, p_hi: int):
        """Collect and reset row w's span-touched cells inside absolute
        pages [p_lo, p_hi): returns (pages, los, his) with pages ascending
        — the release-publish payload, replacing
        ``sorted(span.touched.items())``.  Touched cells are reset to the
        untouched sentinel so the planes are clean for the next span."""
        if self.span_lo is None:
            z = np.zeros(0, np.int64)
            return z, z, z
        b = int(self.base[w])
        s = self.sl(w, p_lo, p_hi)
        seg_hi = self.span_hi[w, s]
        cols = np.nonzero(seg_hi != _I64_MIN)[0] + s.start
        if cols.size == 0:
            z = np.zeros(0, np.int64)
            return z, z, z
        los = self.span_lo[w, cols].copy()
        his = self.span_hi[w, cols].copy()
        self.span_lo[w, cols] = _I64_MAX
        self.span_hi[w, cols] = _I64_MIN
        return cols + b, los, his

    # ------------------------------------------------------------------
    # race vector-clock planes (detect_races mode)
    # ------------------------------------------------------------------

    def race_note(self, w: int, p_lo: int, p_hi: int, epoch: int,
                  is_write: bool):
        """Record worker w's access to absolute pages [p_lo, p_hi) at its
        current ``epoch`` into the matching vector-clock plane.  Epochs
        are monotone per worker, so recording is a plain store (≡ max).
        The window must already cover the range (the engine ensures every
        declared access interval before/while executing it; detection
        hooks run after the event, so the windows are always grown)."""
        self.ensure_race()
        plane = self.race_w if is_write else self.race_r
        plane[w, self.sl(w, p_lo, p_hi)] = epoch
        mx = self.race_maxw if is_write else self.race_maxr
        if epoch > mx[w]:
            mx[w] = epoch

    def race_note_rows(self, rows: np.ndarray, p_lo: np.ndarray,
                       p_hi: np.ndarray, epochs: np.ndarray,
                       is_write: bool):
        """Vectorized ``race_note`` over ``rows``: record row rows[i]'s
        access to absolute pages [p_lo[i], p_hi[i]) at epochs[rows[i]]
        — the batched detector's fast path when the screen proves no
        check can fire.  Windows must already cover the ranges."""
        self.ensure_race()
        plane = self.race_w if is_write else self.race_r
        L = p_hi - p_lo
        j = np.arange(int(L.max()) if L.size else 0)
        cols = (p_lo - self.base[rows])[:, None] + j[None, :]
        m = j[None, :] < L[:, None]
        ri, ci = np.nonzero(m)
        plane[rows[ri], cols[ri, ci]] = epochs[rows[ri]]
        mx = self.race_maxw if is_write else self.race_maxr
        # fancy-indexed out= would write a copy — scatter explicitly
        np.maximum.at(mx, rows, epochs[rows])

    def race_hits(self, p_lo: int, p_hi: int, vcw: np.ndarray,
                  is_write: bool):
        """(rows, pages) of write (or read) epochs recorded over absolute
        pages [p_lo, p_hi) that are NOT ordered under the view ``vcw`` —
        the scalar detector's check.  Row-screened: a row whose window
        misses the range (out-of-window cells read 0 — "never accessed",
        ordered under any view) or whose recorded region max is already
        covered by the view (every cell of row u is <= race_max*[u])
        provably holds no firing cell and is skipped without touching
        its plane, so a check costs O(W) when nothing can fire instead
        of materializing a (W, pages) gather."""
        z = np.zeros(0, np.int64)
        if self.race_w is None:
            return z, z
        mx = self.race_maxw if is_write else self.race_maxr
        ov_lo = np.maximum(p_lo, self.base)
        ov_hi = np.minimum(p_hi, self.base + self.length)
        cand = np.nonzero((mx > vcw) & (ov_hi > ov_lo)
                          & (self.base >= 0))[0]
        if cand.size == 0:
            return z, z
        plane = self.race_w if is_write else self.race_r
        cols = (p_lo - self.base[cand])[:, None] + np.arange(p_hi - p_lo)
        inr = (cols >= 0) & (cols < self.length[cand][:, None])
        G = np.where(inr, plane[cand[:, None], np.where(inr, cols, 0)], 0)
        ui, ji = np.nonzero(G > vcw[cand][:, None])
        return cand[ui], p_lo + ji

    # ------------------------------------------------------------------
    # batched eviction primitives (segment LRU over touch-run spans)
    # ------------------------------------------------------------------

    def row_block(self, rows: np.ndarray):
        """Row indexer for (rows x column-slice) plane access: a basic
        slice (zero-copy views, in-place updates) when ``rows`` is an
        ascending contiguous run — the whole axis or a lockstep-group
        stretch, the spill steady states — else the index array itself
        (gather/scatter).  Contiguity is PROVEN (unit steps), not
        inferred from size/bounds: a permuted row set must never alias a
        slice, or per-row values misalign with the plane's row order."""
        if rows.size > 1:
            if bool((np.diff(rows) == 1).all()):
                return slice(int(rows[0]), int(rows[-1]) + 1)
        elif rows.size == 1:
            return slice(int(rows[0]), int(rows[0]) + 1)
        return rows

    def run_live(self, rows: np.ndarray, start: int, length: int,
                 run_ticks: np.ndarray) -> np.ndarray:
        """(R, length) liveness mask of one LRU touch run per row: cell j
        of row i is live (still the current LRU entry for its page, and
        the page still occupies a cache slot) iff its touch tick still
        equals the run's tick ``run_ticks[i]`` and ``incache`` is set
        (ticks are one-per-run and globally monotone, so any re-touch by
        a later run strictly exceeds it).  All rows' runs must share the
        column span [start, start+length) — the lockstep case batched
        eviction groups on."""
        s = slice(start, start + length)
        rb = self.row_block(rows)
        return ((self.touch[rb, s] == run_ticks[:, None])
                & self.incache[rb, s])

    def lru_take(self, live: np.ndarray, k: np.ndarray,
                 tot: Optional[np.ndarray] = None) -> np.ndarray:
        """Segment-LRU selection: per row, the first (oldest-tick) k[i]
        live cells of the run.  Fully-live runs (``tot`` == run length —
        the streaming steady state) reduce to a columnar cutoff; else a
        boolean prefix-count on numpy, or on 'pallas' the run packs to
        uint32 bitmasks and the ``take_first_k`` rank-select kernel
        computes the mask (integer-exact either way)."""
        k = np.asarray(k)
        if tot is not None and bool((tot == live.shape[1]).all()):
            return np.arange(live.shape[1]) < k[:, None]
        if self.backend != "numpy":
            from repro.kernels import protocol_sweep as _ps
            bits = _ps.take_first_k(_ps.pack_mask_rows(live),
                                    np.asarray(k, np.int64),
                                    backend=self.backend,
                                    stats=self.jit_stats)
            return _ps.unpack_mask_rows(bits, live.shape[1])
        return live & (np.cumsum(live, axis=1, dtype=np.int32)
                       <= k[:, None])

    def take_upto_row(self, live: np.ndarray,
                      k: int) -> Tuple[np.ndarray, int]:
        """Rank-select over ONE run's live mask (the refetch replay
        engine's victim scan): the mask of the first k live cells and the
        scan cut — the index just past the k-th live cell, up to which the
        run is consumed.  The caller guarantees the run holds MORE than k
        live cells (whole-run consumption never needs a mask).  On
        'pallas' the mask packs to uint32 bitmasks and the
        ``take_first_k`` rank-select kernel computes it (the cut falls
        out of the take mask itself); integer-exact either way.  The
        standalone ``kth_set_index`` rank-query kernel answers the cut
        without unpacking; on 'pallas-jit' the fused ``take_and_cut``
        program computes mask AND cut in ONE device dispatch."""
        if self.backend == "pallas-jit":
            from repro.kernels import protocol_sweep as _ps
            bits, cut = _ps.take_and_cut(_ps.pack_mask_rows(live[None]),
                                         np.asarray([k], np.int64),
                                         backend=self.backend,
                                         stats=self.jit_stats)
            return _ps.unpack_mask_rows(bits, live.size)[0], int(cut[0]) + 1
        if self.backend == "pallas":
            from repro.kernels import protocol_sweep as _ps
            take = _ps.unpack_mask_rows(
                _ps.take_first_k(_ps.pack_mask_rows(live[None]),
                                 np.asarray([k], np.int64),
                                 backend=self.backend),
                live.size)[0]
            return take, int(np.flatnonzero(take)[-1]) + 1
        cs = np.cumsum(live, dtype=np.int64)
        take = live & (cs <= k)
        return take, int(np.argmax(cs >= k)) + 1

    def evict_rows(self, rows: np.ndarray, start: int, length: int,
                   take: Optional[np.ndarray], *,
                   set_wprot: bool) -> np.ndarray:
        """Batched eviction of the ``take`` cells (an (R, length) mask over
        columns [start, start+length) of ``rows``; None takes the whole
        span — the streaming steady state): dirty victims clear and
        re-arm write protection (when ``set_wprot``), then valid and the
        cache slot (incache) drop.  Returns per-row dirty-victim counts —
        the runtime's writeback charge; on 'pallas' the count is a packed
        bitmask popcount.  Plane updates only: traffic/clock accounting
        (and the sharer-invalidation step, which the caller must have
        proven a no-op) stay in the runtime."""
        s = slice(start, start + length)
        rb = self.row_block(rows)
        dm = self.dirty[rb, s] if take is None else self.dirty[rb, s] & take
        if self.backend != "numpy":
            from repro.kernels import protocol_sweep as _ps
            db = _ps.popcount_rows(_ps.pack_mask_rows(dm),
                                   backend=self.backend,
                                   stats=self.jit_stats)
        else:
            db = dm.sum(axis=1, dtype=np.int64)
        if take is None:
            if db.any():
                if set_wprot and self.wprot is not None:
                    self.wprot[rb, s] |= dm
                self.dirty[rb, s] = False
            self.valid[rb, s] = False
            self.incache[rb, s] = False
        else:
            keep = ~take
            if db.any():
                self.dirty[rb, s] &= ~dm
                if set_wprot and self.wprot is not None:
                    self.wprot[rb, s] |= dm
            self.valid[rb, s] &= keep
            self.incache[rb, s] &= keep
        return db

    def overlap_rows(self, lo: int, hi: int,
                     exclude: Optional[int] = None) -> np.ndarray:
        """Workers whose window intersects absolute pages [lo, hi)."""
        m = (self.base >= 0) & (self.base < hi) & (self.base + self.length > lo)
        if exclude is not None:
            m[exclude] = False
        return np.nonzero(m)[0]

    def gather_valid(self, rows: np.ndarray,
                     pages: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(len(rows), len(pages)) validity matrix plus the column-index
        matrix (for scattering back).  Out-of-window cells read False."""
        cols = pages[None, :] - self.base[rows][:, None]
        inr = (cols >= 0) & (cols < self.length[rows][:, None])
        sub = self.valid[rows[:, None], np.where(inr, cols, 0)] & inr
        return sub, cols

    def clear_valid_cells(self, rows: np.ndarray, cols: np.ndarray,
                          hit: np.ndarray) -> np.ndarray:
        """Clear valid at the True cells of ``hit`` (a (rows x pages) mask
        aligned with ``cols``); returns per-row cleared counts."""
        ri, ci = np.nonzero(hit)
        if ri.size:
            self.valid[rows[ri], cols[ri, ci]] = False
        return hit.sum(axis=1)

    def _refresh_bounds(self):
        if self._cov_stale:
            live = self.base >= 0
            self._sorted_bases = np.sort(self.base[live])
            self._sorted_ends = np.sort((self.base + self.length)[live])
            self._jit_geom = None          # window geometry changed
            self._cov_stale = False

    def jit_geometry(self):
        """(base, sorted_bases, sorted_ends) as int32 — the fused flush
        chain's window-geometry operands (``kernels.phase_step``), cached
        until a window changes (``_cov_stale`` drops it).  The packed
        dirty planes are rebuilt per flush (their contents changed) but
        geometry survives phases — the steady state re-packs one plane
        and reuses everything else."""
        self._refresh_bounds()
        if self._jit_geom is None:
            self._jit_geom = (self.base.astype(np.int32),
                              self._sorted_bases.astype(np.int32),
                              self._sorted_ends.astype(np.int32))
        return self._jit_geom

    def shared_intervals(self) -> Tuple[np.ndarray, np.ndarray]:
        """Absolute page intervals covered by >= 2 worker windows, as
        (starts, ends) arrays — a sweep over the 2W window bounds.  Pages
        outside these intervals cannot have sharers, so barrier flushes
        skip them without per-page work.  The coverage cumsum runs on the
        selected backend (``kernels.protocol_sweep`` for 'pallas')."""
        self._refresh_bounds()
        b, e = self._sorted_bases, self._sorted_ends
        if b.size < 2:
            z = np.zeros(0, np.int64)
            return z, z
        pts = np.concatenate([b, e])
        delta = np.concatenate([np.ones(b.size, np.int64),
                                np.full(e.size, -1, np.int64)])
        order = np.argsort(pts, kind="stable")
        pts = pts[order]
        if self.backend != "numpy":
            from repro.kernels import protocol_sweep as _ps
            multi = _ps.coverage_multi(delta[order], backend=self.backend,
                                       stats=self.jit_stats)
        else:
            multi = np.cumsum(delta[order]) >= 2
        edge = np.diff(np.concatenate([[False], multi]).astype(np.int8))
        starts = pts[np.nonzero(edge == 1)[0]]
        ends_i = np.nonzero(edge == -1)[0]
        ends = pts[ends_i]
        if multi[-1]:
            ends = np.concatenate([ends, pts[-1:]])
        keep = ends > starts
        return starts[keep], ends[keep]

    def dirty_counts(self) -> np.ndarray:
        """(W,) per-row dirty-page counts — the barrier-flush popcount.
        On the 'pallas' backend the boolean plane is packed into uint32
        bitmasks and popcounted by the protocol-sweep kernel; cells outside
        a row's live window are always False, so whole-plane reduction is
        exact on every backend.  (On 'pallas-jit' the barrier flush
        bypasses this per-region call for the fused ``phase_step`` chain;
        this path serves direct callers.)"""
        if self.backend != "numpy":
            from repro.kernels import protocol_sweep as _ps
            return _ps.popcount_rows(_ps.pack_mask_rows(self.dirty),
                                     backend=self.backend,
                                     stats=self.jit_stats)
        return self.dirty.sum(axis=1)

    def row_dirty_cols(self, w: int) -> np.ndarray:
        n = int(self.length[w])
        return np.nonzero(self.dirty[w, :n])[0]

    # ------------------------------------------------------------------
    # snapshot / restore (see DIRECTORY.md "Recovery contract")
    # ------------------------------------------------------------------

    def state_arrays(self, rows=None) -> Tuple[dict, dict]:
        """Full plane state as (arrays, meta) — everything needed to
        rebuild a row-for-row, cell-for-cell clone.  Planes are stored at
        their current capacity; the derived coverage caches
        (``_sorted_bases``/``_sorted_ends``) are recomputed on restore.

        Every array here is worker-major (first dim ``W``), so ``rows``
        (a slice or index array) restricts the payload to a shard's
        worker slice — the cluster checkpoint path; ``meta`` still
        records the full ``W`` (a slice is a view of the whole table,
        not a smaller directory)."""
        sl = slice(None) if rows is None else rows
        arrays = {"base": self.base[sl].copy(),
                  "length": self.length[sl].copy(),
                  "shift": self.shift[sl].copy(),
                  "valid": self.valid[sl].copy(),
                  "dirty": self.dirty[sl].copy(),
                  "dirty_lo": self.dirty_lo[sl].copy(),
                  "dirty_hi": self.dirty_hi[sl].copy()}
        for name in ("wprot", "touch", "incache", "span_lo", "span_hi",
                     "race_w", "race_r", "race_maxw", "race_maxr"):
            arr = getattr(self, name)
            if arr is not None:
                arrays[name] = arr[sl].copy()
        meta = {"W": self.W, "region": self.region,
                "page_lo": self.page_lo, "page_hi": self.page_hi,
                "cap": self.cap, "maybe_dirty": bool(self.maybe_dirty),
                "track_wprot": self.wprot is not None,
                "track_touch": self.touch is not None,
                "has_span": self.span_lo is not None,
                "has_race": self.race_w is not None,
                "backend": self.backend}
        return arrays, meta

    @classmethod
    def from_state(cls, arrays: dict, meta: dict) -> "RegionDirectory":
        d = cls(meta["W"], meta["region"], meta["page_lo"],
                meta["page_hi"], track_wprot=meta["track_wprot"],
                track_touch=meta["track_touch"], backend=meta["backend"])
        d.cap = int(meta["cap"])
        d.base = np.asarray(arrays["base"], np.int64).copy()
        d.length = np.asarray(arrays["length"], np.int64).copy()
        d.shift = np.asarray(arrays["shift"], np.int64).copy()
        d.valid = np.asarray(arrays["valid"], bool).copy()
        d.dirty = np.asarray(arrays["dirty"], bool).copy()
        d.dirty_lo = np.asarray(arrays["dirty_lo"], np.int64).copy()
        d.dirty_hi = np.asarray(arrays["dirty_hi"], np.int64).copy()
        if meta["track_wprot"]:
            d.wprot = np.asarray(arrays["wprot"], bool).copy()
        if meta["track_touch"]:
            d.touch = np.asarray(arrays["touch"], np.int64).copy()
            d.incache = np.asarray(arrays["incache"], bool).copy()
        if meta["has_span"]:
            d.span_lo = np.asarray(arrays["span_lo"], np.int64).copy()
            d.span_hi = np.asarray(arrays["span_hi"], np.int64).copy()
        if meta.get("has_race"):
            d.race_w = np.asarray(arrays["race_w"], np.int64).copy()
            d.race_r = np.asarray(arrays["race_r"], np.int64).copy()
            d.race_maxw = np.asarray(arrays["race_maxw"], np.int64).copy()
            d.race_maxr = np.asarray(arrays["race_maxr"], np.int64).copy()
        d.maybe_dirty = bool(meta["maybe_dirty"])
        d._cov_stale = True
        return d


class IntervalLog:
    """Flat, version-segmented (page, lo, hi) notice log for one lock.

    ``append_version`` records one release's notices; ``pending`` returns
    the per-page coalesced (min lo, max hi) intervals of every version in
    ``[v_from, v_to)`` — a slice of the flat arrays plus one vectorized
    segment-min/max, replacing the reference's dict-merge over versions.
    Pages come back sorted ascending, matching the reference's
    ``sorted(pending.items())`` replay order.
    """

    __slots__ = ("_p", "_lo", "_hi", "_n", "voff")

    def __init__(self):
        self._p = np.zeros(8, np.int64)
        self._lo = np.zeros(8, np.int64)
        self._hi = np.zeros(8, np.int64)
        self._n = 0
        self.voff = [0]

    def _reserve(self, k: int):
        need = self._n + k
        if need > self._p.size:
            cap = max(need, 2 * self._p.size)
            for name in ("_p", "_lo", "_hi"):
                arr = getattr(self, name)
                new = np.zeros(cap, np.int64)
                new[:self._n] = arr[:self._n]
                setattr(self, name, new)

    def append_version(self, pages, los, his):
        k = len(pages)
        self._reserve(k)
        n = self._n
        self._p[n:n + k] = pages
        self._lo[n:n + k] = los
        self._hi[n:n + k] = his
        self._n = n + k
        self.voff.append(self._n)

    def append_versions(self, pages, los, his, counts):
        """Append SEVERAL release versions in one reserve+copy: version i
        of the batch owns the next ``counts[i]`` entries of the flat
        (pages, los, his) arrays.  One numpy copy + one ``voff`` extend
        replaces per-release ``append_version`` calls — the span_all
        pipelined-release path (every worker of a uniform lock group
        publishes the same interval set, tiled by the caller)."""
        k = len(pages)
        assert int(np.sum(counts)) == k, (counts, k)
        self._reserve(k)
        n = self._n
        self._p[n:n + k] = pages
        self._lo[n:n + k] = los
        self._hi[n:n + k] = his
        self._n = n + k
        self.voff.extend((n + np.cumsum(counts, dtype=np.int64)).tolist())

    def payload_matches(self, v_from: int, v_to: int, pages, los,
                        his) -> bool:
        """True iff every version in [v_from, v_to) carries exactly this
        payload (same pages/los/his, in order) — the span_all uniform
        group's backlog check.  The caller must already know each
        version's entry count equals ``len(pages)``."""
        a, b = self.voff[v_from], self.voff[v_to]
        k = v_to - v_from
        n = len(pages)
        if b - a != k * n:
            return False
        return (bool((self._p[a:b].reshape(k, n) == pages).all())
                and bool((self._lo[a:b].reshape(k, n) == los).all())
                and bool((self._hi[a:b].reshape(k, n) == his).all()))

    def page_bounds(self, v_from: int, v_to: int):
        """Bounding (lo, hi) page interval of every notice in versions
        [v_from, v_to), or None when the slice is empty — the span_all
        flush-hoist screen's conservative pending-page footprint."""
        a, b = self.voff[v_from], self.voff[v_to]
        if a == b:
            return None
        seg = self._p[a:b]
        return int(seg.min()), int(seg.max()) + 1

    def state_arrays(self) -> dict:
        """Live log contents (entries [0, _n) plus the version offsets) —
        the snapshot payload; spare capacity is not serialized."""
        n = self._n
        return {"p": self._p[:n].copy(), "lo": self._lo[:n].copy(),
                "hi": self._hi[:n].copy(),
                "voff": np.asarray(self.voff, np.int64)}

    @classmethod
    def from_state(cls, arrays: dict) -> "IntervalLog":
        log = cls()
        p = np.asarray(arrays["p"], np.int64)
        n = int(p.size)
        log._reserve(n)
        log._p[:n] = p
        log._lo[:n] = np.asarray(arrays["lo"], np.int64)
        log._hi[:n] = np.asarray(arrays["hi"], np.int64)
        log._n = n
        log.voff = [int(v) for v in np.asarray(arrays["voff"], np.int64)]
        return log

    def pending(self, v_from: int, v_to: int):
        """Coalesced (pages, lo_min, hi_max) over versions [v_from, v_to)."""
        a, b = self.voff[v_from], self.voff[v_to]
        if a == b:
            e = np.zeros(0, np.int64)
            return e, e, e
        seg_p = self._p[a:b]
        u, inv = np.unique(seg_p, return_inverse=True)
        lo_min = np.full(u.size, np.iinfo(np.int64).max, np.int64)
        hi_max = np.full(u.size, np.iinfo(np.int64).min, np.int64)
        np.minimum.at(lo_min, inv, self._lo[a:b])
        np.maximum.at(hi_max, inv, self._hi[a:b])
        return u, lo_min, hi_max
