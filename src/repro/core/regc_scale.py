"""Directory-vectorized RegC protocol engine for paper-scale runs.

Same protocol as ``core.regc.RegCRuntime`` — same rules, same traffic
accounting — but all cross-worker paths are vectorized over the worker axis
through a per-region sharing directory (``core.directory.RegionDirectory``)
so the paper's figures (STREAM TRIAD / Jacobi / MD up to 256 cores,
millions of pages) run in seconds.  ``tests/test_regc_scale.py`` and
``tests/test_directory.py`` cross-validate the traffic counters (exactly)
and the modeled clocks (to float tolerance) against the reference runtime.

Key representation choices:

* page state is per *region*: ``valid/dirty/wprot/touch`` live in one 2D
  ``(W, window)`` directory per allocation region, rows = workers, each row
  offset to the worker's touched window, so memory is O(touched) while
  sharer invalidation, barrier flushes, and notice replay are single
  boolean-mask / gather-scatter numpy ops instead of ``range(W)`` loops;
* reads/writes are per-*interval* (vectorized over the page range);
* eviction is watermark-triggered: a per-worker resident counter makes the
  common no-eviction case O(1); past the watermark the oldest pages pop
  from a tick-ordered FIFO of touch runs (one monotone tick per run —
  victim order within a run is its column order, which is the reference's
  per-op LRU order; see DIRECTORY.md).  ``phase_all`` never abandons the
  batched path under spill: a window-disjointness analysis over the
  declared ranges proves which workers' evictions cannot interact, evicts
  them with vectorized segment-LRU plane ops, and replays only the
  residual interacting workers tick-ordered.  Ops that can evict pages of
  their own range before touching them (the mid-op refetch pattern,
  flagged by ``_danger``) resolve through an analytic segmented
  evict-then-refetch schedule (``_danger_replay``) instead of a per-page
  Python walk, in BOTH drivers;
* lock notices are flat, version-segmented numpy interval logs
  (``core.directory.IntervalLog``); acquire/barrier replay is one slice +
  segment-min/max coalesce per (lock, worker);
* consistency-region spans are plane-tracked (``span_lo``/``span_hi``
  word-interval planes; release harvests and publishes one batched log
  append), and whole span PASSES batch through ``span_all``: grants stay
  serialized — they are the lock — while each worker's release-flush and
  the next holder's acquire-replay pipeline as plane ops
  (``_span_group_vec``); only nested spans keep the per-page dict.

Beyond the reference runtime, this engine also models the paper's two
store-tracking *mechanisms* (§IV):

* ``fine``  (samhita): every store is instrumented with a runtime call
  (LLVM pass) -> ``instr_s_per_word`` per stored word, in ordinary AND
  consistency regions (the MD result: overhead visible even when almost all
  stores are ordinary);
* ``page``  (samhita_page): write detection via VM protection -> one
  ``fault_s`` per (page x write-epoch), re-armed when the page is flushed.
"""
from __future__ import annotations

import bisect
import dataclasses
import re
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.config import (DANGER_MODES, FAULT_S, INSTR_S_PER_WORD,
                               PROTOCOLS, check_choice)
from repro.core.directory import IntervalLog, RegionDirectory, use_dense
from repro.core.regc import (FINE_PROTO, IDEAL_PROTO, PAGE_PROTO, GasArray,
                             Traffic, _WORD)
from repro.dsm.costmodel import CostModel, IB_2013


class _Span:
    __slots__ = ("lock", "touched", "plane", "bounds")

    def __init__(self, lock, plane: bool = False):
        self.lock = lock
        self.plane = plane
        # A depth-1 (outermost) span tracks its touches in the directory's
        # span planes (vectorized interval merge, no per-page dict);
        # ``bounds`` records the touched page bounding interval per region
        # for the release harvest.  Nested (inner) spans keep the
        # reference's per-page dict — at most one plane-tracked span is
        # open per worker, so the planes never mix two spans' touches.
        self.touched: Optional[Dict[int, Tuple[int, int]]] = (
            None if plane else {})
        self.bounds: Optional[Dict[int, list]] = {} if plane else None


class _Lock:
    __slots__ = ("version", "log", "last_release_time", "seen", "race_vc")

    def __init__(self, n_workers):
        self.version = 0
        self.log = IntervalLog()
        self.last_release_time = 0.0
        self.seen = np.zeros(n_workers, np.int64)
        # detect_races only: the lock's vector clock — the join of every
        # releaser's clock at release time (see DIRECTORY.md
        # "Race-detection contract")
        self.race_vc = np.zeros(n_workers, np.int64)


class RegCScaleRuntime:
    """Drop-in (metadata-only) directory-vectorized version of RegCRuntime."""

    def __init__(self, n_workers: int, *, page_words: int = 1024,
                 protocol: str = FINE_PROTO, cost: CostModel = IB_2013,
                 cache_pages: Optional[int] = None, prefetch: int = 1,
                 n_mem_servers: int = 1, model_mechanism: bool = True,
                 instr_s_per_word: float = INSTR_S_PER_WORD,
                 fault_s: float = FAULT_S, fetch_batch: int = 1,
                 backend: str = "numpy", danger_mode: str = "vec",
                 detect_races: bool = False,
                 chaos=None, injector=None, straggler=None):
        check_choice("protocol", protocol, PROTOCOLS)
        # 'vec' | 'scalar': how ops flagged by the per-op ``_danger``
        # screen (mid-op refetch possible) replay.  'vec' evaluates the
        # analytic segmented evict-then-refetch schedule (_danger_replay);
        # 'scalar' forces the page-by-page reference walk — the oracle the
        # trace-fuzz suite cross-validates against.  Both are
        # traffic-exact; only wall time differs.
        check_choice("danger_mode", danger_mode, DANGER_MODES)
        self.danger_mode = danger_mode
        # 'numpy' | 'pallas' | 'pallas-jit': backend for the whole-plane
        # directory reductions (kernels.protocol_sweep).  Integer-exact
        # on every tier; 'pallas-jit' compiles the barrier-flush hot path
        # into ONE fused device dispatch per phase (see DIRECTORY.md
        # "Compiled-phase contract").  Degrades to numpy with a warning
        # when jax is unavailable (or REPRO_FORCE_NUMPY=1).
        from repro.kernels.protocol_sweep import resolve_backend
        self.backend = resolve_backend(backend)
        self.W = n_workers
        self.page_words = page_words
        self.page_bytes = page_words * _WORD
        self.protocol = protocol
        self.cost = cost
        self.cache_pages = cache_pages
        self.prefetch = prefetch
        self.n_mem_servers = max(1, n_mem_servers)
        self.model_mechanism = model_mechanism
        self.instr_s_per_word = instr_s_per_word
        self.fault_s = fault_s
        # Samhita's bulk-fetch optimization (paper §V-A): a miss run of k
        # pages costs ceil(k/fetch_batch) request/reply pairs, not k.
        # fetch_batch=1 == reference runtime accounting.
        self.fetch_batch = max(1, fetch_batch)
        self._track_wprot = (protocol == PAGE_PROTO and model_mechanism)
        self._track_touch = cache_pages is not None

        self.n_pages = 0
        self._region_starts: List[int] = []     # sorted page_lo per region
        self._region_ends: List[int] = []
        self._region_starts_np = np.zeros(0, np.int64)
        self.dirs: List[RegionDirectory] = []
        self.spans: List[List[_Span]] = [[] for _ in range(n_workers)]
        self.locks: Dict[int, _Lock] = {}
        self.clock = np.zeros(n_workers)
        self.traffic = Traffic()
        # per-worker cache occupancy (valid + invalidated-but-not-evicted
        # pages, matching the reference's LRU dict): the eviction watermark
        self.resident = np.zeros(n_workers, np.int64)
        # per-worker FIFO of touch runs
        # [t0, region, col0, n, off, shift0, pristine]: ticks are globally
        # monotone (one per run), so the queue is tick-ordered and an LRU
        # pop is a front scan that lazily skips re-touched (stale) and
        # already-evicted cells — amortized O(1) per page.  ``pristine``
        # runs were never overlapped by a later op of the same worker, so
        # their live cells are exactly the [off, n) suffix and eviction
        # needs no touch scan (see _q_append)
        self._lru_q: List[deque] = [deque() for _ in range(n_workers)]
        self._q_degraded = np.zeros(n_workers, bool)
        self._dirty_regions: List[set] = [set() for _ in range(n_workers)]
        self._reductions: Dict[str, List[Tuple[float, str]]] = {}
        self._reduction_results: Dict[str, float] = {}
        self._tick = 0
        self._rows_all = np.arange(n_workers)
        # when a dict, _danger_replay records its eviction schedule into
        # it (the shared-schedule leader run — see _danger_shared)
        self._danger_rec: Optional[dict] = None
        # phase_all path counters (which engine paths ran; the trace-fuzz
        # suite asserts the batched-eviction and residual paths are
        # actually exercised rather than silently bypassed)
        self.stats = {"batched_phases": 0, "evict_batch_rounds": 0,
                      "danger_ops": 0, "residual_replays": 0,
                      "danger_vec_ops": 0, "danger_scalar_ops": 0,
                      "danger_shared_ops": 0, "danger_subgroup_ops": 0,
                      "span_all_calls": 0, "span_serial_calls": 0,
                      "span_groups_vec": 0, "span_workers_vec": 0,
                      "span_multi_region_groups": 0,
                      "span_serial_workers": 0,
                      "span_backlog_serial": 0,
                      "race_ww": 0, "race_rw": 0,
                      # 'pallas-jit' accounting: fused/jitted device
                      # dispatches and first-seen-shape compiles.  CI's
                      # kernels smoke gates jit_dispatches > 0 on jit
                      # bench legs — a silent fallback to numpy keeps
                      # traffic identical but zeroes the counter.
                      "jit_dispatches": 0, "jit_cache_misses": 0}
        # race-detection mode (pure observer; see DIRECTORY.md
        # "Race-detection contract"): per-worker vector clocks, the
        # canonical flagged-race set, and a suspension flag the batched
        # drivers set while replaying ops internally (phase_all residual
        # replay, span_all fallbacks) so detection runs exactly once per
        # access — in the driver-level batched pass.
        self.detect_races = detect_races
        self.race_vc = (np.eye(n_workers, dtype=np.int64)
                        if detect_races else None)
        self.races: set = set()
        self._race_suspend = False
        # fault-tolerance wiring (see ft/coherence.py and DIRECTORY.md
        # "Recovery contract"): ``chaos`` is a dsm.costmodel.ChaosNet
        # message-loss model (one per-worker tick per clock-charged
        # message-group event — per-worker event order is identical
        # across drivers, so retry charges keep loop/batched bit-equal);
        # ``injector`` is a ft.runtime.FailureInjector fired at phase/
        # span/barrier boundaries (``chaos_tick``); ``straggler`` is a
        # ft.runtime.StragglerMonitor observed on per-barrier walls.
        self.chaos = chaos
        self.injector = injector
        self.straggler = straggler
        if chaos is not None:
            chaos.bind(n_workers, self.stats)
        if straggler is not None:
            assert straggler.n == n_workers, (straggler.n, n_workers)
            self.stats.setdefault("straggler_checks", 0)
            self.stats.setdefault("straggler_flags", 0)
        self._phase_idx = 0
        self._bar_clock0 = np.zeros(n_workers)

    def chaos_tick(self):
        """Advance the phase-program position and give the failure
        injector its shot.  Called internally at ``phase_all`` /
        ``span_all`` / ``barrier`` entry; loop-driver harnesses call it
        once per equivalent event so both drivers see the same
        per-event injection schedule.  A raise here interrupts BEFORE
        any of the event's state mutations — the runtime is exactly its
        post-previous-event self, which a barrier checkpoint + replayed
        event prefix reproduces bit-for-bit."""
        self._phase_idx += 1
        if self.injector is not None:
            self.injector.check(self._phase_idx)

    # ------------------------------------------------------------------
    def alloc(self, n_elems: int) -> GasArray:
        pages = -(-n_elems // self.page_words)
        ga = GasArray(self.n_pages, n_elems, self.page_words)
        self._region_starts.append(self.n_pages)
        self._region_ends.append(self.n_pages + pages)
        self._region_starts_np = np.asarray(self._region_starts, np.int64)
        d = RegionDirectory(
            self.W, len(self.dirs), self.n_pages, self.n_pages + pages,
            track_wprot=self._track_wprot, track_touch=self._track_touch,
            backend=self.backend)
        d.jit_stats = self.stats
        self.dirs.append(d)
        self.n_pages += pages
        return ga

    def _region_of(self, page: int) -> int:
        i = bisect.bisect_right(self._region_starts, page) - 1
        assert 0 <= i and page < self._region_ends[i], page
        return i

    def _net(self, w: int, n_bytes: float, msgs: int = 1):
        if self.protocol == IDEAL_PROTO:
            return
        self.clock[w] += self.cost.xfer_s(n_bytes, msgs)
        if self.chaos is not None:
            self.clock[w] += self.chaos.retry1(w)

    def compute(self, w: int, *, flops: float = 0.0, mem_bytes: float = 0.0,
                seconds: float = 0.0):
        self.clock[w] += seconds + self.cost.compute_s(
            flops, mem_bytes, self.cost.workers_on_node(self.W))

    def instr_stores(self, w: int, n_words: float):
        """Inner-loop stores to shared memory that the LLVM pass instruments
        (e.g. MD force accumulation): charged per word under the fine
        protocol; under the page protocol they hit already-faulted pages."""
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[w] += n_words * self.instr_s_per_word

    # ------------------------------------------------------------------
    # interval fetch / batched eviction
    # ------------------------------------------------------------------

    _Q_SCAN_LIMIT = 64

    def _q_append(self, w: int, region: int, col0: int, n: int,
                  shift0: int) -> int:
        """Append a touch run to w's tick-ordered LRU queue and return its
        fresh (monotone) tick.  Older queued runs of the same region whose
        live span overlaps the new run lose their ``pristine`` flag —
        their overlapped cells are re-touched by this op, so the
        prefix-liveness shortcut no longer holds for them.  Queues longer
        than the scan limit (per-page danger-path runs) degrade wholesale
        to non-pristine, keeping appends O(1) amortized; eviction then
        falls back to the exact touch scan."""
        self._tick += 1
        q = self._lru_q[w]
        pristine = True
        if len(q) > self._Q_SCAN_LIMIT:
            if not self._q_degraded[w]:
                for e in q:
                    e[6] = False
                self._q_degraded[w] = True
            pristine = False
        else:
            self._q_degraded[w] = False
            hi = col0 + n
            for e in q:
                if e[1] != region or not e[6]:
                    continue
                ec0 = e[2] + (shift0 - e[5])
                if ec0 + e[4] < hi and ec0 + e[3] > col0:
                    e[6] = False
        q.append([self._tick, region, col0, n, 0, shift0, pristine])
        return self._tick

    def _fetch_range(self, w: int, region: int, p_lo: int, p_hi: int):
        """Make pages [p_lo, p_hi) valid at w, charging misses."""
        d = self.dirs[region]
        d.ensure(w, p_lo, p_hi)
        s = d.sl(w, p_lo, p_hi)
        n = p_hi - p_lo
        n_miss = n - int(d.valid[w, s].sum())
        if d.touch is not None:
            # one monotone tick per touch RUN (column order within a run
            # is the reference's per-op LRU order, so per-page tick values
            # are redundant — see DIRECTORY.md): re-touches by later runs
            # get strictly larger ticks, which is all staleness detection
            # compares
            d.touch[w, s] = self._q_append(w, region, s.start, n,
                                           int(d.shift[w]))
            n_enter = n - int(d.incache[w, s].sum())
            if n_enter:
                d.incache[w, s] = True
                self.resident[w] += n_enter
        if n_miss:
            if self.protocol != IDEAL_PROTO:
                self.traffic.page_fetches += n_miss
                self.traffic.fetch_bytes += n_miss * self.page_bytes
                n_req = -(-n_miss // self.fetch_batch)
                self._net(w, n_miss * self.page_bytes, 2 * n_req)
            d.valid[w, s] = True

    def _danger(self, w: int, n_enter: int, n: int) -> bool:
        """Batched end-of-op eviction is exact unless this op can evict a
        page of its *own* range (one already occupying a cache slot) before
        touching it — the reference would then refetch / re-enter it
        mid-op.  That needs both an in-cache page in the range
        (n_enter < n) and an eviction this op; fully-cold ranges (the spill
        benchmarks' steady state) and eviction-free ops stay on the batch
        path."""
        return (self.cache_pages is not None
                and self.protocol != IDEAL_PROTO
                and n_enter < n
                and int(self.resident[w]) + n_enter > self.cache_pages)

    def _evict_now(self, w: int, d: RegionDirectory, vc: np.ndarray):
        """Evict the cells ``vc`` (ascending tick order) of w's row in
        region d: dirty victims (valid or not) write back first — one
        message per page, matching the reference's per-page eviction flush
        — then both ``valid`` and the cache slot (``incache``) drop.
        Contiguous victim runs (the streaming-spill steady state) use
        slice ops instead of fancy indexing."""
        lo, hi = int(vc[0]), int(vc[-1]) + 1
        sl = slice(lo, hi) if hi - lo == vc.size else vc
        dmask = d.dirty[w, sl]
        if dmask.any():
            db = vc[dmask]
            d.dirty[w, sl] = False     # only the db cells were set
            if self.protocol != IDEAL_PROTO:
                self.traffic.writeback_bytes += db.size * self.page_bytes
                self.clock[w] += (self.cost.net_latency_s * db.size
                                  + db.size * self.page_bytes
                                  / self.cost.net_bw_Bps)
                if self.chaos is not None:
                    self.clock[w] += self.chaos.retry1(w)
                if d.wprot is not None:
                    d.wprot[w, db] = True
                self._invalidate_sharers(w, d.region, d.base[w] + db)
        d.valid[w, sl] = False
        d.incache[w, sl] = False
        self.resident[w] -= vc.size

    def _evict_cells(self, w: int, k: int):
        """Evict w's k least-recently-touched cache occupants by scanning
        the tick-ordered run queue from the front, lazily skipping cells
        that were re-touched (their live entry is a later run) or already
        evicted.  Each queue cell is examined O(1) times overall, so
        steady-state spill eviction is amortized O(1) per page."""
        q = self._lru_q[w]
        while k > 0:
            run = q[0]
            t0, region, col0, n, off, shift0, pristine = run
            d = self.dirs[region]
            c0 = col0 + (int(d.shift[w]) - shift0)
            if pristine:
                # never re-touched: live cells are exactly [off, n), so
                # the victims are a contiguous prefix — no touch scan
                tk = min(k, n - off)
                self._evict_now(w, d, np.arange(c0 + off, c0 + off + tk))
                k -= tk
                if off + tk == n:
                    q.popleft()
                else:
                    run[4] = off + tk
                continue
            sl = slice(c0 + off, c0 + n)      # run cells are contiguous
            live = (d.touch[w, sl] == t0) & d.incache[w, sl]
            idx = np.nonzero(live)[0]
            if idx.size == 0:
                q.popleft()
                continue
            take = idx[:k]
            self._evict_now(w, d, c0 + off + take)
            k -= take.size
            if take.size == idx.size:
                q.popleft()          # no live cells remain in this run
            else:
                run[4] = off + int(take[-1]) + 1

    def _touch_page_exact(self, w: int, d: RegionDirectory, p: int,
                          fetch: bool) -> int:
        """Per-page touch/fetch + immediate LRU eviction, mirroring the
        reference's ``_fetch``/``_touch_lru`` sequence for dangerous ops.
        Returns the number of pages fetched (0/1); the *caller* charges
        the fetch messages once per op so batching (``fetch_batch``)
        costs the same on this path as on the batch path."""
        col = p - int(d.base[w])
        n_miss = 0
        if not d.valid[w, col]:
            if fetch and self.protocol != IDEAL_PROTO:
                self.traffic.page_fetches += 1
                self.traffic.fetch_bytes += self.page_bytes
                n_miss = 1
            d.valid[w, col] = True
        if not d.incache[w, col]:
            d.incache[w, col] = True
            self.resident[w] += 1
        d.touch[w, col] = self._q_append(w, d.region, col, 1,
                                         int(d.shift[w]))
        if self.resident[w] > self.cache_pages:
            self._evict_cells(w, int(self.resident[w]) - self.cache_pages)
        return n_miss

    def _danger_replay(self, w: int, d: RegionDirectory, region: int,
                       p_lo: int, p_hi: int,
                       fetch_flag: Optional[np.ndarray], *,
                       is_write: bool) -> int:
        """Vectorized mid-op refetch replay: the exact effects of the
        reference's page-by-page touch/fetch/evict interleave for one
        danger-flagged op, computed analytically as a segmented
        evict-then-refetch schedule instead of a Python loop over pages.

        The key structure (see DIRECTORY.md §refetch schedule): within an
        op the touch front sweeps the op's columns left to right while
        the eviction front consumes the worker's LRU victim stream in
        tick order, and the two interact only at the op's *in-cache
        segments* — maximal column runs of the op range that are cache
        slots of one pre-op touch run (victim order within a run is
        column order, so both fronts traverse a segment the same way).
        When the touch front reaches a segment none of whose cells have
        been evicted yet, touching makes the whole segment stale before
        any eviction can reach it (touching is free — no enters, so the
        eviction front cannot advance).  When at least one cell has been
        evicted, the eviction front is ahead of the touch front inside
        the segment and every touch refetches an evicted cell — an enter
        that (past the watermark) evicts exactly one more victim, keeping
        the front ahead: the WHOLE segment evicts-then-refetches.  The
        schedule therefore resolves per segment, not per page: cold cells
        and refetched segments contribute enters in bulk, victims are
        consumed from the LRU queue run-by-run (rank-select over each
        run's live mask — ``directory.take_upto_row``, packed
        ``take_first_k``/``kth_set_index`` kernels on 'pallas'), and once
        the pre-op stream is exhausted the op consumes its own oldest
        touched columns (a prefix, since op ticks ascend with columns).

        ``fetch_flag`` marks which pages charge a fetch when invalid at
        touch time (None = all; writes pass the partial-page mask).
        Returns the fetch-miss count — the caller charges the op's fetch
        messages once, like the batch path.  Traffic is identical to the
        scalar walk cell for cell; clock charges group per victim run
        (allclose vs the reference, bit-equal across drivers since both
        run this same code)."""
        C = int(self.cache_pages)
        base = int(d.base[w])
        c0 = int(p_lo) - base
        n = int(p_hi) - int(p_lo)
        s = slice(c0, c0 + n)
        incache0 = d.incache[w, s].copy()
        valid0 = d.valid[w, s].copy()
        dirty0 = d.dirty[w, s].copy()
        touch0 = d.touch[w, s].copy()
        R0 = int(self.resident[w])
        slack = C - R0
        q = self._lru_q[w]
        pb = self.page_bytes

        # maximal op segments of constant (in-cache, owning run): cold
        # cells key to -1, in-cache cells to their touch tick
        key = np.where(incache0, touch0, np.int64(-1))
        cuts = np.flatnonzero(np.diff(key)) + 1
        seg_lo = np.concatenate(([0], cuts))
        seg_hi = np.concatenate((cuts, [n]))

        evicted_pre = np.zeros(n, bool)   # evicted before their touch
        touch_front = 0
        qi = 0                            # victim stream cursor: run index
        roff = int(q[0][4]) if q else 0   # ... and scan offset within it
        rec = self._danger_rec            # shared-schedule leader run

        def consume(k: int) -> int:
            """Consume k victims from the pre-op stream in tick order,
            applying eviction effects; returns the shortfall once the
            stream is exhausted (consumed from the op's own cells)."""
            nonlocal qi, roff
            while k > 0 and qi < len(q):
                run = q[qi]
                t0r, rg, col0, nr = run[0], run[1], run[2], run[3]
                if roff >= nr:
                    qi += 1
                    roff = int(q[qi][4]) if qi < len(q) else 0
                    continue
                dr = self.dirs[rg]
                cc0 = col0 + (int(dr.shift[w]) - run[5])
                a, b = cc0 + roff, cc0 + nr
                in_op = dr is d and a < c0 + n and b > c0
                if run[6] and not in_op:
                    # pristine, outside the op: a contiguous live prefix
                    take = min(k, nr - roff)
                    if rec is not None:
                        rec["events"].append((qi, np.arange(roff,
                                                            roff + take)))
                    self._evict_now(w, dr, np.arange(a, a + take))
                    k -= take
                    roff += take
                    continue
                live = (np.ones(b - a, bool) if run[6]
                        else (dr.touch[w, a:b] == t0r) & dr.incache[w, a:b])
                if in_op:
                    # cells of the op range already touched are the
                    # newest copies — never pre-op victims
                    opj = np.arange(a - c0, b - c0)
                    stale = (opj >= 0) & (opj < n) & (opj < touch_front)
                    live &= ~stale
                tot = int(live.sum())
                if tot <= k:
                    vc = np.flatnonzero(live) + a
                    if vc.size:
                        if rec is not None:
                            rec["events"].append((qi, vc - cc0))
                        self._evict_now(w, dr, vc)
                        if in_op:
                            ej = vc - c0
                            ej = ej[(ej >= 0) & (ej < n)]
                            evicted_pre[ej] = True
                    k -= tot
                    roff = nr
                    continue
                take_mask, cut = dr.take_upto_row(live, k)
                vc = np.flatnonzero(take_mask) + a
                if rec is not None:
                    rec["events"].append((qi, vc - cc0))
                self._evict_now(w, dr, vc)
                if in_op:
                    ej = vc - c0
                    ej = ej[(ej >= 0) & (ej < n)]
                    evicted_pre[ej] = True
                roff += cut
                k = 0
            return k

        enters = 0
        ev_done = 0
        own_done = 0
        for j0, j1 in zip(seg_lo, seg_hi):
            j0, j1 = int(j0), int(j1)
            if incache0[j0] and not evicted_pre[j0]:
                touch_front = j1          # stale touches: no enters
                continue
            # cold cells, or an in-cache segment whose prefix was already
            # evicted (the refetch cascade claims the whole segment)
            enters += j1 - j0
            target = enters - slack
            if target > ev_done:
                own_done += consume(target - ev_done)
                ev_done = target
            touch_front = j1

        # fetch misses: every cell invalid at its touch (never valid, or
        # evicted mid-op) whose page charges a fetch
        miss = ~valid0 | evicted_pre
        if fetch_flag is not None:
            miss &= fetch_flag
        n_miss = int(miss.sum())
        if n_miss and self.protocol != IDEAL_PROTO:
            self.traffic.page_fetches += n_miss
            self.traffic.fetch_bytes += n_miss * pb

        # final plane state of the op range, then the op's own oldest
        # columns consumed once the stream ran dry (always a prefix — op
        # ticks ascend with columns) evict through the shared `_evict_now`
        # effect sequence, reading their post-touch dirty state (write ops
        # just marked them dirty) straight off the planes
        d.valid[w, s] = True
        d.incache[w, s] = True
        if is_write:
            d.dirty[w, s] = True
            d.maybe_dirty = True
            self._dirty_regions[w].add(region)
        else:
            d.dirty[w, s] = dirty0 & ~evicted_pre
        assert own_done < n, (own_done, n)
        if rec is not None:
            rec.update(qi=qi, roff=roff, evicted_pre=evicted_pre,
                       enters=enters, own_done=own_done, n_miss=n_miss)
        if own_done:
            self._evict_now(w, d, np.arange(c0, c0 + own_done))

        # queue: drop fully-consumed front runs, advance the partial one,
        # append the op's own touch run (its consumed prefix starts dead)
        for _ in range(min(qi, len(q))):
            q.popleft()
        if q:
            if roff >= q[0][3]:       # cursor drained the run exactly
                q.popleft()
            else:
                q[0][4] = roff
        tick = self._q_append(w, region, c0, n, int(d.shift[w]))
        d.touch[w, s] = tick
        if own_done:
            q[-1][4] = own_done
        self.resident[w] += enters     # _evict_now debited every victim
        assert int(self.resident[w]) == min(R0 + enters, C), (
            self.resident[w], R0, enters, C)
        return n_miss

    _DANGER_SHARE_CELLS = 1 << 18

    def _danger_shared(self, rows: np.ndarray, d: RegionDirectory,
                       region: int, ga, lo: np.ndarray, hi: np.ndarray,
                       p_lo: np.ndarray, p_hi: np.ndarray, *,
                       is_write: bool) -> bool:
        """Dedupe lockstep-uniform danger workers into ONE shared
        evict-then-refetch schedule (the rotating-spill steady state:
        every flagged worker's cache state is the same picture shifted to
        its own window).

        Soundness is checked, not assumed: the workers must be
        *isomorphic* — same op geometry, same pre-op valid/incache/dirty
        (and wprot) patterns over the op range, same touch-run boundary
        structure, and structurally identical LRU queues (same run
        lengths/offsets/pristine flags, uniform run-to-op offsets in the
        op's region, identical live and dirty patterns over every run the
        schedule could consume — walked until the guaranteed victim
        supply covers the op's maximal demand).  When the check fails the
        caller falls back to per-worker replays; when it passes, the
        leader runs the ordinary ``_danger_replay`` once with its
        eviction schedule recorded, and every other row applies the
        recorded schedule as batched plane ops with the per-worker charge
        sequence replicated term for term — bit-equal to having replayed
        each worker.  ``stats['danger_shared_ops']`` counts the absorbed
        ops."""
        R = int(rows.size)
        w0 = int(rows[0])
        pw = self.page_words
        L = p_hi[rows] - p_lo[rows]
        n = int(L[0])
        if not (L == n).all() or n == 0:
            return False
        if is_write:
            # uniform page phase => uniform partial-page fetch mask
            if (not (lo[rows] % pw == int(lo[w0]) % pw).all()
                    or not (hi[rows] % pw == int(hi[w0]) % pw).all()
                    or not (hi[rows] - lo[rows]
                            == int(hi[w0]) - int(lo[w0])).all()):
                return False
        if not (self.resident[rows] == self.resident[w0]).all():
            return False
        qs = [self._lru_q[int(w)] for w in rows]
        qlen = len(qs[0])
        if any(len(q) != qlen for q in qs[1:]) or qlen == 0:
            return False
        if not (self._q_degraded[rows] == self._q_degraded[w0]).all():
            return False
        d.ensure_rows(p_lo[rows], p_hi[rows], rows)
        c0 = (p_lo[rows] - d.base[rows]).astype(np.int64)
        ri = rows[:, None]
        colmat = c0[:, None] + np.arange(n)[None, :]
        inc0 = d.incache[ri, colmat]
        val0 = d.valid[ri, colmat]
        dir0 = d.dirty[ri, colmat]
        if ((inc0 != inc0[0]).any() or (val0 != val0[0]).any()
                or (dir0 != dir0[0]).any()):
            return False
        if n > 1:
            t0 = d.touch[ri, colmat]
            if ((np.diff(t0, axis=1) != 0)
                    != (np.diff(t0[0]) != 0)[None, :]).any():
                return False
        wp_faults = 0
        if self._track_wprot:
            wp0 = d.wprot[ri, colmat]
            if (wp0 != wp0[0]).any():
                return False
            wp_faults = int(wp0[0].sum())

        # --- queue walk: verify every run the schedule could consume.
        # The op demands at most n victims; a run's GUARANTEED supply is
        # its live cells outside the op range (in-op cells may go stale
        # first), so once the cumulative guaranteed supply reaches n the
        # schedule provably never looks further.
        need = n
        cum = 0
        cells = n * R
        run_info = []               # per run: (region, members' cc0)
        for j in range(qlen):
            metas = [q[j] for q in qs]
            m0 = metas[0]
            rg, nr, off, pris = m0[1], m0[3], m0[4], m0[6]
            for mm in metas[1:]:
                if (mm[1] != rg or mm[3] != nr or mm[4] != off
                        or mm[6] != pris):
                    return False
            dr = self.dirs[rg]
            cc0 = np.array(
                [metas[i][2] + (int(dr.shift[rows[i]]) - metas[i][5])
                 for i in range(R)], np.int64)
            if rg == region and not ((cc0 - c0) == (cc0[0] - c0[0])).all():
                return False
            run_info.append((rg, cc0))
            ln = nr - off
            if ln <= 0:
                continue
            cells += ln * R
            if cells > self._DANGER_SHARE_CELLS:
                return False
            cm = cc0[:, None] + np.arange(off, nr)[None, :]
            dm = dr.dirty[ri, cm]
            if (dm != dm[0]).any():
                return False
            if rg == region:
                cols0 = cc0[0] + np.arange(off, nr)
                outside = (cols0 < c0[0]) | (cols0 >= c0[0] + n)
            else:
                outside = None
            if pris:
                cum += int(outside.sum()) if outside is not None else ln
            else:
                tks = np.array([metas[i][0] for i in range(R)], np.int64)
                lv = (dr.touch[ri, cm] == tks[:, None]) & dr.incache[ri, cm]
                if (lv != lv[0]).any():
                    return False
                cum += int((lv[0] & outside).sum() if outside is not None
                           else lv[0].sum())
            if cum >= need:
                break

        # --- leader runs the ordinary replay, recording the schedule
        self._danger_rec = rec = {"events": []}
        try:
            if is_write:
                self.write(w0, ga, int(lo[w0]), int(hi[w0]))
            else:
                self.read(w0, ga, int(lo[w0]), int(hi[w0]))
        finally:
            self._danger_rec = None
        self._danger_apply(rows, d, region, lo, hi, p_lo, p_hi, rec,
                           run_info, c0, colmat, dir0[0],
                           wp_faults, is_write=is_write)
        # members resolve vectorized too (the leader's read/write call
        # counted itself): danger_vec semantics — and the committed
        # per-row bench counters — are unchanged by sharing
        self.stats["danger_vec_ops"] += R - 1
        self.stats["danger_shared_ops"] += R
        return True

    def _danger_apply(self, rows: np.ndarray, d: RegionDirectory,
                      region: int, lo, hi, p_lo, p_hi, rec: dict,
                      run_info, c0: np.ndarray, colmat: np.ndarray,
                      dirty0: np.ndarray, wp_faults: int, *,
                      is_write: bool):
        """Apply the leader's recorded schedule to the other isomorphic
        rows as batched plane ops, replicating the per-worker charge
        sequence term for term (see _danger_shared)."""
        m = rows[1:]
        R = int(m.size)
        mi = m[:, None]
        cm_op = colmat[1:]
        n = int(p_hi[rows[0]] - p_lo[rows[0]])
        pb = self.page_bytes
        lat = self.cost.net_latency_s
        bwd = self.cost.net_bw_Bps

        if is_write:
            # write()'s pre-danger charges: instrumented stores, then
            # write faults (wprot cleared over the range)
            if self.model_mechanism and self.protocol == FINE_PROTO:
                self.clock[m] += ((int(hi[rows[0]]) - int(lo[rows[0]]))
                                  * self.instr_s_per_word)
            if self._track_wprot:
                self.clock[m] += wp_faults * self.fault_s
                d.wprot[mi, cm_op] = False
            d.note_dirty(m, p_lo[m], p_hi[m])

        def evict_cols(dr, cols):
            dm = dr.dirty[mi, cols]
            db = int(dm[0].sum())
            assert (dm.sum(axis=1) == db).all(), "isomorphism violated"
            if db:
                r_i, c_i = np.nonzero(dm)
                dr.dirty[m[r_i], cols[r_i, c_i]] = False
                self.traffic.writeback_bytes += db * pb * R
                self.clock[m] += (lat * db + db * pb / bwd)
                if self.chaos is not None:
                    self.clock[m] += self.chaos.retry_rows(m)
                if dr.wprot is not None:
                    dr.wprot[m[r_i], cols[r_i, c_i]] = True
                # sharer invalidation is a proven no-op here: shared
                # danger rows come from the independent set, whose dirty
                # victims no other worker's reach intersects
            dr.valid[mi, cols] = False
            dr.incache[mi, cols] = False
            self.resident[m] -= cols.shape[1]

        for qi_ev, rel in rec["events"]:
            rg, cc0 = run_info[qi_ev]
            evict_cols(self.dirs[rg], cc0[1:][:, None] + rel[None, :])

        # fetch-miss traffic + the op's final plane state
        n_miss = rec["n_miss"]
        if n_miss:
            self.traffic.page_fetches += n_miss * R
            self.traffic.fetch_bytes += n_miss * pb * R
        d.valid[mi, cm_op] = True
        d.incache[mi, cm_op] = True
        if is_write:
            d.dirty[mi, cm_op] = True
            d.maybe_dirty = True
            for w in m:
                self._dirty_regions[w].add(region)
        else:
            d.dirty[mi, cm_op] = (dirty0 & ~rec["evicted_pre"])[None, :]
        own_done = rec["own_done"]
        if own_done:
            evict_cols(d, cm_op[:, :own_done])

        # queue cleanup + the op's own touch run, per row (deques are
        # per-row Python state; O(consumed runs) each)
        qi, roff = rec["qi"], rec["roff"]
        ticks = np.empty(R, np.int64)
        for i, w in enumerate(m):
            q = self._lru_q[w]
            for _ in range(min(qi, len(q))):
                q.popleft()
            if q:
                if roff >= q[0][3]:
                    q.popleft()
                else:
                    q[0][4] = roff
            ticks[i] = self._q_append(int(w), region, int(c0[1 + i]), n,
                                      int(d.shift[w]))
            if own_done:
                q[-1][4] = own_done
        d.touch[mi, cm_op] = ticks[:, None]
        enters = rec["enters"]
        self.resident[m] += enters
        C = int(self.cache_pages)
        assert (self.resident[m] == min(int(self.resident[rows[0]]), C)
                ).all(), "isomorphism violated (resident)"

        # the op's fetch messages, once per worker (read/write charge
        # these after _danger_replay returns)
        if n_miss:
            self.clock[m] += self.cost.xfer_s(
                n_miss * pb, 2 * -(-n_miss // self.fetch_batch))
            if self.chaos is not None:
                self.clock[m] += self.chaos.retry_rows(m)

    def _danger_sig(self, w: int, d: RegionDirectory, lo, hi,
                    p_lo, p_hi, *, is_write: bool) -> tuple:
        """Cheap per-row isomorphism-class key for ``_danger_subgroups``:
        op geometry, occupancy, op-range plane patterns, and the LRU
        queue's run structure (op-region runs keyed by their column
        offset relative to the op — the shift-invariant part of the
        ``_danger_shared`` contract).  Rows with equal keys are only
        *candidates*: ``_danger_shared`` still re-verifies every
        cross-row condition (run dirty/live patterns, the cell budget)
        before any schedule is shared."""
        pw = self.page_words
        p0, p1 = int(p_lo[w]), int(p_hi[w])
        n = p1 - p0
        s = d.sl(w, p0, p1)
        sig: list = [n, int(self.resident[w]), bool(self._q_degraded[w])]
        if is_write:
            sig += [int(lo[w]) % pw, int(hi[w]) % pw,
                    int(hi[w]) - int(lo[w])]
        sig.append(d.incache[w, s].tobytes())
        sig.append(d.valid[w, s].tobytes())
        sig.append(d.dirty[w, s].tobytes())
        if n > 1:
            sig.append((np.diff(d.touch[w, s]) != 0).tobytes())
        if self._track_wprot:
            sig.append(d.wprot[w, s].tobytes())
        c0 = p0 - int(d.base[w])
        for _t0, rg, col0, nr, off, _shift0, pris in self._lru_q[w]:
            cc = col0 + (int(self.dirs[rg].shift[w]) - _shift0)
            sig.append((rg, nr, off, bool(pris),
                        cc - c0 if rg == d.region else -(1 << 30)))
        return tuple(sig)

    def _danger_subgroups(self, drows: np.ndarray, d: RegionDirectory,
                          ga, lo, hi, p_lo, p_hi, *,
                          is_write: bool) -> np.ndarray:
        """The packed multi-row victim scan for danger groups that are
        almost-but-not-quite isomorphic: when the whole-group
        ``_danger_shared`` check fails (typically one clamped or
        phase-skewed row breaking an otherwise-lockstep group),
        partition the rows into candidate classes by ``_danger_sig``
        and let every class of >= 2 rows attempt the shared schedule on
        its own.  Only rows whose class is a singleton — or fails the
        full cross-row re-verification — drop to per-worker replay.
        Returns those residual rows, ascending.  Exact for the same
        reason the split itself is: the rows are proven independent, so
        subgroup replay order is interchangeable, and each subgroup's
        shared schedule is bit-equal to its per-worker replays."""
        groups: Dict[tuple, List[int]] = {}
        d.ensure_rows(p_lo[drows], p_hi[drows], drows)
        for w in drows.tolist():
            groups.setdefault(self._danger_sig(w, d, lo, hi, p_lo, p_hi,
                                               is_write=is_write),
                              []).append(w)
        resid: List[int] = []
        for ws in groups.values():
            grp = np.asarray(ws, np.int64)
            # a class spanning the whole group IS the attempt that just
            # failed — re-running it cannot succeed
            if (2 <= grp.size < drows.size
                    and self._danger_shared(grp, d, d.region, ga, lo, hi,
                                            p_lo, p_hi,
                                            is_write=is_write)):
                self.stats["danger_subgroup_ops"] += int(grp.size)
                continue
            resid.extend(ws)
        resid.sort()
        return np.asarray(resid, np.int64)

    def _maybe_evict(self, w: int):
        """Watermark-triggered batched eviction: no per-op work unless the
        occupancy counter crossed ``cache_pages``; then the oldest pages
        (exact LRU via monotone ticks) are evicted in one queue pass."""
        if self.cache_pages is None or self.resident[w] <= self.cache_pages:
            return
        self._evict_cells(w, int(self.resident[w]) - self.cache_pages)

    # ------------------------------------------------------------------
    # reads / writes (interval API)
    # ------------------------------------------------------------------

    def read(self, w: int, ga: GasArray, lo: int, hi: int):
        region = self._region_of(ga.page_lo)
        p_lo = ga.page_lo + lo // self.page_words
        p_hi = ga.page_lo + (max(hi - 1, lo)) // self.page_words + 1
        if self.detect_races and not self._race_suspend:
            # the DECLARED range only — prefetch is a cache artifact, not
            # an access, so it must not create happens-before obligations
            self._race_access(w, region, p_lo, p_hi, False)
        arr_end = ga.page_lo + -(-ga.n_elems // self.page_words)
        p_hi_pf = min(p_hi + self.prefetch, arr_end)   # sequential prefetch
        p_hi = max(p_hi_pf, p_hi)
        if self.cache_pages is not None:
            d = self.dirs[region]
            d.ensure(w, p_lo, p_hi)
            s = d.sl(w, p_lo, p_hi)
            n = p_hi - p_lo
            n_enter = n - int(d.incache[w, s].sum())
            if self._danger(w, n_enter, n):
                if self.danger_mode == "vec" and self.cache_pages >= 1:
                    self.stats["danger_vec_ops"] += 1
                    n_miss = self._danger_replay(w, d, region, p_lo, p_hi,
                                                 None, is_write=False)
                else:
                    self.stats["danger_scalar_ops"] += 1
                    n_miss = 0
                    for p in range(p_lo, p_hi):
                        n_miss += self._touch_page_exact(w, d, p, fetch=True)
                if n_miss:
                    self._net(w, n_miss * self.page_bytes,
                              2 * -(-n_miss // self.fetch_batch))
                return None
        self._fetch_range(w, region, p_lo, p_hi)
        self._maybe_evict(w)
        return None

    def write(self, w: int, ga: GasArray, lo: int, hi: int, values=None):
        region = self._region_of(ga.page_lo)
        p_lo = ga.page_lo + lo // self.page_words
        p_hi = ga.page_lo + (max(hi - 1, lo)) // self.page_words + 1
        if self.detect_races and not self._race_suspend:
            self._race_access(w, region, p_lo, p_hi, True)
        d = self.dirs[region]
        d.ensure(w, p_lo, p_hi)
        in_span = bool(self.spans[w])
        if not in_span:
            d.note_dirty(w, p_lo, p_hi)
        n_words = hi - lo

        # mechanism cost: instrumented stores (fine) / write faults (page)
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[w] += n_words * self.instr_s_per_word
        if self._track_wprot:
            s = d.sl(w, p_lo, p_hi)
            n_faults = int(d.wprot[w, s].sum())
            self.clock[w] += n_faults * self.fault_s
            d.wprot[w, s] = False

        if self.cache_pages is not None and self.protocol != IDEAL_PROTO:
            s = d.sl(w, p_lo, p_hi)
            n = p_hi - p_lo
            n_enter0 = n - int(d.incache[w, s].sum())
            if self._danger(w, n_enter0, n):
                if (self.danger_mode == "vec" and self.cache_pages >= 1
                        and not in_span):
                    # danger-flagged in-span writes keep the exact
                    # per-page LRU walk (critical sections touch few
                    # pages; their intervals still land in the span
                    # planes in one note after the walk)
                    self.stats["danger_vec_ops"] += 1
                    pages = np.arange(p_lo, p_hi)
                    bw_ = (pages - ga.page_lo) * self.page_words
                    wlo_v = np.maximum(lo - bw_, 0)
                    whi_v = np.minimum(hi - bw_, self.page_words)
                    n_miss = self._danger_replay(
                        w, d, region, p_lo, p_hi,
                        (whi_v - wlo_v) < self.page_words, is_write=True)
                    if n_miss:
                        self._net(w, n_miss * self.page_bytes,
                                  2 * -(-n_miss // self.fetch_batch))
                    return
                # exact per-page replica of the reference's write-allocate +
                # LRU sequence (see _danger)
                self.stats["danger_scalar_ops"] += 1
                span = self.spans[w][-1] if in_span else None
                base = int(d.base[w])
                n_miss = 0
                for p in range(p_lo, p_hi):
                    wlo, whi = ga.word_range_in_page(p, lo, hi)
                    n_miss += self._touch_page_exact(
                        w, d, p, fetch=(whi - wlo) < self.page_words)
                    if in_span:
                        if not span.plane:
                            old = span.touched.get(p)
                            span.touched[p] = ((min(wlo, old[0]),
                                                max(whi, old[1]))
                                               if old else (wlo, whi))
                    else:
                        d.dirty[w, p - base] = True
                        d.maybe_dirty = True
                        self._dirty_regions[w].add(region)
                if in_span and span.plane:
                    # interval merge is order-insensitive and eviction
                    # never reads the span planes, so one note after the
                    # exact per-page walk is equivalent
                    self._span_note(w, span, d, region, ga, lo, hi,
                                    p_lo, p_hi)
                if n_miss:
                    self._net(w, n_miss * self.page_bytes,
                              2 * -(-n_miss // self.fetch_batch))
                return

        # write-allocate: partial edge pages must be fetched; interior
        # full-page writes just become valid
        if self.protocol != IDEAL_PROTO:
            if p_hi - p_lo == 1:
                if n_words < self.page_words:
                    self._fetch_range(w, region, p_lo, p_lo + 1)
            else:
                if lo % self.page_words != 0:
                    self._fetch_range(w, region, p_lo, p_lo + 1)
                if hi % self.page_words != 0:
                    self._fetch_range(w, region, p_hi - 1, p_hi)
        s = d.sl(w, p_lo, p_hi)
        n = p_hi - p_lo
        n_new = n - int(d.valid[w, s].sum())
        if d.touch is not None:
            d.touch[w, s] = self._q_append(w, region, s.start, n,
                                           int(d.shift[w]))
            n_enter = n - int(d.incache[w, s].sum())
            if n_enter:
                d.incache[w, s] = True
                self.resident[w] += n_enter
        if n_new:
            d.valid[w, s] = True

        if in_span:
            span = self.spans[w][-1]
            if span.plane:
                self._span_note(w, span, d, region, ga, lo, hi, p_lo, p_hi)
            else:
                for p in range(p_lo, p_hi):
                    wlo, whi = ga.word_range_in_page(p, lo, hi)
                    old = span.touched.get(p)
                    span.touched[p] = ((min(wlo, old[0]), max(whi, old[1]))
                                       if old else (wlo, whi))
        else:
            d.dirty[w, s] = True
            d.maybe_dirty = True
            self._dirty_regions[w].add(region)
        self._maybe_evict(w)

    # ------------------------------------------------------------------
    # ordinary flush (page granularity in both protocols)
    # ------------------------------------------------------------------

    def _invalidate_sharers(self, w: int, region: int, pages: np.ndarray):
        """Invalidate every other worker's valid copy of ``pages``.

        Small page sets (accumulator pages, many overlapping rows) use one
        dense boolean-mask gather over the worker axis; wide page sets
        (block flushes — few overlapping neighbours, thousands of pages)
        intersect each row's window with the sorted page list instead, so
        work tracks actual coverage rather than rows x pages."""
        d = self.dirs[region]
        rows = d.overlap_rows(int(pages[0]), int(pages[-1]) + 1, exclude=w)
        if rows.size == 0:
            return
        if pages.size <= 64:
            hit, cols = d.gather_valid(rows, pages)
            n_inv = int(hit.sum())
            if n_inv:
                # valid drops but the pages keep their cache slots
                # (``incache``) until evicted, like the reference's LRU dict
                d.clear_valid_cells(rows, cols, hit)
                self.traffic.invalidations += n_inv
                self.traffic.control_msgs += n_inv
                if self.chaos is not None:
                    self.chaos.inval_msgs(n_inv)
            return
        n_inv = 0
        for v in rows:
            b = int(d.base[v])
            i0 = int(np.searchsorted(pages, b))
            i1 = int(np.searchsorted(pages, b + int(d.length[v])))
            if i0 >= i1:
                continue
            cols = pages[i0:i1] - b
            vcells = d.valid[v, cols]
            k = int(vcells.sum())
            if k:
                d.valid[v, cols[vcells]] = False
                n_inv += k
        if n_inv:
            self.traffic.invalidations += n_inv
            self.traffic.control_msgs += n_inv
            if self.chaos is not None:
                self.chaos.inval_msgs(n_inv)

    def _flush_worker(self, w: int):
        """Write back + invalidate sharers for all of w's ordinary-dirty
        pages (the single-flusher path used by acquire)."""
        regions = self._dirty_regions[w]
        if not regions:
            return
        for region in sorted(regions):
            d = self.dirs[region]
            cols = d.row_dirty_cols(w)
            d.clear_dirty_bounds(w)
            if cols.size == 0:
                continue
            d.dirty[w, cols] = False
            if self.protocol == IDEAL_PROTO:
                continue
            n_dirty = cols.size
            self.traffic.writeback_bytes += n_dirty * self.page_bytes
            self._net(w, n_dirty * self.page_bytes,
                      -(-n_dirty // self.fetch_batch))   # batched writeback
            if d.wprot is not None:
                d.wprot[w, cols] = True     # re-arm write protection
            self._invalidate_sharers(w, region, d.base[w] + cols)
        regions.clear()

    def _flush_all_workers(self, mask: Optional[np.ndarray] = None):
        """Batched flush of every (masked) worker's ordinary-dirty pages,
        in one pass per region that reproduces the sequential flush-order
        semantics analytically (see DIRECTORY.md):

        for a page with dirty-worker set D (flushed in worker order) and
        initial valid set V, the sequential per-worker flushes produce
        ``|V \\ {d0}| + [|D|>1]*[d0 in V]`` invalidations and leave the page
        valid only at d0 when ``|D|==1``.  Pages covered by a single worker
        window contribute nothing (their only possible sharer is their own
        writer), so the gather runs only over multiply-covered pages.

        ``mask`` restricts the flush to a (W,) bool subset of workers —
        span_all's hoisted flush phase; unmasked workers' dirty state and
        bounds are left untouched.  ``None`` flushes everyone (barrier).
        Charge expressions equal the single-worker ``_flush_worker`` term
        for term, so hoisting a worker's flush out of its acquire keeps
        clocks bit-equal to the per-worker span loop.
        """
        mrows = None if mask is None else np.nonzero(mask)[0]
        # 'pallas-jit': run the whole flush chain — per-row popcount,
        # shared-interval coverage stab, sharer-candidate mask — for ALL
        # dirty regions as ONE fused device dispatch, then consume its
        # outputs region by region below.  Charging, wprot re-arm and the
        # analytic invalidation stay host-side (they are cheap and carry
        # the exactness contract), so traffic/clocks are bit-equal to the
        # unfused path by construction.  IDEAL skips sharer work entirely
        # and keeps the short-circuit path.
        jit_counts = jit_shared = None
        ji = 0
        if self.backend == "pallas-jit" and self.protocol != IDEAL_PROTO:
            cand = [d for d in self.dirs if d.maybe_dirty and d.cap > 0]
            if cand:
                jit_counts, jit_shared = self._jit_flush_chain(cand, mask)
        for d in self.dirs:
            if not d.maybe_dirty:
                continue
            if jit_counts is not None and d.cap > 0:
                nD_w = jit_counts[ji]      # fused chain output
                sub_bits = jit_shared[ji]
                ji += 1
            else:
                nD_w = d.dirty_counts()    # bitmask popcount on 'pallas'
                sub_bits = None
            if mask is not None:
                rest = int(nD_w[~mask].sum())
                nD_w = np.where(mask, nD_w, 0)
            total = int(nD_w.sum())
            d.maybe_dirty = False if mask is None else rest > 0
            d.clear_dirty_bounds(mrows)
            if total == 0:
                continue
            if self.protocol == IDEAL_PROTO:
                if mask is None:
                    d.dirty[:] = False
                else:
                    d.dirty[mrows] = False
                continue
            active = np.nonzero(nD_w)[0]
            # per-(worker, region) writeback charge, as in the sequential
            # flush: one batched message group per worker window
            self.traffic.writeback_bytes += total * self.page_bytes
            msgs = -(-nD_w[active] // self.fetch_batch)
            self.clock[active] += (self.cost.net_latency_s * msgs
                                   + (nD_w[active] * self.page_bytes)
                                   / self.cost.net_bw_Bps)
            if self.chaos is not None:
                self.clock[active] += self.chaos.retry_rows(active)
            if d.wprot is not None:
                if mask is None:
                    np.logical_or(d.wprot, d.dirty, out=d.wprot)  # re-arm own
                else:
                    d.wprot[active] |= d.dirty[active]
            # sharer invalidation: only pages under >= 2 worker windows can
            # have sharers, so per-cell work is confined to the (small)
            # halo/global intervals instead of every dirty page
            if sub_bits is not None:
                # fused chain already intersected dirty & multi-coverage &
                # active-row on device; row-major nonzero over the active
                # rows reproduces the sequential worker-major /
                # column-ascending flush order exactly
                from repro.kernels.protocol_sweep import unpack_mask_rows
                sub = unpack_mask_rows(sub_bits[active], int(d.cap))
                ai, cols = np.nonzero(sub)
                if ai.size:
                    self._invalidate_shared_dirty(
                        d, active[ai].astype(np.int64),
                        cols.astype(np.int64))
            else:
                starts, ends = d.shared_intervals()
                if starts.size:
                    w_list, col_list = [], []
                    for w in active:
                        b = int(d.base[w])
                        e = b + int(d.length[w])
                        i0 = int(np.searchsorted(ends, b, "right"))
                        i1 = int(np.searchsorted(starts, e, "left"))
                        for i in range(i0, i1):
                            lo = max(int(starts[i]), b)
                            hi = min(int(ends[i]), e)
                            if lo >= hi:
                                continue
                            c = np.nonzero(d.dirty[w, lo - b:hi - b])[0]
                            if c.size:
                                col_list.append(c + (lo - b))
                                w_list.append(np.full(c.size, w, np.int64))
                    if col_list:
                        w_idx = np.concatenate(w_list)  # ascending worker
                        cols = np.concatenate(col_list)  # == seq. order
                        self._invalidate_shared_dirty(d, w_idx, cols)
            if mask is None:
                d.dirty[:] = False
            else:
                d.dirty[active] = False
        if mask is None:
            for regions in self._dirty_regions:
                regions.clear()
        else:
            for w in mrows:
                self._dirty_regions[w].clear()

    def _jit_flush_chain(self, cand, mask: Optional[np.ndarray]):
        """Stack every dirty region's packed dirty plane + cached int32
        window geometry into one (R, W, nw) batch and run the fused
        barrier-flush chain (``kernels.phase_step``) as a single jitted
        device dispatch.  Returns ``(counts, shared)`` — per-region
        per-row UNMASKED dirty counts (the caller applies ``mask`` for
        the ``rest`` bookkeeping, exactly as the unfused path) and packed
        shared-dirty candidate masks (dirty & >=2-coverage & active row).
        Returns ``(None, None)`` when page ids could overflow the int32
        device arithmetic — the caller falls back to the unfused sweep."""
        from repro.kernels import protocol_sweep as _ps
        R, W = len(cand), self.W
        nw_max = max(-(-int(d.cap) // 32) for d in cand)
        # page = base + col with col < nw_max*32; bound it in int32 (pads
        # are INT32_MAX and must stay strictly above every probed page)
        if max(int(d.page_hi) for d in cand) + nw_max * 32 >= (1 << 31) - 1:
            return None, None
        i32max = np.iinfo(np.int32).max
        bits = np.zeros((R, W, nw_max), np.uint32)
        base32 = np.empty((R, W), np.int32)
        sbs = np.full((R, W), i32max, np.int32)
        ses = np.full((R, W), i32max, np.int32)
        for i, d in enumerate(cand):
            pk = _ps.pack_mask_rows(d.dirty)
            bits[i, :, :pk.shape[1]] = pk
            b32, sb, se = d.jit_geometry()
            base32[i] = b32
            sbs[i, :sb.size] = sb
            ses[i, :se.size] = se
        rowmask = (np.ones((R, W), bool) if mask is None
                   else np.broadcast_to(mask, (R, W)))
        counts, shared = _ps.phase_step(bits, base32, rowmask, sbs, ses,
                                        stats=self.stats)
        return counts, shared

    def _invalidate_shared_dirty(self, d: RegionDirectory,
                                 w_idx: np.ndarray, cols: np.ndarray):
        """Apply the analytic sequential-flush invalidation to the dirty
        cells (worker-major order) of multiply-covered pages.

        The gather is sparse: worker windows are intervals, so each row
        sees only a contiguous slice of the page list ``u`` — total
        (row, page) pairs ~ the actual window coverage, not rows x pages
        (a dense gather over block-partitioned arrays touches W x |u|
        cells to find ~2 live ones per page)."""
        pages = d.base[w_idx] + cols
        u, first, counts = np.unique(pages, return_index=True,
                                     return_counts=True)
        d0_rows = w_idx[first]                # min dirty worker per page
        d0_valid = d.valid[d0_rows, cols[first]]
        rows = d.overlap_rows(int(u[0]), int(u[-1]) + 1)
        pr_l, pu_l, pc_l = [], [], []
        for w in rows:
            b = int(d.base[w])
            i0 = int(np.searchsorted(u, b))
            i1 = int(np.searchsorted(u, b + int(d.length[w])))
            if i0 < i1:
                pr_l.append(np.full(i1 - i0, w, np.int64))
                pu_l.append(np.arange(i0, i1))
                pc_l.append(u[i0:i1] - b)
        pr = np.concatenate(pr_l)             # pair: worker row
        pu = np.concatenate(pu_l)             # pair: index into u
        pc = np.concatenate(pc_l)             # pair: column in row
        val = d.valid[pr, pc]
        nV0 = np.bincount(pu[val], minlength=u.size)
        d0v = d0_valid.astype(np.int64)
        n_inv = int((nV0 - d0v + np.where(counts > 1, d0v, 0)).sum())
        if n_inv:
            self.traffic.invalidations += n_inv
            self.traffic.control_msgs += n_inv
            if self.chaos is not None:
                self.chaos.inval_msgs(n_inv)
        # final valid state: keep only a sole dirty writer's copy
        keep = (counts == 1)[pu] & (pr == d0_rows[pu])
        hot = val & ~keep
        if hot.any():
            d.valid[pr[hot], pc[hot]] = False

    # ------------------------------------------------------------------
    # spans + notice replay
    # ------------------------------------------------------------------

    def _span_note(self, w: int, span: _Span, d: RegionDirectory,
                   region: int, ga, lo: int, hi: int, p_lo: int, p_hi: int):
        """Record one in-span write's per-page word intervals in the span
        planes (plane-tracked spans only): the vectorized replacement for
        the per-page ``span.touched`` dict merge."""
        b = span.bounds.get(region)
        if b is None:
            span.bounds[region] = [p_lo, p_hi]
        else:
            if p_lo < b[0]:
                b[0] = p_lo
            if p_hi > b[1]:
                b[1] = p_hi
        d.ensure_span()
        if p_hi - p_lo == 1:
            wlo, whi = ga.word_range_in_page(p_lo, lo, hi)
            d.span_note(w, p_lo, p_hi, wlo, whi)
            return
        bw_ = (np.arange(p_lo, p_hi) - ga.page_lo) * self.page_words
        d.span_note(w, p_lo, p_hi, np.maximum(lo - bw_, 0),
                    np.minimum(hi - bw_, self.page_words))

    def _replay_invalidate(self, w: int, pages: np.ndarray, rearm: bool):
        """Page-protocol notice replay: invalidate w's valid copies of
        ``pages`` (grouped per region), returning the number invalidated."""
        total = 0
        regions = np.searchsorted(self._region_starts_np, pages, "right") - 1
        for r in np.unique(regions):
            d = self.dirs[int(r)]
            if d.base[w] < 0:
                continue
            pr = pages[regions == r]
            cols = pr - d.base[w]
            inr = (cols >= 0) & (cols < d.length[w])
            vcells = d.valid[w, np.where(inr, cols, 0)] & inr
            n = int(vcells.sum())
            if n:
                hot = cols[vcells]
                d.valid[w, hot] = False
                if rearm and d.wprot is not None:
                    d.wprot[w, hot] = True
                total += n
        return total

    def acquire(self, w: int, lock_id: int):
        lk = self.locks.setdefault(lock_id, _Lock(self.W))
        self._flush_worker(w)                       # RegC rule 1
        self._net(w, 64, 2)
        self.traffic.control_msgs += 2
        self.clock[w] = max(self.clock[w], lk.last_release_time)
        # RegC rule 2, notices coalesced per page (matches reference)
        u, lo_u, hi_u = lk.log.pending(int(lk.seen[w]), lk.version)
        if u.size:
            if self.protocol == FINE_PROTO:
                nbytes = (hi_u - lo_u) * _WORD + self.page_words // 8
                tot = int(nbytes.sum())
                self.traffic.diff_bytes += tot
                self.clock[w] += (self.cost.net_latency_s * u.size
                                  + tot / self.cost.net_bw_Bps)
                if self.chaos is not None:
                    self.clock[w] += self.chaos.retry1(w)
            else:
                n_inv = self._replay_invalidate(
                    w, u, rearm=self.model_mechanism)
                self.traffic.invalidations += n_inv
                self.traffic.control_msgs += int(u.size)
                if self.chaos is not None:
                    self.chaos.inval_msgs(n_inv)
        lk.seen[w] = lk.version
        if self.detect_races and not self._race_suspend:
            # acquire happens-after every release of this lock: join the
            # lock's vector clock into the acquirer's view
            np.maximum(self.race_vc[w], lk.race_vc, out=self.race_vc[w])
        self.spans[w].append(_Span(lock_id, plane=not self.spans[w]))

    def _span_harvest(self, w: int, span: _Span):
        """The release-publish payload of ``span`` — (pages, los, his)
        ascending by page — from the span planes (plane-tracked spans;
        cells reset for the next span) or the per-page dict (nested
        spans).  Region order is page order, so multi-region harvests
        concatenate already sorted."""
        if span.plane:
            parts = [self.dirs[region].span_harvest(w, lo_b, hi_b)
                     for region, (lo_b, hi_b) in sorted(span.bounds.items())]
            if not parts:
                z = np.zeros(0, np.int64)
                return z, z, z
            if len(parts) == 1:
                return parts[0]
            return tuple(np.concatenate([p[i] for p in parts])
                         for i in range(3))
        items = sorted(span.touched.items())
        return (np.array([p for p, _ in items], np.int64),
                np.array([iv[0] for _, iv in items], np.int64),
                np.array([iv[1] for _, iv in items], np.int64))

    def _span_publish(self, w: int, lk: _Lock, pages: np.ndarray,
                      los: np.ndarray, his: np.ndarray):
        """Release-time publish: traffic + ONE batched clock charge for
        the span's coalesced page intervals (the reference charges one
        message per page; the batch groups them — allclose, and bit-equal
        across drivers since every release runs this same code), then one
        log append for the whole version."""
        n = int(pages.size)
        if n:
            if self.protocol == FINE_PROTO:
                tot = (int((his - los).sum()) * _WORD
                       + n * (self.page_words // 8))
                self.traffic.diff_bytes += tot
            else:
                tot = n * self.page_bytes
                self.traffic.writeback_bytes += tot
            self.clock[w] += (self.cost.net_latency_s * n
                              + tot / self.cost.net_bw_Bps)
            if self.chaos is not None:
                self.clock[w] += self.chaos.retry1(w)
        lk.log.append_version(pages, los, his)
        lk.version += 1
        lk.seen[w] = lk.version

    def release(self, w: int, lock_id: int):
        span = self.spans[w].pop()
        assert span.lock == lock_id, "unbalanced lock release"
        lk = self.locks[lock_id]
        if self.protocol != IDEAL_PROTO:
            self._span_publish(w, lk, *self._span_harvest(w, span))
        elif span.plane:
            # IDEAL publishes nothing, but the planes must reset
            for region, (lo_b, hi_b) in span.bounds.items():
                self.dirs[region].span_harvest(w, lo_b, hi_b)
        self._net(w, 64, 1)
        self.traffic.control_msgs += 1
        lk.last_release_time = self.clock[w]
        if self.detect_races and not self._race_suspend:
            # publish the releaser's view into the lock, then open a new
            # epoch so later accesses are not ordered under this release
            np.maximum(lk.race_vc, self.race_vc[w], out=lk.race_vc)
            self.race_vc[w, w] += 1

    class _SpanCtx:
        def __init__(self, rt, w, lock_id):
            self.rt, self.w, self.lock_id = rt, w, lock_id

        def __enter__(self):
            self.rt.acquire(self.w, self.lock_id)

        def __exit__(self, *exc):
            self.rt.release(self.w, self.lock_id)
            return False

    def span(self, w: int, lock_id: int):
        return self._SpanCtx(self, w, lock_id)

    # ------------------------------------------------------------------
    # race detection (detect_races mode; pure observer — touches only
    # race_vc / lock race_vc / the directory race planes / self.races,
    # never traffic, clocks, windows beyond what the op itself ensures,
    # or any protocol plane.  See DIRECTORY.md "Race-detection contract".
    # ------------------------------------------------------------------

    def _race_record(self, p: int, w: int, u: int, kind: str):
        a, b = (w, u) if w < u else (u, w)
        t = (p, a, b, kind)
        if t not in self.races:
            self.races.add(t)
            self.stats["race_" + kind] += 1

    def _race_access(self, w: int, region: int, p_lo: int, p_hi: int,
                     is_write: bool):
        """Check-then-record one worker's declared page range: flag every
        (page, other-worker) recorded epoch not ordered before w's view,
        then stamp w's current epoch into the matching plane.  The check
        is ``RegionDirectory.race_hits`` — row-screened on window overlap
        and recorded maxima, so a quiet check is O(W), not a (W, pages)
        gather."""
        d = self.dirs[region]
        d.ensure_race()
        d.ensure(w, p_lo, p_hi)
        vcw = self.race_vc[w]
        ui, pi = d.race_hits(p_lo, p_hi, vcw, True)
        for u, p in zip(ui.tolist(), pi.tolist()):
            self._race_record(p, w, u, "ww" if is_write else "rw")
        if is_write:
            ui, pi = d.race_hits(p_lo, p_hi, vcw, False)
            for u, p in zip(ui.tolist(), pi.tolist()):
                self._race_record(p, w, u, "rw")
        d.race_note(w, p_lo, p_hi, int(vcw[w]), is_write)

    def _race_op_all(self, ga, lo: np.ndarray, hi: np.ndarray,
                     is_write: bool):
        """Batched detection of one phase op across all workers.  Fast
        path: when the region's recorded-epoch maxima are all ordered
        under the phase's minimum vector-clock view (no cross-phase
        check can fire) and write ranges are pairwise disjoint (no
        same-phase pair), recording collapses to one plane scatter.
        Otherwise fall to the per-worker check — whose result is
        processing-order independent (a peer's current epoch is never
        visible in another row's clock until its next release), so
        op-major here matches the loop driver's worker-major order."""
        pw = self.page_words
        region = self._region_of(ga.page_lo)
        d = self.dirs[region]
        p_lo = ga.page_lo + lo // pw
        p_hi = ga.page_lo + np.maximum(hi - 1, lo) // pw + 1
        vc = self.race_vc
        cross = False
        if d.race_w is not None:
            vcmin = vc.min(axis=0)
            cross = bool((d.race_maxw > vcmin).any())
            if is_write and not cross:
                cross = bool((d.race_maxr > vcmin).any())
        overlap = False
        if is_write and not cross:
            order = np.argsort(p_lo, kind="stable")
            run_hi = np.maximum.accumulate(p_hi[order])[:-1]
            overlap = bool((run_hi > p_lo[order][1:]).any())
        if cross or overlap:
            for w in range(self.W):
                self._race_access(w, region, int(p_lo[w]), int(p_hi[w]),
                                  is_write)
        else:
            d.ensure_race()
            d.ensure_rows(p_lo, p_hi, self._rows_all)
            d.race_note_rows(self._rows_all, p_lo, p_hi,
                             vc.diagonal(), is_write)

    def _race_phase_all(self, reads, writes):
        """End-of-phase batched detection over the declared op ranges —
        vector clocks are static inside a phase and page-granular
        flagging is order independent, so one uniform pass here covers
        every engine path (batched rows, danger rows, shared-schedule
        members, residual replays) exactly once."""
        for ga, lo, hi in reads:
            self._race_op_all(ga, lo, hi, False)
        for ga, lo, hi in writes:
            self._race_op_all(ga, lo, hi, True)

    def _race_span_all(self, rows: np.ndarray, locks: np.ndarray,
                       reads, writes):
        """End-of-span_all detection: replay each lock group's grant
        chain (workers ascending — the engine's grant order in both the
        analytic and serial paths) through the scalar acquire/access/
        release detector.  Group processing order is immaterial: rows
        and lock clocks are disjoint across groups, and cross-group
        same-call accesses can never be happens-before ordered."""
        pw = self.page_words
        vc = self.race_vc
        for lk_id in np.unique(locks[rows]):
            lk = self.locks[int(lk_id)]
            for w in rows[locks[rows] == lk_id].tolist():
                np.maximum(vc[w], lk.race_vc, out=vc[w])
                for ops, is_write in ((reads, False), (writes, True)):
                    for ga, lo, hi in ops:
                        region = self._region_of(ga.page_lo)
                        lo_w, hi_w = int(lo[w]), int(hi[w])
                        p_lo = ga.page_lo + lo_w // pw
                        p_hi = ga.page_lo + max(hi_w - 1, lo_w) // pw + 1
                        self._race_access(w, region, p_lo, p_hi, is_write)
                np.maximum(lk.race_vc, vc[w], out=lk.race_vc)
                vc[w, w] += 1

    @property
    def race_counts(self) -> Dict[str, int]:
        return {"race_ww": self.stats["race_ww"],
                "race_rw": self.stats["race_rw"]}

    # ------------------------------------------------------------------
    # batched SPMD driver fast path
    # ------------------------------------------------------------------

    def phase(self, w: int, reads=(), writes=(), *, flops: float = 0.0,
              mem_bytes: float = 0.0, seconds: float = 0.0,
              instr_words: float = 0.0):
        """One worker-phase in a single runtime call: interval reads, then
        interval writes, then the modeled compute + instrumented stores.
        ``reads``/``writes`` are sequences of ``(ga, lo, hi)``.  This is
        the per-worker reference path that ``phase_all`` batches over the
        worker axis (and through which it replays the residual
        interacting workers of eviction-capable phases)."""
        for ga, lo, hi in reads:
            self.read(w, ga, lo, hi)
        for ga, lo, hi in writes:
            self.write(w, ga, lo, hi)
        if flops or mem_bytes or seconds:
            self.compute(w, flops=flops, mem_bytes=mem_bytes, seconds=seconds)
        if instr_words:
            self.instr_stores(w, instr_words)

    # ------------------------------------------------------------------
    # worker-axis batched driver (phase_all)
    # ------------------------------------------------------------------

    def _w_arr(self, v) -> np.ndarray:
        return np.broadcast_to(np.asarray(v, np.int64), (self.W,))

    def _page_range_all(self, ga, lo: np.ndarray, hi: np.ndarray, *,
                        prefetch: bool):
        pw = self.page_words
        p_lo = ga.page_lo + lo // pw
        p_hi = ga.page_lo + np.maximum(hi - 1, lo) // pw + 1
        if prefetch:
            arr_end = ga.page_lo + -(-ga.n_elems // pw)
            p_hi = np.maximum(np.minimum(p_hi + self.prefetch, arr_end), p_hi)
        return self._region_of(int(ga.page_lo)), p_lo, p_hi

    def _may_evict_mask(self, ranges) -> Optional[np.ndarray]:
        """Per-worker eviction-possibility upper bound for one phase (the
        per-worker refinement of the old all-or-nothing ``_phase_fits``
        precheck): every page that can newly occupy a cache slot this
        phase is not-incache at phase start and lies in some declared
        range, so ``resident + sum over ops of (range length - in-cache
        count)`` bounds each worker's peak occupancy (overlapping ranges
        only loosen the bound).  Returns None when no worker can cross
        the watermark — the phase then runs fully batched with no
        eviction work at all."""
        if self.cache_pages is None:
            return None
        quick = self.resident.copy()
        for region, p_lo, p_hi in ranges:
            quick += p_hi - p_lo
        if (quick <= self.cache_pages).all():
            return None            # even all-cold ranges fit: no gathers
        ub = self.resident.copy()
        for region, p_lo, p_hi in ranges:
            d = self.dirs[region]
            ub += (p_hi - p_lo) - d.count_range(d.incache, p_lo, p_hi)
        may = ub > self.cache_pages
        return may if may.any() else None

    def _residual_workers(self, rranges, wranges,
                          may: np.ndarray) -> np.ndarray:
        """Window-disjointness analysis: which workers' phase executions
        can interact through eviction.

        Within a phase (no barriers, no spans) the ONLY cross-worker
        effect is an eviction writeback invalidating another worker's
        valid copy of the victim page — and only ``may``-workers can
        evict.  An evictor's dirty victims lie inside its conservative
        dirty bounds (the directory's per-row dirty bounding interval,
        widened by this phase's declared write ranges); another worker can
        observe the writeback only if those pages intersect its *reach*
        (current window + declared ranges: valid copies exist only inside
        the window, and this phase fetches only inside the ranges).
        Workers touched by no such intersection are pairwise independent
        — their per-worker op sequences commute, so they run batched.
        The returned mask marks the rest, which replay tick-ordered."""
        resid = np.zeros(self.W, bool)
        reach: Dict[int, list] = {}
        for region, p_lo, p_hi in rranges + wranges:
            r = reach.get(region)
            if r is None:
                reach[region] = [p_lo.copy(), p_hi.copy()]
            else:
                np.minimum(r[0], p_lo, out=r[0])
                np.maximum(r[1], p_hi, out=r[1])
        wr: Dict[int, list] = {}
        for region, p_lo, p_hi in wranges:
            r = wr.get(region)
            if r is None:
                wr[region] = [p_lo.copy(), p_hi.copy()]
            else:
                np.minimum(r[0], p_lo, out=r[0])
                np.maximum(r[1], p_hi, out=r[1])
        imax = np.iinfo(np.int64).max
        imin = np.iinfo(np.int64).min
        for ri, d in enumerate(self.dirs):
            dlo, dhi = d.dirty_lo, d.dirty_hi
            if ri in wr:
                dlo = np.minimum(dlo, wr[ri][0])
                dhi = np.maximum(dhi, wr[ri][1])
            e = may & (dlo < dhi)
            if not e.any():
                continue
            live = d.base >= 0
            rlo = np.where(live, d.base, imax)
            rhi = np.where(live, d.base + d.length, imin)
            if ri in reach:
                rlo = np.minimum(rlo, reach[ri][0])
                rhi = np.maximum(rhi, reach[ri][1])
                live = np.ones(self.W, bool)
            E = np.nonzero(e)[0]
            M = ((rlo[None, :] < dhi[E][:, None])
                 & (rhi[None, :] > dlo[E][:, None]) & live[None, :])
            M[np.arange(E.size), E] = False
            if M.any():
                ei, vi = np.nonzero(M)
                resid[E[ei]] = True
                resid[vi] = True
        return resid

    def _op_danger_split(self, d, ga, lo, hi, p_lo, p_hi, rows,
                         may: np.ndarray, *, is_write: bool) -> np.ndarray:
        """Per-op ``_danger`` screening for the batched path: workers
        whose op could evict a still-in-cache page of its own range
        before touching it (the mid-op refetch pattern) replay THIS op
        per worker — ``read``/``write`` resolve it through the analytic
        refetch schedule (``_danger_replay``) — and the rest stay
        batched.  Exact because the split only runs over workers already
        proven independent, so any interleaving of their op executions
        is equivalent."""
        if self.protocol == IDEAL_PROTO:
            return rows
        L = p_hi - p_lo
        cand = may[rows] & (self.resident[rows] + L[rows] > self.cache_pages)
        if not cand.any():
            return rows
        crows = rows[cand]
        n_in = d.count_range(d.incache, p_lo[crows], p_hi[crows], rows=crows)
        n_enter = L[crows] - n_in
        danger = (n_enter < L[crows]) & (
            self.resident[crows] + n_enter > self.cache_pages)
        if not danger.any():
            return rows
        drows = crows[danger]
        self.stats["danger_ops"] += int(drows.size)
        # lockstep-uniform danger workers (the rotating steady state)
        # share one schedule: the leader replays once, recording, and the
        # rest apply the recorded schedule as batched plane ops
        shareable = (drows.size >= 2 and self.danger_mode == "vec"
                     and self.cache_pages >= 1)
        if not (shareable
                and self._danger_shared(drows, d, d.region, ga, lo, hi,
                                        p_lo, p_hi, is_write=is_write)):
            # near-isomorphic residue: a size->=3 group that failed the
            # whole-group check may still contain a lockstep subgroup
            # (one clamped row breaking an otherwise-uniform phase) —
            # the packed multi-row victim scan shares what it can
            resid = (self._danger_subgroups(drows, d, ga, lo, hi,
                                            p_lo, p_hi, is_write=is_write)
                     if shareable and drows.size >= 3 else drows)
            for w in resid:
                if is_write:
                    self.write(int(w), ga, int(lo[w]), int(hi[w]))
                else:
                    self.read(int(w), ga, int(lo[w]), int(hi[w]))
        keep = np.ones(rows.size, bool)
        keep[np.nonzero(cand)[0][danger]] = False
        return rows[keep]

    def _evict_rows_batch(self, rows: np.ndarray):
        """Watermark eviction for ``rows`` after a batched op: each worker
        over the watermark evicts its least-recently-touched pages
        run-by-run from its tick-ordered queue — same victims, same
        per-run charges as ``_evict_cells`` — but rows whose front runs
        cover the same column span (the lockstep steady state of uniform
        spill phases) apply their liveness test, segment-LRU selection
        and plane updates as single 2D ops (``directory.run_live`` /
        ``lru_take`` / ``evict_rows``).  Only called for workers whose
        evictions provably cannot invalidate any other worker (window
        disjointness), so ``_evict_now``'s sharer-invalidation step is
        skipped as a proven no-op."""
        if rows.size == 0 or self.cache_pages is None:
            return
        k = self.resident[rows] - self.cache_pages
        over = k > 0
        if not over.any():
            return
        rows = rows[over]
        k = k[over].astype(np.int64)
        charge = self.protocol != IDEAL_PROTO
        while rows.size:
            if rows.size < 4:
                for w, kw in zip(rows, k):
                    self._evict_cells(int(w), int(kw))
                return
            self.stats["evict_batch_rounds"] += 1
            # one front run per needy worker, grouped by column span;
            # pristine runs (never re-touched) are fully live on [off, n),
            # so their groups skip the touch scan entirely
            groups: Dict[Tuple[int, int, int, bool], list] = {}
            bts = np.empty(rows.size, np.int64)
            for i, w in enumerate(rows):
                t0, region, col0, n, off, shift0, pris = self._lru_q[w][0]
                d = self.dirs[region]
                c0 = col0 + (int(d.shift[w]) - shift0)
                bts[i] = t0
                groups.setdefault((region, c0 + off, n - off, pris),
                                  []).append(i)
            keep_rows, keep_k = [], []
            for (region, start, length, pris), idxs in groups.items():
                idxs = np.asarray(idxs, np.int64)
                R, kk = rows[idxs], k[idxs]
                d = self.dirs[region]
                if R.size < 4:
                    for w, kw in zip(R, kk):
                        self._evict_cells(int(w), int(kw))
                    continue
                if pris:
                    live = None
                    tot = np.full(R.size, length, np.int64)
                else:
                    live = d.run_live(R, start, length, bts[idxs])
                    tot = live.sum(axis=1, dtype=np.int64)
                part = kk < tot
                for si in (np.nonzero(~part)[0], np.nonzero(part)[0]):
                    if si.size == 0:
                        continue
                    is_part = bool(part[si[0]])
                    whole = si.size == R.size
                    Rs, ks = R[si], kk[si]
                    tots = tot[si]
                    fully = pris or bool((tots == length).all())
                    # segment-LRU selection only where the run outlives
                    # the demand; whole-run and prefix takes of fully-live
                    # runs (the streaming steady state) skip masks
                    span = length
                    if not is_part:
                        take = None if fully else live[si]
                    elif pris and int(ks.min()) == int(ks.max()):
                        span = int(ks[0])      # uniform prefix: short span
                        take = None
                    elif pris:
                        take = np.arange(length) < ks[:, None]
                    else:
                        lv = live if whole else live[si]
                        take = d.lru_take(lv, ks, tots)
                    db = d.evict_rows(Rs, start, span, take,
                                      set_wprot=charge)
                    if charge and db.any():
                        self.traffic.writeback_bytes += (int(db.sum())
                                                         * self.page_bytes)
                        hit = db > 0
                        self.clock[Rs[hit]] += (
                            self.cost.net_latency_s * db[hit]
                            + db[hit] * self.page_bytes
                            / self.cost.net_bw_Bps)
                        if self.chaos is not None:
                            self.clock[Rs[hit]] += (
                                self.chaos.retry_rows(Rs[hit]))
                    if is_part:
                        # advance each run past its last taken cell
                        self.resident[Rs] -= ks
                        if fully:          # columnar take: cutoff is k
                            last = ks - 1
                        else:
                            last = take.shape[1] - 1 - np.argmax(
                                take[:, ::-1], axis=1)
                        for i, w in enumerate(Rs):
                            self._lru_q[w][0][4] += int(last[i]) + 1
                    else:
                        self.resident[Rs] -= tots
                        for w in Rs:
                            self._lru_q[w].popleft()
                        rem = ks - tots
                        m = rem > 0
                        if m.any():
                            keep_rows.append(Rs[m])
                            keep_k.append(rem[m])
            if not keep_rows:
                return
            rows = np.concatenate(keep_rows)
            k = np.concatenate(keep_k)
            # group leftovers concatenate in group order — restore the
            # ascending row order every plane primitive assumes
            order = np.argsort(rows)
            rows = rows[order]
            k = k[order]

    def _fetch_range_all(self, region: int, p_lo: np.ndarray,
                         p_hi: np.ndarray, rows: np.ndarray):
        """Vectorized ``_fetch_range`` over ``rows`` of the worker axis:
        identical per-worker traffic and clock charges.  Strategy is
        per-op: dense (R, Lmax) gather/scatter matrices in the
        many-rows/narrow-intervals regime; otherwise rows group by their
        shared (window-relative start, length) — block-partitioned phases
        are uniform — and each group runs single 2D slice-plane ops."""
        d = self.dirs[region]
        d.ensure_rows(p_lo, p_hi, rows)
        L = p_hi - p_lo
        if use_dense(rows.size, int(L.max())):
            self._fetch_dense(d, region, p_lo, p_hi, rows)
            return
        c0 = p_lo - d.base[rows]
        uk, inv = np.unique(np.stack([c0, L], axis=1), axis=0,
                            return_inverse=True)
        for g in range(uk.shape[0]):
            self._fetch_uniform(d, region, rows[inv == g],
                                int(uk[g, 0]), int(uk[g, 1]))

    def _fetch_uniform(self, d: RegionDirectory, region: int,
                       rows: np.ndarray, c0: int, n: int):
        """One uniform-span fetch group: all ``rows`` fetch columns
        [c0, c0+n) of their windows, so every plane pass is a contiguous
        2D slice op — no gather matrices, no per-row Python loop.  Charge
        expressions match ``_fetch_range`` term for term."""
        s = slice(c0, c0 + n)
        rb = d.row_block(rows)              # slice views for lockstep rows
        n_miss = n - d.valid[rb, s].sum(axis=1)
        if d.touch is not None:
            shifts = d.shift[rows]
            t0 = np.array([self._q_append(int(w), region, c0, n,
                                          int(shifts[i]))
                           for i, w in enumerate(rows)], np.int64)
            d.touch[rb, s] = t0[:, None]
            n_enter = n - d.incache[rb, s].sum(axis=1)
            d.incache[rb, s] = True
            self.resident[rows] += n_enter
        tot_miss = int(n_miss.sum())
        if tot_miss:
            if self.protocol != IDEAL_PROTO:
                self.traffic.page_fetches += tot_miss
                self.traffic.fetch_bytes += tot_miss * self.page_bytes
                n_req = -(-n_miss // self.fetch_batch)
                t = (self.cost.net_latency_s * (2 * n_req)
                     + (n_miss * self.page_bytes) / self.cost.net_bw_Bps)
                hit = n_miss > 0
                self.clock[rows[hit]] += t[hit]
                if self.chaos is not None:
                    self.clock[rows[hit]] += self.chaos.retry_rows(
                        rows[hit])
            d.valid[rb, s] = True

    def _fetch_dense(self, d: RegionDirectory, region: int,
                     p_lo: np.ndarray, p_hi: np.ndarray, rows: np.ndarray):
        cols, mask = d.range_cols(p_lo, p_hi, rows)
        safe = np.where(mask, cols, 0)
        r2 = rows[:, None]
        vsub = d.valid[r2, safe] & mask
        L = p_hi - p_lo
        n_miss = L - vsub.sum(axis=1)
        if d.touch is not None:
            # one monotone tick per (worker, op) run: relative order within
            # each worker matches the per-worker path, which is all the
            # LRU victim selection compares (ticks never cross workers)
            t0 = np.array([self._q_append(int(w), region, int(cols[i, 0]),
                                          int(L[i]), int(d.shift[w]))
                           for i, w in enumerate(rows)], np.int64)
            ri, ci = np.nonzero(mask)
            d.touch[rows[ri], cols[ri, ci]] = t0[ri]
            isub = d.incache[r2, safe] & mask
            ri, ci = np.nonzero(mask & ~isub)
            if ri.size:
                d.incache[rows[ri], cols[ri, ci]] = True
            self.resident[rows] += L - isub.sum(axis=1)
        tot_miss = int(n_miss.sum())
        if tot_miss:
            if self.protocol != IDEAL_PROTO:
                self.traffic.page_fetches += tot_miss
                self.traffic.fetch_bytes += tot_miss * self.page_bytes
                n_req = -(-n_miss // self.fetch_batch)
                t = (self.cost.net_latency_s * (2 * n_req)
                     + (n_miss * self.page_bytes) / self.cost.net_bw_Bps)
                hit = n_miss > 0
                self.clock[rows[hit]] += t[hit]
                if self.chaos is not None:
                    self.clock[rows[hit]] += self.chaos.retry_rows(
                        rows[hit])
            ri, ci = np.nonzero(mask & ~vsub)
            d.valid[rows[ri], cols[ri, ci]] = True

    def _read_all(self, ga, lo: np.ndarray, hi: np.ndarray, rows=None,
                  may=None):
        region, p_lo, p_hi = self._page_range_all(ga, lo, hi, prefetch=True)
        rows = self._rows_all if rows is None else rows
        if may is not None:
            rows = self._op_danger_split(self.dirs[region], ga, lo, hi,
                                         p_lo, p_hi, rows, may,
                                         is_write=False)
        if rows.size:
            self._fetch_range_all(region, p_lo[rows], p_hi[rows], rows)
        if may is not None:
            self._evict_rows_batch(rows)

    def _write_all(self, ga, lo: np.ndarray, hi: np.ndarray, rows=None,
                   may=None):
        region, p_lo, p_hi = self._page_range_all(ga, lo, hi, prefetch=False)
        d = self.dirs[region]
        rows = self._rows_all if rows is None else rows
        if may is not None:
            rows = self._op_danger_split(d, ga, lo, hi, p_lo, p_hi, rows,
                                         may, is_write=True)
        if rows.size:
            d.ensure_rows(p_lo[rows], p_hi[rows], rows)
            d.note_dirty(rows, p_lo[rows], p_hi[rows])
            L = (p_hi - p_lo)[rows]
            if use_dense(rows.size, int(L.max())):
                self._write_dense(d, region, ga, lo, hi, p_lo, p_hi, rows)
            else:
                c0 = p_lo[rows] - d.base[rows]
                uk, inv = np.unique(np.stack([c0, L], axis=1), axis=0,
                                    return_inverse=True)
                for g in range(uk.shape[0]):
                    self._write_uniform(d, region, lo, hi, p_lo, p_hi,
                                        rows[inv == g],
                                        int(uk[g, 0]), int(uk[g, 1]))
            d.maybe_dirty = True
            for w in rows:
                self._dirty_regions[w].add(region)
        if may is not None:
            self._evict_rows_batch(rows)

    def _write_dense(self, d: RegionDirectory, region: int, ga,
                     lo: np.ndarray, hi: np.ndarray, p_lo: np.ndarray,
                     p_hi: np.ndarray, rows: np.ndarray):
        pw = self.page_words
        n_words = (hi - lo)[rows]

        # mechanism cost, in the per-worker path's charge order
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[rows] += n_words * self.instr_s_per_word
        if self._track_wprot:
            cols, mask = d.range_cols(p_lo[rows], p_hi[rows], rows)
            wsub = d.wprot[rows[:, None], np.where(mask, cols, 0)] & mask
            self.clock[rows] += wsub.sum(axis=1) * self.fault_s
            ri, ci = np.nonzero(mask)
            d.wprot[rows[ri], cols[ri, ci]] = False

        # write-allocate edge fetches (first page, then last page — the
        # per-worker path's order), only for the workers that need them
        n_pg = (p_hi - p_lo)[rows]
        if self.protocol != IDEAL_PROTO:
            single = n_pg == 1
            first = np.where(single, n_words < pw, lo[rows] % pw != 0)
            last = (~single) & (hi[rows] % pw != 0)
            if first.any():
                r = rows[np.nonzero(first)[0]]
                self._fetch_range_all(region, p_lo[r], p_lo[r] + 1, r)
            if last.any():
                r = rows[np.nonzero(last)[0]]
                self._fetch_range_all(region, p_hi[r] - 1, p_hi[r], r)

        cols, mask = d.range_cols(p_lo[rows], p_hi[rows], rows)
        safe = np.where(mask, cols, 0)
        vsub = d.valid[rows[:, None], safe] & mask
        if d.touch is not None:
            shifts = d.shift[rows]
            t0 = np.array([self._q_append(int(w), region, int(cols[i, 0]),
                                          int(n_pg[i]), int(shifts[i]))
                           for i, w in enumerate(rows)], np.int64)
            ri, ci = np.nonzero(mask)
            d.touch[rows[ri], cols[ri, ci]] = t0[ri]
            isub = d.incache[rows[:, None], safe] & mask
            ri, ci = np.nonzero(mask & ~isub)
            if ri.size:
                d.incache[rows[ri], cols[ri, ci]] = True
            self.resident[rows] += n_pg - isub.sum(axis=1)
        ri, ci = np.nonzero(mask & ~vsub)
        if ri.size:
            d.valid[rows[ri], cols[ri, ci]] = True
        ri, ci = np.nonzero(mask)
        d.dirty[rows[ri], cols[ri, ci]] = True

    def _write_uniform(self, d: RegionDirectory, region: int,
                       lo: np.ndarray, hi: np.ndarray, p_lo: np.ndarray,
                       p_hi: np.ndarray, rows: np.ndarray, c0: int, n: int):
        """One uniform-span write group: all ``rows`` write columns
        [c0, c0+n) of their windows — single 2D slice-plane ops, charge
        expressions term-for-term those of the per-worker ``write``."""
        pw = self.page_words
        s = slice(c0, c0 + n)
        rb = d.row_block(rows)              # slice views for lockstep rows
        n_words = (hi - lo)[rows]
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[rows] += n_words * self.instr_s_per_word
        if self._track_wprot:
            n_faults = d.wprot[rb, s].sum(axis=1)
            self.clock[rows] += n_faults * self.fault_s
            d.wprot[rb, s] = False
        if self.protocol != IDEAL_PROTO:
            if n == 1:
                first = n_words < pw
                last = np.zeros(rows.size, bool)
            else:
                first = lo[rows] % pw != 0
                last = hi[rows] % pw != 0
            if first.any():
                r = rows[np.nonzero(first)[0]]
                self._fetch_range_all(region, p_lo[r], p_lo[r] + 1, r)
            if last.any():
                r = rows[np.nonzero(last)[0]]
                self._fetch_range_all(region, p_hi[r] - 1, p_hi[r], r)
        if d.touch is not None:
            shifts = d.shift[rows]
            t0 = np.array([self._q_append(int(w), region, c0, n,
                                          int(shifts[i]))
                           for i, w in enumerate(rows)], np.int64)
            d.touch[rb, s] = t0[:, None]
            n_enter = n - d.incache[rb, s].sum(axis=1)
            d.incache[rb, s] = True
            self.resident[rows] += n_enter
        d.valid[rb, s] = True
        d.dirty[rb, s] = True

    def phase_all(self, reads=(), writes=(), *, flops=0.0, mem_bytes=0.0,
                  seconds=0.0, instr_words=0.0):
        """One SPMD phase for ALL workers in a single runtime call.

        ``reads``/``writes`` are sequences of ``(ga, lo, hi)`` with
        ``lo``/``hi`` as (W,) int arrays (scalars broadcast); ``flops``/
        ``mem_bytes``/``seconds``/``instr_words`` may be scalars or (W,)
        arrays.  Bit-exactly equivalent to
        ``for w in range(W): phase(w, ...)``: within a phase (no barriers,
        no spans) workers interact only through eviction writebacks.  The
        engine therefore never leaves the batched path wholesale:

        * when no worker can cross the eviction watermark (per-worker
          upper bound, ``_may_evict_mask``) ops run op-major as single
          vectorized passes over the (W, window) directory planes;
        * otherwise a window-disjointness analysis over the declared
          ranges (``_residual_workers``) proves which workers' evictions
          cannot observe each other's directory updates — those run
          batched too, with watermark eviction applied per op as
          vectorized segment-LRU plane ops (``_evict_rows_batch``) and
          the per-op ``_danger`` refetch pattern screened per worker;
        * only the residual *interacting* workers replay tick-ordered
          through the per-worker ``phase`` path, in worker order.

        Must be called outside spans — consistency regions serialize
        through their locks and stay per-worker
        (``span``/``acquire``/``release``)."""
        assert not any(self.spans), "phase_all must run outside spans"
        self.chaos_tick()
        W = self.W
        reads = [(ga, self._w_arr(lo), self._w_arr(hi))
                 for ga, lo, hi in reads]
        writes = [(ga, self._w_arr(lo), self._w_arr(hi))
                  for ga, lo, hi in writes]
        rranges = [self._page_range_all(ga, lo, hi, prefetch=True)
                   for ga, lo, hi in reads]
        wranges = [self._page_range_all(ga, lo, hi, prefetch=False)
                   for ga, lo, hi in writes]
        may = self._may_evict_mask(rranges + wranges)
        resid = None
        if may is not None and self.protocol != IDEAL_PROTO:
            r = self._residual_workers(rranges, wranges, may)
            if r.any():
                resid = r
        rows = None if resid is None else np.nonzero(~resid)[0]
        self.stats["batched_phases"] += 1
        self._race_suspend = True
        if rows is None or rows.size:
            for ga, lo, hi in reads:
                self._read_all(ga, lo, hi, rows=rows, may=may)
            for ga, lo, hi in writes:
                self._write_all(ga, lo, hi, rows=rows, may=may)
        fl = np.asarray(flops, np.float64)
        mb = np.asarray(mem_bytes, np.float64)
        sec = np.asarray(seconds, np.float64)
        iw = np.asarray(instr_words, np.float64)
        crows = self._rows_all if rows is None else rows
        if crows.size:
            if fl.any() or mb.any() or sec.any():
                sharing = self.cost.workers_on_node(W)
                bw = self.cost.node_bw(sharing) / max(1, sharing)
                t = np.broadcast_to(
                    sec + np.maximum(fl / self.cost.flops_per_worker,
                                     mb / bw), (W,))
                self.clock[crows] += t[crows]
            if (self.model_mechanism and self.protocol == FINE_PROTO
                    and iw.any()):
                self.clock[crows] += np.broadcast_to(
                    iw * self.instr_s_per_word, (W,))[crows]
        if resid is not None:
            # tick-ordered replay of the interacting workers, in worker
            # order (the loop driver's order within each dependence class)
            self.stats["residual_replays"] += int(resid.sum())
            flb = np.broadcast_to(fl, (W,))
            mbb = np.broadcast_to(mb, (W,))
            secb = np.broadcast_to(sec, (W,))
            iwb = np.broadcast_to(iw, (W,))
            for w in np.nonzero(resid)[0]:
                self.phase(
                    int(w),
                    reads=[(ga, int(lo[w]), int(hi[w]))
                           for ga, lo, hi in reads],
                    writes=[(ga, int(lo[w]), int(hi[w]))
                            for ga, lo, hi in writes],
                    flops=float(flb[w]), mem_bytes=float(mbb[w]),
                    seconds=float(secb[w]), instr_words=float(iwb[w]))
        self._race_suspend = False
        if self.detect_races:
            self._race_phase_all(reads, writes)

    # ------------------------------------------------------------------
    # worker-axis batched span driver (span_all)
    # ------------------------------------------------------------------

    def _span_one(self, w: int, lock_id: int, reads, writes):
        """One worker's whole consistency region through the per-worker
        path — the serialized reference body every batched span_all path
        is proven bit-equal against (and the fallback when spill or
        flush/span page interactions make batching unsound)."""
        self.acquire(w, lock_id)
        for ga, lo, hi in reads:
            self.read(w, ga, int(lo[w]), int(hi[w]))
        for ga, lo, hi in writes:
            self.write(w, ga, int(lo[w]), int(hi[w]))
        self.release(w, lock_id)

    def _span_flush_safe(self, rows: np.ndarray, locks: np.ndarray,
                         ranges) -> bool:
        """May every masked worker's acquire-time ordinary flush hoist to
        one batched pass BEFORE any span body runs?  Sound iff no flushed
        dirty page (or its sharer invalidation) can be observed by any
        span body or notice replay of this pass: the masked workers'
        conservative dirty bounds must be disjoint from every *span
        interaction interval* — the declared (prefetch-extended)
        read/write page ranges plus the pending-notice page bounds of
        every involved lock.  All intervals are absolute page numbers, so
        region resolution is unnecessary."""
        spans_iv = []
        for region, p_lo, p_hi in ranges:
            spans_iv.append((int(p_lo[rows].min()), int(p_hi[rows].max())))
        for lk_id in np.unique(locks[rows]):
            lk = self.locks.get(int(lk_id))
            if lk is None:
                continue
            grp = rows[locks[rows] == lk_id]
            v_min = int(lk.seen[grp].min())
            if v_min >= lk.version:
                continue
            pb_iv = lk.log.page_bounds(v_min, lk.version)
            if pb_iv is not None:
                spans_iv.append(pb_iv)
        if not spans_iv:
            return True
        for d in self.dirs:
            dlo, dhi = d.dirty_lo[rows], d.dirty_hi[rows]
            m = dlo < dhi
            if not m.any():
                continue
            lo, hi = int(dlo[m].min()), int(dhi[m].max())
            for rlo, rhi in spans_iv:
                if rlo < hi and rhi > lo:
                    return False
        return True

    def _span_group_vec(self, grp: np.ndarray, lock_id: int, reads, writes,
                        rranges, wranges) -> bool:
        """Analytic batched pass for one uniform same-lock span group —
        the pipelined fast path of ``span_all``.

        Grants stay serialized (the release-time chain below is the only
        true serialization point), but everything *around* the grant
        pipelines across the group as plane ops: the pending-notice set of
        the i-th holder is exactly the earlier holders' releases of THIS
        pass (precondition: every member has replayed the lock's log —
        ``seen == version`` — the post-barrier steady state), and every
        release publishes the same declared write intervals, so replay
        invalidations, fetch misses, write faults and release payloads
        resolve as (G, pages) matrix ops, one batched log append
        (``IntervalLog.append_versions``), and a G-step scalar clock chain
        whose per-worker charge sequence replicates the per-worker path
        term for term (bit-equal clocks).

        Unsynced members are allowed in ONE analytically tractable shape
        — the repeated uniform pass (e.g. the second sweep of the same
        accumulation before any barrier): when every log version a member
        has not replayed carries exactly THIS pass's payload, its
        coalesced pending is that payload no matter how far behind it is.
        Any other backlog, differing per-worker intervals, or an empty
        interval returns False (caller falls back to the per-worker
        serial body).  Ops across several regions resolve region-by-
        region: plane matrices, pending masks and replay hits are
        per-region separable (a page belongs to exactly one region), and
        the release payload is the per-region payloads concatenated in
        region order — which IS page order, matching ``_span_harvest``'s
        sorted multi-region concatenation.  Eviction inside spans never
        reaches here — span_all screens it into the full-serial
        fallback."""
        lk = self.locks.setdefault(lock_id, _Lock(self.W))
        w0 = int(grp[0])
        ops = []      # (ga, lo, hi, p_lo, p_hi, is_write, region) — uniform
        regions = []  # ascending (rranges/wranges come region-resolved)
        for (ga, lo, hi), (region, p_lo, p_hi), is_w in (
                [(o, r, False) for o, r in zip(reads, rranges)]
                + [(o, r, True) for o, r in zip(writes, wranges)]):
            if (not (lo[grp] == lo[w0]).all()
                    or not (hi[grp] == hi[w0]).all()):
                return False
            if int(hi[w0]) <= int(lo[w0]):
                return False
            if region not in regions:
                regions.append(region)
            ops.append((ga, int(lo[w0]), int(hi[w0]),
                        int(p_lo[w0]), int(p_hi[w0]), is_w, region))
        regions.sort()

        G = int(grp.size)
        IDEAL = self.protocol == IDEAL_PROTO
        FINE = self.protocol == FINE_PROTO
        pw = self.page_words
        pb = self.page_bytes
        track = self.cache_pages is not None
        imax = np.iinfo(np.int64).max
        imin = np.iinfo(np.int64).min
        gi = grp[:, None]

        # per-region context: union window, gathered plane matrices, and
        # the uniform release payload accumulator (per declared-write
        # page, the (min, max)-coalesced word interval — what each member
        # publishes and what each later holder replays)
        ctx = {}
        for r in regions:
            d_r = self.dirs[r]
            u_lo = min(op[3] for op in ops if op[6] == r)
            u_hi = max(op[4] for op in ops if op[6] == r)
            P = u_hi - u_lo
            d_r.ensure_rows(np.full(G, u_lo, np.int64),
                            np.full(G, u_hi, np.int64), grp)
            colm = (u_lo - d_r.base[grp])[:, None] + np.arange(P)[None, :]
            ctx[r] = {
                "d": d_r, "u_lo": u_lo, "colm": colm,
                "V": (d_r.valid[gi, colm]).copy(),
                "IC": (d_r.incache[gi, colm]).copy() if track else None,
                "WP": ((d_r.wprot[gi, colm]).copy()
                       if self._track_wprot else None),
                "pend": np.zeros(P, bool),
                "wlo": np.full(P, imax, np.int64),
                "whi": np.full(P, imin, np.int64),
            }
        for ga, lo, hi, p_lo, p_hi, is_w, r in ops:
            if not is_w:
                continue
            c = ctx[r]
            sl = slice(p_lo - c["u_lo"], p_hi - c["u_lo"])
            bw_ = (np.arange(p_lo, p_hi) - ga.page_lo) * pw
            c["pend"][sl] = True
            np.minimum(c["wlo"][sl], np.maximum(lo - bw_, 0),
                       out=c["wlo"][sl])
            np.maximum(c["whi"][sl], np.minimum(hi - bw_, pw),
                       out=c["whi"][sl])
        if regions:
            parts = []
            for r in regions:
                c = ctx[r]
                rel_idx = np.nonzero(c["pend"])[0]
                parts.append((rel_idx + c["u_lo"], c["wlo"][rel_idx],
                              c["whi"][rel_idx]))
            rel_pages = np.concatenate([p[0] for p in parts])
            rel_los = np.concatenate([p[1] for p in parts])
            rel_his = np.concatenate([p[2] for p in parts])
        else:
            rel_pages = rel_los = rel_his = np.zeros(0, np.int64)
        npend = int(rel_pages.size)
        pub_bytes = 0
        if npend:
            if FINE:
                pub_bytes = (int((rel_his - rel_los).sum()) * _WORD
                             + npend * (pw // 8))
            else:
                pub_bytes = npend * pb

        # ---- pending sets: member i replays the earlier i releases of
        # THIS pass, plus any backlog — tolerated only when the backlog
        # repeats this very payload (then the coalesced pending IS the
        # payload, however far behind a member is)
        v0 = lk.version
        seen = lk.seen[grp]
        has_pend = np.ones(G, bool)
        has_pend[0] = int(seen[0]) < v0
        v_min = int(seen.min())
        if v_min < v0:
            voff = lk.log.voff
            sizes = np.diff(np.asarray(voff[v_min:v0 + 1], np.int64))
            if npend == 0 or not (sizes == npend).all():
                # mixed-shape backlog: some member must replay versions
                # whose interval counts differ from this pass's — per-
                # member pending sets diverge (see DIRECTORY.md "Why the
                # mixed-payload backlog stays serial")
                self.stats["span_backlog_serial"] += 1
                return False
            if not lk.log.payload_matches(v_min, v0, rel_pages, rel_los,
                                          rel_his):
                # mixed-payload backlog: right shape, different pages —
                # coalesced pendings are not THIS payload, so the uniform
                # (G, P) replay algebra below does not apply
                self.stats["span_backlog_serial"] += 1
                return False

        # ---- replay effects --------------------------------------------
        if npend and not IDEAL and not FINE:
            n_inv = 0
            for r in regions:
                c = ctx[r]
                if not c["pend"].any():
                    continue
                hits = c["V"] & c["pend"][None, :] & has_pend[:, None]
                nh = int(hits.sum())
                if nh:
                    if c["WP"] is not None and self.model_mechanism:
                        c["WP"] |= hits
                    c["V"] &= ~(has_pend[:, None] & c["pend"][None, :])
                n_inv += nh
            self.traffic.invalidations += n_inv
            self.traffic.control_msgs += npend * int(has_pend.sum())
            if self.chaos is not None:
                self.chaos.inval_msgs(n_inv)

        # ---- op effects, op-major (rows are mutually independent) ------
        op_miss = []       # per read op: (G,) fetch-miss counts
        op_faults = []     # per write op: (G,) wprot fault counts
        op_edges = []      # per write op: (first(G,)|None, last(G,)|None)
        for ga, lo, hi, p_lo, p_hi, is_w, r in ops:
            cx = ctx[r]
            V, IC, WP = cx["V"], cx["IC"], cx["WP"]
            d, u_lo, colm = cx["d"], cx["u_lo"], cx["colm"]
            sl = slice(p_lo - u_lo, p_hi - u_lo)
            n = p_hi - p_lo
            if not is_w:
                miss = ((~V[:, sl]).sum(axis=1) if not IDEAL
                        else np.zeros(G, np.int64))
                op_miss.append(miss)
                V[:, sl] = True
                if track:
                    self._span_track_touch(d, grp, gi, colm, IC, r,
                                           p_lo, n, sl)
                tot = int(miss.sum())
                if tot:
                    self.traffic.page_fetches += tot
                    self.traffic.fetch_bytes += tot * pb
                continue
            if self._track_wprot:
                op_faults.append(WP[:, sl].sum(axis=1))
                WP[:, sl] = False
            else:
                op_faults.append(None)
            first = last = None
            if not IDEAL:
                n_words = hi - lo
                if n == 1:
                    f_part, l_part = n_words < pw, False
                else:
                    f_part = lo % pw != 0
                    l_part = hi % pw != 0
                if f_part:
                    c = p_lo - u_lo
                    first = (~V[:, c]).astype(np.int64)
                    V[:, c] = True
                    if track:
                        self._span_track_touch(d, grp, gi, colm, IC,
                                               r, p_lo, 1,
                                               slice(c, c + 1))
                    tot = int(first.sum())
                    if tot:
                        self.traffic.page_fetches += tot
                        self.traffic.fetch_bytes += tot * pb
                if l_part:
                    c = p_hi - 1 - u_lo
                    last = (~V[:, c]).astype(np.int64)
                    V[:, c] = True
                    if track:
                        self._span_track_touch(d, grp, gi, colm, IC,
                                               r, p_hi - 1, 1,
                                               slice(c, c + 1))
                    tot = int(last.sum())
                    if tot:
                        self.traffic.page_fetches += tot
                        self.traffic.fetch_bytes += tot * pb
            op_edges.append((first, last))
            if track:
                self._span_track_touch(d, grp, gi, colm, IC, r,
                                       p_lo, n, sl)
            V[:, sl] = True

        # ---- commit planes --------------------------------------------
        for r in regions:
            cx = ctx[r]
            d, colm = cx["d"], cx["colm"]
            d.valid[gi, colm] = cx["V"]
            if cx["IC"] is not None:
                d.incache[gi, colm] = cx["IC"]
            if cx["WP"] is not None:
                d.wprot[gi, colm] = cx["WP"]

        # ---- publish: one batched log append, G versions --------------
        if not IDEAL:
            if FINE and npend:
                self.traffic.diff_bytes += (pub_bytes                # replays
                                            * int(has_pend.sum()))
            if npend:
                if FINE:
                    self.traffic.diff_bytes += pub_bytes * G    # releases
                else:
                    self.traffic.writeback_bytes += pub_bytes * G
            lk.log.append_versions(
                np.tile(rel_pages, G), np.tile(rel_los, G),
                np.tile(rel_his, G), np.full(G, npend, np.int64))
            lk.version = v0 + G
            lk.seen[grp] = v0 + np.arange(1, G + 1)
        self.traffic.control_msgs += 3 * G          # acquire 2 + release 1

        # ---- the grant chain: the only serialized part ----------------
        # per-worker charge sequence replicates the per-worker path term
        # for term (same scalar expressions, same order), so clocks stay
        # bit-equal to the span loop
        xfer = self.cost.xfer_s
        lat = self.cost.net_latency_s
        bw = self.cost.net_bw_Bps
        fb = self.fetch_batch
        ctrl2 = xfer(64, 2)
        ctrl1 = xfer(64, 1)
        t_rel = lk.last_release_time
        for i in range(G):
            w = int(grp[i])
            c = float(self.clock[w])
            if not IDEAL:
                c += ctrl2
                if self.chaos is not None:
                    c += self.chaos.retry1(w)
            c = max(c, t_rel)
            if has_pend[i] and npend and not IDEAL and FINE:
                c += lat * npend + pub_bytes / bw
                if self.chaos is not None:
                    c += self.chaos.retry1(w)
            ri = wi = 0
            for ga, lo, hi, p_lo, p_hi, is_w, _r in ops:
                if not is_w:
                    m = int(op_miss[ri][i])
                    ri += 1
                    if m and not IDEAL:
                        c += xfer(m * pb, 2 * -(-m // fb))
                        if self.chaos is not None:
                            c += self.chaos.retry1(w)
                    continue
                if self.model_mechanism and FINE:
                    c += (hi - lo) * self.instr_s_per_word
                if op_faults[wi] is not None:
                    c += int(op_faults[wi][i]) * self.fault_s
                first, last = op_edges[wi]
                wi += 1
                if first is not None and first[i]:
                    c += xfer(pb, 2)
                    if self.chaos is not None:
                        c += self.chaos.retry1(w)
                if last is not None and last[i]:
                    c += xfer(pb, 2)
                    if self.chaos is not None:
                        c += self.chaos.retry1(w)
            if not IDEAL and npend:
                c += lat * npend + pub_bytes / bw
                if self.chaos is not None:
                    c += self.chaos.retry1(w)
            if not IDEAL:
                c += ctrl1
                if self.chaos is not None:
                    c += self.chaos.retry1(w)
            self.clock[w] = c
            t_rel = c
        lk.last_release_time = t_rel
        self.stats["span_groups_vec"] += 1
        self.stats["span_workers_vec"] += G
        if len(regions) > 1:
            self.stats["span_multi_region_groups"] += 1
        return True

    def _span_track_touch(self, d: RegionDirectory, grp, gi, colm, IC,
                          region: int, p_lo: int, n: int, sl: slice):
        """LRU/touch bookkeeping of one uniform group op (cache runs
        only): one touch run per worker in the per-worker path's order,
        cache-slot entries counted off the gathered occupancy matrix.
        ``sl`` addresses [p_lo, p_lo+n) in the group's U-window columns.
        Eviction is impossible here (span_all screens it out), so the
        watermark never trips."""
        ticks = np.empty(grp.size, np.int64)
        for i, w in enumerate(grp):
            ticks[i] = self._q_append(int(w), region,
                                      int(p_lo - d.base[w]), n,
                                      int(d.shift[w]))
        d.touch[gi, colm[:, sl]] = ticks[:, None]
        enters = (~IC[:, sl]).sum(axis=1)
        IC[:, sl] = True
        self.resident[grp] += enters

    def span_all(self, w_mask=None, lock_ids=0, reads=(), writes=()):
        """One consistency-region pass for many workers in a single call.

        Equivalent — traffic field-for-field, clocks bit-equal — to the
        per-worker span loop::

            for w in <masked workers, ascending>:
                with rt.span(w, lock_ids[w]):
                    for ga, lo, hi in reads:  rt.read(w, ga, lo[w], hi[w])
                    for ga, lo, hi in writes: rt.write(w, ga, lo[w], hi[w])

        ``w_mask`` is a (W,) bool mask (None = all workers); ``lock_ids``
        scalar or (W,); ``reads``/``writes`` as in ``phase_all``.

        Lock grants are the only true serialization point, and they stay
        serialized (the release-time chain).  Everything around them
        pipelines:

        * every masked worker's acquire-time ordinary flush hoists into
          ONE batched sequential-flush pass (``_flush_all_workers`` over
          the mask) when the flushed dirty bounds provably cannot touch
          any span page or pending notice (``_span_flush_safe``);
        * workers sharing a lock form a *grant group*; uniform groups
          (same declared intervals, members synced to the lock's log)
          resolve analytically as plane ops (``_span_group_vec``) — the
          i-th holder's replay set is exactly the earlier holders'
          releases of this pass;
        * distinct locks' groups are mutually independent (span bodies
          touch only their own directory rows once eviction is excluded),
          so groups run one after another without interleaving cost.

        Falls back — exactly, never approximately — to the per-worker
        body for non-uniform groups, and to the fully serial worker-order
        loop when a span could evict (capacity pressure inside spans) or
        when flushed pages and span/notice pages may interact."""
        assert not any(self.spans), "span_all must run outside spans"
        self.chaos_tick()
        W = self.W
        if w_mask is None:
            rows = self._rows_all
        else:
            w_mask = np.asarray(w_mask)
            rows = (np.nonzero(w_mask)[0] if w_mask.dtype == bool
                    else np.unique(np.asarray(w_mask, np.int64)))
        locks = self._w_arr(lock_ids)
        reads = [(ga, self._w_arr(lo), self._w_arr(hi))
                 for ga, lo, hi in reads]
        writes = [(ga, self._w_arr(lo), self._w_arr(hi))
                  for ga, lo, hi in writes]
        self.stats["span_all_calls"] += 1
        if rows.size == 0:
            return
        rranges = [self._page_range_all(ga, lo, hi, prefetch=True)
                   for ga, lo, hi in reads]
        wranges = [self._page_range_all(ga, lo, hi, prefetch=False)
                   for ga, lo, hi in writes]
        serial = False
        if self.cache_pages is not None:
            # any possible in-span eviction (even the bookkeeping-only
            # IDEAL kind) serializes the whole pass: an eviction can
            # write back into another worker's reach and the LRU queue
            # walk is inherently tick-ordered
            ub = self.resident.copy()
            for region, p_lo, p_hi in rranges + wranges:
                ub += p_hi - p_lo
            serial = bool((ub[rows] > self.cache_pages).any())
        if not serial and self.protocol != IDEAL_PROTO:
            serial = not self._span_flush_safe(rows, locks,
                                               rranges + wranges)
        self._race_suspend = True
        if serial:
            self.stats["span_serial_calls"] += 1
            self.stats["span_serial_workers"] += int(rows.size)
            for w in rows:
                self._span_one(int(w), int(locks[w]), reads, writes)
        else:
            mask = np.zeros(W, bool)
            mask[rows] = True
            self._flush_all_workers(mask)
            for lk_id in np.unique(locks[rows]):
                grp = rows[locks[rows] == int(lk_id)]
                if not self._span_group_vec(grp, int(lk_id), reads, writes,
                                            rranges, wranges):
                    self.stats["span_serial_workers"] += int(grp.size)
                    for w in grp:
                        self._span_one(int(w), int(lk_id), reads, writes)
        self._race_suspend = False
        if self.detect_races:
            self._race_span_all(rows, locks, reads, writes)

    # ------------------------------------------------------------------
    def reduce(self, w: int, name: str, value: float, op: str = "sum"):
        self._reductions.setdefault(name, []).append((float(value), op))

    def reduce_all(self, name: str, values, op: str = "sum"):
        """Batched ``reduce``: one contribution per worker in a single
        call (``values`` scalar or (W,)); combines identically at the
        barrier (same values, same op, same reduction_msgs)."""
        vals = np.broadcast_to(np.asarray(values, np.float64), (self.W,))
        self._reductions.setdefault(name, []).extend(
            (float(v), op) for v in vals)

    def reduction_result(self, name: str) -> float:
        return self._reduction_results[name]

    def barrier(self):
        self.chaos_tick()
        self._flush_all_workers()
        if self.protocol != IDEAL_PROTO:
            for lk in self.locks.values():
                if (lk.seen == lk.version).all():
                    continue       # everyone current (usual post-span state)
                for w in range(self.W):
                    if lk.seen[w] == lk.version:
                        continue
                    u, lo_u, hi_u = lk.log.pending(int(lk.seen[w]),
                                                   lk.version)
                    lk.seen[w] = lk.version
                    if not u.size:
                        continue
                    if self.protocol == FINE_PROTO:
                        # fine-grain update of valid stale copies only
                        regions = np.searchsorted(
                            self._region_starts_np, u, "right") - 1
                        for r in np.unique(regions):
                            d = self.dirs[int(r)]
                            if d.base[w] < 0:
                                continue
                            m = regions == r
                            cols = u[m] - d.base[w]
                            inr = (cols >= 0) & (cols < d.length[w])
                            vcells = d.valid[w, np.where(inr, cols, 0)] & inr
                            self.traffic.diff_bytes += int(
                                ((hi_u[m] - lo_u[m]) * _WORD)[vcells].sum())
                    else:
                        n_inv = self._replay_invalidate(w, u, rearm=False)
                        self.traffic.invalidations += n_inv
                        if self.chaos is not None:
                            self.chaos.inval_msgs(n_inv)
        if self.straggler is not None:
            flagged = self.straggler.observe(self.clock - self._bar_clock0)
            self.stats["straggler_checks"] += 1
            self.stats["straggler_flags"] += len(flagged)
        log_w = max(1, int(np.ceil(np.log2(max(self.W, 2)))))
        for name, contribs in self._reductions.items():
            vals = [v for v, _ in contribs]
            op = contribs[0][1]
            fn = {"sum": np.sum, "max": np.max, "min": np.min}[op]
            self._reduction_results[name] = float(fn(vals))
            self.traffic.reduction_msgs += self.W - 1
        self._reductions.clear()
        if self.detect_races:
            # barrier orders everyone against everyone: join all views,
            # then every worker opens a fresh epoch
            j = self.race_vc.max(axis=0)
            self.race_vc[:] = j[None, :]
            self.race_vc[self._rows_all, self._rows_all] += 1
        t = float(self.clock.max()) + self.cost.net_latency_s * log_w * (
            0 if self.protocol == IDEAL_PROTO else 1) + 1e-7 * log_w
        self.clock[:] = t
        self._bar_clock0 = self.clock.copy()

    @property
    def time(self) -> float:
        return float(self.clock.max())

    # ------------------------------------------------------------------
    # barrier-consistent checkpoints (ft/coherence.py; DIRECTORY.md
    # "Recovery contract")
    # ------------------------------------------------------------------

    def snapshot(self, rows: "Optional[Tuple[int, int]]" = None
                 ) -> Tuple[dict, dict]:
        """Serialize the COMPLETE runtime state as (arrays, meta).

        Only legal at a consistent cut — no open spans, no unresolved
        reductions, no in-flight danger recording — i.e. right after a
        ``barrier()`` (or before any work).  At such a cut the directory
        planes, lock logs, LRU queues, clocks, traffic, stats, and the
        chaos/straggler counters are the *entire* protocol state:
        :meth:`from_snapshot` rebuilds a runtime whose every subsequent
        event is bit-identical to the original's.  ``arrays`` holds only
        numpy arrays (npz-shardable, no jax); ``meta`` is
        JSON-serializable.

        ``rows=(w_lo, w_hi)`` restricts the worker-major payload to one
        shard's contiguous worker slice (directory plane rows, clocks,
        LRU queues, lock ``seen`` vectors, per-worker chaos/straggler
        counters); worker-independent state (lock logs, reduction
        results, global counters) is carried in full by every slice —
        :meth:`compose_snapshots` reassembles the slices into a full
        snapshot and *asserts* the replicated globals agree bit-for-bit
        (the cluster's divergence check).  A slice records
        ``meta["slice"]`` and cannot be restored directly."""
        assert not any(self.spans), "snapshot inside an open span"
        assert not self._reductions, "snapshot with unresolved reductions"
        assert self._danger_rec is None, "snapshot during danger recording"
        arrays: Dict[str, np.ndarray] = {
            "clock": self.clock.copy(),
            "bar_clock0": self._bar_clock0.copy(),
            "resident": self.resident.copy(),
            "q_degraded": self._q_degraded.copy(),
        }
        # LRU touch-run queues: flat (N, 7) entry rows + per-worker counts
        lru_counts = np.array([len(q) for q in self._lru_q], np.int64)
        if int(lru_counts.sum()):
            lru_entries = np.array(
                [list(e) for q in self._lru_q for e in q], np.int64)
        else:
            lru_entries = np.zeros((0, 7), np.int64)
        arrays["lru_counts"] = lru_counts
        arrays["lru_entries"] = lru_entries
        dr_counts = np.array([len(s) for s in self._dirty_regions],
                             np.int64)
        arrays["dirty_region_counts"] = dr_counts
        arrays["dirty_region_flat"] = np.array(
            [r for s in self._dirty_regions for r in sorted(s)], np.int64)
        red_names = sorted(self._reduction_results)
        arrays["red_vals"] = np.array(
            [self._reduction_results[k] for k in red_names], np.float64)
        dir_metas = []
        for r, d in enumerate(self.dirs):
            darr, dmeta = d.state_arrays()
            for k, v in darr.items():
                arrays[f"d{r:05d}_{k}"] = v
            dir_metas.append(dmeta)
        lock_metas = []
        for j, (lid, lk) in enumerate(sorted(self.locks.items())):
            pre = f"lk{j:05d}_"
            arrays[pre + "seen"] = lk.seen.copy()
            arrays[pre + "lrt"] = np.array([lk.last_release_time],
                                           np.float64)
            if self.detect_races:
                arrays[pre + "vc"] = lk.race_vc.copy()
            for k, v in lk.log.state_arrays().items():
                arrays[pre + k] = v
            lock_metas.append({"id": int(lid), "version": int(lk.version)})
        if self.detect_races:
            # worker vector clocks slice per shard; the flagged set is
            # replicated (global) — compose_snapshots asserts it agrees
            # across shards, another divergence check for free
            arrays["race_vc"] = self.race_vc.copy()
            arrays["race_set"] = (np.array(
                sorted((p, a, b, 0 if kind == "ww" else 1)
                       for p, a, b, kind in self.races), np.int64)
                if self.races else np.zeros((0, 4), np.int64))
        if self.chaos is not None:
            arrays.update(self.chaos.state_arrays())
        if self.straggler is not None:
            for k, v in self.straggler.state_arrays().items():
                arrays["strag_" + k] = v
        meta = {
            "config": {"n_workers": self.W, "page_words": self.page_words,
                       "protocol": self.protocol,
                       "cache_pages": self.cache_pages,
                       "prefetch": self.prefetch,
                       "n_mem_servers": self.n_mem_servers,
                       "model_mechanism": self.model_mechanism,
                       "instr_s_per_word": self.instr_s_per_word,
                       "fault_s": self.fault_s,
                       "fetch_batch": self.fetch_batch,
                       "backend": self.backend,
                       "danger_mode": self.danger_mode,
                       "detect_races": self.detect_races},
            "cost": dataclasses.asdict(self.cost),
            "traffic": dataclasses.asdict(self.traffic),
            "stats": dict(self.stats),
            "tick": self._tick,
            "phase_idx": self._phase_idx,
            "n_pages": self.n_pages,
            "region_starts": [int(x) for x in self._region_starts],
            "region_ends": [int(x) for x in self._region_ends],
            "dirs": dir_metas,
            "locks": lock_metas,
            "red_names": red_names,
            "chaos": (None if self.chaos is None
                      else self.chaos.config()),
            "straggler": (None if self.straggler is None
                          else self.straggler.config()),
        }
        if rows is not None:
            w_lo, w_hi = int(rows[0]), int(rows[1])
            assert 0 <= w_lo < w_hi <= self.W, rows
            arrays = _slice_snapshot_arrays(arrays, w_lo, w_hi)
            meta["slice"] = [w_lo, w_hi]
        return arrays, meta

    @classmethod
    def from_snapshot(cls, arrays: dict, meta: dict, *,
                      injector=None) -> "RegCScaleRuntime":
        """Rebuild a runtime from :meth:`snapshot` output.  The clone is
        bit-identical going forward: same clocks, traffic, stats,
        directory planes, lock logs, LRU order, chaos counters.  Pass a
        (possibly already partially fired) ``injector`` to rearm failure
        injection on the replayed suffix."""
        assert meta.get("slice") is None, (
            "partial (shard-slice) snapshot: compose_snapshots first")
        cfg = meta["config"]
        chaos = None
        if meta.get("chaos") is not None:
            from repro.dsm.costmodel import ChaosNet
            chaos = ChaosNet(**meta["chaos"])
        straggler = None
        if meta.get("straggler") is not None:
            from repro.ft.runtime import StragglerMonitor
            sarr = {k[len("strag_"):]: v for k, v in arrays.items()
                    if k.startswith("strag_")}
            straggler = StragglerMonitor.from_state(sarr,
                                                    meta["straggler"])
        cache_pages = cfg["cache_pages"]
        rt = cls(int(cfg["n_workers"]),
                 page_words=int(cfg["page_words"]),
                 protocol=cfg["protocol"],
                 cost=CostModel(**meta["cost"]),
                 cache_pages=(None if cache_pages is None
                              else int(cache_pages)),
                 prefetch=int(cfg["prefetch"]),
                 n_mem_servers=int(cfg["n_mem_servers"]),
                 model_mechanism=bool(cfg["model_mechanism"]),
                 instr_s_per_word=float(cfg["instr_s_per_word"]),
                 fault_s=float(cfg["fault_s"]),
                 fetch_batch=int(cfg["fetch_batch"]),
                 backend=cfg["backend"],
                 danger_mode=cfg["danger_mode"],
                 detect_races=bool(cfg.get("detect_races", False)),
                 chaos=chaos, injector=injector, straggler=straggler)
        rt.n_pages = int(meta["n_pages"])
        rt._region_starts = [int(x) for x in meta["region_starts"]]
        rt._region_ends = [int(x) for x in meta["region_ends"]]
        rt._region_starts_np = np.asarray(rt._region_starts, np.int64)
        rt.dirs = []
        for r, dmeta in enumerate(meta["dirs"]):
            pre = f"d{r:05d}_"
            darr = {k[len(pre):]: v for k, v in arrays.items()
                    if k.startswith(pre)}
            d = RegionDirectory.from_state(darr, dmeta)
            d.jit_stats = rt.stats
            rt.dirs.append(d)
        rt.locks = {}
        for j, lm in enumerate(meta["locks"]):
            pre = f"lk{j:05d}_"
            lk = _Lock(rt.W)
            lk.version = int(lm["version"])
            lk.seen = np.asarray(arrays[pre + "seen"], np.int64).copy()
            lk.last_release_time = float(
                np.asarray(arrays[pre + "lrt"])[0])
            lk.log = IntervalLog.from_state(
                {k: arrays[pre + k] for k in ("p", "lo", "hi", "voff")})
            if pre + "vc" in arrays:
                lk.race_vc = np.asarray(arrays[pre + "vc"],
                                        np.int64).copy()
            rt.locks[int(lm["id"])] = lk
        if rt.detect_races:
            rt.race_vc = np.asarray(arrays["race_vc"], np.int64).copy()
            rs = np.asarray(arrays["race_set"], np.int64).reshape(-1, 4)
            rt.races = {(int(p), int(a), int(b), "ww" if k == 0 else "rw")
                        for p, a, b, k in rs}
        rt.clock = np.asarray(arrays["clock"], np.float64).copy()
        rt._bar_clock0 = np.asarray(arrays["bar_clock0"],
                                    np.float64).copy()
        rt.resident = np.asarray(arrays["resident"], np.int64).copy()
        rt._q_degraded = np.asarray(arrays["q_degraded"], bool).copy()
        lru_counts = np.asarray(arrays["lru_counts"], np.int64)
        ents = np.asarray(arrays["lru_entries"],
                          np.int64).reshape(-1, 7)
        rt._lru_q = []
        off = 0
        for w in range(rt.W):
            n = int(lru_counts[w])
            rt._lru_q.append(deque(
                [int(x) for x in e] for e in ents[off:off + n]))
            off += n
        dr_counts = np.asarray(arrays["dirty_region_counts"], np.int64)
        dr_flat = np.asarray(arrays["dirty_region_flat"], np.int64)
        rt._dirty_regions = []
        off = 0
        for w in range(rt.W):
            n = int(dr_counts[w])
            rt._dirty_regions.append(
                set(int(x) for x in dr_flat[off:off + n]))
            off += n
        rt.traffic = Traffic(**meta["traffic"])
        # IN PLACE: a bound ChaosNet holds a reference to rt.stats
        rt.stats.clear()
        rt.stats.update(meta["stats"])
        if chaos is not None:
            chaos.load_state(arrays)
        rt._tick = int(meta["tick"])
        rt._phase_idx = int(meta["phase_idx"])
        rt._reduction_results = {
            k: float(v) for k, v in zip(
                meta["red_names"],
                np.asarray(arrays["red_vals"], np.float64))}
        return rt

    @classmethod
    def compose_snapshots(cls, parts) -> Tuple[dict, dict]:
        """Reassemble shard-slice snapshots (``snapshot(rows=...)``
        output, any order) into one full (arrays, meta) restorable by
        :meth:`from_snapshot`.

        The slices must tile ``[0, W)`` exactly.  Worker-major arrays are
        concatenated in rank order; the replicated globals (lock logs,
        reduction results, global chaos/straggler counters, traffic,
        stats, configs) must agree bit-for-bit across every slice — a
        mismatch means the shard replicas diverged, which the cluster
        treats as a hard protocol error, not something to paper over."""
        parts = sorted(parts, key=lambda p: p[1]["slice"][0])
        assert parts, "compose_snapshots of nothing"
        metas = [m for _a, m in parts]
        W = int(metas[0]["config"]["n_workers"])
        bounds = [tuple(m["slice"]) for m in metas]
        want = 0
        for lo, hi in bounds:
            assert lo == want, f"slices do not tile: gap before {lo}"
            want = hi
        assert want == W, f"slices cover [0, {want}) of {W} workers"
        ref_meta = {k: v for k, v in metas[0].items() if k != "slice"}
        for m in metas[1:]:
            other = {k: v for k, v in m.items() if k != "slice"}
            assert other == ref_meta, "shard snapshot metas diverged"
        keys = set(parts[0][0])
        for a, _m in parts[1:]:
            assert set(a) == keys, "shard snapshot keys diverged"
        out: Dict[str, np.ndarray] = {}
        for k in keys:
            vals = [a[k] for a, _m in parts]
            if _snapshot_key_kind(k) == "global":
                for v in vals[1:]:
                    assert (v.dtype == vals[0].dtype
                            and np.array_equal(v, vals[0])), (
                        f"replicated snapshot key {k!r} diverged "
                        "across shards")
                out[k] = vals[0].copy()
            else:
                out[k] = np.concatenate(vals, axis=0)
        return out, ref_meta

    def gas_for_region(self, region: int, n_elems: int) -> GasArray:
        """Handle for an allocation that already exists in the directory
        (the restore-side replacement for ``alloc``: snapshots persist
        regions, not the caller's GasArray handles)."""
        return GasArray(self._region_starts[region], n_elems,
                        self.page_words)


# ---------------------------------------------------------------------------
# shard-slice snapshot plumbing (repro.cluster; DIRECTORY.md "Cluster
# contract").  Snapshot keys fall into three kinds:
#   rows   — worker-major, first dim W: sliced per shard, concatenated
#            back in rank order by compose_snapshots
#   flat   — variable-length per-worker payloads stored as (flat, counts)
#            pairs: sliced by the counts' prefix sums, concatenated back
#   global — worker-independent replicated state (lock logs/version
#            clocks, reduction results, global chaos/straggler totals):
#            carried whole by every slice, asserted bit-equal on compose
# ---------------------------------------------------------------------------

_SNAP_ROW_KEYS = frozenset({
    "clock", "bar_clock0", "resident", "q_degraded",
    "lru_counts", "dirty_region_counts", "race_vc",
    "chaos_msg_seq", "strag_hist_counts", "strag_streak"})
_SNAP_FLAT_COUNTS = {"lru_entries": "lru_counts",
                     "dirty_region_flat": "dirty_region_counts",
                     "strag_hist": "strag_hist_counts"}
_SNAP_DIR_RE = re.compile(r"^d\d{5}_")       # directory planes: all (W, ...)
# per-worker lock state: version seen + (detect_races) lock vector clock
_SNAP_SEEN_RE = re.compile(r"^lk\d{5}_(seen|vc)$")


def _snapshot_key_kind(key: str) -> str:
    if key in _SNAP_ROW_KEYS or _SNAP_DIR_RE.match(key) \
            or _SNAP_SEEN_RE.match(key):
        return "rows"
    if key in _SNAP_FLAT_COUNTS:
        return "flat"
    return "global"


def _slice_snapshot_arrays(arrays: Dict[str, np.ndarray], w_lo: int,
                           w_hi: int) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    for k, v in arrays.items():
        kind = _snapshot_key_kind(k)
        if kind == "rows":
            out[k] = v[w_lo:w_hi].copy()
        elif kind == "flat":
            counts = np.asarray(arrays[_SNAP_FLAT_COUNTS[k]], np.int64)
            off = np.concatenate([[0], np.cumsum(counts)])
            out[k] = v[int(off[w_lo]):int(off[w_hi])].copy()
        else:
            out[k] = v.copy()
    return out
