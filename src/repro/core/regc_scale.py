"""Directory-vectorized RegC protocol engine for paper-scale runs.

Same protocol as ``core.regc.RegCRuntime`` — same rules, same traffic
accounting — but all cross-worker paths are vectorized over the worker axis
through a per-region sharing directory (``core.directory.RegionDirectory``)
so the paper's figures (STREAM TRIAD / Jacobi / MD up to 256 cores,
millions of pages) run in seconds.  ``tests/test_regc_scale.py`` and
``tests/test_directory.py`` cross-validate the traffic counters (exactly)
and the modeled clocks (to float tolerance) against the reference runtime.

Key representation choices:

* page state is per *region*: ``valid/dirty/wprot/touch`` live in one 2D
  ``(W, window)`` directory per allocation region, rows = workers, each row
  offset to the worker's touched window, so memory is O(touched) while
  sharer invalidation, barrier flushes, and notice replay are single
  boolean-mask / gather-scatter numpy ops instead of ``range(W)`` loops;
* reads/writes are per-*interval* (vectorized over the page range);
* eviction is watermark-triggered: a per-worker resident counter makes the
  common no-eviction case O(1); past the watermark the oldest pages pop
  from a tick-ordered FIFO of touch runs (one monotone tick per run —
  victim order within a run is its column order, which is the reference's
  per-op LRU order; see DIRECTORY.md).  ``phase_all`` never abandons the
  batched path under spill: a window-disjointness analysis over the
  declared ranges proves which workers' evictions cannot interact, evicts
  them with vectorized segment-LRU plane ops, and replays only the
  residual interacting workers tick-ordered.  Ops that can evict pages of
  their own range before touching them (the mid-op refetch pattern,
  flagged by ``_danger``) resolve through an analytic segmented
  evict-then-refetch schedule (``_danger_replay``) instead of a per-page
  Python walk, in BOTH drivers;
* lock notices are flat, version-segmented numpy interval logs
  (``core.directory.IntervalLog``); acquire/barrier replay is one slice +
  segment-min/max coalesce per (lock, worker);
* span-touched pages stay in small dicts (critical sections touch few
  pages — that is the paper's whole point).

Beyond the reference runtime, this engine also models the paper's two
store-tracking *mechanisms* (§IV):

* ``fine``  (samhita): every store is instrumented with a runtime call
  (LLVM pass) -> ``instr_s_per_word`` per stored word, in ordinary AND
  consistency regions (the MD result: overhead visible even when almost all
  stores are ordinary);
* ``page``  (samhita_page): write detection via VM protection -> one
  ``fault_s`` per (page x write-epoch), re-armed when the page is flushed.
"""
from __future__ import annotations

import bisect
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.directory import IntervalLog, RegionDirectory, use_dense
from repro.core.regc import (FINE_PROTO, IDEAL_PROTO, PAGE_PROTO, GasArray,
                             Traffic, _WORD)
from repro.dsm.costmodel import CostModel, IB_2013

# mechanism costs (calibration constants; provenance in EXPERIMENTS.md
# §Paper-repro): instrumented store = call + hash-table update; write fault
# = trap + mprotect re-arm, order ~microseconds on the paper's Harpertown.
INSTR_S_PER_WORD = 1.5e-9
FAULT_S = 4.0e-6


class _Span:
    __slots__ = ("lock", "touched")

    def __init__(self, lock):
        self.lock = lock
        self.touched: Dict[int, Tuple[int, int]] = {}


class _Lock:
    __slots__ = ("version", "log", "last_release_time", "seen")

    def __init__(self, n_workers):
        self.version = 0
        self.log = IntervalLog()
        self.last_release_time = 0.0
        self.seen = np.zeros(n_workers, np.int64)


class RegCScaleRuntime:
    """Drop-in (metadata-only) directory-vectorized version of RegCRuntime."""

    def __init__(self, n_workers: int, *, page_words: int = 1024,
                 protocol: str = FINE_PROTO, cost: CostModel = IB_2013,
                 cache_pages: Optional[int] = None, prefetch: int = 1,
                 n_mem_servers: int = 1, model_mechanism: bool = True,
                 instr_s_per_word: float = INSTR_S_PER_WORD,
                 fault_s: float = FAULT_S, fetch_batch: int = 1,
                 backend: str = "numpy", danger_mode: str = "vec"):
        assert protocol in (PAGE_PROTO, FINE_PROTO, IDEAL_PROTO)
        # 'vec' | 'scalar': how ops flagged by the per-op ``_danger``
        # screen (mid-op refetch possible) replay.  'vec' evaluates the
        # analytic segmented evict-then-refetch schedule (_danger_replay);
        # 'scalar' forces the page-by-page reference walk — the oracle the
        # trace-fuzz suite cross-validates against.  Both are
        # traffic-exact; only wall time differs.
        assert danger_mode in ("vec", "scalar"), danger_mode
        self.danger_mode = danger_mode
        # 'numpy' | 'pallas': backend for the whole-plane directory
        # reductions (kernels.protocol_sweep).  Integer-exact either way;
        # degrades to numpy with a warning when jax is unavailable.
        from repro.kernels.protocol_sweep import resolve_backend
        self.backend = resolve_backend(backend)
        self.W = n_workers
        self.page_words = page_words
        self.page_bytes = page_words * _WORD
        self.protocol = protocol
        self.cost = cost
        self.cache_pages = cache_pages
        self.prefetch = prefetch
        self.n_mem_servers = max(1, n_mem_servers)
        self.model_mechanism = model_mechanism
        self.instr_s_per_word = instr_s_per_word
        self.fault_s = fault_s
        # Samhita's bulk-fetch optimization (paper §V-A): a miss run of k
        # pages costs ceil(k/fetch_batch) request/reply pairs, not k.
        # fetch_batch=1 == reference runtime accounting.
        self.fetch_batch = max(1, fetch_batch)
        self._track_wprot = (protocol == PAGE_PROTO and model_mechanism)
        self._track_touch = cache_pages is not None

        self.n_pages = 0
        self._region_starts: List[int] = []     # sorted page_lo per region
        self._region_ends: List[int] = []
        self._region_starts_np = np.zeros(0, np.int64)
        self.dirs: List[RegionDirectory] = []
        self.spans: List[List[_Span]] = [[] for _ in range(n_workers)]
        self.locks: Dict[int, _Lock] = {}
        self.clock = np.zeros(n_workers)
        self.traffic = Traffic()
        # per-worker cache occupancy (valid + invalidated-but-not-evicted
        # pages, matching the reference's LRU dict): the eviction watermark
        self.resident = np.zeros(n_workers, np.int64)
        # per-worker FIFO of touch runs
        # [t0, region, col0, n, off, shift0, pristine]: ticks are globally
        # monotone (one per run), so the queue is tick-ordered and an LRU
        # pop is a front scan that lazily skips re-touched (stale) and
        # already-evicted cells — amortized O(1) per page.  ``pristine``
        # runs were never overlapped by a later op of the same worker, so
        # their live cells are exactly the [off, n) suffix and eviction
        # needs no touch scan (see _q_append)
        self._lru_q: List[deque] = [deque() for _ in range(n_workers)]
        self._q_degraded = np.zeros(n_workers, bool)
        self._dirty_regions: List[set] = [set() for _ in range(n_workers)]
        self._reductions: Dict[str, List[Tuple[float, str]]] = {}
        self._reduction_results: Dict[str, float] = {}
        self._tick = 0
        self._rows_all = np.arange(n_workers)
        # phase_all path counters (which engine paths ran; the trace-fuzz
        # suite asserts the batched-eviction and residual paths are
        # actually exercised rather than silently bypassed)
        self.stats = {"batched_phases": 0, "evict_batch_rounds": 0,
                      "danger_ops": 0, "residual_replays": 0,
                      "danger_vec_ops": 0, "danger_scalar_ops": 0}

    # ------------------------------------------------------------------
    def alloc(self, n_elems: int) -> GasArray:
        pages = -(-n_elems // self.page_words)
        ga = GasArray(self.n_pages, n_elems, self.page_words)
        self._region_starts.append(self.n_pages)
        self._region_ends.append(self.n_pages + pages)
        self._region_starts_np = np.asarray(self._region_starts, np.int64)
        self.dirs.append(RegionDirectory(
            self.W, len(self.dirs), self.n_pages, self.n_pages + pages,
            track_wprot=self._track_wprot, track_touch=self._track_touch,
            backend=self.backend))
        self.n_pages += pages
        return ga

    def _region_of(self, page: int) -> int:
        i = bisect.bisect_right(self._region_starts, page) - 1
        assert 0 <= i and page < self._region_ends[i], page
        return i

    def _net(self, w: int, n_bytes: float, msgs: int = 1):
        if self.protocol == IDEAL_PROTO:
            return
        self.clock[w] += self.cost.xfer_s(n_bytes, msgs)

    def compute(self, w: int, *, flops: float = 0.0, mem_bytes: float = 0.0,
                seconds: float = 0.0):
        self.clock[w] += seconds + self.cost.compute_s(
            flops, mem_bytes, self.cost.workers_on_node(self.W))

    def instr_stores(self, w: int, n_words: float):
        """Inner-loop stores to shared memory that the LLVM pass instruments
        (e.g. MD force accumulation): charged per word under the fine
        protocol; under the page protocol they hit already-faulted pages."""
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[w] += n_words * self.instr_s_per_word

    # ------------------------------------------------------------------
    # interval fetch / batched eviction
    # ------------------------------------------------------------------

    _Q_SCAN_LIMIT = 64

    def _q_append(self, w: int, region: int, col0: int, n: int,
                  shift0: int) -> int:
        """Append a touch run to w's tick-ordered LRU queue and return its
        fresh (monotone) tick.  Older queued runs of the same region whose
        live span overlaps the new run lose their ``pristine`` flag —
        their overlapped cells are re-touched by this op, so the
        prefix-liveness shortcut no longer holds for them.  Queues longer
        than the scan limit (per-page danger-path runs) degrade wholesale
        to non-pristine, keeping appends O(1) amortized; eviction then
        falls back to the exact touch scan."""
        self._tick += 1
        q = self._lru_q[w]
        pristine = True
        if len(q) > self._Q_SCAN_LIMIT:
            if not self._q_degraded[w]:
                for e in q:
                    e[6] = False
                self._q_degraded[w] = True
            pristine = False
        else:
            self._q_degraded[w] = False
            hi = col0 + n
            for e in q:
                if e[1] != region or not e[6]:
                    continue
                ec0 = e[2] + (shift0 - e[5])
                if ec0 + e[4] < hi and ec0 + e[3] > col0:
                    e[6] = False
        q.append([self._tick, region, col0, n, 0, shift0, pristine])
        return self._tick

    def _fetch_range(self, w: int, region: int, p_lo: int, p_hi: int):
        """Make pages [p_lo, p_hi) valid at w, charging misses."""
        d = self.dirs[region]
        d.ensure(w, p_lo, p_hi)
        s = d.sl(w, p_lo, p_hi)
        n = p_hi - p_lo
        n_miss = n - int(d.valid[w, s].sum())
        if d.touch is not None:
            # one monotone tick per touch RUN (column order within a run
            # is the reference's per-op LRU order, so per-page tick values
            # are redundant — see DIRECTORY.md): re-touches by later runs
            # get strictly larger ticks, which is all staleness detection
            # compares
            d.touch[w, s] = self._q_append(w, region, s.start, n,
                                           int(d.shift[w]))
            n_enter = n - int(d.incache[w, s].sum())
            if n_enter:
                d.incache[w, s] = True
                self.resident[w] += n_enter
        if n_miss:
            if self.protocol != IDEAL_PROTO:
                self.traffic.page_fetches += n_miss
                self.traffic.fetch_bytes += n_miss * self.page_bytes
                n_req = -(-n_miss // self.fetch_batch)
                self._net(w, n_miss * self.page_bytes, 2 * n_req)
            d.valid[w, s] = True

    def _danger(self, w: int, n_enter: int, n: int) -> bool:
        """Batched end-of-op eviction is exact unless this op can evict a
        page of its *own* range (one already occupying a cache slot) before
        touching it — the reference would then refetch / re-enter it
        mid-op.  That needs both an in-cache page in the range
        (n_enter < n) and an eviction this op; fully-cold ranges (the spill
        benchmarks' steady state) and eviction-free ops stay on the batch
        path."""
        return (self.cache_pages is not None
                and self.protocol != IDEAL_PROTO
                and n_enter < n
                and int(self.resident[w]) + n_enter > self.cache_pages)

    def _evict_now(self, w: int, d: RegionDirectory, vc: np.ndarray):
        """Evict the cells ``vc`` (ascending tick order) of w's row in
        region d: dirty victims (valid or not) write back first — one
        message per page, matching the reference's per-page eviction flush
        — then both ``valid`` and the cache slot (``incache``) drop.
        Contiguous victim runs (the streaming-spill steady state) use
        slice ops instead of fancy indexing."""
        lo, hi = int(vc[0]), int(vc[-1]) + 1
        sl = slice(lo, hi) if hi - lo == vc.size else vc
        dmask = d.dirty[w, sl]
        if dmask.any():
            db = vc[dmask]
            d.dirty[w, sl] = False     # only the db cells were set
            if self.protocol != IDEAL_PROTO:
                self.traffic.writeback_bytes += db.size * self.page_bytes
                self.clock[w] += (self.cost.net_latency_s * db.size
                                  + db.size * self.page_bytes
                                  / self.cost.net_bw_Bps)
                if d.wprot is not None:
                    d.wprot[w, db] = True
                self._invalidate_sharers(w, d.region, d.base[w] + db)
        d.valid[w, sl] = False
        d.incache[w, sl] = False
        self.resident[w] -= vc.size

    def _evict_cells(self, w: int, k: int):
        """Evict w's k least-recently-touched cache occupants by scanning
        the tick-ordered run queue from the front, lazily skipping cells
        that were re-touched (their live entry is a later run) or already
        evicted.  Each queue cell is examined O(1) times overall, so
        steady-state spill eviction is amortized O(1) per page."""
        q = self._lru_q[w]
        while k > 0:
            run = q[0]
            t0, region, col0, n, off, shift0, pristine = run
            d = self.dirs[region]
            c0 = col0 + (int(d.shift[w]) - shift0)
            if pristine:
                # never re-touched: live cells are exactly [off, n), so
                # the victims are a contiguous prefix — no touch scan
                tk = min(k, n - off)
                self._evict_now(w, d, np.arange(c0 + off, c0 + off + tk))
                k -= tk
                if off + tk == n:
                    q.popleft()
                else:
                    run[4] = off + tk
                continue
            sl = slice(c0 + off, c0 + n)      # run cells are contiguous
            live = (d.touch[w, sl] == t0) & d.incache[w, sl]
            idx = np.nonzero(live)[0]
            if idx.size == 0:
                q.popleft()
                continue
            take = idx[:k]
            self._evict_now(w, d, c0 + off + take)
            k -= take.size
            if take.size == idx.size:
                q.popleft()          # no live cells remain in this run
            else:
                run[4] = off + int(take[-1]) + 1

    def _touch_page_exact(self, w: int, d: RegionDirectory, p: int,
                          fetch: bool) -> int:
        """Per-page touch/fetch + immediate LRU eviction, mirroring the
        reference's ``_fetch``/``_touch_lru`` sequence for dangerous ops.
        Returns the number of pages fetched (0/1); the *caller* charges
        the fetch messages once per op so batching (``fetch_batch``)
        costs the same on this path as on the batch path."""
        col = p - int(d.base[w])
        n_miss = 0
        if not d.valid[w, col]:
            if fetch and self.protocol != IDEAL_PROTO:
                self.traffic.page_fetches += 1
                self.traffic.fetch_bytes += self.page_bytes
                n_miss = 1
            d.valid[w, col] = True
        if not d.incache[w, col]:
            d.incache[w, col] = True
            self.resident[w] += 1
        d.touch[w, col] = self._q_append(w, d.region, col, 1,
                                         int(d.shift[w]))
        if self.resident[w] > self.cache_pages:
            self._evict_cells(w, int(self.resident[w]) - self.cache_pages)
        return n_miss

    def _danger_replay(self, w: int, d: RegionDirectory, region: int,
                       p_lo: int, p_hi: int,
                       fetch_flag: Optional[np.ndarray], *,
                       is_write: bool) -> int:
        """Vectorized mid-op refetch replay: the exact effects of the
        reference's page-by-page touch/fetch/evict interleave for one
        danger-flagged op, computed analytically as a segmented
        evict-then-refetch schedule instead of a Python loop over pages.

        The key structure (see DIRECTORY.md §refetch schedule): within an
        op the touch front sweeps the op's columns left to right while
        the eviction front consumes the worker's LRU victim stream in
        tick order, and the two interact only at the op's *in-cache
        segments* — maximal column runs of the op range that are cache
        slots of one pre-op touch run (victim order within a run is
        column order, so both fronts traverse a segment the same way).
        When the touch front reaches a segment none of whose cells have
        been evicted yet, touching makes the whole segment stale before
        any eviction can reach it (touching is free — no enters, so the
        eviction front cannot advance).  When at least one cell has been
        evicted, the eviction front is ahead of the touch front inside
        the segment and every touch refetches an evicted cell — an enter
        that (past the watermark) evicts exactly one more victim, keeping
        the front ahead: the WHOLE segment evicts-then-refetches.  The
        schedule therefore resolves per segment, not per page: cold cells
        and refetched segments contribute enters in bulk, victims are
        consumed from the LRU queue run-by-run (rank-select over each
        run's live mask — ``directory.take_upto_row``, packed
        ``take_first_k``/``kth_set_index`` kernels on 'pallas'), and once
        the pre-op stream is exhausted the op consumes its own oldest
        touched columns (a prefix, since op ticks ascend with columns).

        ``fetch_flag`` marks which pages charge a fetch when invalid at
        touch time (None = all; writes pass the partial-page mask).
        Returns the fetch-miss count — the caller charges the op's fetch
        messages once, like the batch path.  Traffic is identical to the
        scalar walk cell for cell; clock charges group per victim run
        (allclose vs the reference, bit-equal across drivers since both
        run this same code)."""
        C = int(self.cache_pages)
        base = int(d.base[w])
        c0 = int(p_lo) - base
        n = int(p_hi) - int(p_lo)
        s = slice(c0, c0 + n)
        incache0 = d.incache[w, s].copy()
        valid0 = d.valid[w, s].copy()
        dirty0 = d.dirty[w, s].copy()
        touch0 = d.touch[w, s].copy()
        R0 = int(self.resident[w])
        slack = C - R0
        q = self._lru_q[w]
        pb = self.page_bytes

        # maximal op segments of constant (in-cache, owning run): cold
        # cells key to -1, in-cache cells to their touch tick
        key = np.where(incache0, touch0, np.int64(-1))
        cuts = np.flatnonzero(np.diff(key)) + 1
        seg_lo = np.concatenate(([0], cuts))
        seg_hi = np.concatenate((cuts, [n]))

        evicted_pre = np.zeros(n, bool)   # evicted before their touch
        touch_front = 0
        qi = 0                            # victim stream cursor: run index
        roff = int(q[0][4]) if q else 0   # ... and scan offset within it

        def consume(k: int) -> int:
            """Consume k victims from the pre-op stream in tick order,
            applying eviction effects; returns the shortfall once the
            stream is exhausted (consumed from the op's own cells)."""
            nonlocal qi, roff
            while k > 0 and qi < len(q):
                run = q[qi]
                t0r, rg, col0, nr = run[0], run[1], run[2], run[3]
                if roff >= nr:
                    qi += 1
                    roff = int(q[qi][4]) if qi < len(q) else 0
                    continue
                dr = self.dirs[rg]
                cc0 = col0 + (int(dr.shift[w]) - run[5])
                a, b = cc0 + roff, cc0 + nr
                in_op = dr is d and a < c0 + n and b > c0
                if run[6] and not in_op:
                    # pristine, outside the op: a contiguous live prefix
                    take = min(k, nr - roff)
                    self._evict_now(w, dr, np.arange(a, a + take))
                    k -= take
                    roff += take
                    continue
                live = (np.ones(b - a, bool) if run[6]
                        else (dr.touch[w, a:b] == t0r) & dr.incache[w, a:b])
                if in_op:
                    # cells of the op range already touched are the
                    # newest copies — never pre-op victims
                    opj = np.arange(a - c0, b - c0)
                    stale = (opj >= 0) & (opj < n) & (opj < touch_front)
                    live &= ~stale
                tot = int(live.sum())
                if tot <= k:
                    vc = np.flatnonzero(live) + a
                    if vc.size:
                        self._evict_now(w, dr, vc)
                        if in_op:
                            ej = vc - c0
                            ej = ej[(ej >= 0) & (ej < n)]
                            evicted_pre[ej] = True
                    k -= tot
                    roff = nr
                    continue
                take_mask, cut = dr.take_upto_row(live, k)
                vc = np.flatnonzero(take_mask) + a
                self._evict_now(w, dr, vc)
                if in_op:
                    ej = vc - c0
                    ej = ej[(ej >= 0) & (ej < n)]
                    evicted_pre[ej] = True
                roff += cut
                k = 0
            return k

        enters = 0
        ev_done = 0
        own_done = 0
        for j0, j1 in zip(seg_lo, seg_hi):
            j0, j1 = int(j0), int(j1)
            if incache0[j0] and not evicted_pre[j0]:
                touch_front = j1          # stale touches: no enters
                continue
            # cold cells, or an in-cache segment whose prefix was already
            # evicted (the refetch cascade claims the whole segment)
            enters += j1 - j0
            target = enters - slack
            if target > ev_done:
                own_done += consume(target - ev_done)
                ev_done = target
            touch_front = j1

        # fetch misses: every cell invalid at its touch (never valid, or
        # evicted mid-op) whose page charges a fetch
        miss = ~valid0 | evicted_pre
        if fetch_flag is not None:
            miss &= fetch_flag
        n_miss = int(miss.sum())
        if n_miss and self.protocol != IDEAL_PROTO:
            self.traffic.page_fetches += n_miss
            self.traffic.fetch_bytes += n_miss * pb

        # final plane state of the op range, then the op's own oldest
        # columns consumed once the stream ran dry (always a prefix — op
        # ticks ascend with columns) evict through the shared `_evict_now`
        # effect sequence, reading their post-touch dirty state (write ops
        # just marked them dirty) straight off the planes
        d.valid[w, s] = True
        d.incache[w, s] = True
        if is_write:
            d.dirty[w, s] = True
            d.maybe_dirty = True
            self._dirty_regions[w].add(region)
        else:
            d.dirty[w, s] = dirty0 & ~evicted_pre
        assert own_done < n, (own_done, n)
        if own_done:
            self._evict_now(w, d, np.arange(c0, c0 + own_done))

        # queue: drop fully-consumed front runs, advance the partial one,
        # append the op's own touch run (its consumed prefix starts dead)
        for _ in range(min(qi, len(q))):
            q.popleft()
        if q:
            if roff >= q[0][3]:       # cursor drained the run exactly
                q.popleft()
            else:
                q[0][4] = roff
        tick = self._q_append(w, region, c0, n, int(d.shift[w]))
        d.touch[w, s] = tick
        if own_done:
            q[-1][4] = own_done
        self.resident[w] += enters     # _evict_now debited every victim
        assert int(self.resident[w]) == min(R0 + enters, C), (
            self.resident[w], R0, enters, C)
        return n_miss

    def _maybe_evict(self, w: int):
        """Watermark-triggered batched eviction: no per-op work unless the
        occupancy counter crossed ``cache_pages``; then the oldest pages
        (exact LRU via monotone ticks) are evicted in one queue pass."""
        if self.cache_pages is None or self.resident[w] <= self.cache_pages:
            return
        self._evict_cells(w, int(self.resident[w]) - self.cache_pages)

    # ------------------------------------------------------------------
    # reads / writes (interval API)
    # ------------------------------------------------------------------

    def read(self, w: int, ga: GasArray, lo: int, hi: int):
        region = self._region_of(ga.page_lo)
        p_lo = ga.page_lo + lo // self.page_words
        p_hi = ga.page_lo + (max(hi - 1, lo)) // self.page_words + 1
        arr_end = ga.page_lo + -(-ga.n_elems // self.page_words)
        p_hi_pf = min(p_hi + self.prefetch, arr_end)   # sequential prefetch
        p_hi = max(p_hi_pf, p_hi)
        if self.cache_pages is not None:
            d = self.dirs[region]
            d.ensure(w, p_lo, p_hi)
            s = d.sl(w, p_lo, p_hi)
            n = p_hi - p_lo
            n_enter = n - int(d.incache[w, s].sum())
            if self._danger(w, n_enter, n):
                if self.danger_mode == "vec" and self.cache_pages >= 1:
                    self.stats["danger_vec_ops"] += 1
                    n_miss = self._danger_replay(w, d, region, p_lo, p_hi,
                                                 None, is_write=False)
                else:
                    self.stats["danger_scalar_ops"] += 1
                    n_miss = 0
                    for p in range(p_lo, p_hi):
                        n_miss += self._touch_page_exact(w, d, p, fetch=True)
                if n_miss:
                    self._net(w, n_miss * self.page_bytes,
                              2 * -(-n_miss // self.fetch_batch))
                return None
        self._fetch_range(w, region, p_lo, p_hi)
        self._maybe_evict(w)
        return None

    def write(self, w: int, ga: GasArray, lo: int, hi: int, values=None):
        region = self._region_of(ga.page_lo)
        p_lo = ga.page_lo + lo // self.page_words
        p_hi = ga.page_lo + (max(hi - 1, lo)) // self.page_words + 1
        d = self.dirs[region]
        d.ensure(w, p_lo, p_hi)
        in_span = bool(self.spans[w])
        if not in_span:
            d.note_dirty(w, p_lo, p_hi)
        n_words = hi - lo

        # mechanism cost: instrumented stores (fine) / write faults (page)
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[w] += n_words * self.instr_s_per_word
        if self._track_wprot:
            s = d.sl(w, p_lo, p_hi)
            n_faults = int(d.wprot[w, s].sum())
            self.clock[w] += n_faults * self.fault_s
            d.wprot[w, s] = False

        if self.cache_pages is not None and self.protocol != IDEAL_PROTO:
            s = d.sl(w, p_lo, p_hi)
            n = p_hi - p_lo
            n_enter0 = n - int(d.incache[w, s].sum())
            if self._danger(w, n_enter0, n):
                if (self.danger_mode == "vec" and self.cache_pages >= 1
                        and not in_span):
                    # spans stay on the scalar walk: critical sections
                    # touch few pages and need per-page span.touched
                    # interval merging
                    self.stats["danger_vec_ops"] += 1
                    pages = np.arange(p_lo, p_hi)
                    bw_ = (pages - ga.page_lo) * self.page_words
                    wlo_v = np.maximum(lo - bw_, 0)
                    whi_v = np.minimum(hi - bw_, self.page_words)
                    n_miss = self._danger_replay(
                        w, d, region, p_lo, p_hi,
                        (whi_v - wlo_v) < self.page_words, is_write=True)
                    if n_miss:
                        self._net(w, n_miss * self.page_bytes,
                                  2 * -(-n_miss // self.fetch_batch))
                    return
                # exact per-page replica of the reference's write-allocate +
                # LRU sequence (see _danger)
                self.stats["danger_scalar_ops"] += 1
                span = self.spans[w][-1] if in_span else None
                base = int(d.base[w])
                n_miss = 0
                for p in range(p_lo, p_hi):
                    wlo, whi = ga.word_range_in_page(p, lo, hi)
                    n_miss += self._touch_page_exact(
                        w, d, p, fetch=(whi - wlo) < self.page_words)
                    if in_span:
                        old = span.touched.get(p)
                        span.touched[p] = ((min(wlo, old[0]),
                                            max(whi, old[1]))
                                           if old else (wlo, whi))
                    else:
                        d.dirty[w, p - base] = True
                        d.maybe_dirty = True
                        self._dirty_regions[w].add(region)
                if n_miss:
                    self._net(w, n_miss * self.page_bytes,
                              2 * -(-n_miss // self.fetch_batch))
                return

        # write-allocate: partial edge pages must be fetched; interior
        # full-page writes just become valid
        if self.protocol != IDEAL_PROTO:
            if p_hi - p_lo == 1:
                if n_words < self.page_words:
                    self._fetch_range(w, region, p_lo, p_lo + 1)
            else:
                if lo % self.page_words != 0:
                    self._fetch_range(w, region, p_lo, p_lo + 1)
                if hi % self.page_words != 0:
                    self._fetch_range(w, region, p_hi - 1, p_hi)
        s = d.sl(w, p_lo, p_hi)
        n = p_hi - p_lo
        n_new = n - int(d.valid[w, s].sum())
        if d.touch is not None:
            d.touch[w, s] = self._q_append(w, region, s.start, n,
                                           int(d.shift[w]))
            n_enter = n - int(d.incache[w, s].sum())
            if n_enter:
                d.incache[w, s] = True
                self.resident[w] += n_enter
        if n_new:
            d.valid[w, s] = True

        if in_span:
            span = self.spans[w][-1]
            for p in range(p_lo, p_hi):
                wlo, whi = ga.word_range_in_page(p, lo, hi)
                old = span.touched.get(p)
                span.touched[p] = ((min(wlo, old[0]), max(whi, old[1]))
                                   if old else (wlo, whi))
        else:
            d.dirty[w, s] = True
            d.maybe_dirty = True
            self._dirty_regions[w].add(region)
        self._maybe_evict(w)

    # ------------------------------------------------------------------
    # ordinary flush (page granularity in both protocols)
    # ------------------------------------------------------------------

    def _invalidate_sharers(self, w: int, region: int, pages: np.ndarray):
        """Invalidate every other worker's valid copy of ``pages``.

        Small page sets (accumulator pages, many overlapping rows) use one
        dense boolean-mask gather over the worker axis; wide page sets
        (block flushes — few overlapping neighbours, thousands of pages)
        intersect each row's window with the sorted page list instead, so
        work tracks actual coverage rather than rows x pages."""
        d = self.dirs[region]
        rows = d.overlap_rows(int(pages[0]), int(pages[-1]) + 1, exclude=w)
        if rows.size == 0:
            return
        if pages.size <= 64:
            hit, cols = d.gather_valid(rows, pages)
            n_inv = int(hit.sum())
            if n_inv:
                # valid drops but the pages keep their cache slots
                # (``incache``) until evicted, like the reference's LRU dict
                d.clear_valid_cells(rows, cols, hit)
                self.traffic.invalidations += n_inv
                self.traffic.control_msgs += n_inv
            return
        n_inv = 0
        for v in rows:
            b = int(d.base[v])
            i0 = int(np.searchsorted(pages, b))
            i1 = int(np.searchsorted(pages, b + int(d.length[v])))
            if i0 >= i1:
                continue
            cols = pages[i0:i1] - b
            vcells = d.valid[v, cols]
            k = int(vcells.sum())
            if k:
                d.valid[v, cols[vcells]] = False
                n_inv += k
        if n_inv:
            self.traffic.invalidations += n_inv
            self.traffic.control_msgs += n_inv

    def _flush_worker(self, w: int):
        """Write back + invalidate sharers for all of w's ordinary-dirty
        pages (the single-flusher path used by acquire)."""
        regions = self._dirty_regions[w]
        if not regions:
            return
        for region in sorted(regions):
            d = self.dirs[region]
            cols = d.row_dirty_cols(w)
            d.clear_dirty_bounds(w)
            if cols.size == 0:
                continue
            d.dirty[w, cols] = False
            if self.protocol == IDEAL_PROTO:
                continue
            n_dirty = cols.size
            self.traffic.writeback_bytes += n_dirty * self.page_bytes
            self._net(w, n_dirty * self.page_bytes,
                      -(-n_dirty // self.fetch_batch))   # batched writeback
            if d.wprot is not None:
                d.wprot[w, cols] = True     # re-arm write protection
            self._invalidate_sharers(w, region, d.base[w] + cols)
        regions.clear()

    def _flush_all_workers(self):
        """Barrier-time flush of every worker's ordinary-dirty pages, in
        one batched pass per region that reproduces the sequential
        flush-order semantics analytically (see DIRECTORY.md):

        for a page with dirty-worker set D (flushed in worker order) and
        initial valid set V, the sequential per-worker flushes produce
        ``|V \\ {d0}| + [|D|>1]*[d0 in V]`` invalidations and leave the page
        valid only at d0 when ``|D|==1``.  Pages covered by a single worker
        window contribute nothing (their only possible sharer is their own
        writer), so the gather runs only over multiply-covered pages.
        """
        for d in self.dirs:
            if not d.maybe_dirty:
                continue
            nD_w = d.dirty_counts()        # bitmask popcount on 'pallas'
            total = int(nD_w.sum())
            d.maybe_dirty = False
            d.clear_dirty_bounds()
            if total == 0:
                continue
            if self.protocol == IDEAL_PROTO:
                d.dirty[:] = False
                continue
            active = np.nonzero(nD_w)[0]
            # per-(worker, region) writeback charge, as in the sequential
            # flush: one batched message group per worker window
            self.traffic.writeback_bytes += total * self.page_bytes
            msgs = -(-nD_w[active] // self.fetch_batch)
            self.clock[active] += (self.cost.net_latency_s * msgs
                                   + (nD_w[active] * self.page_bytes)
                                   / self.cost.net_bw_Bps)
            if d.wprot is not None:
                np.logical_or(d.wprot, d.dirty, out=d.wprot)  # re-arm own
            # sharer invalidation: only pages under >= 2 worker windows can
            # have sharers, so per-cell work is confined to the (small)
            # halo/global intervals instead of every dirty page
            starts, ends = d.shared_intervals()
            if starts.size:
                w_list, col_list = [], []
                for w in active:
                    b = int(d.base[w])
                    e = b + int(d.length[w])
                    i0 = int(np.searchsorted(ends, b, "right"))
                    i1 = int(np.searchsorted(starts, e, "left"))
                    for i in range(i0, i1):
                        lo = max(int(starts[i]), b)
                        hi = min(int(ends[i]), e)
                        if lo >= hi:
                            continue
                        c = np.nonzero(d.dirty[w, lo - b:hi - b])[0]
                        if c.size:
                            col_list.append(c + (lo - b))
                            w_list.append(np.full(c.size, w, np.int64))
                if col_list:
                    w_idx = np.concatenate(w_list)   # ascending worker ==
                    cols = np.concatenate(col_list)  # sequential flush order
                    self._invalidate_shared_dirty(d, w_idx, cols)
            d.dirty[:] = False
        for regions in self._dirty_regions:
            regions.clear()

    def _invalidate_shared_dirty(self, d: RegionDirectory,
                                 w_idx: np.ndarray, cols: np.ndarray):
        """Apply the analytic sequential-flush invalidation to the dirty
        cells (worker-major order) of multiply-covered pages.

        The gather is sparse: worker windows are intervals, so each row
        sees only a contiguous slice of the page list ``u`` — total
        (row, page) pairs ~ the actual window coverage, not rows x pages
        (a dense gather over block-partitioned arrays touches W x |u|
        cells to find ~2 live ones per page)."""
        pages = d.base[w_idx] + cols
        u, first, counts = np.unique(pages, return_index=True,
                                     return_counts=True)
        d0_rows = w_idx[first]                # min dirty worker per page
        d0_valid = d.valid[d0_rows, cols[first]]
        rows = d.overlap_rows(int(u[0]), int(u[-1]) + 1)
        pr_l, pu_l, pc_l = [], [], []
        for w in rows:
            b = int(d.base[w])
            i0 = int(np.searchsorted(u, b))
            i1 = int(np.searchsorted(u, b + int(d.length[w])))
            if i0 < i1:
                pr_l.append(np.full(i1 - i0, w, np.int64))
                pu_l.append(np.arange(i0, i1))
                pc_l.append(u[i0:i1] - b)
        pr = np.concatenate(pr_l)             # pair: worker row
        pu = np.concatenate(pu_l)             # pair: index into u
        pc = np.concatenate(pc_l)             # pair: column in row
        val = d.valid[pr, pc]
        nV0 = np.bincount(pu[val], minlength=u.size)
        d0v = d0_valid.astype(np.int64)
        n_inv = int((nV0 - d0v + np.where(counts > 1, d0v, 0)).sum())
        if n_inv:
            self.traffic.invalidations += n_inv
            self.traffic.control_msgs += n_inv
        # final valid state: keep only a sole dirty writer's copy
        keep = (counts == 1)[pu] & (pr == d0_rows[pu])
        hot = val & ~keep
        if hot.any():
            d.valid[pr[hot], pc[hot]] = False

    # ------------------------------------------------------------------
    # spans + notice replay
    # ------------------------------------------------------------------

    def _replay_invalidate(self, w: int, pages: np.ndarray, rearm: bool):
        """Page-protocol notice replay: invalidate w's valid copies of
        ``pages`` (grouped per region), returning the number invalidated."""
        total = 0
        regions = np.searchsorted(self._region_starts_np, pages, "right") - 1
        for r in np.unique(regions):
            d = self.dirs[int(r)]
            if d.base[w] < 0:
                continue
            pr = pages[regions == r]
            cols = pr - d.base[w]
            inr = (cols >= 0) & (cols < d.length[w])
            vcells = d.valid[w, np.where(inr, cols, 0)] & inr
            n = int(vcells.sum())
            if n:
                hot = cols[vcells]
                d.valid[w, hot] = False
                if rearm and d.wprot is not None:
                    d.wprot[w, hot] = True
                total += n
        return total

    def acquire(self, w: int, lock_id: int):
        lk = self.locks.setdefault(lock_id, _Lock(self.W))
        self._flush_worker(w)                       # RegC rule 1
        self._net(w, 64, 2)
        self.traffic.control_msgs += 2
        self.clock[w] = max(self.clock[w], lk.last_release_time)
        # RegC rule 2, notices coalesced per page (matches reference)
        u, lo_u, hi_u = lk.log.pending(int(lk.seen[w]), lk.version)
        if u.size:
            if self.protocol == FINE_PROTO:
                nbytes = (hi_u - lo_u) * _WORD + self.page_words // 8
                tot = int(nbytes.sum())
                self.traffic.diff_bytes += tot
                self.clock[w] += (self.cost.net_latency_s * u.size
                                  + tot / self.cost.net_bw_Bps)
            else:
                n_inv = self._replay_invalidate(
                    w, u, rearm=self.model_mechanism)
                self.traffic.invalidations += n_inv
                self.traffic.control_msgs += int(u.size)
        lk.seen[w] = lk.version
        self.spans[w].append(_Span(lock_id))

    def release(self, w: int, lock_id: int):
        span = self.spans[w].pop()
        assert span.lock == lock_id, "unbalanced lock release"
        lk = self.locks[lock_id]
        pages, los, his = [], [], []
        for p, (lo, hi) in sorted(span.touched.items()):
            if self.protocol == IDEAL_PROTO:
                continue
            if self.protocol == FINE_PROTO:
                nbytes = (hi - lo) * _WORD + self.page_words // 8
                self.traffic.diff_bytes += nbytes
            else:
                nbytes = self.page_bytes
                self.traffic.writeback_bytes += nbytes
            self._net(w, nbytes, 1)
            pages.append(p)
            los.append(lo)
            his.append(hi)
        if self.protocol != IDEAL_PROTO:
            lk.log.append_version(pages, los, his)
            lk.version += 1
            lk.seen[w] = lk.version
        self._net(w, 64, 1)
        self.traffic.control_msgs += 1
        lk.last_release_time = self.clock[w]

    class _SpanCtx:
        def __init__(self, rt, w, lock_id):
            self.rt, self.w, self.lock_id = rt, w, lock_id

        def __enter__(self):
            self.rt.acquire(self.w, self.lock_id)

        def __exit__(self, *exc):
            self.rt.release(self.w, self.lock_id)
            return False

    def span(self, w: int, lock_id: int):
        return self._SpanCtx(self, w, lock_id)

    # ------------------------------------------------------------------
    # batched SPMD driver fast path
    # ------------------------------------------------------------------

    def phase(self, w: int, reads=(), writes=(), *, flops: float = 0.0,
              mem_bytes: float = 0.0, seconds: float = 0.0,
              instr_words: float = 0.0):
        """One worker-phase in a single runtime call: interval reads, then
        interval writes, then the modeled compute + instrumented stores.
        ``reads``/``writes`` are sequences of ``(ga, lo, hi)``.  This is
        the per-worker reference path that ``phase_all`` batches over the
        worker axis (and through which it replays the residual
        interacting workers of eviction-capable phases)."""
        for ga, lo, hi in reads:
            self.read(w, ga, lo, hi)
        for ga, lo, hi in writes:
            self.write(w, ga, lo, hi)
        if flops or mem_bytes or seconds:
            self.compute(w, flops=flops, mem_bytes=mem_bytes, seconds=seconds)
        if instr_words:
            self.instr_stores(w, instr_words)

    # ------------------------------------------------------------------
    # worker-axis batched driver (phase_all)
    # ------------------------------------------------------------------

    def _w_arr(self, v) -> np.ndarray:
        return np.broadcast_to(np.asarray(v, np.int64), (self.W,))

    def _page_range_all(self, ga, lo: np.ndarray, hi: np.ndarray, *,
                        prefetch: bool):
        pw = self.page_words
        p_lo = ga.page_lo + lo // pw
        p_hi = ga.page_lo + np.maximum(hi - 1, lo) // pw + 1
        if prefetch:
            arr_end = ga.page_lo + -(-ga.n_elems // pw)
            p_hi = np.maximum(np.minimum(p_hi + self.prefetch, arr_end), p_hi)
        return self._region_of(int(ga.page_lo)), p_lo, p_hi

    def _may_evict_mask(self, ranges) -> Optional[np.ndarray]:
        """Per-worker eviction-possibility upper bound for one phase (the
        per-worker refinement of the old all-or-nothing ``_phase_fits``
        precheck): every page that can newly occupy a cache slot this
        phase is not-incache at phase start and lies in some declared
        range, so ``resident + sum over ops of (range length - in-cache
        count)`` bounds each worker's peak occupancy (overlapping ranges
        only loosen the bound).  Returns None when no worker can cross
        the watermark — the phase then runs fully batched with no
        eviction work at all."""
        if self.cache_pages is None:
            return None
        quick = self.resident.copy()
        for region, p_lo, p_hi in ranges:
            quick += p_hi - p_lo
        if (quick <= self.cache_pages).all():
            return None            # even all-cold ranges fit: no gathers
        ub = self.resident.copy()
        for region, p_lo, p_hi in ranges:
            d = self.dirs[region]
            ub += (p_hi - p_lo) - d.count_range(d.incache, p_lo, p_hi)
        may = ub > self.cache_pages
        return may if may.any() else None

    def _residual_workers(self, rranges, wranges,
                          may: np.ndarray) -> np.ndarray:
        """Window-disjointness analysis: which workers' phase executions
        can interact through eviction.

        Within a phase (no barriers, no spans) the ONLY cross-worker
        effect is an eviction writeback invalidating another worker's
        valid copy of the victim page — and only ``may``-workers can
        evict.  An evictor's dirty victims lie inside its conservative
        dirty bounds (the directory's per-row dirty bounding interval,
        widened by this phase's declared write ranges); another worker can
        observe the writeback only if those pages intersect its *reach*
        (current window + declared ranges: valid copies exist only inside
        the window, and this phase fetches only inside the ranges).
        Workers touched by no such intersection are pairwise independent
        — their per-worker op sequences commute, so they run batched.
        The returned mask marks the rest, which replay tick-ordered."""
        resid = np.zeros(self.W, bool)
        reach: Dict[int, list] = {}
        for region, p_lo, p_hi in rranges + wranges:
            r = reach.get(region)
            if r is None:
                reach[region] = [p_lo.copy(), p_hi.copy()]
            else:
                np.minimum(r[0], p_lo, out=r[0])
                np.maximum(r[1], p_hi, out=r[1])
        wr: Dict[int, list] = {}
        for region, p_lo, p_hi in wranges:
            r = wr.get(region)
            if r is None:
                wr[region] = [p_lo.copy(), p_hi.copy()]
            else:
                np.minimum(r[0], p_lo, out=r[0])
                np.maximum(r[1], p_hi, out=r[1])
        imax = np.iinfo(np.int64).max
        imin = np.iinfo(np.int64).min
        for ri, d in enumerate(self.dirs):
            dlo, dhi = d.dirty_lo, d.dirty_hi
            if ri in wr:
                dlo = np.minimum(dlo, wr[ri][0])
                dhi = np.maximum(dhi, wr[ri][1])
            e = may & (dlo < dhi)
            if not e.any():
                continue
            live = d.base >= 0
            rlo = np.where(live, d.base, imax)
            rhi = np.where(live, d.base + d.length, imin)
            if ri in reach:
                rlo = np.minimum(rlo, reach[ri][0])
                rhi = np.maximum(rhi, reach[ri][1])
                live = np.ones(self.W, bool)
            E = np.nonzero(e)[0]
            M = ((rlo[None, :] < dhi[E][:, None])
                 & (rhi[None, :] > dlo[E][:, None]) & live[None, :])
            M[np.arange(E.size), E] = False
            if M.any():
                ei, vi = np.nonzero(M)
                resid[E[ei]] = True
                resid[vi] = True
        return resid

    def _op_danger_split(self, d, ga, lo, hi, p_lo, p_hi, rows,
                         may: np.ndarray, *, is_write: bool) -> np.ndarray:
        """Per-op ``_danger`` screening for the batched path: workers
        whose op could evict a still-in-cache page of its own range
        before touching it (the mid-op refetch pattern) replay THIS op
        per worker — ``read``/``write`` resolve it through the analytic
        refetch schedule (``_danger_replay``) — and the rest stay
        batched.  Exact because the split only runs over workers already
        proven independent, so any interleaving of their op executions
        is equivalent."""
        if self.protocol == IDEAL_PROTO:
            return rows
        L = p_hi - p_lo
        cand = may[rows] & (self.resident[rows] + L[rows] > self.cache_pages)
        if not cand.any():
            return rows
        crows = rows[cand]
        n_in = d.count_range(d.incache, p_lo[crows], p_hi[crows], rows=crows)
        n_enter = L[crows] - n_in
        danger = (n_enter < L[crows]) & (
            self.resident[crows] + n_enter > self.cache_pages)
        if not danger.any():
            return rows
        self.stats["danger_ops"] += int(danger.sum())
        for w in crows[danger]:
            if is_write:
                self.write(int(w), ga, int(lo[w]), int(hi[w]))
            else:
                self.read(int(w), ga, int(lo[w]), int(hi[w]))
        keep = np.ones(rows.size, bool)
        keep[np.nonzero(cand)[0][danger]] = False
        return rows[keep]

    def _evict_rows_batch(self, rows: np.ndarray):
        """Watermark eviction for ``rows`` after a batched op: each worker
        over the watermark evicts its least-recently-touched pages
        run-by-run from its tick-ordered queue — same victims, same
        per-run charges as ``_evict_cells`` — but rows whose front runs
        cover the same column span (the lockstep steady state of uniform
        spill phases) apply their liveness test, segment-LRU selection
        and plane updates as single 2D ops (``directory.run_live`` /
        ``lru_take`` / ``evict_rows``).  Only called for workers whose
        evictions provably cannot invalidate any other worker (window
        disjointness), so ``_evict_now``'s sharer-invalidation step is
        skipped as a proven no-op."""
        if rows.size == 0 or self.cache_pages is None:
            return
        k = self.resident[rows] - self.cache_pages
        over = k > 0
        if not over.any():
            return
        rows = rows[over]
        k = k[over].astype(np.int64)
        charge = self.protocol != IDEAL_PROTO
        while rows.size:
            if rows.size < 4:
                for w, kw in zip(rows, k):
                    self._evict_cells(int(w), int(kw))
                return
            self.stats["evict_batch_rounds"] += 1
            # one front run per needy worker, grouped by column span;
            # pristine runs (never re-touched) are fully live on [off, n),
            # so their groups skip the touch scan entirely
            groups: Dict[Tuple[int, int, int, bool], list] = {}
            bts = np.empty(rows.size, np.int64)
            for i, w in enumerate(rows):
                t0, region, col0, n, off, shift0, pris = self._lru_q[w][0]
                d = self.dirs[region]
                c0 = col0 + (int(d.shift[w]) - shift0)
                bts[i] = t0
                groups.setdefault((region, c0 + off, n - off, pris),
                                  []).append(i)
            keep_rows, keep_k = [], []
            for (region, start, length, pris), idxs in groups.items():
                idxs = np.asarray(idxs, np.int64)
                R, kk = rows[idxs], k[idxs]
                d = self.dirs[region]
                if R.size < 4:
                    for w, kw in zip(R, kk):
                        self._evict_cells(int(w), int(kw))
                    continue
                if pris:
                    live = None
                    tot = np.full(R.size, length, np.int64)
                else:
                    live = d.run_live(R, start, length, bts[idxs])
                    tot = live.sum(axis=1, dtype=np.int64)
                part = kk < tot
                for si in (np.nonzero(~part)[0], np.nonzero(part)[0]):
                    if si.size == 0:
                        continue
                    is_part = bool(part[si[0]])
                    whole = si.size == R.size
                    Rs, ks = R[si], kk[si]
                    tots = tot[si]
                    fully = pris or bool((tots == length).all())
                    # segment-LRU selection only where the run outlives
                    # the demand; whole-run and prefix takes of fully-live
                    # runs (the streaming steady state) skip masks
                    span = length
                    if not is_part:
                        take = None if fully else live[si]
                    elif pris and int(ks.min()) == int(ks.max()):
                        span = int(ks[0])      # uniform prefix: short span
                        take = None
                    elif pris:
                        take = np.arange(length) < ks[:, None]
                    else:
                        lv = live if whole else live[si]
                        take = d.lru_take(lv, ks, tots)
                    db = d.evict_rows(Rs, start, span, take,
                                      set_wprot=charge)
                    if charge and db.any():
                        self.traffic.writeback_bytes += (int(db.sum())
                                                         * self.page_bytes)
                        hit = db > 0
                        self.clock[Rs[hit]] += (
                            self.cost.net_latency_s * db[hit]
                            + db[hit] * self.page_bytes
                            / self.cost.net_bw_Bps)
                    if is_part:
                        # advance each run past its last taken cell
                        self.resident[Rs] -= ks
                        if fully:          # columnar take: cutoff is k
                            last = ks - 1
                        else:
                            last = take.shape[1] - 1 - np.argmax(
                                take[:, ::-1], axis=1)
                        for i, w in enumerate(Rs):
                            self._lru_q[w][0][4] += int(last[i]) + 1
                    else:
                        self.resident[Rs] -= tots
                        for w in Rs:
                            self._lru_q[w].popleft()
                        rem = ks - tots
                        m = rem > 0
                        if m.any():
                            keep_rows.append(Rs[m])
                            keep_k.append(rem[m])
            if not keep_rows:
                return
            rows = np.concatenate(keep_rows)
            k = np.concatenate(keep_k)
            # group leftovers concatenate in group order — restore the
            # ascending row order every plane primitive assumes
            order = np.argsort(rows)
            rows = rows[order]
            k = k[order]

    def _fetch_range_all(self, region: int, p_lo: np.ndarray,
                         p_hi: np.ndarray, rows: np.ndarray):
        """Vectorized ``_fetch_range`` over ``rows`` of the worker axis:
        identical per-worker traffic and clock charges.  Strategy is
        per-op: dense (R, Lmax) gather/scatter matrices in the
        many-rows/narrow-intervals regime; otherwise rows group by their
        shared (window-relative start, length) — block-partitioned phases
        are uniform — and each group runs single 2D slice-plane ops."""
        d = self.dirs[region]
        d.ensure_rows(p_lo, p_hi, rows)
        L = p_hi - p_lo
        if use_dense(rows.size, int(L.max())):
            self._fetch_dense(d, region, p_lo, p_hi, rows)
            return
        c0 = p_lo - d.base[rows]
        uk, inv = np.unique(np.stack([c0, L], axis=1), axis=0,
                            return_inverse=True)
        for g in range(uk.shape[0]):
            self._fetch_uniform(d, region, rows[inv == g],
                                int(uk[g, 0]), int(uk[g, 1]))

    def _fetch_uniform(self, d: RegionDirectory, region: int,
                       rows: np.ndarray, c0: int, n: int):
        """One uniform-span fetch group: all ``rows`` fetch columns
        [c0, c0+n) of their windows, so every plane pass is a contiguous
        2D slice op — no gather matrices, no per-row Python loop.  Charge
        expressions match ``_fetch_range`` term for term."""
        s = slice(c0, c0 + n)
        rb = d.row_block(rows)              # slice views for lockstep rows
        n_miss = n - d.valid[rb, s].sum(axis=1)
        if d.touch is not None:
            shifts = d.shift[rows]
            t0 = np.array([self._q_append(int(w), region, c0, n,
                                          int(shifts[i]))
                           for i, w in enumerate(rows)], np.int64)
            d.touch[rb, s] = t0[:, None]
            n_enter = n - d.incache[rb, s].sum(axis=1)
            d.incache[rb, s] = True
            self.resident[rows] += n_enter
        tot_miss = int(n_miss.sum())
        if tot_miss:
            if self.protocol != IDEAL_PROTO:
                self.traffic.page_fetches += tot_miss
                self.traffic.fetch_bytes += tot_miss * self.page_bytes
                n_req = -(-n_miss // self.fetch_batch)
                t = (self.cost.net_latency_s * (2 * n_req)
                     + (n_miss * self.page_bytes) / self.cost.net_bw_Bps)
                hit = n_miss > 0
                self.clock[rows[hit]] += t[hit]
            d.valid[rb, s] = True

    def _fetch_dense(self, d: RegionDirectory, region: int,
                     p_lo: np.ndarray, p_hi: np.ndarray, rows: np.ndarray):
        cols, mask = d.range_cols(p_lo, p_hi, rows)
        safe = np.where(mask, cols, 0)
        r2 = rows[:, None]
        vsub = d.valid[r2, safe] & mask
        L = p_hi - p_lo
        n_miss = L - vsub.sum(axis=1)
        if d.touch is not None:
            # one monotone tick per (worker, op) run: relative order within
            # each worker matches the per-worker path, which is all the
            # LRU victim selection compares (ticks never cross workers)
            t0 = np.array([self._q_append(int(w), region, int(cols[i, 0]),
                                          int(L[i]), int(d.shift[w]))
                           for i, w in enumerate(rows)], np.int64)
            ri, ci = np.nonzero(mask)
            d.touch[rows[ri], cols[ri, ci]] = t0[ri]
            isub = d.incache[r2, safe] & mask
            ri, ci = np.nonzero(mask & ~isub)
            if ri.size:
                d.incache[rows[ri], cols[ri, ci]] = True
            self.resident[rows] += L - isub.sum(axis=1)
        tot_miss = int(n_miss.sum())
        if tot_miss:
            if self.protocol != IDEAL_PROTO:
                self.traffic.page_fetches += tot_miss
                self.traffic.fetch_bytes += tot_miss * self.page_bytes
                n_req = -(-n_miss // self.fetch_batch)
                t = (self.cost.net_latency_s * (2 * n_req)
                     + (n_miss * self.page_bytes) / self.cost.net_bw_Bps)
                hit = n_miss > 0
                self.clock[rows[hit]] += t[hit]
            ri, ci = np.nonzero(mask & ~vsub)
            d.valid[rows[ri], cols[ri, ci]] = True

    def _read_all(self, ga, lo: np.ndarray, hi: np.ndarray, rows=None,
                  may=None):
        region, p_lo, p_hi = self._page_range_all(ga, lo, hi, prefetch=True)
        rows = self._rows_all if rows is None else rows
        if may is not None:
            rows = self._op_danger_split(self.dirs[region], ga, lo, hi,
                                         p_lo, p_hi, rows, may,
                                         is_write=False)
        if rows.size:
            self._fetch_range_all(region, p_lo[rows], p_hi[rows], rows)
        if may is not None:
            self._evict_rows_batch(rows)

    def _write_all(self, ga, lo: np.ndarray, hi: np.ndarray, rows=None,
                   may=None):
        region, p_lo, p_hi = self._page_range_all(ga, lo, hi, prefetch=False)
        d = self.dirs[region]
        rows = self._rows_all if rows is None else rows
        if may is not None:
            rows = self._op_danger_split(d, ga, lo, hi, p_lo, p_hi, rows,
                                         may, is_write=True)
        if rows.size:
            d.ensure_rows(p_lo[rows], p_hi[rows], rows)
            d.note_dirty(rows, p_lo[rows], p_hi[rows])
            L = (p_hi - p_lo)[rows]
            if use_dense(rows.size, int(L.max())):
                self._write_dense(d, region, ga, lo, hi, p_lo, p_hi, rows)
            else:
                c0 = p_lo[rows] - d.base[rows]
                uk, inv = np.unique(np.stack([c0, L], axis=1), axis=0,
                                    return_inverse=True)
                for g in range(uk.shape[0]):
                    self._write_uniform(d, region, lo, hi, p_lo, p_hi,
                                        rows[inv == g],
                                        int(uk[g, 0]), int(uk[g, 1]))
            d.maybe_dirty = True
            for w in rows:
                self._dirty_regions[w].add(region)
        if may is not None:
            self._evict_rows_batch(rows)

    def _write_dense(self, d: RegionDirectory, region: int, ga,
                     lo: np.ndarray, hi: np.ndarray, p_lo: np.ndarray,
                     p_hi: np.ndarray, rows: np.ndarray):
        pw = self.page_words
        n_words = (hi - lo)[rows]

        # mechanism cost, in the per-worker path's charge order
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[rows] += n_words * self.instr_s_per_word
        if self._track_wprot:
            cols, mask = d.range_cols(p_lo[rows], p_hi[rows], rows)
            wsub = d.wprot[rows[:, None], np.where(mask, cols, 0)] & mask
            self.clock[rows] += wsub.sum(axis=1) * self.fault_s
            ri, ci = np.nonzero(mask)
            d.wprot[rows[ri], cols[ri, ci]] = False

        # write-allocate edge fetches (first page, then last page — the
        # per-worker path's order), only for the workers that need them
        n_pg = (p_hi - p_lo)[rows]
        if self.protocol != IDEAL_PROTO:
            single = n_pg == 1
            first = np.where(single, n_words < pw, lo[rows] % pw != 0)
            last = (~single) & (hi[rows] % pw != 0)
            if first.any():
                r = rows[np.nonzero(first)[0]]
                self._fetch_range_all(region, p_lo[r], p_lo[r] + 1, r)
            if last.any():
                r = rows[np.nonzero(last)[0]]
                self._fetch_range_all(region, p_hi[r] - 1, p_hi[r], r)

        cols, mask = d.range_cols(p_lo[rows], p_hi[rows], rows)
        safe = np.where(mask, cols, 0)
        vsub = d.valid[rows[:, None], safe] & mask
        if d.touch is not None:
            shifts = d.shift[rows]
            t0 = np.array([self._q_append(int(w), region, int(cols[i, 0]),
                                          int(n_pg[i]), int(shifts[i]))
                           for i, w in enumerate(rows)], np.int64)
            ri, ci = np.nonzero(mask)
            d.touch[rows[ri], cols[ri, ci]] = t0[ri]
            isub = d.incache[rows[:, None], safe] & mask
            ri, ci = np.nonzero(mask & ~isub)
            if ri.size:
                d.incache[rows[ri], cols[ri, ci]] = True
            self.resident[rows] += n_pg - isub.sum(axis=1)
        ri, ci = np.nonzero(mask & ~vsub)
        if ri.size:
            d.valid[rows[ri], cols[ri, ci]] = True
        ri, ci = np.nonzero(mask)
        d.dirty[rows[ri], cols[ri, ci]] = True

    def _write_uniform(self, d: RegionDirectory, region: int,
                       lo: np.ndarray, hi: np.ndarray, p_lo: np.ndarray,
                       p_hi: np.ndarray, rows: np.ndarray, c0: int, n: int):
        """One uniform-span write group: all ``rows`` write columns
        [c0, c0+n) of their windows — single 2D slice-plane ops, charge
        expressions term-for-term those of the per-worker ``write``."""
        pw = self.page_words
        s = slice(c0, c0 + n)
        rb = d.row_block(rows)              # slice views for lockstep rows
        n_words = (hi - lo)[rows]
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[rows] += n_words * self.instr_s_per_word
        if self._track_wprot:
            n_faults = d.wprot[rb, s].sum(axis=1)
            self.clock[rows] += n_faults * self.fault_s
            d.wprot[rb, s] = False
        if self.protocol != IDEAL_PROTO:
            if n == 1:
                first = n_words < pw
                last = np.zeros(rows.size, bool)
            else:
                first = lo[rows] % pw != 0
                last = hi[rows] % pw != 0
            if first.any():
                r = rows[np.nonzero(first)[0]]
                self._fetch_range_all(region, p_lo[r], p_lo[r] + 1, r)
            if last.any():
                r = rows[np.nonzero(last)[0]]
                self._fetch_range_all(region, p_hi[r] - 1, p_hi[r], r)
        if d.touch is not None:
            shifts = d.shift[rows]
            t0 = np.array([self._q_append(int(w), region, c0, n,
                                          int(shifts[i]))
                           for i, w in enumerate(rows)], np.int64)
            d.touch[rb, s] = t0[:, None]
            n_enter = n - d.incache[rb, s].sum(axis=1)
            d.incache[rb, s] = True
            self.resident[rows] += n_enter
        d.valid[rb, s] = True
        d.dirty[rb, s] = True

    def phase_all(self, reads=(), writes=(), *, flops=0.0, mem_bytes=0.0,
                  seconds=0.0, instr_words=0.0):
        """One SPMD phase for ALL workers in a single runtime call.

        ``reads``/``writes`` are sequences of ``(ga, lo, hi)`` with
        ``lo``/``hi`` as (W,) int arrays (scalars broadcast); ``flops``/
        ``mem_bytes``/``seconds``/``instr_words`` may be scalars or (W,)
        arrays.  Bit-exactly equivalent to
        ``for w in range(W): phase(w, ...)``: within a phase (no barriers,
        no spans) workers interact only through eviction writebacks.  The
        engine therefore never leaves the batched path wholesale:

        * when no worker can cross the eviction watermark (per-worker
          upper bound, ``_may_evict_mask``) ops run op-major as single
          vectorized passes over the (W, window) directory planes;
        * otherwise a window-disjointness analysis over the declared
          ranges (``_residual_workers``) proves which workers' evictions
          cannot observe each other's directory updates — those run
          batched too, with watermark eviction applied per op as
          vectorized segment-LRU plane ops (``_evict_rows_batch``) and
          the per-op ``_danger`` refetch pattern screened per worker;
        * only the residual *interacting* workers replay tick-ordered
          through the per-worker ``phase`` path, in worker order.

        Must be called outside spans — consistency regions serialize
        through their locks and stay per-worker
        (``span``/``acquire``/``release``)."""
        assert not any(self.spans), "phase_all must run outside spans"
        W = self.W
        reads = [(ga, self._w_arr(lo), self._w_arr(hi))
                 for ga, lo, hi in reads]
        writes = [(ga, self._w_arr(lo), self._w_arr(hi))
                  for ga, lo, hi in writes]
        rranges = [self._page_range_all(ga, lo, hi, prefetch=True)
                   for ga, lo, hi in reads]
        wranges = [self._page_range_all(ga, lo, hi, prefetch=False)
                   for ga, lo, hi in writes]
        may = self._may_evict_mask(rranges + wranges)
        resid = None
        if may is not None and self.protocol != IDEAL_PROTO:
            r = self._residual_workers(rranges, wranges, may)
            if r.any():
                resid = r
        rows = None if resid is None else np.nonzero(~resid)[0]
        self.stats["batched_phases"] += 1
        if rows is None or rows.size:
            for ga, lo, hi in reads:
                self._read_all(ga, lo, hi, rows=rows, may=may)
            for ga, lo, hi in writes:
                self._write_all(ga, lo, hi, rows=rows, may=may)
        fl = np.asarray(flops, np.float64)
        mb = np.asarray(mem_bytes, np.float64)
        sec = np.asarray(seconds, np.float64)
        iw = np.asarray(instr_words, np.float64)
        crows = self._rows_all if rows is None else rows
        if crows.size:
            if fl.any() or mb.any() or sec.any():
                sharing = self.cost.workers_on_node(W)
                bw = self.cost.node_bw(sharing) / max(1, sharing)
                t = np.broadcast_to(
                    sec + np.maximum(fl / self.cost.flops_per_worker,
                                     mb / bw), (W,))
                self.clock[crows] += t[crows]
            if (self.model_mechanism and self.protocol == FINE_PROTO
                    and iw.any()):
                self.clock[crows] += np.broadcast_to(
                    iw * self.instr_s_per_word, (W,))[crows]
        if resid is not None:
            # tick-ordered replay of the interacting workers, in worker
            # order (the loop driver's order within each dependence class)
            self.stats["residual_replays"] += int(resid.sum())
            flb = np.broadcast_to(fl, (W,))
            mbb = np.broadcast_to(mb, (W,))
            secb = np.broadcast_to(sec, (W,))
            iwb = np.broadcast_to(iw, (W,))
            for w in np.nonzero(resid)[0]:
                self.phase(
                    int(w),
                    reads=[(ga, int(lo[w]), int(hi[w]))
                           for ga, lo, hi in reads],
                    writes=[(ga, int(lo[w]), int(hi[w]))
                            for ga, lo, hi in writes],
                    flops=float(flb[w]), mem_bytes=float(mbb[w]),
                    seconds=float(secb[w]), instr_words=float(iwb[w]))

    # ------------------------------------------------------------------
    def reduce(self, w: int, name: str, value: float, op: str = "sum"):
        self._reductions.setdefault(name, []).append((float(value), op))

    def reduce_all(self, name: str, values, op: str = "sum"):
        """Batched ``reduce``: one contribution per worker in a single
        call (``values`` scalar or (W,)); combines identically at the
        barrier (same values, same op, same reduction_msgs)."""
        vals = np.broadcast_to(np.asarray(values, np.float64), (self.W,))
        self._reductions.setdefault(name, []).extend(
            (float(v), op) for v in vals)

    def reduction_result(self, name: str) -> float:
        return self._reduction_results[name]

    def barrier(self):
        self._flush_all_workers()
        if self.protocol != IDEAL_PROTO:
            for lk in self.locks.values():
                if (lk.seen == lk.version).all():
                    continue       # everyone current (usual post-span state)
                for w in range(self.W):
                    if lk.seen[w] == lk.version:
                        continue
                    u, lo_u, hi_u = lk.log.pending(int(lk.seen[w]),
                                                   lk.version)
                    lk.seen[w] = lk.version
                    if not u.size:
                        continue
                    if self.protocol == FINE_PROTO:
                        # fine-grain update of valid stale copies only
                        regions = np.searchsorted(
                            self._region_starts_np, u, "right") - 1
                        for r in np.unique(regions):
                            d = self.dirs[int(r)]
                            if d.base[w] < 0:
                                continue
                            m = regions == r
                            cols = u[m] - d.base[w]
                            inr = (cols >= 0) & (cols < d.length[w])
                            vcells = d.valid[w, np.where(inr, cols, 0)] & inr
                            self.traffic.diff_bytes += int(
                                ((hi_u[m] - lo_u[m]) * _WORD)[vcells].sum())
                    else:
                        n_inv = self._replay_invalidate(w, u, rearm=False)
                        self.traffic.invalidations += n_inv
        log_w = max(1, int(np.ceil(np.log2(max(self.W, 2)))))
        for name, contribs in self._reductions.items():
            vals = [v for v, _ in contribs]
            op = contribs[0][1]
            fn = {"sum": np.sum, "max": np.max, "min": np.min}[op]
            self._reduction_results[name] = float(fn(vals))
            self.traffic.reduction_msgs += self.W - 1
        self._reductions.clear()
        t = float(self.clock.max()) + self.cost.net_latency_s * log_w * (
            0 if self.protocol == IDEAL_PROTO else 1) + 1e-7 * log_w
        self.clock[:] = t

    @property
    def time(self) -> float:
        return float(self.clock.max())
