"""Vectorized RegC protocol engine for paper-scale runs (256 workers).

Same protocol as ``core.regc.RegCRuntime`` — same rules, same traffic
accounting — but metadata-only and interval-vectorized so the paper's
figures (STREAM TRIAD / Jacobi / MD up to 256 cores, millions of pages) run
in seconds.  ``tests/test_regc_scale.py`` cross-validates the traffic
counters against the reference runtime on random traces.

Key representation choices:

* cache state is per (worker, allocation-region) *window* — a numpy array
  over the contiguous page range of that region the worker actually touches
  (workers in the paper's benchmarks access contiguous blocks + halos), so
  state is O(touched), never O(n_pages x workers);
* reads/writes are per-*interval* (vectorized over the page range), not
  per-page Python loops;
* span-touched pages stay in small dicts (critical sections touch few
  pages — that is the paper's whole point).

Beyond the reference runtime, this engine also models the paper's two
store-tracking *mechanisms* (§IV):

* ``fine``  (samhita): every store is instrumented with a runtime call
  (LLVM pass) -> ``instr_s_per_word`` per stored word, in ordinary AND
  consistency regions (the MD result: overhead visible even when almost all
  stores are ordinary);
* ``page``  (samhita_page): write detection via VM protection -> one
  ``fault_s`` per (page x write-epoch), re-armed when the page is flushed.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.regc import (FINE_PROTO, IDEAL_PROTO, PAGE_PROTO, GasArray,
                             Traffic, _WORD)
from repro.dsm.costmodel import CostModel, IB_2013

# mechanism costs (calibration constants; provenance in EXPERIMENTS.md
# §Paper-repro): instrumented store = call + hash-table update; write fault
# = trap + mprotect re-arm, order ~microseconds on the paper's Harpertown.
INSTR_S_PER_WORD = 1.5e-9
FAULT_S = 4.0e-6


class _Window:
    """Windowed page state of one (worker, region)."""

    __slots__ = ("region", "base", "valid", "dirty", "wprot", "touch")

    def __init__(self, region: int):
        self.region = region
        self.base = -1
        self.valid = np.zeros(0, bool)
        self.dirty = np.zeros(0, bool)     # ordinary-region dirty pages
        self.wprot = np.zeros(0, bool)     # page proto: write-protected
        self.touch = np.zeros(0, np.int64)

    def ensure(self, lo: int, hi: int):
        if self.base < 0:
            self.base = lo
            n = hi - lo
            self.valid = np.zeros(n, bool)
            self.dirty = np.zeros(n, bool)
            self.wprot = np.ones(n, bool)
            self.touch = np.zeros(n, np.int64)
            return
        if lo < self.base:
            pad = self.base - lo
            self.valid = np.concatenate([np.zeros(pad, bool), self.valid])
            self.dirty = np.concatenate([np.zeros(pad, bool), self.dirty])
            self.wprot = np.concatenate([np.ones(pad, bool), self.wprot])
            self.touch = np.concatenate([np.zeros(pad, np.int64), self.touch])
            self.base = lo
        if hi > self.base + self.valid.size:
            pad = hi - (self.base + self.valid.size)
            self.valid = np.concatenate([self.valid, np.zeros(pad, bool)])
            self.dirty = np.concatenate([self.dirty, np.zeros(pad, bool)])
            self.wprot = np.concatenate([self.wprot, np.ones(pad, bool)])
            self.touch = np.concatenate([self.touch, np.zeros(pad, np.int64)])

    def sl(self, lo: int, hi: int) -> slice:
        return slice(lo - self.base, hi - self.base)

    def intersect(self, lo: int, hi: int) -> Optional[Tuple[int, int]]:
        if self.base < 0:
            return None
        lo = max(lo, self.base)
        hi = min(hi, self.base + self.valid.size)
        return (lo, hi) if lo < hi else None


class _Span:
    __slots__ = ("lock", "touched")

    def __init__(self, lock):
        self.lock = lock
        self.touched: Dict[int, Tuple[int, int]] = {}


class _Lock:
    __slots__ = ("version", "notices", "last_release_time", "seen")

    def __init__(self, n_workers):
        self.version = 0
        self.notices: List[List[Tuple[int, int, int]]] = []
        self.last_release_time = 0.0
        self.seen = np.zeros(n_workers, np.int64)


class RegCScaleRuntime:
    """Drop-in (metadata-only) scale version of RegCRuntime."""

    def __init__(self, n_workers: int, *, page_words: int = 1024,
                 protocol: str = FINE_PROTO, cost: CostModel = IB_2013,
                 cache_pages: Optional[int] = None, prefetch: int = 1,
                 n_mem_servers: int = 1, model_mechanism: bool = True,
                 instr_s_per_word: float = INSTR_S_PER_WORD,
                 fault_s: float = FAULT_S, fetch_batch: int = 1):
        assert protocol in (PAGE_PROTO, FINE_PROTO, IDEAL_PROTO)
        self.W = n_workers
        self.page_words = page_words
        self.page_bytes = page_words * _WORD
        self.protocol = protocol
        self.cost = cost
        self.cache_pages = cache_pages
        self.prefetch = prefetch
        self.n_mem_servers = max(1, n_mem_servers)
        self.model_mechanism = model_mechanism
        self.instr_s_per_word = instr_s_per_word
        self.fault_s = fault_s
        # Samhita's bulk-fetch optimization (paper §V-A): a miss run of k
        # pages costs ceil(k/fetch_batch) request/reply pairs, not k.
        # fetch_batch=1 == reference runtime accounting.
        self.fetch_batch = max(1, fetch_batch)

        self.n_pages = 0
        self._region_starts: List[int] = []     # sorted page_lo per region
        self._region_ends: List[int] = []
        # windows[w][region] created lazily
        self.windows: List[Dict[int, _Window]] = [dict() for _ in range(n_workers)]
        self.spans: List[List[_Span]] = [[] for _ in range(n_workers)]
        self.locks: Dict[int, _Lock] = {}
        self.clock = np.zeros(n_workers)
        self.traffic = Traffic()
        self._reductions: Dict[str, List[Tuple[float, str]]] = {}
        self._reduction_results: Dict[str, float] = {}
        self._tick = 0

    # ------------------------------------------------------------------
    def alloc(self, n_elems: int) -> GasArray:
        pages = -(-n_elems // self.page_words)
        ga = GasArray(self.n_pages, n_elems, self.page_words)
        self._region_starts.append(self.n_pages)
        self._region_ends.append(self.n_pages + pages)
        self.n_pages += pages
        return ga

    def _region_of(self, page: int) -> int:
        i = bisect.bisect_right(self._region_starts, page) - 1
        assert 0 <= i and page < self._region_ends[i], page
        return i

    def _window(self, w: int, region: int) -> _Window:
        win = self.windows[w].get(region)
        if win is None:
            win = _Window(region)
            self.windows[w][region] = win
        return win

    def _net(self, w: int, n_bytes: float, msgs: int = 1):
        if self.protocol == IDEAL_PROTO:
            return
        self.clock[w] += self.cost.xfer_s(n_bytes, msgs)

    def compute(self, w: int, *, flops: float = 0.0, mem_bytes: float = 0.0,
                seconds: float = 0.0):
        self.clock[w] += seconds + self.cost.compute_s(
            flops, mem_bytes, self.cost.workers_on_node(self.W))

    def instr_stores(self, w: int, n_words: float):
        """Inner-loop stores to shared memory that the LLVM pass instruments
        (e.g. MD force accumulation): charged per word under the fine
        protocol; under the page protocol they hit already-faulted pages."""
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[w] += n_words * self.instr_s_per_word

    # ------------------------------------------------------------------
    # interval fetch / evict
    # ------------------------------------------------------------------

    def _fetch_range(self, w: int, region: int, p_lo: int, p_hi: int):
        """Make pages [p_lo, p_hi) valid at w, charging misses."""
        c = self._window(w, region)
        c.ensure(p_lo, p_hi)
        s = c.sl(p_lo, p_hi)
        n_miss = int((~c.valid[s]).sum())
        self._tick += 1
        c.touch[s] = self._tick
        if n_miss and self.protocol != IDEAL_PROTO:
            self.traffic.page_fetches += n_miss
            self.traffic.fetch_bytes += n_miss * self.page_bytes
            n_req = -(-n_miss // self.fetch_batch)
            self._net(w, n_miss * self.page_bytes, 2 * n_req)
        c.valid[s] = True
        self._evict(w)

    def _evict(self, w: int):
        if self.cache_pages is None:
            return
        wins = list(self.windows[w].values())
        n_valid = sum(int(c.valid.sum()) for c in wins)
        n_over = n_valid - self.cache_pages
        if n_over <= 0:
            return
        # gather (touch, window, local_idx) of all valid pages; evict oldest
        cands = []
        for c in wins:
            idx = np.nonzero(c.valid)[0]
            if idx.size:
                cands.append((c.touch[idx], np.full(idx.size, c.region), idx))
        touch = np.concatenate([t for t, _, _ in cands])
        regs = np.concatenate([r for _, r, _ in cands])
        locs = np.concatenate([i for _, _, i in cands])
        order = np.argpartition(touch, min(n_over, touch.size - 1))[:n_over]
        for ri, li in zip(regs[order], locs[order]):
            c = self.windows[w][int(ri)]
            if c.dirty[li]:      # dirty victims write back before eviction
                self._writeback_ordinary(w, c, c.base + int(li),
                                         c.base + int(li) + 1)
            c.valid[li] = False

    # ------------------------------------------------------------------
    # reads / writes (interval API)
    # ------------------------------------------------------------------

    def read(self, w: int, ga: GasArray, lo: int, hi: int):
        region = self._region_of(ga.page_lo)
        p_lo = ga.page_lo + lo // self.page_words
        p_hi = ga.page_lo + (max(hi - 1, lo)) // self.page_words + 1
        arr_end = ga.page_lo + -(-ga.n_elems // self.page_words)
        p_hi_pf = min(p_hi + self.prefetch, arr_end)   # sequential prefetch
        self._fetch_range(w, region, p_lo, max(p_hi_pf, p_hi))
        return None

    def write(self, w: int, ga: GasArray, lo: int, hi: int, values=None):
        region = self._region_of(ga.page_lo)
        p_lo = ga.page_lo + lo // self.page_words
        p_hi = ga.page_lo + (max(hi - 1, lo)) // self.page_words + 1
        c = self._window(w, region)
        c.ensure(p_lo, p_hi)
        in_span = bool(self.spans[w])
        n_words = hi - lo

        # mechanism cost: instrumented stores (fine) / write faults (page)
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[w] += n_words * self.instr_s_per_word
        if self.model_mechanism and self.protocol == PAGE_PROTO:
            s = c.sl(p_lo, p_hi)
            n_faults = int(c.wprot[s].sum())
            self.clock[w] += n_faults * self.fault_s
            c.wprot[s] = False

        # write-allocate: partial edge pages must be fetched; interior
        # full-page writes just become valid
        if self.protocol != IDEAL_PROTO:
            if p_hi - p_lo == 1:
                if n_words < self.page_words:
                    self._fetch_range(w, region, p_lo, p_lo + 1)
            else:
                if lo % self.page_words != 0:
                    self._fetch_range(w, region, p_lo, p_lo + 1)
                if hi % self.page_words != 0 and hi < ga.n_elems:
                    self._fetch_range(w, region, p_hi - 1, p_hi)
                elif hi % self.page_words != 0:   # last page of the array,
                    self._fetch_range(w, region, p_hi - 1, p_hi)  # partial
        s = c.sl(p_lo, p_hi)
        self._tick += 1
        c.valid[s] = True
        c.touch[s] = self._tick

        if in_span:
            span = self.spans[w][-1]
            for p in range(p_lo, p_hi):
                wlo, whi = ga.word_range_in_page(p, lo, hi)
                old = span.touched.get(p)
                span.touched[p] = ((min(wlo, old[0]), max(whi, old[1]))
                                   if old else (wlo, whi))
        else:
            c.dirty[s] = True
        self._evict(w)

    # ------------------------------------------------------------------
    # ordinary flush (page granularity in both protocols)
    # ------------------------------------------------------------------

    def _writeback_ordinary(self, w: int, c: _Window, p_lo: int, p_hi: int):
        """Write back + invalidate sharers for dirty pages of window c in
        [p_lo, p_hi)."""
        iv = c.intersect(p_lo, p_hi)
        if iv is None:
            return
        s = c.sl(*iv)
        dirty_idx = np.nonzero(c.dirty[s])[0]
        n_dirty = dirty_idx.size
        if n_dirty == 0:
            return
        c.dirty[s] = False
        if self.protocol == IDEAL_PROTO:
            return
        self.traffic.writeback_bytes += n_dirty * self.page_bytes
        self._net(w, n_dirty * self.page_bytes,
                  -(-n_dirty // self.fetch_batch))   # batched writeback
        if self.model_mechanism and self.protocol == PAGE_PROTO:
            c.wprot[s.start + dirty_idx] = True     # re-arm write protection
        # invalidate sharers (same region windows of other workers)
        dirty_pages_abs = iv[0] + dirty_idx
        for v in range(self.W):
            if v == w:
                continue
            cv = self.windows[v].get(c.region)
            if cv is None:
                continue
            ivv = cv.intersect(iv[0], iv[1])
            if ivv is None:
                continue
            mask = (dirty_pages_abs >= ivv[0]) & (dirty_pages_abs < ivv[1])
            pages_v = dirty_pages_abs[mask] - cv.base
            if pages_v.size == 0:
                continue
            shared = cv.valid[pages_v]
            n_inv = int(shared.sum())
            if n_inv:
                cv.valid[pages_v[shared]] = False
                self.traffic.invalidations += n_inv
                self.traffic.control_msgs += n_inv

    def _flush_ordinary(self, w: int):
        for c in self.windows[w].values():
            if c.base >= 0 and c.dirty.any():
                self._writeback_ordinary(w, c, c.base, c.base + c.dirty.size)

    # ------------------------------------------------------------------
    # spans
    # ------------------------------------------------------------------

    def acquire(self, w: int, lock_id: int):
        lk = self.locks.setdefault(lock_id, _Lock(self.W))
        self._flush_ordinary(w)                     # RegC rule 1
        self._net(w, 64, 2)
        self.traffic.control_msgs += 2
        self.clock[w] = max(self.clock[w], lk.last_release_time)
        # RegC rule 2, notices coalesced per page (matches reference)
        pending: Dict[int, Tuple[int, int]] = {}
        for ver in range(int(lk.seen[w]), lk.version):
            for (p, lo, hi) in lk.notices[ver]:
                old = pending.get(p)
                pending[p] = ((min(lo, old[0]), max(hi, old[1]))
                              if old else (lo, hi))
        for p, (lo, hi) in sorted(pending.items()):
            if self.protocol == FINE_PROTO:
                nbytes = (hi - lo) * _WORD + self.page_words // 8
                self.traffic.diff_bytes += nbytes
                self._net(w, nbytes, 1)
            else:
                c = self.windows[w].get(self._region_of(p))
                if c is not None and c.intersect(p, p + 1) is not None \
                        and c.valid[c.sl(p, p + 1)][0]:
                    c.valid[c.sl(p, p + 1)] = False
                    self.traffic.invalidations += 1
                    if self.model_mechanism:
                        c.wprot[c.sl(p, p + 1)] = True
                self.traffic.control_msgs += 1
        lk.seen[w] = lk.version
        self.spans[w].append(_Span(lock_id))

    def release(self, w: int, lock_id: int):
        span = self.spans[w].pop()
        assert span.lock == lock_id, "unbalanced lock release"
        lk = self.locks[lock_id]
        notices = []
        for p, (lo, hi) in sorted(span.touched.items()):
            if self.protocol == IDEAL_PROTO:
                continue
            if self.protocol == FINE_PROTO:
                nbytes = (hi - lo) * _WORD + self.page_words // 8
                self.traffic.diff_bytes += nbytes
            else:
                nbytes = self.page_bytes
                self.traffic.writeback_bytes += nbytes
            self._net(w, nbytes, 1)
            notices.append((p, lo, hi))
        if self.protocol != IDEAL_PROTO:
            lk.notices.append(notices)
            lk.version += 1
            lk.seen[w] = lk.version
        self._net(w, 64, 1)
        self.traffic.control_msgs += 1
        lk.last_release_time = self.clock[w]

    class _SpanCtx:
        def __init__(self, rt, w, lock_id):
            self.rt, self.w, self.lock_id = rt, w, lock_id

        def __enter__(self):
            self.rt.acquire(self.w, self.lock_id)

        def __exit__(self, *exc):
            self.rt.release(self.w, self.lock_id)
            return False

    def span(self, w: int, lock_id: int):
        return self._SpanCtx(self, w, lock_id)

    # ------------------------------------------------------------------
    def reduce(self, w: int, name: str, value: float, op: str = "sum"):
        self._reductions.setdefault(name, []).append((float(value), op))

    def reduction_result(self, name: str) -> float:
        return self._reduction_results[name]

    def barrier(self):
        for w in range(self.W):
            self._flush_ordinary(w)
        if self.protocol != IDEAL_PROTO:
            for lk in self.locks.values():
                for w in range(self.W):
                    pending: Dict[int, Tuple[int, int]] = {}
                    for ver in range(int(lk.seen[w]), lk.version):
                        for (p, lo, hi) in lk.notices[ver]:
                            old = pending.get(p)
                            pending[p] = ((min(lo, old[0]), max(hi, old[1]))
                                          if old else (lo, hi))
                    for p, (lo, hi) in sorted(pending.items()):
                        c = self.windows[w].get(self._region_of(p))
                        if c is None or c.intersect(p, p + 1) is None \
                                or not c.valid[c.sl(p, p + 1)][0]:
                            continue
                        if self.protocol == FINE_PROTO:
                            self.traffic.diff_bytes += (hi - lo) * _WORD
                        else:
                            c.valid[c.sl(p, p + 1)] = False
                            self.traffic.invalidations += 1
                    lk.seen[w] = lk.version
        log_w = max(1, int(np.ceil(np.log2(max(self.W, 2)))))
        for name, contribs in self._reductions.items():
            vals = [v for v, _ in contribs]
            op = contribs[0][1]
            fn = {"sum": np.sum, "max": np.max, "min": np.min}[op]
            self._reduction_results[name] = float(fn(vals))
            self.traffic.reduction_msgs += self.W - 1
        self._reductions.clear()
        t = float(self.clock.max()) + self.cost.net_latency_s * log_w * (
            0 if self.protocol == IDEAL_PROTO else 1) + 1e-7 * log_w
        self.clock[:] = t

    @property
    def time(self) -> float:
        return float(self.clock.max())
