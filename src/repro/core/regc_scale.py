"""Directory-vectorized RegC protocol engine for paper-scale runs.

Same protocol as ``core.regc.RegCRuntime`` — same rules, same traffic
accounting — but all cross-worker paths are vectorized over the worker axis
through a per-region sharing directory (``core.directory.RegionDirectory``)
so the paper's figures (STREAM TRIAD / Jacobi / MD up to 256 cores,
millions of pages) run in seconds.  ``tests/test_regc_scale.py`` and
``tests/test_directory.py`` cross-validate the traffic counters (exactly)
and the modeled clocks (to float tolerance) against the reference runtime.

Key representation choices:

* page state is per *region*: ``valid/dirty/wprot/touch`` live in one 2D
  ``(W, window)`` directory per allocation region, rows = workers, each row
  offset to the worker's touched window, so memory is O(touched) while
  sharer invalidation, barrier flushes, and notice replay are single
  boolean-mask / gather-scatter numpy ops instead of ``range(W)`` loops;
* reads/writes are per-*interval* (vectorized over the page range);
* eviction is watermark-triggered: a per-worker resident counter makes the
  common no-eviction case O(1), and when the watermark is crossed the
  oldest pages are selected in one batched argpartition at the *end* of
  the op.  Per-page monotone touch ticks make the victim set identical to
  the reference runtime's per-op LRU (proved equivalent because no page is
  re-touched after its last tick within an op — see DIRECTORY.md);
* lock notices are flat, version-segmented numpy interval logs
  (``core.directory.IntervalLog``); acquire/barrier replay is one slice +
  segment-min/max coalesce per (lock, worker);
* span-touched pages stay in small dicts (critical sections touch few
  pages — that is the paper's whole point).

Beyond the reference runtime, this engine also models the paper's two
store-tracking *mechanisms* (§IV):

* ``fine``  (samhita): every store is instrumented with a runtime call
  (LLVM pass) -> ``instr_s_per_word`` per stored word, in ordinary AND
  consistency regions (the MD result: overhead visible even when almost all
  stores are ordinary);
* ``page``  (samhita_page): write detection via VM protection -> one
  ``fault_s`` per (page x write-epoch), re-armed when the page is flushed.
"""
from __future__ import annotations

import bisect
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.directory import IntervalLog, RegionDirectory, use_dense
from repro.core.regc import (FINE_PROTO, IDEAL_PROTO, PAGE_PROTO, GasArray,
                             Traffic, _WORD)
from repro.dsm.costmodel import CostModel, IB_2013

# mechanism costs (calibration constants; provenance in EXPERIMENTS.md
# §Paper-repro): instrumented store = call + hash-table update; write fault
# = trap + mprotect re-arm, order ~microseconds on the paper's Harpertown.
INSTR_S_PER_WORD = 1.5e-9
FAULT_S = 4.0e-6


class _Span:
    __slots__ = ("lock", "touched")

    def __init__(self, lock):
        self.lock = lock
        self.touched: Dict[int, Tuple[int, int]] = {}


class _Lock:
    __slots__ = ("version", "log", "last_release_time", "seen")

    def __init__(self, n_workers):
        self.version = 0
        self.log = IntervalLog()
        self.last_release_time = 0.0
        self.seen = np.zeros(n_workers, np.int64)


class RegCScaleRuntime:
    """Drop-in (metadata-only) directory-vectorized version of RegCRuntime."""

    def __init__(self, n_workers: int, *, page_words: int = 1024,
                 protocol: str = FINE_PROTO, cost: CostModel = IB_2013,
                 cache_pages: Optional[int] = None, prefetch: int = 1,
                 n_mem_servers: int = 1, model_mechanism: bool = True,
                 instr_s_per_word: float = INSTR_S_PER_WORD,
                 fault_s: float = FAULT_S, fetch_batch: int = 1,
                 backend: str = "numpy"):
        assert protocol in (PAGE_PROTO, FINE_PROTO, IDEAL_PROTO)
        # 'numpy' | 'pallas': backend for the whole-plane directory
        # reductions (kernels.protocol_sweep).  Integer-exact either way;
        # degrades to numpy with a warning when jax is unavailable.
        from repro.kernels.protocol_sweep import resolve_backend
        self.backend = resolve_backend(backend)
        self.W = n_workers
        self.page_words = page_words
        self.page_bytes = page_words * _WORD
        self.protocol = protocol
        self.cost = cost
        self.cache_pages = cache_pages
        self.prefetch = prefetch
        self.n_mem_servers = max(1, n_mem_servers)
        self.model_mechanism = model_mechanism
        self.instr_s_per_word = instr_s_per_word
        self.fault_s = fault_s
        # Samhita's bulk-fetch optimization (paper §V-A): a miss run of k
        # pages costs ceil(k/fetch_batch) request/reply pairs, not k.
        # fetch_batch=1 == reference runtime accounting.
        self.fetch_batch = max(1, fetch_batch)
        self._track_wprot = (protocol == PAGE_PROTO and model_mechanism)
        self._track_touch = cache_pages is not None

        self.n_pages = 0
        self._region_starts: List[int] = []     # sorted page_lo per region
        self._region_ends: List[int] = []
        self._region_starts_np = np.zeros(0, np.int64)
        self.dirs: List[RegionDirectory] = []
        self.spans: List[List[_Span]] = [[] for _ in range(n_workers)]
        self.locks: Dict[int, _Lock] = {}
        self.clock = np.zeros(n_workers)
        self.traffic = Traffic()
        # per-worker cache occupancy (valid + invalidated-but-not-evicted
        # pages, matching the reference's LRU dict): the eviction watermark
        self.resident = np.zeros(n_workers, np.int64)
        # per-worker FIFO of touch runs [t0, region, col0, n, off, shift0]:
        # ticks are globally monotone, so the queue is tick-ordered and an
        # LRU pop is a front scan that lazily skips re-touched (stale) and
        # already-evicted cells — amortized O(1) per page
        self._lru_q: List[deque] = [deque() for _ in range(n_workers)]
        self._dirty_regions: List[set] = [set() for _ in range(n_workers)]
        self._reductions: Dict[str, List[Tuple[float, str]]] = {}
        self._reduction_results: Dict[str, float] = {}
        self._tick = 0
        self._rows_all = np.arange(n_workers)
        # one-way latch: once a phase_all precheck fails, later phases go
        # straight to the per-worker path (a spilling workload keeps
        # spilling; both paths are exact, so the hint only affects speed)
        self._assume_spill = False

    # ------------------------------------------------------------------
    def alloc(self, n_elems: int) -> GasArray:
        pages = -(-n_elems // self.page_words)
        ga = GasArray(self.n_pages, n_elems, self.page_words)
        self._region_starts.append(self.n_pages)
        self._region_ends.append(self.n_pages + pages)
        self._region_starts_np = np.asarray(self._region_starts, np.int64)
        self.dirs.append(RegionDirectory(
            self.W, len(self.dirs), self.n_pages, self.n_pages + pages,
            track_wprot=self._track_wprot, track_touch=self._track_touch,
            backend=self.backend))
        self.n_pages += pages
        return ga

    def _region_of(self, page: int) -> int:
        i = bisect.bisect_right(self._region_starts, page) - 1
        assert 0 <= i and page < self._region_ends[i], page
        return i

    def _net(self, w: int, n_bytes: float, msgs: int = 1):
        if self.protocol == IDEAL_PROTO:
            return
        self.clock[w] += self.cost.xfer_s(n_bytes, msgs)

    def compute(self, w: int, *, flops: float = 0.0, mem_bytes: float = 0.0,
                seconds: float = 0.0):
        self.clock[w] += seconds + self.cost.compute_s(
            flops, mem_bytes, self.cost.workers_on_node(self.W))

    def instr_stores(self, w: int, n_words: float):
        """Inner-loop stores to shared memory that the LLVM pass instruments
        (e.g. MD force accumulation): charged per word under the fine
        protocol; under the page protocol they hit already-faulted pages."""
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[w] += n_words * self.instr_s_per_word

    # ------------------------------------------------------------------
    # interval fetch / batched eviction
    # ------------------------------------------------------------------

    def _fetch_range(self, w: int, region: int, p_lo: int, p_hi: int):
        """Make pages [p_lo, p_hi) valid at w, charging misses."""
        d = self.dirs[region]
        d.ensure(w, p_lo, p_hi)
        s = d.sl(w, p_lo, p_hi)
        n = p_hi - p_lo
        n_miss = n - int(d.valid[w, s].sum())
        if d.touch is not None:
            # per-page monotone ticks: ascending within the interval, so
            # batched eviction reproduces the reference's per-op LRU exactly
            d.touch[w, s] = np.arange(self._tick + 1, self._tick + 1 + n)
            self._lru_q[w].append([self._tick + 1, region, s.start, n, 0,
                                   int(d.shift[w])])
            n_enter = n - int(d.incache[w, s].sum())
            if n_enter:
                d.incache[w, s] = True
                self.resident[w] += n_enter
        self._tick += n
        if n_miss:
            if self.protocol != IDEAL_PROTO:
                self.traffic.page_fetches += n_miss
                self.traffic.fetch_bytes += n_miss * self.page_bytes
                n_req = -(-n_miss // self.fetch_batch)
                self._net(w, n_miss * self.page_bytes, 2 * n_req)
            d.valid[w, s] = True

    def _danger(self, w: int, n_enter: int, n: int) -> bool:
        """Batched end-of-op eviction is exact unless this op can evict a
        page of its *own* range (one already occupying a cache slot) before
        touching it — the reference would then refetch / re-enter it
        mid-op.  That needs both an in-cache page in the range
        (n_enter < n) and an eviction this op; fully-cold ranges (the spill
        benchmarks' steady state) and eviction-free ops stay on the batch
        path."""
        return (self.cache_pages is not None
                and self.protocol != IDEAL_PROTO
                and n_enter < n
                and int(self.resident[w]) + n_enter > self.cache_pages)

    def _evict_now(self, w: int, d: RegionDirectory, vc: np.ndarray):
        """Evict the cells ``vc`` (ascending tick order) of w's row in
        region d: dirty victims (valid or not) write back first — one
        message per page, matching the reference's per-page eviction flush
        — then both ``valid`` and the cache slot (``incache``) drop.
        Contiguous victim runs (the streaming-spill steady state) use
        slice ops instead of fancy indexing."""
        lo, hi = int(vc[0]), int(vc[-1]) + 1
        sl = slice(lo, hi) if hi - lo == vc.size else vc
        dmask = d.dirty[w, sl]
        if dmask.any():
            db = vc[dmask]
            d.dirty[w, sl] = False     # only the db cells were set
            if self.protocol != IDEAL_PROTO:
                self.traffic.writeback_bytes += db.size * self.page_bytes
                self.clock[w] += (self.cost.net_latency_s * db.size
                                  + db.size * self.page_bytes
                                  / self.cost.net_bw_Bps)
                if d.wprot is not None:
                    d.wprot[w, db] = True
                self._invalidate_sharers(w, d.region, d.base[w] + db)
        d.valid[w, sl] = False
        d.incache[w, sl] = False
        self.resident[w] -= vc.size

    def _evict_cells(self, w: int, k: int):
        """Evict w's k least-recently-touched cache occupants by scanning
        the tick-ordered run queue from the front, lazily skipping cells
        that were re-touched (their live entry is a later run) or already
        evicted.  Each queue cell is examined O(1) times overall, so
        steady-state spill eviction is amortized O(1) per page."""
        q = self._lru_q[w]
        while k > 0:
            run = q[0]
            t0, region, col0, n, off, shift0 = run
            d = self.dirs[region]
            c0 = col0 + (int(d.shift[w]) - shift0)
            sl = slice(c0 + off, c0 + n)      # run cells are contiguous
            live = ((d.touch[w, sl] == np.arange(t0 + off, t0 + n))
                    & d.incache[w, sl])
            idx = np.nonzero(live)[0]
            if idx.size == 0:
                q.popleft()
                continue
            take = idx[:k]
            self._evict_now(w, d, c0 + off + take)
            k -= take.size
            if take.size == idx.size:
                q.popleft()          # no live cells remain in this run
            else:
                run[4] = off + int(take[-1]) + 1

    def _touch_page_exact(self, w: int, d: RegionDirectory, p: int,
                          fetch: bool) -> int:
        """Per-page touch/fetch + immediate LRU eviction, mirroring the
        reference's ``_fetch``/``_touch_lru`` sequence for dangerous ops.
        Returns the number of pages fetched (0/1); the *caller* charges
        the fetch messages once per op so batching (``fetch_batch``)
        costs the same on this path as on the batch path."""
        col = p - int(d.base[w])
        n_miss = 0
        if not d.valid[w, col]:
            if fetch and self.protocol != IDEAL_PROTO:
                self.traffic.page_fetches += 1
                self.traffic.fetch_bytes += self.page_bytes
                n_miss = 1
            d.valid[w, col] = True
        if not d.incache[w, col]:
            d.incache[w, col] = True
            self.resident[w] += 1
        self._tick += 1
        d.touch[w, col] = self._tick
        self._lru_q[w].append([self._tick, d.region, col, 1, 0,
                               int(d.shift[w])])
        if self.resident[w] > self.cache_pages:
            self._evict_cells(w, int(self.resident[w]) - self.cache_pages)
        return n_miss

    def _maybe_evict(self, w: int):
        """Watermark-triggered batched eviction: no per-op work unless the
        occupancy counter crossed ``cache_pages``; then the oldest pages
        (exact LRU via monotone ticks) are evicted in one queue pass."""
        if self.cache_pages is None or self.resident[w] <= self.cache_pages:
            return
        self._evict_cells(w, int(self.resident[w]) - self.cache_pages)

    # ------------------------------------------------------------------
    # reads / writes (interval API)
    # ------------------------------------------------------------------

    def read(self, w: int, ga: GasArray, lo: int, hi: int):
        region = self._region_of(ga.page_lo)
        p_lo = ga.page_lo + lo // self.page_words
        p_hi = ga.page_lo + (max(hi - 1, lo)) // self.page_words + 1
        arr_end = ga.page_lo + -(-ga.n_elems // self.page_words)
        p_hi_pf = min(p_hi + self.prefetch, arr_end)   # sequential prefetch
        p_hi = max(p_hi_pf, p_hi)
        if self.cache_pages is not None:
            d = self.dirs[region]
            d.ensure(w, p_lo, p_hi)
            s = d.sl(w, p_lo, p_hi)
            n = p_hi - p_lo
            n_enter = n - int(d.incache[w, s].sum())
            if self._danger(w, n_enter, n):
                n_miss = 0
                for p in range(p_lo, p_hi):
                    n_miss += self._touch_page_exact(w, d, p, fetch=True)
                if n_miss:
                    self._net(w, n_miss * self.page_bytes,
                              2 * -(-n_miss // self.fetch_batch))
                return None
        self._fetch_range(w, region, p_lo, p_hi)
        self._maybe_evict(w)
        return None

    def write(self, w: int, ga: GasArray, lo: int, hi: int, values=None):
        region = self._region_of(ga.page_lo)
        p_lo = ga.page_lo + lo // self.page_words
        p_hi = ga.page_lo + (max(hi - 1, lo)) // self.page_words + 1
        d = self.dirs[region]
        d.ensure(w, p_lo, p_hi)
        in_span = bool(self.spans[w])
        n_words = hi - lo

        # mechanism cost: instrumented stores (fine) / write faults (page)
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock[w] += n_words * self.instr_s_per_word
        if self._track_wprot:
            s = d.sl(w, p_lo, p_hi)
            n_faults = int(d.wprot[w, s].sum())
            self.clock[w] += n_faults * self.fault_s
            d.wprot[w, s] = False

        if self.cache_pages is not None and self.protocol != IDEAL_PROTO:
            s = d.sl(w, p_lo, p_hi)
            n = p_hi - p_lo
            n_enter0 = n - int(d.incache[w, s].sum())
            if self._danger(w, n_enter0, n):
                # exact per-page replica of the reference's write-allocate +
                # LRU sequence (see _danger)
                span = self.spans[w][-1] if in_span else None
                base = int(d.base[w])
                n_miss = 0
                for p in range(p_lo, p_hi):
                    wlo, whi = ga.word_range_in_page(p, lo, hi)
                    n_miss += self._touch_page_exact(
                        w, d, p, fetch=(whi - wlo) < self.page_words)
                    if in_span:
                        old = span.touched.get(p)
                        span.touched[p] = ((min(wlo, old[0]),
                                            max(whi, old[1]))
                                           if old else (wlo, whi))
                    else:
                        d.dirty[w, p - base] = True
                        d.maybe_dirty = True
                        self._dirty_regions[w].add(region)
                if n_miss:
                    self._net(w, n_miss * self.page_bytes,
                              2 * -(-n_miss // self.fetch_batch))
                return

        # write-allocate: partial edge pages must be fetched; interior
        # full-page writes just become valid
        if self.protocol != IDEAL_PROTO:
            if p_hi - p_lo == 1:
                if n_words < self.page_words:
                    self._fetch_range(w, region, p_lo, p_lo + 1)
            else:
                if lo % self.page_words != 0:
                    self._fetch_range(w, region, p_lo, p_lo + 1)
                if hi % self.page_words != 0:
                    self._fetch_range(w, region, p_hi - 1, p_hi)
        s = d.sl(w, p_lo, p_hi)
        n = p_hi - p_lo
        n_new = n - int(d.valid[w, s].sum())
        if d.touch is not None:
            d.touch[w, s] = np.arange(self._tick + 1, self._tick + 1 + n)
            self._lru_q[w].append([self._tick + 1, region, s.start, n, 0,
                                   int(d.shift[w])])
            n_enter = n - int(d.incache[w, s].sum())
            if n_enter:
                d.incache[w, s] = True
                self.resident[w] += n_enter
        self._tick += n
        if n_new:
            d.valid[w, s] = True

        if in_span:
            span = self.spans[w][-1]
            for p in range(p_lo, p_hi):
                wlo, whi = ga.word_range_in_page(p, lo, hi)
                old = span.touched.get(p)
                span.touched[p] = ((min(wlo, old[0]), max(whi, old[1]))
                                   if old else (wlo, whi))
        else:
            d.dirty[w, s] = True
            d.maybe_dirty = True
            self._dirty_regions[w].add(region)
        self._maybe_evict(w)

    # ------------------------------------------------------------------
    # ordinary flush (page granularity in both protocols)
    # ------------------------------------------------------------------

    def _invalidate_sharers(self, w: int, region: int, pages: np.ndarray):
        """Invalidate every other worker's valid copy of ``pages``.

        Small page sets (accumulator pages, many overlapping rows) use one
        dense boolean-mask gather over the worker axis; wide page sets
        (block flushes — few overlapping neighbours, thousands of pages)
        intersect each row's window with the sorted page list instead, so
        work tracks actual coverage rather than rows x pages."""
        d = self.dirs[region]
        rows = d.overlap_rows(int(pages[0]), int(pages[-1]) + 1, exclude=w)
        if rows.size == 0:
            return
        if pages.size <= 64:
            hit, cols = d.gather_valid(rows, pages)
            n_inv = int(hit.sum())
            if n_inv:
                # valid drops but the pages keep their cache slots
                # (``incache``) until evicted, like the reference's LRU dict
                d.clear_valid_cells(rows, cols, hit)
                self.traffic.invalidations += n_inv
                self.traffic.control_msgs += n_inv
            return
        n_inv = 0
        for v in rows:
            b = int(d.base[v])
            i0 = int(np.searchsorted(pages, b))
            i1 = int(np.searchsorted(pages, b + int(d.length[v])))
            if i0 >= i1:
                continue
            cols = pages[i0:i1] - b
            vcells = d.valid[v, cols]
            k = int(vcells.sum())
            if k:
                d.valid[v, cols[vcells]] = False
                n_inv += k
        if n_inv:
            self.traffic.invalidations += n_inv
            self.traffic.control_msgs += n_inv

    def _flush_worker(self, w: int):
        """Write back + invalidate sharers for all of w's ordinary-dirty
        pages (the single-flusher path used by acquire)."""
        regions = self._dirty_regions[w]
        if not regions:
            return
        for region in sorted(regions):
            d = self.dirs[region]
            cols = d.row_dirty_cols(w)
            if cols.size == 0:
                continue
            d.dirty[w, cols] = False
            if self.protocol == IDEAL_PROTO:
                continue
            n_dirty = cols.size
            self.traffic.writeback_bytes += n_dirty * self.page_bytes
            self._net(w, n_dirty * self.page_bytes,
                      -(-n_dirty // self.fetch_batch))   # batched writeback
            if d.wprot is not None:
                d.wprot[w, cols] = True     # re-arm write protection
            self._invalidate_sharers(w, region, d.base[w] + cols)
        regions.clear()

    def _flush_all_workers(self):
        """Barrier-time flush of every worker's ordinary-dirty pages, in
        one batched pass per region that reproduces the sequential
        flush-order semantics analytically (see DIRECTORY.md):

        for a page with dirty-worker set D (flushed in worker order) and
        initial valid set V, the sequential per-worker flushes produce
        ``|V \\ {d0}| + [|D|>1]*[d0 in V]`` invalidations and leave the page
        valid only at d0 when ``|D|==1``.  Pages covered by a single worker
        window contribute nothing (their only possible sharer is their own
        writer), so the gather runs only over multiply-covered pages.
        """
        for d in self.dirs:
            if not d.maybe_dirty:
                continue
            nD_w = d.dirty_counts()        # bitmask popcount on 'pallas'
            total = int(nD_w.sum())
            d.maybe_dirty = False
            if total == 0:
                continue
            if self.protocol == IDEAL_PROTO:
                d.dirty[:] = False
                continue
            active = np.nonzero(nD_w)[0]
            # per-(worker, region) writeback charge, as in the sequential
            # flush: one batched message group per worker window
            self.traffic.writeback_bytes += total * self.page_bytes
            msgs = -(-nD_w[active] // self.fetch_batch)
            self.clock[active] += (self.cost.net_latency_s * msgs
                                   + (nD_w[active] * self.page_bytes)
                                   / self.cost.net_bw_Bps)
            if d.wprot is not None:
                np.logical_or(d.wprot, d.dirty, out=d.wprot)  # re-arm own
            # sharer invalidation: only pages under >= 2 worker windows can
            # have sharers, so per-cell work is confined to the (small)
            # halo/global intervals instead of every dirty page
            starts, ends = d.shared_intervals()
            if starts.size:
                w_list, col_list = [], []
                for w in active:
                    b = int(d.base[w])
                    e = b + int(d.length[w])
                    i0 = int(np.searchsorted(ends, b, "right"))
                    i1 = int(np.searchsorted(starts, e, "left"))
                    for i in range(i0, i1):
                        lo = max(int(starts[i]), b)
                        hi = min(int(ends[i]), e)
                        if lo >= hi:
                            continue
                        c = np.nonzero(d.dirty[w, lo - b:hi - b])[0]
                        if c.size:
                            col_list.append(c + (lo - b))
                            w_list.append(np.full(c.size, w, np.int64))
                if col_list:
                    w_idx = np.concatenate(w_list)   # ascending worker ==
                    cols = np.concatenate(col_list)  # sequential flush order
                    self._invalidate_shared_dirty(d, w_idx, cols)
            d.dirty[:] = False
        for regions in self._dirty_regions:
            regions.clear()

    def _invalidate_shared_dirty(self, d: RegionDirectory,
                                 w_idx: np.ndarray, cols: np.ndarray):
        """Apply the analytic sequential-flush invalidation to the dirty
        cells (worker-major order) of multiply-covered pages.

        The gather is sparse: worker windows are intervals, so each row
        sees only a contiguous slice of the page list ``u`` — total
        (row, page) pairs ~ the actual window coverage, not rows x pages
        (a dense gather over block-partitioned arrays touches W x |u|
        cells to find ~2 live ones per page)."""
        pages = d.base[w_idx] + cols
        u, first, counts = np.unique(pages, return_index=True,
                                     return_counts=True)
        d0_rows = w_idx[first]                # min dirty worker per page
        d0_valid = d.valid[d0_rows, cols[first]]
        rows = d.overlap_rows(int(u[0]), int(u[-1]) + 1)
        pr_l, pu_l, pc_l = [], [], []
        for w in rows:
            b = int(d.base[w])
            i0 = int(np.searchsorted(u, b))
            i1 = int(np.searchsorted(u, b + int(d.length[w])))
            if i0 < i1:
                pr_l.append(np.full(i1 - i0, w, np.int64))
                pu_l.append(np.arange(i0, i1))
                pc_l.append(u[i0:i1] - b)
        pr = np.concatenate(pr_l)             # pair: worker row
        pu = np.concatenate(pu_l)             # pair: index into u
        pc = np.concatenate(pc_l)             # pair: column in row
        val = d.valid[pr, pc]
        nV0 = np.bincount(pu[val], minlength=u.size)
        d0v = d0_valid.astype(np.int64)
        n_inv = int((nV0 - d0v + np.where(counts > 1, d0v, 0)).sum())
        if n_inv:
            self.traffic.invalidations += n_inv
            self.traffic.control_msgs += n_inv
        # final valid state: keep only a sole dirty writer's copy
        keep = (counts == 1)[pu] & (pr == d0_rows[pu])
        hot = val & ~keep
        if hot.any():
            d.valid[pr[hot], pc[hot]] = False

    # ------------------------------------------------------------------
    # spans + notice replay
    # ------------------------------------------------------------------

    def _replay_invalidate(self, w: int, pages: np.ndarray, rearm: bool):
        """Page-protocol notice replay: invalidate w's valid copies of
        ``pages`` (grouped per region), returning the number invalidated."""
        total = 0
        regions = np.searchsorted(self._region_starts_np, pages, "right") - 1
        for r in np.unique(regions):
            d = self.dirs[int(r)]
            if d.base[w] < 0:
                continue
            pr = pages[regions == r]
            cols = pr - d.base[w]
            inr = (cols >= 0) & (cols < d.length[w])
            vcells = d.valid[w, np.where(inr, cols, 0)] & inr
            n = int(vcells.sum())
            if n:
                hot = cols[vcells]
                d.valid[w, hot] = False
                if rearm and d.wprot is not None:
                    d.wprot[w, hot] = True
                total += n
        return total

    def acquire(self, w: int, lock_id: int):
        lk = self.locks.setdefault(lock_id, _Lock(self.W))
        self._flush_worker(w)                       # RegC rule 1
        self._net(w, 64, 2)
        self.traffic.control_msgs += 2
        self.clock[w] = max(self.clock[w], lk.last_release_time)
        # RegC rule 2, notices coalesced per page (matches reference)
        u, lo_u, hi_u = lk.log.pending(int(lk.seen[w]), lk.version)
        if u.size:
            if self.protocol == FINE_PROTO:
                nbytes = (hi_u - lo_u) * _WORD + self.page_words // 8
                tot = int(nbytes.sum())
                self.traffic.diff_bytes += tot
                self.clock[w] += (self.cost.net_latency_s * u.size
                                  + tot / self.cost.net_bw_Bps)
            else:
                n_inv = self._replay_invalidate(
                    w, u, rearm=self.model_mechanism)
                self.traffic.invalidations += n_inv
                self.traffic.control_msgs += int(u.size)
        lk.seen[w] = lk.version
        self.spans[w].append(_Span(lock_id))

    def release(self, w: int, lock_id: int):
        span = self.spans[w].pop()
        assert span.lock == lock_id, "unbalanced lock release"
        lk = self.locks[lock_id]
        pages, los, his = [], [], []
        for p, (lo, hi) in sorted(span.touched.items()):
            if self.protocol == IDEAL_PROTO:
                continue
            if self.protocol == FINE_PROTO:
                nbytes = (hi - lo) * _WORD + self.page_words // 8
                self.traffic.diff_bytes += nbytes
            else:
                nbytes = self.page_bytes
                self.traffic.writeback_bytes += nbytes
            self._net(w, nbytes, 1)
            pages.append(p)
            los.append(lo)
            his.append(hi)
        if self.protocol != IDEAL_PROTO:
            lk.log.append_version(pages, los, his)
            lk.version += 1
            lk.seen[w] = lk.version
        self._net(w, 64, 1)
        self.traffic.control_msgs += 1
        lk.last_release_time = self.clock[w]

    class _SpanCtx:
        def __init__(self, rt, w, lock_id):
            self.rt, self.w, self.lock_id = rt, w, lock_id

        def __enter__(self):
            self.rt.acquire(self.w, self.lock_id)

        def __exit__(self, *exc):
            self.rt.release(self.w, self.lock_id)
            return False

    def span(self, w: int, lock_id: int):
        return self._SpanCtx(self, w, lock_id)

    # ------------------------------------------------------------------
    # batched SPMD driver fast path
    # ------------------------------------------------------------------

    def phase(self, w: int, reads=(), writes=(), *, flops: float = 0.0,
              mem_bytes: float = 0.0, seconds: float = 0.0,
              instr_words: float = 0.0):
        """One worker-phase in a single runtime call: interval reads, then
        interval writes, then the modeled compute + instrumented stores.
        ``reads``/``writes`` are sequences of ``(ga, lo, hi)``.  This is
        the per-worker reference path that ``phase_all`` batches over the
        worker axis (and falls back to when eviction is possible)."""
        for ga, lo, hi in reads:
            self.read(w, ga, lo, hi)
        for ga, lo, hi in writes:
            self.write(w, ga, lo, hi)
        if flops or mem_bytes or seconds:
            self.compute(w, flops=flops, mem_bytes=mem_bytes, seconds=seconds)
        if instr_words:
            self.instr_stores(w, instr_words)

    # ------------------------------------------------------------------
    # worker-axis batched driver (phase_all)
    # ------------------------------------------------------------------

    def _w_arr(self, v) -> np.ndarray:
        return np.broadcast_to(np.asarray(v, np.int64), (self.W,))

    def _page_range_all(self, ga, lo: np.ndarray, hi: np.ndarray, *,
                        prefetch: bool):
        pw = self.page_words
        p_lo = ga.page_lo + lo // pw
        p_hi = ga.page_lo + np.maximum(hi - 1, lo) // pw + 1
        if prefetch:
            arr_end = ga.page_lo + -(-ga.n_elems // pw)
            p_hi = np.maximum(np.minimum(p_hi + self.prefetch, arr_end), p_hi)
        return self._region_of(int(ga.page_lo)), p_lo, p_hi

    def _phase_fits(self, ranges) -> bool:
        """Conservative per-phase no-eviction check: every page that can
        newly occupy a cache slot this phase is not-incache at phase start
        and lies in some op range, so ``resident + sum over ops of
        (range length - in-cache count)`` bounds each worker's peak
        occupancy; overlapping ranges only loosen the bound.  Under the
        watermark for every worker, no eviction can trigger, hence no
        cross-worker invalidation mid-phase — the batched op-major order
        is then bit-exact vs the per-worker order."""
        quick = self.resident.copy()
        for region, p_lo, p_hi in ranges:
            quick += p_hi - p_lo
        if (quick <= self.cache_pages).all():
            return True            # even all-cold ranges fit: no gathers
        ub = self.resident.copy()
        for region, p_lo, p_hi in ranges:
            d = self.dirs[region]
            ub += (p_hi - p_lo) - d.count_range(d.incache, p_lo, p_hi)
        return bool((ub <= self.cache_pages).all())

    def _fetch_range_all(self, region: int, p_lo: np.ndarray,
                         p_hi: np.ndarray, rows: np.ndarray):
        """Vectorized ``_fetch_range`` over ``rows`` of the worker axis:
        identical per-worker traffic and clock charges, one gather/scatter
        per plane instead of a Python loop."""
        d = self.dirs[region]
        d.ensure_rows(p_lo, p_hi, rows)
        cols, mask = d.range_cols(p_lo, p_hi, rows)
        safe = np.where(mask, cols, 0)
        r2 = rows[:, None]
        vsub = d.valid[r2, safe] & mask
        L = p_hi - p_lo
        n_miss = L - vsub.sum(axis=1)
        if d.touch is not None:
            # per-(worker, op) monotone tick blocks: relative order within
            # each worker matches the per-worker path, which is all the
            # LRU victim selection compares (ticks never cross workers)
            t0 = self._tick + np.concatenate(([0], np.cumsum(L[:-1])))
            tick_vals = t0[:, None] + 1 + np.arange(cols.shape[1])[None, :]
            ri, ci = np.nonzero(mask)
            d.touch[rows[ri], cols[ri, ci]] = tick_vals[ri, ci]
            for i, w in enumerate(rows):
                self._lru_q[w].append([int(t0[i]) + 1, region,
                                       int(cols[i, 0]), int(L[i]), 0,
                                       int(d.shift[w])])
            isub = d.incache[r2, safe] & mask
            ri, ci = np.nonzero(mask & ~isub)
            if ri.size:
                d.incache[rows[ri], cols[ri, ci]] = True
            self.resident[rows] += L - isub.sum(axis=1)
        self._tick += int(L.sum())
        tot_miss = int(n_miss.sum())
        if tot_miss:
            if self.protocol != IDEAL_PROTO:
                self.traffic.page_fetches += tot_miss
                self.traffic.fetch_bytes += tot_miss * self.page_bytes
                n_req = -(-n_miss // self.fetch_batch)
                t = (self.cost.net_latency_s * (2 * n_req)
                     + (n_miss * self.page_bytes) / self.cost.net_bw_Bps)
                hit = n_miss > 0
                self.clock[rows[hit]] += t[hit]
            ri, ci = np.nonzero(mask & ~vsub)
            d.valid[rows[ri], cols[ri, ci]] = True

    def _read_all(self, ga, lo: np.ndarray, hi: np.ndarray):
        region, p_lo, p_hi = self._page_range_all(ga, lo, hi, prefetch=True)
        if not use_dense(self.W, int((p_hi - p_lo).max())):
            # wide per-worker intervals: contiguous per-row slice ops beat
            # the dense gather matrices (see directory.use_dense); still
            # op-major, so charges stay bit-identical
            for w in range(self.W):
                self.read(w, ga, int(lo[w]), int(hi[w]))
            return
        self._fetch_range_all(region, p_lo, p_hi, self._rows_all)

    def _write_all(self, ga, lo: np.ndarray, hi: np.ndarray):
        pw = self.page_words
        region, p_lo, p_hi = self._page_range_all(ga, lo, hi, prefetch=False)
        if not use_dense(self.W, int((p_hi - p_lo).max())):
            for w in range(self.W):
                self.write(w, ga, int(lo[w]), int(hi[w]))
            return
        d = self.dirs[region]
        rows = self._rows_all
        d.ensure_rows(p_lo, p_hi, rows)
        n_words = hi - lo

        # mechanism cost, in the per-worker path's charge order
        if self.model_mechanism and self.protocol == FINE_PROTO:
            self.clock += n_words * self.instr_s_per_word
        if self._track_wprot:
            cols, mask = d.range_cols(p_lo, p_hi, rows)
            wsub = d.wprot[rows[:, None], np.where(mask, cols, 0)] & mask
            self.clock += wsub.sum(axis=1) * self.fault_s
            ri, ci = np.nonzero(mask)
            d.wprot[rows[ri], cols[ri, ci]] = False

        # write-allocate edge fetches (first page, then last page — the
        # per-worker path's order), only for the workers that need them
        n_pg = p_hi - p_lo
        if self.protocol != IDEAL_PROTO:
            single = n_pg == 1
            first = np.where(single, n_words < pw, lo % pw != 0)
            last = (~single) & (hi % pw != 0)
            if first.any():
                r = np.nonzero(first)[0]
                self._fetch_range_all(region, p_lo[r], p_lo[r] + 1, r)
            if last.any():
                r = np.nonzero(last)[0]
                self._fetch_range_all(region, p_hi[r] - 1, p_hi[r], r)

        cols, mask = d.range_cols(p_lo, p_hi, rows)
        safe = np.where(mask, cols, 0)
        vsub = d.valid[rows[:, None], safe] & mask
        if d.touch is not None:
            t0 = self._tick + np.concatenate(([0], np.cumsum(n_pg[:-1])))
            tick_vals = t0[:, None] + 1 + np.arange(cols.shape[1])[None, :]
            ri, ci = np.nonzero(mask)
            d.touch[rows[ri], cols[ri, ci]] = tick_vals[ri, ci]
            for w in range(self.W):
                self._lru_q[w].append([int(t0[w]) + 1, region,
                                       int(cols[w, 0]), int(n_pg[w]), 0,
                                       int(d.shift[w])])
            isub = d.incache[rows[:, None], safe] & mask
            ri, ci = np.nonzero(mask & ~isub)
            if ri.size:
                d.incache[rows[ri], cols[ri, ci]] = True
            self.resident += n_pg - isub.sum(axis=1)
        self._tick += int(n_pg.sum())
        ri, ci = np.nonzero(mask & ~vsub)
        if ri.size:
            d.valid[rows[ri], cols[ri, ci]] = True
        ri, ci = np.nonzero(mask)
        d.dirty[rows[ri], cols[ri, ci]] = True
        d.maybe_dirty = True
        for w in range(self.W):
            self._dirty_regions[w].add(region)

    def phase_all(self, reads=(), writes=(), *, flops=0.0, mem_bytes=0.0,
                  seconds=0.0, instr_words=0.0):
        """One SPMD phase for ALL workers in a single runtime call.

        ``reads``/``writes`` are sequences of ``(ga, lo, hi)`` with
        ``lo``/``hi`` as (W,) int arrays (scalars broadcast); ``flops``/
        ``mem_bytes``/``seconds``/``instr_words`` may be scalars or (W,)
        arrays.  Bit-exactly equivalent to
        ``for w in range(W): phase(w, ...)``: within a phase (no barriers,
        no spans) workers interact only through eviction writebacks, so
        when no worker can cross the eviction watermark (checked
        conservatively up front) the per-worker ops are independent and
        run op-major as single vectorized passes over the (W, window)
        directory planes; otherwise the whole phase falls back to the
        per-worker path, which resolves eviction and the ``_danger``
        pattern in tick order.  Must be called outside spans — consistency
        regions serialize through their locks and stay per-worker
        (``span``/``acquire``/``release``)."""
        assert not any(self.spans), "phase_all must run outside spans"
        W = self.W
        reads = [(ga, self._w_arr(lo), self._w_arr(hi))
                 for ga, lo, hi in reads]
        writes = [(ga, self._w_arr(lo), self._w_arr(hi))
                  for ga, lo, hi in writes]
        if self.cache_pages is not None and (
                self._assume_spill or not self._phase_fits(
                    [self._page_range_all(ga, lo, hi, prefetch=True)
                     for ga, lo, hi in reads]
                    + [self._page_range_all(ga, lo, hi, prefetch=False)
                       for ga, lo, hi in writes])):
            self._assume_spill = True
            fl = np.broadcast_to(np.asarray(flops, np.float64), (W,))
            mb = np.broadcast_to(np.asarray(mem_bytes, np.float64), (W,))
            sec = np.broadcast_to(np.asarray(seconds, np.float64), (W,))
            iw = np.broadcast_to(np.asarray(instr_words, np.float64), (W,))
            for w in range(W):
                self.phase(
                    w,
                    reads=[(ga, int(lo[w]), int(hi[w]))
                           for ga, lo, hi in reads],
                    writes=[(ga, int(lo[w]), int(hi[w]))
                            for ga, lo, hi in writes],
                    flops=float(fl[w]), mem_bytes=float(mb[w]),
                    seconds=float(sec[w]), instr_words=float(iw[w]))
            return
        for ga, lo, hi in reads:
            self._read_all(ga, lo, hi)
        for ga, lo, hi in writes:
            self._write_all(ga, lo, hi)
        fl = np.asarray(flops, np.float64)
        mb = np.asarray(mem_bytes, np.float64)
        sec = np.asarray(seconds, np.float64)
        if fl.any() or mb.any() or sec.any():
            sharing = self.cost.workers_on_node(W)
            bw = self.cost.node_bw(sharing) / max(1, sharing)
            self.clock += sec + np.maximum(
                fl / self.cost.flops_per_worker, mb / bw)
        if self.model_mechanism and self.protocol == FINE_PROTO:
            iw = np.asarray(instr_words, np.float64)
            if iw.any():
                self.clock += iw * self.instr_s_per_word

    # ------------------------------------------------------------------
    def reduce(self, w: int, name: str, value: float, op: str = "sum"):
        self._reductions.setdefault(name, []).append((float(value), op))

    def reduce_all(self, name: str, values, op: str = "sum"):
        """Batched ``reduce``: one contribution per worker in a single
        call (``values`` scalar or (W,)); combines identically at the
        barrier (same values, same op, same reduction_msgs)."""
        vals = np.broadcast_to(np.asarray(values, np.float64), (self.W,))
        self._reductions.setdefault(name, []).extend(
            (float(v), op) for v in vals)

    def reduction_result(self, name: str) -> float:
        return self._reduction_results[name]

    def barrier(self):
        self._flush_all_workers()
        if self.protocol != IDEAL_PROTO:
            for lk in self.locks.values():
                if (lk.seen == lk.version).all():
                    continue       # everyone current (usual post-span state)
                for w in range(self.W):
                    if lk.seen[w] == lk.version:
                        continue
                    u, lo_u, hi_u = lk.log.pending(int(lk.seen[w]),
                                                   lk.version)
                    lk.seen[w] = lk.version
                    if not u.size:
                        continue
                    if self.protocol == FINE_PROTO:
                        # fine-grain update of valid stale copies only
                        regions = np.searchsorted(
                            self._region_starts_np, u, "right") - 1
                        for r in np.unique(regions):
                            d = self.dirs[int(r)]
                            if d.base[w] < 0:
                                continue
                            m = regions == r
                            cols = u[m] - d.base[w]
                            inr = (cols >= 0) & (cols < d.length[w])
                            vcells = d.valid[w, np.where(inr, cols, 0)] & inr
                            self.traffic.diff_bytes += int(
                                ((hi_u[m] - lo_u[m]) * _WORD)[vcells].sum())
                    else:
                        n_inv = self._replay_invalidate(w, u, rearm=False)
                        self.traffic.invalidations += n_inv
        log_w = max(1, int(np.ceil(np.log2(max(self.W, 2)))))
        for name, contribs in self._reductions.items():
            vals = [v for v, _ in contribs]
            op = contribs[0][1]
            fn = {"sum": np.sum, "max": np.max, "min": np.min}[op]
            self._reduction_results[name] = float(fn(vals))
            self.traffic.reduction_msgs += self.W - 1
        self._reductions.clear()
        t = float(self.clock.max()) + self.cost.net_latency_s * log_w * (
            0 if self.protocol == IDEAL_PROTO else 1) + 1e-7 * log_w
        self.clock[:] = t

    @property
    def time(self) -> float:
        return float(self.clock.max())
