"""Public configuration surface for the RegC runtimes.

One frozen spec (``RuntimeConfig``) + one factory (``make_runtime``) build
either protocol engine — the directory-vectorized ``RegCScaleRuntime``
(``engine="scale"``) or the per-page oracle ``RegCRuntime``
(``engine="reference"``) — from the same declaration, replacing the two
keyword constructors as the supported entry point (the old constructors
remain as thin back-compat shims; ``tests/test_api.py`` proves bit-equal
traffic/clocks either way).

This module is the bottom layer of ``repro.core``: it defines the
canonical string-knob vocabularies (``PROTOCOLS``, ``BACKENDS``,
``DANGER_MODES``, ``DRIVERS``, ``ENGINES``) and the shared validator
``check_choice`` the engines use instead of bare ``assert``s, and imports
nothing from the engine modules at import time (they import *us*).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.dsm.costmodel import CostModel, IB_2013

# protocol vocabulary (the paper's three series)
PAGE_PROTO = "page"    # samhita_page: page invalidation for BOTH region kinds
FINE_PROTO = "fine"    # samhita: fine-grain diffs for consistency regions
IDEAL_PROTO = "ideal"  # cache-coherent shared memory (Pthreads baseline)

PROTOCOLS = (FINE_PROTO, PAGE_PROTO, IDEAL_PROTO)
# plane-reduction backend (scale engine): boolean-plane numpy reductions,
# per-op Pallas kernels (interpret mode off-TPU), or the fused jitted
# kernel chain over device-resident packed planes (see DIRECTORY.md
# "Compiled-phase contract")
BACKENDS = ("numpy", "pallas", "pallas-jit")
DANGER_MODES = ("vec", "scalar")    # mid-op refetch replay path (scale)
DRIVERS = ("auto", "batched", "loop")   # SPMD phase/span drivers (Session)
ENGINES = ("scale", "reference")        # make_runtime targets

# mechanism costs (calibration constants; provenance in EXPERIMENTS.md
# §Paper-repro): instrumented store = call + hash-table update; write fault
# = trap + mprotect re-arm, order ~microseconds on the paper's Harpertown.
INSTR_S_PER_WORD = 1.5e-9
FAULT_S = 4.0e-6


def check_choice(name: str, value, allowed) -> str:
    """Validate a string knob against its canonical vocabulary.

    Raises ``ValueError`` naming the bad value AND the allowed set —
    the one replacement for the bare ``assert knob in (...)`` checks that
    used to die with a bare ``AssertionError`` (or pass silently under
    ``python -O``)."""
    if value not in allowed:
        raise ValueError(
            f"invalid {name}={value!r}; allowed: "
            + ", ".join(repr(c) for c in allowed))
    return value


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Frozen spec for building a RegC runtime (either engine).

    Engine-specific knobs are documented per field; the reference engine
    ignores the scale engine's *performance/mechanism* knobs (they change
    wall time or modeled mechanism cost, never protocol semantics) but
    refuses the fault-injection hooks it cannot honor (``chaos``,
    ``injector``, ``straggler`` — behavior-bearing)."""

    page_words: int = 1024
    protocol: str = FINE_PROTO
    cost: CostModel = IB_2013
    cache_pages: Optional[int] = None   # per-worker cache (None = infinite)
    prefetch: int = 1
    n_mem_servers: int = 1
    track_values: bool = True           # reference only: materialize pages
    model_mechanism: bool = True        # scale only: §IV store tracking
    instr_s_per_word: float = INSTR_S_PER_WORD   # scale only
    fault_s: float = FAULT_S                     # scale only
    fetch_batch: int = 1                # scale only: bulk-fetch batching
    backend: str = "numpy"              # scale only: plane reductions
    danger_mode: str = "vec"            # scale only: mid-op refetch replay
    detect_races: bool = False          # pure-observer race detection
    chaos: Any = None                   # scale only: ChaosNet hook
    injector: Any = None                # scale only: FaultInjector hook
    straggler: Any = None               # scale only: StragglerMonitor hook

    def __post_init__(self):
        check_choice("protocol", self.protocol, PROTOCOLS)
        check_choice("backend", self.backend, BACKENDS)
        check_choice("danger_mode", self.danger_mode, DANGER_MODES)


def make_runtime(n_workers: int, config: Optional[RuntimeConfig] = None,
                 *, engine: str = "scale", **overrides):
    """Build a RegC runtime from one spec.

    ``config`` defaults to ``RuntimeConfig()``; keyword ``overrides``
    are applied on top via ``dataclasses.replace`` (unknown field names
    raise, catching typos the old ``**kw`` constructors swallowed into
    ``TypeError`` at the wrong frame).  ``engine="scale"`` returns the
    directory-vectorized ``RegCScaleRuntime``; ``engine="reference"``
    the per-page oracle ``RegCRuntime``.  Both are driven through the
    same declared-access API (``repro.dsm.session``)."""
    check_choice("engine", engine, ENGINES)
    cfg = config if config is not None else RuntimeConfig()
    if overrides:
        try:
            cfg = dataclasses.replace(cfg, **overrides)
        except TypeError as e:
            known = ", ".join(f.name for f in dataclasses.fields(cfg))
            raise ValueError(
                f"make_runtime(): unknown RuntimeConfig override "
                f"({e}); known fields: {known}") from None
    if engine == "scale":
        from repro.core.regc_scale import RegCScaleRuntime
        return RegCScaleRuntime(
            n_workers, page_words=cfg.page_words, protocol=cfg.protocol,
            cost=cfg.cost, cache_pages=cfg.cache_pages,
            prefetch=cfg.prefetch, n_mem_servers=cfg.n_mem_servers,
            model_mechanism=cfg.model_mechanism,
            instr_s_per_word=cfg.instr_s_per_word, fault_s=cfg.fault_s,
            fetch_batch=cfg.fetch_batch, backend=cfg.backend,
            danger_mode=cfg.danger_mode, detect_races=cfg.detect_races,
            chaos=cfg.chaos, injector=cfg.injector,
            straggler=cfg.straggler)
    for hook in ("chaos", "injector", "straggler"):
        if getattr(cfg, hook) is not None:
            raise ValueError(
                f"make_runtime(engine='reference'): the reference engine "
                f"does not support the {hook!r} fault-injection hook "
                f"(use engine='scale')")
    from repro.core.regc import RegCRuntime
    return RegCRuntime(
        n_workers, page_words=cfg.page_words, protocol=cfg.protocol,
        cost=cfg.cost, track_values=cfg.track_values,
        cache_pages=cfg.cache_pages, prefetch=cfg.prefetch,
        n_mem_servers=cfg.n_mem_servers, detect_races=cfg.detect_races)
