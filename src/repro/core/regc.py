"""Regional Consistency (RegC) — executable protocol runtime.

This is the paper's contribution as a first-class artifact: the two region
kinds (ordinary / consistency), spans, the three formal visibility rules
(§III-A), both Samhita protocols (page-granularity invalidation vs
fine-grained diffs), the reduction extension (§V-B), per-worker caches with
LRU + sequential prefetch, memory-server striping, and an exact traffic
ledger driving an alpha-beta cost model (see ``dsm.costmodel``).

Execution model: phase-structured SPMD (the paper's benchmarks are all
fork-join).  Worker bodies run sequentially in virtual time; each worker
carries a clock advanced by modeled compute and by protocol transfers; locks
serialize spans through their grant times; barriers join clocks.  Traffic
counts are EXACT — only time is modeled (DESIGN.md §6).

Two value modes:
* ``track_values=True``  — page data is materialized; diffs are computed by
  the ``page_diff`` Pallas kernel (interpret mode on CPU) and the final GAS
  contents can be checked against a sequential oracle (tests do this).
* ``track_values=False`` — metadata-only: writes record word *intervals*;
  diff bytes are exact for interval writes with zero data storage (used by
  the 256-worker scaling benchmarks).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.config import (FINE_PROTO, IDEAL_PROTO, PAGE_PROTO,
                               PROTOCOLS, check_choice)
from repro.dsm.costmodel import CostModel, IB_2013

_WORD = 4  # fp32 words


@dataclasses.dataclass
class Traffic:
    page_fetches: int = 0
    fetch_bytes: int = 0
    writeback_bytes: int = 0
    diff_bytes: int = 0
    invalidations: int = 0
    control_msgs: int = 0
    reduction_msgs: int = 0

    @property
    def total_bytes(self) -> int:
        return self.fetch_bytes + self.writeback_bytes + self.diff_bytes

    def add(self, other: "Traffic"):
        for f in dataclasses.fields(Traffic):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


@dataclasses.dataclass
class GasArray:
    """Handle to a page-aligned allocation in the global address space."""
    page_lo: int
    n_elems: int
    page_words: int

    def pages_of(self, lo: int, hi: int) -> range:
        return range(self.page_lo + lo // self.page_words,
                     self.page_lo + (max(hi - 1, lo)) // self.page_words + 1)

    def word_range_in_page(self, p: int, lo: int, hi: int) -> Tuple[int, int]:
        base = (p - self.page_lo) * self.page_words
        return max(lo - base, 0), min(hi - base, self.page_words)


class _Span:
    __slots__ = ("lock", "touched", "twins")

    def __init__(self, lock: int):
        self.lock = lock
        self.touched: Dict[int, Tuple[int, int]] = {}   # page -> (lo, hi) words
        self.twins: Dict[int, np.ndarray] = {}


class _Lock:
    __slots__ = ("version", "notices", "last_release_time", "seen", "race_vc")

    def __init__(self, n_workers: int):
        self.version = 0
        # notices[i] = (page, lo, hi, values|None) for release version i+1
        self.notices: List[List[Tuple[int, int, int, Optional[np.ndarray]]]] = []
        self.last_release_time = 0.0
        self.seen = np.zeros(n_workers, np.int64)
        # race detection: the lock's vector clock (join of every releaser)
        self.race_vc = np.zeros(n_workers, np.int64)


class RegCRuntime:
    """The Samhita-analogue DSM runtime implementing RegC."""

    def __init__(self, n_workers: int, *, page_words: int = 1024,
                 protocol: str = FINE_PROTO, cost: CostModel = IB_2013,
                 track_values: bool = True, cache_pages: Optional[int] = None,
                 prefetch: int = 1, n_mem_servers: int = 1,
                 detect_races: bool = False):
        check_choice("protocol", protocol, PROTOCOLS)
        self.W = n_workers
        self.page_words = page_words
        self.page_bytes = page_words * _WORD
        self.protocol = protocol
        self.cost = cost
        self.track_values = track_values
        self.cache_pages = cache_pages
        self.prefetch = prefetch
        self.n_mem_servers = max(1, n_mem_servers)

        self.n_pages = 0
        self.home: Optional[np.ndarray] = None           # (n_pages, W) values
        self.cache_data: Dict[Tuple[int, int], np.ndarray] = {}
        self.valid = np.zeros((n_workers, 0), bool)
        self.lru: List[OrderedDict] = [OrderedDict() for _ in range(n_workers)]
        # ordinary-region dirty intervals: (w, page) -> (lo, hi)
        self.ord_dirty: Dict[Tuple[int, int], Tuple[int, int]] = {}
        # word-exact dirty masks (track_values only): false-sharing merges
        # need per-word resolution, not the interval union
        self.ord_mask: Dict[Tuple[int, int], np.ndarray] = {}
        self.spans: List[List[_Span]] = [[] for _ in range(n_workers)]
        self.locks: Dict[int, _Lock] = {}
        self.clock = np.zeros(n_workers)
        self.traffic = Traffic()
        self.per_worker_traffic = [Traffic() for _ in range(n_workers)]
        self._reductions: Dict[str, List[Tuple[float, str]]] = {}
        self._reduction_results: Dict[str, float] = {}
        self._barrier_count = 0
        # race detection (pure observer — never touches traffic or clocks):
        # per-worker vector clocks, page-granular last-access epochs, and
        # the canonical flagged set {(page, a, b, kind)} with a < b and
        # kind in {"ww", "rw"}
        self.detect_races = detect_races
        self.race_vc = (np.eye(n_workers, dtype=np.int64)
                        if detect_races else None)
        self._race_wpage: Dict[int, np.ndarray] = {}
        self._race_rpage: Dict[int, np.ndarray] = {}
        self.races: set = set()

    # ------------------------------------------------------------------
    # allocation
    # ------------------------------------------------------------------

    def alloc(self, n_elems: int) -> GasArray:
        pages = -(-n_elems // self.page_words)
        ga = GasArray(self.n_pages, n_elems, self.page_words)
        self.n_pages += pages
        if self.track_values:
            new = np.zeros((self.n_pages, self.page_words), np.float32)
            if self.home is not None:
                new[: self.home.shape[0]] = self.home
            self.home = new
        self.valid = np.pad(self.valid,
                            ((0, 0), (0, self.n_pages - self.valid.shape[1])))
        return ga

    def mem_server_of(self, page: int) -> int:
        return page % self.n_mem_servers  # striped allocation (paper §IV)

    # ------------------------------------------------------------------
    # cost helpers
    # ------------------------------------------------------------------

    def _sharing(self) -> int:
        return self.cost.workers_on_node(self.W)

    def _net(self, w: int, n_bytes: float, msgs: int = 1):
        if self.protocol == IDEAL_PROTO:
            return
        t = self.cost.xfer_s(n_bytes, msgs)
        self.clock[w] += t

    def compute(self, w: int, *, flops: float = 0.0, mem_bytes: float = 0.0,
                seconds: float = 0.0):
        self.clock[w] += seconds + self.cost.compute_s(
            flops, mem_bytes, self._sharing())

    def instr_stores(self, w: int, n_words: float):
        """Mechanism-cost hook (modeled only by the scale engine)."""

    # ------------------------------------------------------------------
    # cache internals
    # ------------------------------------------------------------------

    def _touch_lru(self, w: int, p: int):
        if self.cache_pages is None:
            return
        lru = self.lru[w]
        lru.pop(p, None)
        lru[p] = True
        while len(lru) > self.cache_pages:
            victim, _ = lru.popitem(last=False)
            # dirty victims write back before eviction
            if (w, victim) in self.ord_dirty:
                self._flush_page_ordinary(w, victim)
            self.valid[w, victim] = False
            self.cache_data.pop((w, victim), None)

    def _fetch(self, w: int, p: int):
        if self.valid[w, p]:
            self._touch_lru(w, p)
            return
        if self.protocol != IDEAL_PROTO:
            self.traffic.page_fetches += 1
            self.traffic.fetch_bytes += self.page_bytes
            self.per_worker_traffic[w].page_fetches += 1
            self.per_worker_traffic[w].fetch_bytes += self.page_bytes
            self._net(w, self.page_bytes, 2)  # request + reply
        if self.track_values:
            fresh = self.home[p].copy()
            # false sharing: if our stale copy carries pending ordinary
            # stores (invalidated-while-dirty), overlay them word-exactly —
            # DRF programs write disjoint words, so the merge is exact
            mask = self.ord_mask.get((w, p))
            if mask is not None and (w, p) in self.cache_data:
                fresh[mask] = self.cache_data[(w, p)][mask]
            self.cache_data[(w, p)] = fresh
        self.valid[w, p] = True
        self._touch_lru(w, p)

    def _page_view(self, w: int, p: int) -> np.ndarray:
        if self.protocol == IDEAL_PROTO:
            return self.home[p]
        return self.cache_data[(w, p)]

    # ------------------------------------------------------------------
    # race detection (scalar oracle; page-granular epoch vector clocks)
    # ------------------------------------------------------------------

    def _race_record(self, p: int, w: int, u: int, kind: str):
        a, b = (w, u) if w < u else (u, w)
        self.races.add((p, a, b, kind))

    def _race_access(self, w: int, ga: GasArray, lo: int, hi: int,
                     is_write: bool):
        """Check-then-record one declared access against the per-page
        last-access epochs.  Accesses are taken at op granularity over the
        declared [lo, hi) range — the cache path (prefetch, write-allocate,
        eviction/refetch) never changes the race set."""
        if not self.detect_races:
            return
        vc = self.race_vc
        for p in ga.pages_of(lo, hi):
            wvc = self._race_wpage.get(p)
            if wvc is not None:
                for u in np.nonzero(wvc > vc[w])[0]:
                    self._race_record(p, w, int(u),
                                      "ww" if is_write else "rw")
            if is_write:
                rvc = self._race_rpage.get(p)
                if rvc is not None:
                    for u in np.nonzero(rvc > vc[w])[0]:
                        self._race_record(p, w, int(u), "rw")
                tgt = self._race_wpage.setdefault(
                    p, np.zeros(self.W, np.int64))
            else:
                tgt = self._race_rpage.setdefault(
                    p, np.zeros(self.W, np.int64))
            tgt[w] = vc[w, w]

    @property
    def race_counts(self) -> Dict[str, int]:
        return {"race_ww": sum(1 for r in self.races if r[3] == "ww"),
                "race_rw": sum(1 for r in self.races if r[3] == "rw")}

    # ------------------------------------------------------------------
    # reads / writes
    # ------------------------------------------------------------------

    def read(self, w: int, ga: GasArray, lo: int, hi: int) -> Optional[np.ndarray]:
        self._race_access(w, ga, lo, hi, False)
        pages = list(ga.pages_of(lo, hi))
        for p in pages:
            self._fetch(w, p)
        # sequential prefetch (paper §V-A cache-spill result)
        for q in range(pages[-1] + 1,
                       min(pages[-1] + 1 + self.prefetch,
                           ga.page_lo + -(-ga.n_elems // self.page_words))):
            self._fetch(w, q)
        if not self.track_values:
            return None
        flat = np.concatenate([self._page_view(w, p) for p in pages])
        base = lo - (pages[0] - ga.page_lo) * self.page_words
        return flat[base: base + (hi - lo)]

    def write(self, w: int, ga: GasArray, lo: int, hi: int,
              values: Optional[np.ndarray] = None):
        self._race_access(w, ga, lo, hi, True)
        pages = list(ga.pages_of(lo, hi))
        in_span = bool(self.spans[w])
        for p in pages:
            wlo, whi = ga.word_range_in_page(p, lo, hi)
            partial = (whi - wlo) < self.page_words
            if self.protocol != IDEAL_PROTO:
                if partial or self.track_values:
                    self._fetch(w, p)      # write-allocate
                else:
                    self.valid[w, p] = True
                    self._touch_lru(w, p)
            if in_span:
                span = self.spans[w][-1]
                if self.track_values and p not in span.twins:
                    span.twins[p] = self._page_view(w, p).copy()
                old = span.touched.get(p)
                span.touched[p] = (min(wlo, old[0]) if old else wlo,
                                   max(whi, old[1]) if old else whi)
            else:
                old = self.ord_dirty.get((w, p))
                self.ord_dirty[(w, p)] = (min(wlo, old[0]) if old else wlo,
                                          max(whi, old[1]) if old else whi)
                if self.track_values:
                    mask = self.ord_mask.setdefault(
                        (w, p), np.zeros(self.page_words, bool))
                    mask[wlo:whi] = True
            if self.track_values and values is not None:
                off = lo - (p - ga.page_lo) * self.page_words
                seg = self._page_view(w, p)
                vlo = max(0, -off)
                seg[wlo:whi] = values[wlo - off: whi - off] if off <= wlo \
                    else values[vlo: vlo + (whi - wlo)]
                if self.protocol == IDEAL_PROTO:
                    self.home[p] = seg

    # ------------------------------------------------------------------
    # ordinary-region flush (page-granularity in BOTH protocols, per paper)
    # ------------------------------------------------------------------

    def _flush_page_ordinary(self, w: int, p: int):
        iv = self.ord_dirty.pop((w, p), None)
        if self.protocol == IDEAL_PROTO:
            return
        self.traffic.writeback_bytes += self.page_bytes
        self.per_worker_traffic[w].writeback_bytes += self.page_bytes
        self._net(w, self.page_bytes, 1)
        mask = self.ord_mask.pop((w, p), None)
        if self.track_values and (w, p) in self.cache_data:
            if mask is not None:
                # merge ONLY our dirty words: concurrent disjoint writers of
                # the same page (false sharing) must not clobber each
                # other's words at the home copy
                self.home[p][mask] = self.cache_data[(w, p)][mask]
            else:
                self.home[p] = self._page_view(w, p).copy()
        # invalidate other cached copies; a sharer that is itself DIRTY on
        # this page keeps its data (its own stores are still pending — they
        # overlay the fresh home copy on its next fetch)
        sharers = [v for v in range(self.W) if v != w and self.valid[v, p]]
        self.traffic.invalidations += len(sharers)
        self.traffic.control_msgs += len(sharers)
        for v in sharers:
            self.valid[v, p] = False
            if (v, p) not in self.ord_dirty:
                self.cache_data.pop((v, p), None)

    def _flush_ordinary(self, w: int):
        for (ww, p) in [k for k in self.ord_dirty if k[0] == w]:
            self._flush_page_ordinary(w, p)

    # ------------------------------------------------------------------
    # spans (consistency regions)
    # ------------------------------------------------------------------

    def acquire(self, w: int, lock_id: int):
        lk = self.locks.setdefault(lock_id, _Lock(self.W))
        # RegC rule 1: ordinary stores performed at w before this span must
        # be performed wrt every worker whose span starts subsequently
        self._flush_ordinary(w)
        # lock grant serializes spans (resource manager round trip)
        self._net(w, 64, 2)
        self.traffic.control_msgs += 2
        self.clock[w] = max(self.clock[w], lk.last_release_time)
        # RegC rule 2: consistent STOREs previously performed wrt this
        # consistency region must be performed wrt w.  Pending notices are
        # COALESCED per page (one merged diff / one invalidation per page,
        # however many releases happened since this worker last acquired).
        pending: Dict[int, Tuple[int, int]] = {}
        for ver in range(int(lk.seen[w]), lk.version):
            for (p, lo, hi, _vals) in lk.notices[ver]:
                old = pending.get(p)
                pending[p] = ((min(lo, old[0]), max(hi, old[1]))
                              if old else (lo, hi))
        for p, (lo, hi) in sorted(pending.items()):
            if self.protocol == FINE_PROTO:
                # fine-grain update: ship only the merged diff
                nbytes = (hi - lo) * _WORD + self.page_words // 8
                self.traffic.diff_bytes += nbytes
                self.per_worker_traffic[w].diff_bytes += nbytes
                self._net(w, nbytes, 1)
                if self.track_values and self.valid[w, p]:
                    seg = self._page_view(w, p)
                    seg[lo:hi] = self.home[p][lo:hi]
            else:
                # page protocol: invalidate; next read refetches the page
                if self.valid[w, p]:
                    self.valid[w, p] = False
                    self.cache_data.pop((w, p), None)
                    self.traffic.invalidations += 1
                self.traffic.control_msgs += 1
        lk.seen[w] = lk.version
        if self.detect_races:
            # happens-before: every prior release of this lock precedes us
            np.maximum(self.race_vc[w], lk.race_vc, out=self.race_vc[w])
        self.spans[w].append(_Span(lock_id))

    def release(self, w: int, lock_id: int):
        span = self.spans[w].pop()
        assert span.lock == lock_id, "unbalanced lock release"
        lk = self.locks[lock_id]
        notices = []
        for p, (lo, hi) in sorted(span.touched.items()):
            if self.protocol == IDEAL_PROTO:
                continue
            if self.protocol == FINE_PROTO and self.track_values:
                curr = self._page_view(w, p)[None, :]
                twin = span.twins[p][None, :]
                try:
                    # diff against twin via the Pallas page_diff kernel
                    from repro.kernels.ops import diff_encode
                    import jax.numpy as jnp
                    mask, vals, count = diff_encode(
                        jnp.asarray(curr), jnp.asarray(twin), interpret=True)
                    mask = np.asarray(mask[0], bool)
                    nwords = int(count[0])
                except ImportError:
                    try:
                        import jax  # noqa: F401 — jax works: a real
                        # defect in the kernel modules, not absence
                    except ImportError:
                        # jax absent: same diff in numpy
                        mask = (curr[0] != twin[0])
                        nwords = int(mask.sum())
                    else:
                        raise
                idx = np.nonzero(mask)[0]
                lo = int(idx[0]) if idx.size else lo
                hi = int(idx[-1]) + 1 if idx.size else lo
                nbytes = nwords * _WORD + self.page_words // 8
                self.home[p][mask] = self._page_view(w, p)[mask]
                stored = None
            elif self.protocol == FINE_PROTO:
                nwords = hi - lo
                nbytes = nwords * _WORD + self.page_words // 8
                stored = None
            else:  # PAGE protocol: whole-page writeback
                nbytes = self.page_bytes
                if self.track_values:
                    self.home[p] = self._page_view(w, p).copy()
                stored = None
            if self.protocol == FINE_PROTO:
                self.traffic.diff_bytes += nbytes
                self.per_worker_traffic[w].diff_bytes += nbytes
            else:
                self.traffic.writeback_bytes += nbytes
                self.per_worker_traffic[w].writeback_bytes += nbytes
            self._net(w, nbytes, 1)
            notices.append((p, lo, hi, stored))
        if self.protocol != IDEAL_PROTO:
            lk.notices.append(notices)
            lk.version += 1
            lk.seen[w] = lk.version
        self._net(w, 64, 1)
        self.traffic.control_msgs += 1
        lk.last_release_time = self.clock[w]
        if self.detect_races:
            # publish our clock into the lock, then start a fresh epoch
            np.maximum(lk.race_vc, self.race_vc[w], out=lk.race_vc)
            self.race_vc[w, w] += 1

    class _SpanCtx:
        def __init__(self, rt, w, lock_id):
            self.rt, self.w, self.lock_id = rt, w, lock_id

        def __enter__(self):
            self.rt.acquire(self.w, self.lock_id)

        def __exit__(self, *exc):
            self.rt.release(self.w, self.lock_id)
            return False

    def span(self, w: int, lock_id: int) -> "_SpanCtx":
        return self._SpanCtx(self, w, lock_id)

    # ------------------------------------------------------------------
    # the reduction extension (paper §V-B)
    # ------------------------------------------------------------------

    def reduce(self, w: int, name: str, value: float, op: str = "sum"):
        """Runtime-implemented reduction replacing a mutex-protected
        accumulation.  Contributions combine at the next barrier in a
        log-tree (object granularity — never a page)."""
        self._reductions.setdefault(name, []).append((float(value), op))

    def reduction_result(self, name: str) -> float:
        return self._reduction_results[name]

    # ------------------------------------------------------------------
    # barrier (RegC rule 3)
    # ------------------------------------------------------------------

    def barrier(self):
        self._barrier_count += 1
        for w in range(self.W):
            self._flush_ordinary(w)
        # every worker must observe every prior store: invalidate stale
        # copies (pages whose home advanced past the cached copy)
        if self.protocol != IDEAL_PROTO:
            # any page anyone else has written since our fetch: conservative
            # per-event invalidation already happened at flush; barriers add
            # the notice sync for all locks
            for lk in self.locks.values():
                for w in range(self.W):
                    pending: Dict[int, Tuple[int, int]] = {}
                    for ver in range(int(lk.seen[w]), lk.version):
                        for (p, lo, hi, _v) in lk.notices[ver]:
                            old = pending.get(p)
                            pending[p] = ((min(lo, old[0]), max(hi, old[1]))
                                          if old else (lo, hi))
                    for p, (lo, hi) in sorted(pending.items()):
                        if self.valid[w, p]:
                            if self.protocol == FINE_PROTO:
                                # fine-grain update of the stale copy
                                if self.track_values:
                                    self.cache_data[(w, p)][lo:hi] = \
                                        self.home[p][lo:hi]
                                self.traffic.diff_bytes += (hi - lo) * _WORD
                            else:
                                self.valid[w, p] = False
                                self.cache_data.pop((w, p), None)
                                self.traffic.invalidations += 1
                    lk.seen[w] = lk.version
        # reductions combine in a log-tree
        log_w = max(1, int(np.ceil(np.log2(max(self.W, 2)))))
        for name, contribs in self._reductions.items():
            vals = [v for v, _ in contribs]
            op = contribs[0][1]
            fn = {"sum": np.sum, "max": np.max, "min": np.min}[op]
            self._reduction_results[name] = float(fn(vals))
            self.traffic.reduction_msgs += self.W - 1
        self._reductions.clear()
        if self.detect_races:
            # barrier joins every worker's clock, then each worker starts a
            # fresh epoch
            j = self.race_vc.max(axis=0)
            self.race_vc[:] = j[None, :]
            self.race_vc[np.arange(self.W), np.arange(self.W)] += 1
        # clocks join (+ tree latency)
        t = float(self.clock.max()) + self.cost.net_latency_s * log_w * (
            0 if self.protocol == IDEAL_PROTO else 1) + 1e-7 * log_w
        self.clock[:] = t

    # ------------------------------------------------------------------
    @property
    def time(self) -> float:
        return float(self.clock.max())
