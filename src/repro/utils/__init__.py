from repro.utils.tree import (
    global_sq_norm, tree_add, tree_bytes, tree_cast, tree_scale, tree_size,
    tree_zeros_like,
)
