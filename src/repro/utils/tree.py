"""Small pytree utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_cast(tree, dtype):
    return jax.tree.map(lambda a: a.astype(dtype), tree)


def tree_zeros_like(tree, dtype=None):
    return jax.tree.map(
        lambda a: jnp.zeros(a.shape, dtype or a.dtype), tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda a: a * s, tree)


def global_sq_norm(tree):
    leaves = jax.tree.leaves(tree)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)


def tree_size(tree) -> int:
    return sum(l.size for l in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))
