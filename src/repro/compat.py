"""jax version-compatibility shims.

The codebase targets the modern public APIs (``jax.shard_map``,
``jax.sharding.AxisType``); this container ships jax 0.4.37 where
``shard_map`` still lives in ``jax.experimental`` (with ``check_rep`` /
``auto`` instead of ``check_vma`` / ``axis_names``) and meshes have no
axis types.  Route every use through here so both generations work.
"""
from __future__ import annotations

import jax

_new_shard_map = getattr(jax, "shard_map", None)

if _new_shard_map is not None:
    def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
                  check_vma: bool = False):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return _new_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma, **kw)
else:
    from jax.experimental.shard_map import shard_map as _old_shard_map

    def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
                  check_vma: bool = False):
        kw = {}
        if axis_names is not None:
            auto = frozenset(mesh.axis_names) - set(axis_names)
            if auto:
                kw["auto"] = auto
        return _old_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma, **kw)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types where the API supports them
    (newer jax) and plain meshes otherwise — Auto is the old default."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kw["axis_types"] = (axis_type.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, **kw)
