from repro.data.pipeline import DataConfig, Prefetcher, make_pipeline
from repro.data.sources import MemmapTokens, SyntheticTokens, write_token_file

__all__ = ["DataConfig", "Prefetcher", "make_pipeline", "MemmapTokens",
           "SyntheticTokens", "write_token_file"]
