"""Token sources: deterministic synthetic stream + memmap-backed corpus.

Both are *stateless by step index*: ``batch_at(step)`` is a pure function of
(seed, step, rank layout), which is what makes checkpoint/restart and elastic
rescale exact — a restarted (or resharded) job replays the identical token
stream from any step without persisting reader state (only the step counter
lives in the checkpoint).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Optional, Tuple

import numpy as np

_MIX = np.uint64(0x9E3779B97F4A7C15)


def _hash64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer — cheap stateless per-element PRNG."""
    x = (x + _MIX) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x ^= x >> np.uint64(30)
    x = (x * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x ^= x >> np.uint64(27)
    x = (x * np.uint64(0x94D049BB133111EB)) & np.uint64(0xFFFFFFFFFFFFFFFF)
    x ^= x >> np.uint64(31)
    return x


@dataclasses.dataclass
class SyntheticTokens:
    """Deterministic pseudo-random tokens with a learnable bigram structure
    (next token correlates with current), so tiny models can overfit it and
    integration tests can assert loss decreases."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int, *, rank: int = 0, world: int = 1
                 ) -> dict:
        assert self.global_batch % world == 0, (self.global_batch, world)
        b_local = self.global_batch // world
        rows = (np.arange(b_local, dtype=np.uint64)
                + np.uint64(rank * b_local)
                + np.uint64(step) * np.uint64(self.global_batch))
        cols = np.arange(self.seq_len + 1, dtype=np.uint64)
        # base stream
        h = _hash64(rows[:, None] * np.uint64(1_000_003) + cols[None, :]
                    + np.uint64(self.seed) * np.uint64(7_919))
        toks = (h % np.uint64(self.vocab_size)).astype(np.int64)
        # bigram structure: with p~0.75, next = f(current) (deterministic map)
        gate = (_hash64(h) % np.uint64(4)) != 0
        mapped = (toks * 31 + 7) % self.vocab_size
        for t in range(1, self.seq_len + 1):
            toks[:, t] = np.where(gate[:, t], mapped[:, t - 1], toks[:, t])
            mapped[:, t] = (toks[:, t] * 31 + 7) % self.vocab_size
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def write_token_file(path: Path, tokens: np.ndarray):
    """uint32 raw token file + .meta sidecar (the on-disk corpus format)."""
    path = Path(path)
    tokens = np.asarray(tokens, np.uint32)
    tmp = path.with_suffix(".tmp")
    tokens.tofile(tmp)
    tmp.rename(path)
    path.with_suffix(path.suffix + ".meta").write_text(
        f"{{\"n_tokens\": {tokens.size}, \"dtype\": \"uint32\"}}\n")


@dataclasses.dataclass
class MemmapTokens:
    """Memmap-backed corpus, sequence-packed, strided per-rank sharding.

    Sample i of step s is the window starting at
    ``(s * global_batch + i) * seq_len  mod  usable`` — contiguous packing,
    wrapping at the end of the corpus (standard LM packing).
    """

    path: Path
    seq_len: int
    global_batch: int

    def __post_init__(self):
        self._mm = np.memmap(self.path, dtype=np.uint32, mode="r")
        self.n_tokens = int(self._mm.shape[0])
        assert self.n_tokens > self.seq_len + 1, "corpus smaller than one window"

    @property
    def n_windows(self) -> int:
        return (self.n_tokens - 1) // self.seq_len

    def batch_at(self, step: int, *, rank: int = 0, world: int = 1) -> dict:
        assert self.global_batch % world == 0
        b_local = self.global_batch // world
        idx = (np.arange(b_local, dtype=np.int64) + rank * b_local
               + np.int64(step) * self.global_batch) % self.n_windows
        starts = idx * self.seq_len
        out = np.empty((b_local, self.seq_len + 1), np.int64)
        for j, st in enumerate(starts):          # windows may wrap
            seg = np.asarray(self._mm[st: st + self.seq_len + 1])
            if seg.shape[0] < self.seq_len + 1:
                seg = np.concatenate(
                    [seg, self._mm[: self.seq_len + 1 - seg.shape[0]]])
            out[j] = seg
        out = out.astype(np.int32)
        return {"tokens": out[:, :-1], "targets": out[:, 1:]}
