"""Input pipeline: source -> device batches with double-buffered prefetch.

The prefetcher runs host-side data generation for step s+1..s+depth on a
background thread while the device executes step s — the training loop never
blocks on token assembly.  ``Prefetcher.at(step)`` keeps the stateless-by-
step contract of the sources, so restart/elastic jumps are just ``at(s0)``.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from pathlib import Path
from typing import Callable, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    kind: str = "synthetic"          # 'synthetic' | 'memmap'
    vocab_size: int = 256
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    path: Optional[str] = None       # memmap corpus file

    def make_source(self):
        from repro.data.sources import MemmapTokens, SyntheticTokens
        if self.kind == "synthetic":
            return SyntheticTokens(self.vocab_size, self.seq_len,
                                   self.global_batch, self.seed)
        if self.kind == "memmap":
            return MemmapTokens(Path(self.path), self.seq_len,
                                self.global_batch)
        raise ValueError(self.kind)


class Prefetcher:
    """Double-buffered background prefetch over a stateless-by-step source."""

    def __init__(self, source, *, start_step: int = 0, depth: int = 2,
                 rank: int = 0, world: int = 1,
                 put_fn: Optional[Callable] = None):
        self.source = source
        self.depth = depth
        self.rank, self.world = rank, world
        self.put_fn = put_fn or (lambda b: b)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._lock = threading.Lock()
        self._gen = 0
        self._thread.start()

    def _worker(self):
        while not self._stop.is_set():
            with self._lock:
                step, gen = self._next, self._gen
                self._next += 1
            batch = self.source.batch_at(step, rank=self.rank,
                                         world=self.world)
            while not self._stop.is_set():
                try:
                    self._q.put((gen, step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def at(self, step: int):
        """Jump the stream (restart / elastic rescale): drop queued batches
        from the old position and resume at ``step``."""
        with self._lock:
            self._gen += 1
            self._next = step
        while True:          # drain stale entries
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        return self

    def __next__(self):
        while True:
            gen, step, batch = self._q.get()
            with self._lock:
                if gen == self._gen:
                    return step, self.put_fn(batch)
            # stale generation: discard

    def close(self):
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break


def make_pipeline(cfg: DataConfig, *, start_step: int = 0, rank: int = 0,
                  world: int = 1, shardings=None, mesh=None) -> Prefetcher:
    """Prefetcher whose put_fn places host arrays onto devices (sharded when
    a shardings tree is given)."""
    def put(batch):
        if shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}

    return Prefetcher(cfg.make_source(), start_step=start_step, rank=rank,
                      world=world, put_fn=put)
