"""Serving steps: batched prefill + single-token decode (the dry-run's
``serve_step``), greedy sampling, and a simple batched-request driver."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.sharding import ShardingCtx


def make_prefill_step(cfg: ModelConfig, ctx: Optional[ShardingCtx] = None,
                      *, max_len: Optional[int] = None, attn_impl="blocked",
                      cache_dtype=jnp.bfloat16):
    """Returns fn(params, batch) -> (first_token_logits (B,V), caches)."""

    def prefill_step(params, batch):
        hidden, caches, _ = M.prefill(
            cfg, params, batch, max_len=max_len or _seq_of(batch),
            ctx=ctx, attn_impl=attn_impl, cache_dtype=cache_dtype)
        w = M._lm_matrix(cfg, params)
        logits = jnp.einsum("bd,dv->bv", hidden[:, -1], w,
                            preferred_element_type=jnp.float32)
        if cfg.final_softcap is not None:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits, caches

    return prefill_step


def _seq_of(batch):
    x = batch.get("tokens", batch.get("embeds"))
    return x.shape[1]


def make_serve_step(cfg: ModelConfig, ctx: Optional[ShardingCtx] = None):
    """One new token with an existing KV/SSM cache — the decode-shape target.

    fn(params, batch, caches, cur_len) -> (next_token (B,), logits, caches)."""

    def serve_step(params, batch, caches, cur_len):
        logits, new_caches = M.decode_step(cfg, params, batch, caches,
                                           cur_len, ctx)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_caches

    return serve_step


def generate(cfg: ModelConfig, params, prompt_batch, *, max_new_tokens: int,
             ctx=None, attn_impl="blocked", cache_dtype=jnp.float32):
    """Greedy generation driver (prefill + decode loop).  Returns (B, T)."""
    S = _seq_of(prompt_batch)
    max_len = S + max_new_tokens
    prefill_step = make_prefill_step(cfg, ctx, max_len=max_len,
                                     attn_impl=attn_impl,
                                     cache_dtype=cache_dtype)
    serve_step = jax.jit(make_serve_step(cfg, ctx))
    logits, caches = prefill_step(params, prompt_batch)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    cur = S
    for _ in range(max_new_tokens - 1):
        if cfg.input_mode == "embeds":
            # modality-frontend stub: next-step embedding from the token table
            batch = {"embeds": params["embed"][tok][:, None]}
        else:
            batch = {"tokens": tok[:, None]}
        if cfg.mrope:
            batch["positions"] = jnp.full((3, tok.shape[0], 1), cur, jnp.int32)
        tok, _, caches = serve_step(params, batch, caches, jnp.asarray(cur))
        out.append(tok)
        cur += 1
    return jnp.stack(out, axis=1)
