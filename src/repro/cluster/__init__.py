"""Partition-tolerant multi-process coherence runtime.

The worker axis of the ``(W, window)`` directory planes is sharded
across N OS processes (``shard.py`` — deterministic full-width replicas
with slice ownership), fronted by a control plane (``control.py``) that
owns membership and heartbeat failure detection (``membership.py``),
per-RPC deadlines with backoff retries and partition/kill injection
(``rpc.py``), barrier-cut composed checkpoints, and degraded-mode
recovery that replays a failed shard's suffix to a bit-equal finish.
See DIRECTORY.md "Cluster contract".
"""
from repro.cluster.control import (ClusterReport, ClusterResult,
                                   ClusterRuntime, ReplicaDivergence)
from repro.cluster.membership import (HeartbeatDetector, MembershipTable,
                                      ShardState)
from repro.cluster.rpc import ShardChannel, ShardDown, ShardError
from repro.cluster.shard import make_runtime, state_digest

__all__ = [
    "ClusterReport", "ClusterResult", "ClusterRuntime",
    "ReplicaDivergence", "HeartbeatDetector", "MembershipTable",
    "ShardState", "ShardChannel", "ShardDown", "ShardError",
    "make_runtime", "state_digest",
]
