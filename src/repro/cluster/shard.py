"""Shard process: a deterministic RegC replica behind an RPC loop.

Each shard process runs the FULL-width ``RegCScaleRuntime`` as a
deterministic replicated state machine: every shard applies the same
event stream in the same order, so all replicas hold bit-identical
protocol state at every round.  What makes a shard a *shard* is slice
ownership, not slice computation — the control plane asks each rank for
``snapshot(rows=its slice)`` at checkpoints and for its slice of the
clocks at gather, and the cross-shard agreement assertions
(per-round state digests here, replicated-global equality in
``compose_snapshots``) turn the redundancy into a divergence detector.
See DIRECTORY.md "Cluster contract" for why this is the right first rung
(bit-equality with the single-process run is non-negotiable; a
plane-partitioned protocol is the next rung, not a prerequisite).

The RPC loop is crash-ready by construction: all state is process-local,
requests are deduplicated by event index (a re-send after a lost ack
re-acks without re-applying), and the process can be SIGKILL'd at any
instant — recovery is always restore-from-checkpoint + replay in a fresh
process, never in-place repair.
"""
from __future__ import annotations

import dataclasses
import hashlib
import importlib
import sys
import traceback
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


def state_digest(rt) -> str:
    """Order-stable fingerprint of the replica-visible runtime state:
    clocks bit-for-bit, traffic field-for-field, stats counters.  Equal
    digests across shards == the replicas took identical engine paths."""
    h = hashlib.blake2b(digest_size=16)
    h.update(np.ascontiguousarray(rt.clock).tobytes())
    h.update(repr(sorted(dataclasses.asdict(rt.traffic).items())).encode())
    h.update(repr(sorted(rt.stats.items())).encode())
    return h.hexdigest()


def make_runtime(cfg: Dict[str, Any]):
    """Build a runtime from the JSON-ish config the control plane ships
    (the same shape ``snapshot()`` meta uses for chaos/straggler)."""
    from repro.core.regc_scale import RegCScaleRuntime
    from repro.dsm.costmodel import ChaosNet, CostModel

    chaos = None
    if cfg.get("chaos") is not None:
        chaos = ChaosNet(**cfg["chaos"])
    straggler = None
    if cfg.get("straggler") is not None:
        from repro.ft.runtime import StragglerMonitor
        straggler = StragglerMonitor(
            int(cfg["straggler"]["n_workers"]),
            window=int(cfg["straggler"]["window"]),
            k=float(cfg["straggler"]["k"]),
            abs_floor_s=float(cfg["straggler"]["abs_floor_s"]),
            patience=int(cfg["straggler"]["patience"]))
    kw = dict(page_words=int(cfg.get("page_words", 1024)),
              protocol=cfg["protocol"],
              cache_pages=cfg.get("cache_pages"),
              fetch_batch=int(cfg.get("fetch_batch", 1)),
              backend=cfg.get("backend", "numpy"),
              danger_mode=cfg.get("danger_mode", "vec"),
              detect_races=bool(cfg.get("detect_races", False)),
              chaos=chaos, straggler=straggler)
    if cfg.get("cost") is not None:
        kw["cost"] = CostModel(**cfg["cost"])
    return RegCScaleRuntime(int(cfg["n_workers"]), **kw)


def _resolve_apply(apply_ref: Tuple[str, str]):
    mod, attr = apply_ref
    return getattr(importlib.import_module(mod), attr)


class _ShardServer:
    """Request dispatcher — one instance per shard process lifetime."""

    def __init__(self):
        self.rt = None
        self.gas: List = []
        self.driver = "batched"
        self.apply_event = None
        self.rank = -1
        # index of the NEXT event to apply; requests for idx below this
        # are duplicates and re-ack with the cached digest
        self.applied_upto = 0
        self.last_digest = ""

    # -- ops ------------------------------------------------------------
    def op_init(self, p):
        self.rank = int(p["rank"])
        self.driver = p["driver"]
        self.apply_event = _resolve_apply(p["apply_ref"])
        self.rt = make_runtime(p["cfg"])
        self.gas = [self.rt.alloc(int(n)) for n in p["gas_words"]]
        self.applied_upto = 0
        self.last_digest = state_digest(self.rt)
        return {"digest": self.last_digest}

    def _apply_one(self, ev):
        from repro.ft.coherence import harness_ticks
        if harness_ticks(ev, self.driver):
            self.rt.chaos_tick()
        self.apply_event(self.rt, ev, self.gas, self.driver)

    def op_apply(self, p):
        idx = int(p["idx"])
        if idx == self.applied_upto:
            self._apply_one(p["ev"])
            self.applied_upto = idx + 1
            self.last_digest = state_digest(self.rt)
        elif idx != self.applied_upto - 1:
            raise AssertionError(
                f"shard {self.rank}: apply idx {idx} vs "
                f"applied_upto {self.applied_upto}")
        # idx == applied_upto - 1 is a duplicate re-send: re-ack only
        return {"idx": idx, "digest": self.last_digest}

    def op_snapshot(self, p):
        arrays, meta = self.rt.snapshot(
            rows=(int(p["w_lo"]), int(p["w_hi"])))
        return {"arrays": arrays, "meta": meta}

    def op_restore(self, p):
        from repro.core.regc_scale import RegCScaleRuntime
        self.rt = RegCScaleRuntime.from_snapshot(p["arrays"], p["meta"])
        self.gas = [self.rt.gas_for_region(r, int(n))
                    for r, n in enumerate(p["gas_words"])]
        self.applied_upto = int(p["cursor"])
        for ev in p["suffix"]:
            self._apply_one(ev)
            self.applied_upto += 1
        self.last_digest = state_digest(self.rt)
        return {"digest": self.last_digest,
                "applied_upto": self.applied_upto}

    def op_gather(self, p):
        w_lo, w_hi = int(p["w_lo"]), int(p["w_hi"])
        return {"clock": self.rt.clock[w_lo:w_hi].copy(),
                "traffic": dataclasses.asdict(self.rt.traffic),
                "stats": dict(self.rt.stats),
                "digest": state_digest(self.rt)}

    def op_ping(self, p):
        return {"applied_upto": self.applied_upto}

    def serve(self, conn):
        while True:
            try:
                seq, op, payload = conn.recv()
            except (EOFError, OSError):
                return                      # control plane went away
            if op == "stop":
                conn.send((seq, "ok", {}))
                return
            try:
                data = getattr(self, f"op_{op}")(payload)
                conn.send((seq, "ok", data))
            except Exception:
                try:
                    conn.send((seq, "err", traceback.format_exc()))
                except (BrokenPipeError, OSError):
                    return


def shard_main(conn, sys_path: List[str]):
    """Spawn-context entry point.  ``sys_path`` is the parent's import
    path — the spawned interpreter starts from the bare environment and
    must be able to import the runtime AND the caller's ``apply_event``
    module (e.g. the trace-fuzz executor living under ``tests/``)."""
    for p in sys_path:
        if p not in sys.path:
            sys.path.append(p)
    _ShardServer().serve(conn)
    conn.close()
