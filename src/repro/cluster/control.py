"""The cluster control plane: membership, rounds, checkpoints, recovery.

``ClusterRuntime`` fronts N spawned shard processes (``shard.py``) and
drives a trace program round by round:

* **Rounds.**  Each event is broadcast to every alive shard, then acks
  are collected under the heartbeat-adaptive deadline chain
  (``membership.HeartbeatDetector`` + ``rpc.ShardChannel``).  Every ack
  carries a state digest; a fully-acked round asserts all replicas
  agree bit-for-bit before advancing.
* **Checkpoints.**  After every barrier event the control plane pulls
  each owner's ``snapshot(rows=slice)``, reassembles them with
  ``RegCScaleRuntime.compose_snapshots`` (which re-asserts replicated-
  global agreement) and commits the composed snapshot through the
  crash-durable checkpoint store.  The checkpoint cursor is the index of
  the next event, exactly like ``ft.coherence.ChaosHarness``.
* **Failure + recovery.**  A dead pipe or an exhausted deadline chain
  marks the shard DEAD; the control plane *fences* it (SIGKILL — a
  partitioned-but-healthy process must not keep running), quarantines
  it, and recovers in one of two degraded modes:

    - ``respawn``: start a replacement process, restore the last barrier
      checkpoint into it, replay the suffix up to (excluding) the
      current round, then retry the round — event-index dedup makes the
      retry idempotent for survivors.
    - ``rebind``: hand the dead rank's worker slice to a survivor
      (instant, capacity-degraded; replicas make this free) — falling
      back to ``respawn`` when nobody survived.

  Either way the finish is traffic field-for-field and clock bit-equal
  to the unfailed single-process run — asserted by the cluster fuzz
  family and inside the fig10_availability bench.

Real RPC wall time (retries, deadlines) never touches the modeled
clocks; it is accounted in the :class:`ClusterReport` through
``ChaosNet.backoff_seconds`` — the same capped backoff term the in-model
loss tier charges.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import sys
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.store import load_arrays, save_arrays
from repro.cluster.membership import (HeartbeatDetector, MembershipTable,
                                      ShardState)
from repro.cluster.rpc import ShardChannel, ShardDown
from repro.cluster.shard import shard_main
from repro.core.regc import Traffic
from repro.core.regc_scale import RegCScaleRuntime
from repro.dsm.costmodel import ChaosNet

_HEAVY_TIMEOUT_S = 120.0      # init/restore/snapshot/gather (bulk pickles,
#   possible jax import in the child) — failure still fast-paths via EOF


class ReplicaDivergence(RuntimeError):
    """Shard replicas disagreed on a state digest — a protocol bug, not
    a fault to recover from."""


@dataclasses.dataclass
class ClusterReport:
    """What a cluster run went through.  The ``rec_*`` counters are
    deterministic functions of (program, injection schedule, recovery
    mode) — benchable and gated exactly like traffic; the wall/retry
    numbers are real-time measurements and stay ungated."""

    n_events: int = 0
    detections: int = 0
    kills: int = 0
    partitions: int = 0
    respawns: int = 0
    rebinds: int = 0
    replayed_events: int = 0
    checkpoints: int = 0
    digest_rounds: int = 0
    rpc_retries: int = 0
    rpc_retry_model_s: float = 0.0
    bar_wall_s: List[float] = dataclasses.field(default_factory=list)

    def counters(self) -> Dict[str, int]:
        return {"rec_detections": self.detections,
                "rec_kills": self.kills,
                "rec_partitions": self.partitions,
                "rec_respawns": self.respawns,
                "rec_rebinds": self.rebinds,
                "rec_replayed_events": self.replayed_events,
                "rec_checkpoints": self.checkpoints,
                "rec_digest_rounds": self.digest_rounds}


@dataclasses.dataclass
class ClusterResult:
    """Gathered end state, shaped like a runtime for the exactness
    asserts (``ft.coherence.assert_bit_equal(result, baseline_rt)``)."""

    traffic: Traffic
    clock: np.ndarray
    stats: Dict[str, int]
    report: ClusterReport

    @property
    def time(self) -> float:
        return float(self.clock.max())


class ClusterRuntime:
    """N shard processes + membership + recovery behind one driver."""

    def __init__(self, cfg: Dict[str, Any], gas_words: Sequence[int],
                 *, n_shards: int, driver: str,
                 apply_ref: Tuple[str, str], root,
                 recovery: str = "respawn", injector=None,
                 rpc_timeout_s: float = 0.25, rpc_attempts: int = 4,
                 rpc_backoff: float = 2.0):
        assert recovery in ("respawn", "rebind"), recovery
        W = int(cfg["n_workers"])
        assert 1 <= n_shards <= W, (n_shards, W)
        self.cfg = dict(cfg)
        self.gas_words = [int(n) for n in gas_words]
        self.W = W
        self.n_shards = int(n_shards)
        self.driver = driver
        self.apply_ref = tuple(apply_ref)
        self.root = root
        self.recovery = recovery
        self.injector = injector
        self.rpc_attempts = int(rpc_attempts)
        self.rpc_backoff = float(rpc_backoff)
        self.detector = HeartbeatDetector(floor_s=float(rpc_timeout_s))
        self.report = ClusterReport()
        self.membership = MembershipTable()
        self.digests: Dict[int, str] = {}   # event idx -> agreed digest
        self._ctx = mp.get_context("spawn")   # fork is unsafe under jax
        self._chans: Dict[int, ShardChannel] = {}
        self._procs: Dict[int, mp.Process] = {}
        bounds = np.linspace(0, W, self.n_shards + 1).astype(int)
        self._slices = [(int(bounds[r]), int(bounds[r + 1]))
                        for r in range(self.n_shards)]
        for rank in range(self.n_shards):
            self._spawn(rank, new_member=True)
            self._init_shard(rank)

    # -- process lifecycle ----------------------------------------------
    def _spawn(self, rank: int, *, new_member: bool):
        parent_conn, child_conn = self._ctx.Pipe(duplex=True)
        proc = self._ctx.Process(target=shard_main,
                                 args=(child_conn, list(sys.path)),
                                 daemon=True)
        proc.start()
        child_conn.close()     # keep only the shard's copy open there,
        #   so a dead shard turns into EOF on our end instead of a hang
        self._chans[rank] = ShardChannel(parent_conn, rank)
        self._procs[rank] = proc
        if new_member:
            lo, hi = self._slices[rank]
            self.membership.add(rank, proc.pid, lo, hi)
        else:
            self.membership.reincarnate(rank, proc.pid)

    def _init_shard(self, rank: int):
        self._chans[rank].request(
            "init", {"rank": rank, "cfg": self.cfg,
                     "gas_words": self.gas_words, "driver": self.driver,
                     "apply_ref": list(self.apply_ref)},
            timeout_s=_HEAVY_TIMEOUT_S)
        self.membership.mark(rank, ShardState.ALIVE)

    def _fence(self, rank: int):
        """Make DEAD mean dead: SIGKILL the process (it may be healthy
        but partitioned — it must not outlive its membership record),
        reap it, drop the channel."""
        proc = self._procs.get(rank)
        if proc is not None:
            if proc.is_alive():
                proc.kill()
            proc.join(timeout=10)
        ch = self._chans.pop(rank, None)
        if ch is not None:
            ch.close()

    def close(self):
        for rank in list(self._chans):
            ch = self._chans[rank]
            try:
                ch.request("stop", {}, timeout_s=5.0)
            except (ShardDown, OSError):
                pass
            self._fence(rank)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- RPC accounting --------------------------------------------------
    def _account_retries(self, levels: int, timeout_s: float):
        if levels <= 0:
            return
        self.report.rpc_retries += levels
        self.report.rpc_retry_model_s += ChaosNet.backoff_seconds(
            timeout_s, self.rpc_backoff, levels)

    def _round_timeout(self) -> float:
        return self.detector.timeout_s()

    # -- rounds ----------------------------------------------------------
    def _apply_round(self, i: int, ev) -> Dict[int, ShardDown]:
        alive = self.membership.alive_ranks()
        assert alive, "no shards left"
        t0 = time.monotonic()
        timeout = self._round_timeout()
        toks: Dict[int, tuple] = {}
        failed: Dict[int, ShardDown] = {}
        for rank in alive:                       # broadcast first ...
            try:
                toks[rank] = self._chans[rank].start(
                    "apply", {"idx": i, "ev": ev})
            except ShardDown as e:
                failed[rank] = e
        digests: Dict[int, str] = {}
        for rank, tok in toks.items():           # ... then collect
            def _suspect(_k, rank=rank):
                self.membership.mark(rank, ShardState.SUSPECT)
            try:
                data, retries = self._chans[rank].finish(
                    tok, timeout_s=timeout, attempts=self.rpc_attempts,
                    backoff=self.rpc_backoff, on_retry=_suspect)
            except ShardDown as e:
                self._account_retries(self.rpc_attempts - 1, timeout)
                failed[rank] = e
                continue
            self._account_retries(retries, timeout)
            self.detector.observe(time.monotonic() - t0)
            self.membership.mark(rank, ShardState.ALIVE)
            digests[rank] = data["digest"]
        if failed:
            return failed
        uniq = set(digests.values())
        if len(uniq) != 1:
            raise ReplicaDivergence(
                f"event {i}: shard digests diverged: {digests}")
        self.report.digest_rounds += 1
        self.digests[i] = uniq.pop()
        if ev[0] == "barrier":
            self.report.bar_wall_s.append(time.monotonic() - t0)
        return {}

    def _checkpoint(self, cursor: int) -> Dict[int, ShardDown]:
        parts = []
        for w_lo, w_hi, rank in self.membership.owners():
            try:
                data, _r = self._chans[rank].request(
                    "snapshot", {"w_lo": w_lo, "w_hi": w_hi},
                    timeout_s=_HEAVY_TIMEOUT_S)
            except ShardDown as e:
                return {rank: e}
            parts.append((data["arrays"], data["meta"]))
        arrays, meta = RegCScaleRuntime.compose_snapshots(parts)
        save_arrays(self.root, cursor, arrays, extra=meta)
        self.report.checkpoints += 1
        return {}

    # -- failure handling -------------------------------------------------
    def _inject(self, kind: str, rank: int):
        rec = self.membership.records.get(rank)
        if rec is None or rec.state not in (ShardState.ALIVE,
                                            ShardState.SUSPECT):
            return
        if kind == "kill":
            self._procs[rank].kill()
            self.report.kills += 1
        elif kind == "partition_c2s":
            self._chans[rank].drop_c2s = True
            self.report.partitions += 1
        elif kind == "partition_s2c":
            self._chans[rank].drop_s2c = True
            self.report.partitions += 1
        else:
            raise ValueError(kind)

    def _recover(self, failed: Dict[int, ShardDown], last_ckpt: int,
                 i: int, prog):
        """Quarantine the dead, then rebind or respawn-replay so the
        retry of round ``i`` finds a full ownership map again."""
        self.report.detections += len(failed)
        for rank in sorted(failed):
            self.membership.mark(rank, ShardState.DEAD)
            self._fence(rank)
            self.membership.mark(rank, ShardState.QUARANTINED)
        survivors = self.membership.alive_ranks()
        if self.recovery == "rebind" and survivors:
            for j, rank in enumerate(sorted(failed)):
                self.membership.rebind(rank,
                                       survivors[j % len(survivors)])
                self.report.rebinds += 1
            return
        arrays, meta = load_arrays(self.root, last_ckpt)
        suffix = list(prog[last_ckpt:i])
        for rank in sorted(failed):
            self._spawn(rank, new_member=False)
            self._init_shard(rank)
            self._chans[rank].request(
                "restore", {"arrays": arrays, "meta": meta,
                            "gas_words": self.gas_words,
                            "cursor": last_ckpt, "suffix": suffix},
                timeout_s=_HEAVY_TIMEOUT_S)
            self.report.respawns += 1
            self.report.replayed_events += len(suffix)

    # -- driver -----------------------------------------------------------
    def run(self, prog) -> ClusterResult:
        inj = self.injector
        self.report.n_events += len(prog)
        failed = self._checkpoint(0)
        assert not failed, "shard died before the t=0 checkpoint"
        last_ckpt = 0
        i = 0
        while i < len(prog):
            if inj is not None:
                for kind, rank in inj.cluster_actions(i + 1):
                    self._inject(kind, rank)
            failed = self._apply_round(i, prog[i])
            if not failed and prog[i][0] == "barrier":
                failed = self._checkpoint(i + 1)
            if failed:
                self._recover(failed, last_ckpt, i, prog)
                continue          # retry round i (dedup-idempotent)
            if prog[i][0] == "barrier":
                last_ckpt = i + 1
            i += 1
        return self._gather()

    def _gather(self) -> ClusterResult:
        clock = np.zeros(self.W, np.float64)
        traffic: Optional[dict] = None
        stats: Optional[dict] = None
        for w_lo, w_hi, rank in self.membership.owners():
            data, _r = self._chans[rank].request(
                "gather", {"w_lo": w_lo, "w_hi": w_hi},
                timeout_s=_HEAVY_TIMEOUT_S)
            clock[w_lo:w_hi] = data["clock"]
            if traffic is None:
                traffic, stats = data["traffic"], data["stats"]
            else:
                assert data["traffic"] == traffic, (
                    "replica traffic diverged at gather")
                assert data["stats"] == stats, (
                    "replica stats diverged at gather")
        return ClusterResult(traffic=Traffic(**traffic), clock=clock,
                             stats=stats, report=self.report)
