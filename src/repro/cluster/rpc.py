"""Control-plane <-> shard RPC over multiprocessing pipes.

One :class:`ShardChannel` per shard process, driven stop-and-wait by the
control plane (rounds are pipelined ACROSS shards by the caller: send to
every shard first, then collect).  The transport carries pickled
``(seq, op, payload)`` requests and ``(seq, status, data)`` replies.

Failure semantics — the whole point of this layer:

* **Deadlines + backoff.**  Every request waits ``timeout_s`` for its
  reply, re-sends, and waits ``timeout_s * backoff**k`` on attempt k.
  Retries are deduplicated shard-side by event/sequence number, so a
  re-send is always safe.  Exhausting ``attempts`` raises
  :class:`ShardDown` — the caller's failure detector.
* **Fast-path death.**  A SIGKILL'd shard closes its pipe; ``recv``
  raises ``EOFError`` and ``send`` raises ``BrokenPipeError``, both
  surfaced as :class:`ShardDown` immediately (no need to burn the full
  deadline chain on a corpse).
* **Partitions.**  ``drop_c2s`` silently discards control->shard sends
  (the shard never hears the request); ``drop_s2c`` discards
  shard->control replies as they arrive (the shard DID the work, but the
  control plane cannot know).  Either direction alone must drive the
  deadline chain to :class:`ShardDown` — that asymmetry is what the
  recovery tests exercise.

Real wall-clock retry time is NOT charged to the modeled runtime clocks
(that would break bit-equality with the single-process run); the control
plane accounts it separately through ``ChaosNet.backoff_seconds`` — the
same capped-exponent term the in-model message-loss tier charges.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional, Tuple


class ShardDown(RuntimeError):
    """A shard stopped answering: dead pipe or exhausted deadline chain."""

    def __init__(self, rank: int, reason: str):
        super().__init__(f"shard {rank} down ({reason})")
        self.rank = rank
        self.reason = reason


class ShardError(RuntimeError):
    """The shard executed the request and raised — a programming error
    propagated verbatim, NOT a failure-detection event."""

    def __init__(self, rank: int, traceback_text: str):
        super().__init__(f"shard {rank} raised:\n{traceback_text}")
        self.rank = rank


class ShardChannel:
    """One control-plane endpoint: seq-numbered requests with deadlines,
    re-sends, partition injection, and dead-pipe detection."""

    def __init__(self, conn, rank: int):
        self.conn = conn
        self.rank = rank
        self.drop_c2s = False     # partition: control -> shard direction
        self.drop_s2c = False     # partition: shard -> control direction
        self._seq = 0

    # -- transport ------------------------------------------------------
    def _send(self, seq: int, op: str, payload: Any):
        if self.drop_c2s:
            return                # the partition eats the request
        try:
            self.conn.send((seq, op, payload))
        except (BrokenPipeError, ConnectionResetError, OSError):
            raise ShardDown(self.rank, "pipe closed on send")

    def _recv_until(self, seq: int, timeout_s: float
                    ) -> Optional[Tuple[str, Any]]:
        """Reply for ``seq`` within ``timeout_s``, or None on deadline.
        Stale replies (earlier attempts / earlier requests) are skipped;
        an s2c partition discards replies as if they were never sent."""
        end = time.monotonic() + timeout_s
        while True:
            remaining = end - time.monotonic()
            if remaining <= 0:
                return None
            try:
                if not self.conn.poll(remaining):
                    return None
                msg = self.conn.recv()
            except (EOFError, ConnectionResetError, OSError):
                raise ShardDown(self.rank, "pipe closed on recv")
            if self.drop_s2c:
                continue          # the partition eats the reply
            mseq, status, data = msg
            if mseq != seq:
                continue          # stale duplicate from a prior attempt
            return status, data

    # -- request API ----------------------------------------------------
    def start(self, op: str, payload: Any) -> Tuple[int, str, Any]:
        """Send attempt 0 and return a token for :meth:`finish` — the
        split lets the control plane broadcast a round to every shard
        before it starts collecting."""
        self._seq += 1
        self._send(self._seq, op, payload)
        return (self._seq, op, payload)

    def finish(self, token: Tuple[int, str, Any], *, timeout_s: float,
               attempts: int, backoff: float,
               on_retry: Optional[Callable[[int], None]] = None
               ) -> Tuple[Any, int]:
        """Collect the reply for ``token``; returns ``(data, retries)``
        where ``retries`` is the number of deadline levels burned.  Each
        timeout re-sends the request (shard-side dedup makes that safe)
        and widens the next deadline by ``backoff``."""
        seq, op, payload = token
        for k in range(attempts):
            reply = self._recv_until(seq, timeout_s * (backoff ** k))
            if reply is not None:
                status, data = reply
                if status == "err":
                    raise ShardError(self.rank, data)
                return data, k
            if on_retry is not None:
                on_retry(k)
            if k + 1 < attempts:
                self._send(seq, op, payload)
        raise ShardDown(self.rank, f"deadline after {attempts} attempts")

    def request(self, op: str, payload: Any, *, timeout_s: float,
                attempts: int = 1, backoff: float = 2.0,
                on_retry: Optional[Callable[[int], None]] = None
                ) -> Tuple[Any, int]:
        return self.finish(self.start(op, payload), timeout_s=timeout_s,
                           attempts=attempts, backoff=backoff,
                           on_retry=on_retry)

    def close(self):
        try:
            self.conn.close()
        except OSError:
            pass
