"""Cluster membership and heartbeat-interval failure detection.

The control plane owns a :class:`MembershipTable`: every shard process
is a member with a lifecycle

    JOINING -> ALIVE <-> SUSPECT -> DEAD -> QUARANTINED

and an *incarnation* number that increments on every respawn (a reply
from a stale incarnation can never be confused with the replacement's).
Worker-slice ownership lives here too: normally rank r owns its own
contiguous slice of the worker axis, but degraded-mode ``rebind`` hands
a dead shard's slice to a survivor — ``owners()`` is the control plane's
single source of truth for who serves which rows of the ``(W, window)``
planes at checkpoint/gather time.

Failure detection is heartbeat-based in the synchronous-RPC sense: every
successful reply IS a heartbeat, and :class:`HeartbeatDetector` keeps a
sliding window of observed reply latencies, deriving the RPC deadline as
``median + k * MAD`` over the window (the same robust-threshold
machinery ``ft.runtime.StragglerMonitor`` applies to barrier walls,
via the shared ``mad_threshold`` helper — degenerate windows fall back
to the configured floor).  A shard that misses one adaptive deadline
turns SUSPECT; exhausting the backoff chain (or a dead pipe) makes it
DEAD, after which the control plane fences it with SIGKILL and
quarantines it — a partitioned-but-healthy process must never keep
mutating state it no longer owns.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.ft.runtime import mad_threshold


class ShardState(enum.Enum):
    JOINING = "joining"
    ALIVE = "alive"
    SUSPECT = "suspect"
    DEAD = "dead"
    QUARANTINED = "quarantined"


@dataclasses.dataclass
class MemberRecord:
    rank: int
    pid: int
    state: ShardState = ShardState.JOINING
    incarnation: int = 0
    home_slice: Tuple[int, int] = (0, 0)   # the slice this rank spawned with


class MembershipTable:
    """Who is in the cluster, what state they are in, who owns which
    worker slice."""

    def __init__(self):
        self.records: Dict[int, MemberRecord] = {}
        # rank -> list of owned (w_lo, w_hi) slices (rebind can stack
        # a dead peer's slice onto a survivor)
        self._owned: Dict[int, List[Tuple[int, int]]] = {}

    def add(self, rank: int, pid: int, w_lo: int, w_hi: int):
        self.records[rank] = MemberRecord(rank, pid,
                                          home_slice=(w_lo, w_hi))
        self._owned[rank] = [(w_lo, w_hi)]

    def mark(self, rank: int, state: ShardState):
        self.records[rank].state = state

    def state(self, rank: int) -> ShardState:
        return self.records[rank].state

    def reincarnate(self, rank: int, pid: int):
        """A replacement process took over this rank (respawn).  The
        home slice is reclaimed from any survivor a ``rebind`` handed
        it to — ownership must never double-count a row."""
        r = self.records[rank]
        r.pid = pid
        r.incarnation += 1
        r.state = ShardState.JOINING
        for other, slices in self._owned.items():
            if other != rank and r.home_slice in slices:
                slices.remove(r.home_slice)
        self._owned[rank] = [r.home_slice]

    def rebind(self, dead_rank: int, to_rank: int):
        """Degraded mode: hand every slice the dead rank owned to a
        survivor (who keeps serving at reduced capacity)."""
        assert to_rank != dead_rank
        moved = self._owned.pop(dead_rank, [])
        self._owned.setdefault(to_rank, []).extend(moved)

    def alive_ranks(self) -> List[int]:
        return sorted(r for r, rec in self.records.items()
                      if rec.state in (ShardState.ALIVE,
                                       ShardState.SUSPECT))

    def owners(self) -> List[Tuple[int, int, int]]:
        """Sorted ``(w_lo, w_hi, rank)`` ownership map over the whole
        worker axis — the checkpoint/gather fan-out plan."""
        out = [(lo, hi, rank) for rank, slices in self._owned.items()
               for lo, hi in slices
               if self.records[rank].state in (ShardState.ALIVE,
                                               ShardState.SUSPECT)]
        return sorted(out)


class HeartbeatDetector:
    """Adaptive RPC deadline from a sliding window of reply latencies:
    ``max(floor, median + k * MAD)``.  Fewer than 2 samples (or a cold
    start) fall back to the floor — the degenerate-window guard shared
    with StragglerMonitor."""

    def __init__(self, *, floor_s: float = 0.25, k: float = 6.0,
                 window: int = 64):
        assert floor_s > 0, floor_s
        self.floor_s = float(floor_s)
        self.k = float(k)
        self._lat: deque = deque(maxlen=int(window))

    def observe(self, latency_s: float):
        self._lat.append(float(latency_s))

    def timeout_s(self) -> float:
        return max(self.floor_s,
                   mad_threshold(self._lat, self.k, self.floor_s))

    def n_samples(self) -> int:
        return len(self._lat)
