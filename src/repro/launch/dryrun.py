import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (including jax
# and repro.*): jax locks the device count at first init.  This flag is set
# ONLY here — tests and benchmarks see the real single CPU device.

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import gc              # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from pathlib import Path  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, all_cells, get_config, get_shape  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import (  # noqa: E402
    HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh,
)
from repro.launch.specs import lower_target, model_flops  # noqa: E402
from repro.train.train_step import TrainHParams  # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             hp: TrainHParams = None, variant: str = "baseline",
             rules_override=None, save_hlo: bool = False,
             out_dir: Path = Path("artifacts/dryrun"), **ctx_opts) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev, "variant": variant, "ok": False,
    }
    t0 = time.time()
    try:
        fn, args, shards, donate = lower_target(cfg, shape, mesh, hp=hp,
                                                rules_override=rules_override,
                                                **ctx_opts)
        with mesh:
            lowered = jax.jit(fn, in_shardings=shards,
                              donate_argnums=donate).lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory_per_device"] = {
            "arguments": ma.argument_size_in_bytes,
            "outputs": ma.output_size_in_bytes,
            "temps": ma.temp_size_in_bytes,
            "aliased": ma.alias_size_in_bytes,
            "total_live": ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
        }
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):     # jax <= 0.4.x: one dict per program
            ca = ca[0] if ca else {}
        rec["xla_cost_analysis"] = {
            k: v for k, v in ca.items()
            if k in ("flops", "bytes accessed") and v == v}

        txt = compiled.as_text()
        rec["hlo_chars"] = len(txt)
        st = hlo_analysis.analyze(txt)
        rec["per_device"] = {
            "flops": st.flops,
            "bytes_accessed": st.bytes_accessed,
            "bytes_hbm_est": st.bytes_hbm_est,
            "bytes_dot": st.bytes_dot,
            "bytes_entry": st.bytes_entry,
            "collective_bytes": st.collective_bytes,
            "collective_count": st.collective_count,
            "collective_bytes_total": st.total_collective_bytes,
            "dot_count": st.dot_count,
            "while_trips": st.while_trips[:50],
        }
        mf = model_flops(cfg, shape)
        rec["model_flops_global"] = mf
        rec["roofline"] = roofline_terms(st, n_dev, mf)
        rec["ok"] = True
        if save_hlo:
            hlo_path = out_dir / f"{arch}__{shape_name}__{rec['mesh']}__{variant}.hlo"
            hlo_path.write_text(txt)
            rec["hlo_file"] = str(hlo_path)
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def roofline_terms(st: hlo_analysis.HloStats, n_dev: int, model_flops_global: float):
    """Three-term roofline (seconds) from per-device HLO stats."""
    t_compute = st.flops / PEAK_FLOPS_BF16
    t_memory = st.bytes_hbm_est / HBM_BW
    t_coll = st.total_collective_bytes / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
        key=lambda kv: kv[1])[0]
    hlo_flops_global = st.flops * n_dev
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": max(t_compute, t_memory, t_coll),
        "model_flops/hlo_flops": (
            model_flops_global / hlo_flops_global if hlo_flops_global else 0.0),
        "mfu_upper_bound": (
            model_flops_global / (max(t_compute, t_memory, t_coll)
                                  * n_dev * PEAK_FLOPS_BF16)
            if max(t_compute, t_memory, t_coll) > 0 else 0.0),
    }


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--remat-segment", type=int, default=0)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ce-chunk", type=int, default=1024)
    ap.add_argument("--moe-impl", choices=["dense", "ep"], default="dense")
    ap.add_argument("--no-gather-fsdp", action="store_true",
                    help="keep FSDP shard on weights (decode variant)")
    ap.add_argument("--opt-impl", choices=["adamw", "adamw8bit"],
                    default="adamw")
    ap.add_argument("--rules", default="default",
                    help="named sharding rules override (see NAMED_RULES)")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    from repro.models.sharding import NAMED_RULES  # noqa: E402
    rules_override = NAMED_RULES[args.rules]

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    hp = TrainHParams(remat=args.remat or None, n_micro=args.n_micro,
                      ce_chunk=args.ce_chunk,
                      remat_segment=args.remat_segment,
                      opt_impl=args.opt_impl)

    cells = []
    if args.all:
        for arch, shapes in all_cells().items():
            cells += [(arch, s) for s in shapes]
    else:
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch, shape_name in cells:
        for mp in meshes:
            mesh_tag = "2x16x16" if mp else "16x16"
            key = f"{arch}__{shape_name}__{mesh_tag}__{args.variant}"
            path = out_dir / (key + ".json")
            rec = run_cell(arch, shape_name, mp, hp=hp, variant=args.variant,
                           save_hlo=args.save_hlo, out_dir=out_dir,
                           rules_override=rules_override,
                           moe_impl=args.moe_impl,
                           gather_fsdp=not args.no_gather_fsdp)
            path.write_text(json.dumps(rec, indent=1, default=float))
            if rec["ok"]:
                r = rec["roofline"]
                print(f"OK   {key}  lower={rec['lower_s']}s compile={rec['compile_s']}s "
                      f"dom={r['dominant']} bound={r['bound_s']*1e3:.2f}ms "
                      f"mfu_ub={r['mfu_upper_bound']:.3f} "
                      f"mem={rec['memory_per_device']['total_live']/2**30:.2f}GiB",
                      flush=True)
            else:
                n_fail += 1
                print(f"FAIL {key}: {rec['error']}", flush=True)
            gc.collect()
    if n_fail:
        raise SystemExit(f"{n_fail} cell(s) failed")


if __name__ == "__main__":
    main()
