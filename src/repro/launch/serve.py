"""Serving launcher: batched-request generation driver.

Runs a REDUCED config locally (CPU container); the FULL configs' serve steps
are exercised by the dry-run (prefill_32k / decode_32k / long_500k cells).
Requests arrive with different prompt lengths; the batcher left-pads to the
batch max, prefills once, then decodes step-by-step with the shared KV/SSM
cache.  A simple continuous-batching loop admits queued requests whenever a
slot frees (finished sequence).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser(description="repro server (batched)")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_reduced
    from repro.models import model as M
    from repro.serve.decode import generate

    cfg = get_reduced(args.arch)
    params = M.init_model_params(cfg, jax.random.PRNGKey(args.seed),
                                 jnp.float32)
    rng = np.random.RandomState(args.seed)
    queue = [rng.randint(0, cfg.vocab_size,
                         size=rng.randint(4, args.max_len - args.max_new))
             for _ in range(args.n_requests)]
    done, t0 = 0, time.perf_counter()
    while queue:
        wave, queue = queue[: args.batch], queue[args.batch:]
        L = max(len(p) for p in wave)
        toks = np.zeros((len(wave), L), np.int32)
        mask = np.zeros((len(wave), L), np.int32)
        for i, p in enumerate(wave):                # left-pad
            toks[i, L - len(p):] = p
            mask[i, L - len(p):] = 1
        out = generate(cfg, params,
                       {"tokens": jnp.asarray(toks)},
                       max_new_tokens=args.max_new)
        done += len(wave)
        print(f"wave of {len(wave)}: prompt_len<= {L}, "
              f"generated {out.shape[1]} tokens/req "
              f"sample={np.asarray(out[0, :8]).tolist()}")
    dt = time.perf_counter() - t0
    print(f"served {done} requests in {dt:.2f}s "
          f"({done * args.max_new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
