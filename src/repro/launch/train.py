"""Training launcher.

Two modes:

* default     — run a REDUCED config of ``--arch`` end-to-end on the local
  device(s): real data pipeline, checkpointing, restart.  This is what runs
  in this container and in CI.
* --dry-run   — delegate to launch.dryrun for the production mesh (512
  placeholder devices); never allocates.

On a real cluster this script is invoked once per host under
``jax.distributed.initialize()`` (SPMD: every host runs the same program);
the mesh spans all pods and the data pipeline shards by
``jax.process_index()``.
"""
from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser(description="repro trainer")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--path", choices=["gspmd", "regc"], default="gspmd")
    ap.add_argument("--sync-granularity", choices=["object", "bucket"],
                    default="bucket")
    ap.add_argument("--sync-compression", choices=["none", "int8_ring"],
                    default="none")
    ap.add_argument("--ckpt-dir", default="ckpts")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", choices=["synthetic", "memmap"],
                    default="synthetic")
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the FULL assigned config (cluster only)")
    ap.add_argument("--reduced-periods", type=int, default=1)
    args = ap.parse_args()

    from repro.configs import get_config, get_reduced
    from repro.data import DataConfig
    from repro.launch.mesh import make_host_mesh
    from repro.regc_sync.policies import RegCSyncPolicy
    from repro.train.train_step import TrainHParams
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = (get_config(args.arch) if args.full_config
           else get_reduced(args.arch, n_periods=args.reduced_periods))
    sync = RegCSyncPolicy(
        ordinary_sync="lazy", granularity=args.sync_granularity,
        compression=None if args.sync_compression == "none" else
        args.sync_compression)
    hp = TrainHParams(lr=args.lr, warmup=max(1, args.steps // 20),
                      total_steps=args.steps, n_micro=args.n_micro,
                      remat=args.remat, ce_chunk=min(1024, args.seq_len),
                      sync=sync)
    tc = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, path=args.path)
    data = DataConfig(kind=args.data, vocab_size=cfg.vocab_size,
                      seq_len=args.seq_len, global_batch=args.global_batch,
                      path=args.data_path)
    mesh = None
    if args.path == "regc":
        n = len(jax.devices())
        from repro.compat import make_mesh
        mesh = make_mesh((n,), ("data",))
    trainer = Trainer(cfg, hp, tc, data, mesh=mesh)
    out = trainer.run()
    print(f"done: step={out['step']} final_loss={out['history'][-1]['loss']:.4f} "
          f"restarts={out['restarts']}")


if __name__ == "__main__":
    main()
