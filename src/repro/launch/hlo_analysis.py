"""Post-compile HLO analysis: FLOPs, HBM-byte and collective-byte accounting
with **while-loop trip-count multipliers**.

``compiled.cost_analysis()`` visits each while body ONCE (verified
empirically: a 10-iteration scan of matmuls reports 1 matmul of FLOPs), so a
scanned-by-depth model would be under-counted by its layer count.  This
module re-derives the three roofline terms from ``compiled.as_text()``:

* computations are parsed into ops (name, opcode, output shape, operands),
* every ``while`` op contributes ``trip_count x`` to its body/condition
  (trip count recovered from the loop-condition constant; jax scans lower to
  canonical 0..N loops),
* ``fusion``/``call``/``to_apply``/branch computations inherit their caller's
  multiplier,
* FLOPs are counted from ``dot`` ops (2*M*N*K from the dot dimension
  numbers), which dominate for transformer workloads,
* bytes = sum over *top-level* ops of (operand + output bytes) — the text is
  post-fusion, so a fusion counts once with its true inputs/outputs,
* collective bytes are summed per opcode over {all-reduce, all-gather,
  reduce-scatter, all-to-all, collective-permute} using operand sizes.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# type group is lazy-any: tuple types may contain /*index=N*/ comments
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_CALL_RE = re.compile(
    r"(?:calls=|condition=|body=|to_apply=)%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Bytes of a shape string like 'bf16[2,4]{1,0}' or '(f32[2], s32[])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    # scalar like 'f32[]' — regex [\d,]* matches empty dims
    return total


@dataclasses.dataclass
class HloOp:
    name: str
    opcode: str
    out_type: str
    rest: str            # text after the opening paren of operands
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, HloOp]
    params: Dict[str, str]        # param name -> type string
    order: List[str]


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        if stripped.endswith("{") and ("->" in stripped):
            m = _COMP_RE.match(stripped)
            if m:
                name = m.group(1)
                params: Dict[str, str] = {}
                for p in m.group(2).split(","):
                    p = p.strip()
                    if ":" in p:
                        pname, ptype = p.split(":", 1)
                        params[pname.strip().lstrip("%")] = ptype.strip()
                cur = Computation(name, {}, params, [])
                comps[name] = cur
                continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            name, out_type, opcode, rest = m.groups()
            # operand section: up to matching close paren (approximate: split
            # at '), ' attr boundary)
            op_section = rest.split("), ")[0]
            operands = _OPERAND_RE.findall(op_section)
            op = HloOp(name, opcode, out_type, rest, operands)
            cur.ops[name] = op
            cur.order.append(name)
    return comps


def _operand_type(comp: Computation, comps, opname: str) -> str:
    if opname in comp.ops:
        return comp.ops[opname].out_type
    if opname in comp.params:
        return comp.params[opname]
    return ""


def _trip_count(cond: Computation, comps) -> int:
    """Recover N from a canonical 0..N while condition (best effort)."""
    consts: List[int] = []

    def scan_comp(c: Computation, depth=0):
        if depth > 3:
            return
        for op in c.ops.values():
            if op.opcode == "constant":
                m = re.search(r"constant\((\d+)\)", "constant(" + op.rest)
                if m:
                    consts.append(int(m.group(1)))
            for callee in _CALL_RE.findall(op.rest):
                if callee in comps:
                    scan_comp(comps[callee], depth + 1)

    scan_comp(cond)
    return max(consts) if consts else 1


def _multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # topological-ish: repeatedly propagate (call graph is a DAG; few passes)
    work = [entry]
    seen_edges = set()
    while work:
        cname = work.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops.values():
            callees = _CALL_RE.findall(op.rest)
            branches = _BRANCH_RE.findall(op.rest)
            for b in branches:
                callees += _OPERAND_RE.findall(b)
            if op.opcode == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                bm = re.search(r"body=%?([\w\.\-]+)", op.rest)
                trip = 1
                if cm and cm.group(1) in comps:
                    trip = _trip_count(comps[cm.group(1)], comps)
                for target, k in ((bm, trip), (cm, trip + 1)):
                    if target and target.group(1) in comps:
                        t = target.group(1)
                        edge = (cname, t, op.name)
                        if edge not in seen_edges:
                            seen_edges.add(edge)
                            mult[t] += m * k
                            work.append(t)
            else:
                for t in callees:
                    if t in comps:
                        edge = (cname, t, op.name)
                        if edge not in seen_edges:
                            seen_edges.add(edge)
                            mult[t] += m
                            work.append(t)
    return dict(mult)


def _dot_flops(comp: Computation, comps, op: HloOp) -> float:
    out_elems = 1
    m = _SHAPE_RE.search(op.out_type)
    if m:
        for d in m.group(2).split(","):
            if d:
                out_elems *= int(d)
    # contracted dims from lhs
    lhs_type = _operand_type(comp, comps, op.operands[0]) if op.operands else ""
    mshape = _SHAPE_RE.search(lhs_type)
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    k = 1
    if mshape and cdims:
        dims = [int(d) for d in mshape.group(2).split(",") if d]
        for ci in cdims.group(1).split(","):
            if ci and int(ci) < len(dims):
                k *= dims[int(ci)]
    return 2.0 * out_elems * k


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0   # every op's I/O x trip count (upper bound)
    bytes_dot: float = 0.0        # dot operand/output traffic x trip count
    bytes_entry: float = 0.0      # entry-level op I/O (optimizer, copies)
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    collective_count: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    dot_count: float = 0.0
    while_trips: List[int] = dataclasses.field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def bytes_hbm_est(self) -> float:
        """HBM-traffic estimate: dot streams (weights/activations feeding the
        MXU must come from HBM each visit — remat recompute included via trip
        multipliers) + entry-level elementwise passes (optimizer, copies).
        ``bytes_accessed`` is kept as the pessimistic bound: it also charges
        every intra-loop elementwise op, which on TPU stays fused in VMEM."""
        return self.bytes_dot + self.bytes_entry


def analyze(text: str) -> HloStats:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:
        # fall back: last computation
        entry = list(comps)[-1] if comps else ""
    mult = _multipliers(comps, entry)
    stats = HloStats()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        is_entry = cname == entry
        for op in comp.ops.values():
            if op.opcode == "dot":
                stats.flops += m * _dot_flops(comp, comps, op)
                stats.dot_count += m
                stats.bytes_dot += m * (
                    shape_bytes(op.out_type) + sum(
                        shape_bytes(_operand_type(comp, comps, o))
                        for o in op.operands))
            if op.opcode == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                if cm and cm.group(1) in comps:
                    stats.while_trips.append(_trip_count(comps[cm.group(1)], comps))
            if op.opcode in _SKIP_BYTES_OPS:
                continue
            ob = shape_bytes(op.out_type)
            ib = sum(
                shape_bytes(_operand_type(comp, comps, o)) for o in op.operands
            )
            # fusions already fold their internals; count I/O once
            stats.bytes_accessed += m * (ob + ib)
            if is_entry:
                stats.bytes_entry += m * (ob + ib)
            if op.opcode in COLLECTIVES:
                stats.collective_bytes[op.opcode] += m * max(ib, ob)
                stats.collective_count[op.opcode] += m
    return stats
