"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.  Single pod: 16x16 = 256 chips (v5e pod), axes
("data", "model").  Multi-pod: 2x16x16 = 512 chips, axes
("pod", "data", "model") — the "pod" axis is the DCN/inter-pod dimension.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from repro.compat import make_mesh
    return make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1) if len(axes) == 2 else (n,)
    from repro.compat import make_mesh
    return make_mesh(shape, axes)


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per chip per direction)
