"""Roofline report generator: artifacts/dryrun/*.json -> the EXPERIMENTS.md
§Roofline markdown table (three terms, dominant bottleneck, useful-flops
ratio, and a what-would-move-it note per cell).

Usage:
    PYTHONPATH=src python -m repro.launch.roofline [--variant baseline]
        [--mesh 16x16] [--md-out artifacts/roofline.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

HBM_PER_CHIP = 16 << 30          # v5e

NOTE_RULES = [
    # (predicate, note) — first match wins
    (lambda r: r["dominant"] == "collective" and r["shape"].startswith("decode")
     and r["fsdp_like"],
     "per-token FSDP weight all-gather dominates; switch decode to 2-D TP "
     "(weights sharded over both axes, no regather)"),
    (lambda r: r["dominant"] == "collective" and r["moe"],
     "MoE dispatch/combine all-reduces dominate; shard experts (EP) with "
     "all-to-all and cap capacity factor"),
    (lambda r: r["dominant"] == "collective" and r["shape"] == "train_4k",
     "gradient/activation all-reduces dominate; reduce-scatter + overlap "
     "with backward, or rebalance TP<->DP"),
    (lambda r: r["dominant"] == "collective",
     "context-parallel KV gathers dominate; stage them over the faster "
     "intra-pod axis only"),
    (lambda r: r["dominant"] == "memory" and r["shape"].startswith(("decode",
                                                                    "long")),
     "weight+KV streaming is the floor at batch*1 token; raise arithmetic "
     "intensity via batched decode or quantized KV"),
    (lambda r: r["dominant"] == "memory" and r["useful"] < 0.2,
     "HLO moves far more bytes than the model needs — remat recompute + "
     "O(S^2) attention materialization; use flash-attention kernel"),
    (lambda r: r["dominant"] == "memory",
     "bytes/flop too high: fuse softmax/norms, keep activations bf16, "
     "shard the long axis"),
    (lambda r: r["useful"] < 0.5,
     "compute-bound but <50% useful flops: relax remat (pay memory for "
     "fewer recomputed dots)"),
    (lambda r: True,
     "near compute roofline; remaining waste is remat recompute"),
]


def improvement_note(rec: dict) -> str:
    roof = rec["roofline"]
    ctx = {
        "dominant": roof["dominant"],
        "shape": rec["shape"],
        "useful": roof["model_flops/hlo_flops"],
        "moe": any(a in rec["arch"] for a in
                   ("moonshot", "grok", "jamba")),
        "fsdp_like": rec["arch"] in ("llama3-405b", "qwen2-vl-72b",
                                     "gemma2-27b", "jamba-1.5-large-398b",
                                     "grok-1-314b", "moonshot-v1-16b-a3b"),
    }
    for pred, note in NOTE_RULES:
        if pred(ctx):
            return note
    return ""


def load(variant: str, mesh: str, art: Path):
    rows = []
    for f in sorted(art.glob(f"*__{variant}.json")):
        r = json.loads(f.read_text())
        if r.get("ok") and (mesh is None or r["mesh"] == mesh):
            rows.append(r)
    return rows


def to_markdown(recs, *, title: str) -> str:
    lines = [
        f"### {title}",
        "",
        "| arch | shape | mesh | t_compute | t_memory | t_collective | "
        "dominant | MODEL/HLO flops | MFU bound | mem/chip | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        roof = r["roofline"]
        mem = r["memory_per_device"]["total_live"]
        fits = "" if mem <= HBM_PER_CHIP else " **(>16G)**"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {roof['t_compute_s']*1e3:,.1f} ms "
            f"| {roof['t_memory_s']*1e3:,.1f} ms "
            f"| {roof['t_collective_s']*1e3:,.1f} ms "
            f"| {roof['dominant']} "
            f"| {roof['model_flops/hlo_flops']:.3f} "
            f"| {roof['mfu_upper_bound']:.4f} "
            f"| {mem/2**30:.1f} GiB{fits} "
            f"| {improvement_note(r)} |")
    return "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--art", default="artifacts/dryrun")
    ap.add_argument("--md-out", default=None)
    args = ap.parse_args()
    recs = load(args.variant, args.mesh, Path(args.art))
    md = to_markdown(recs, title=f"Roofline — variant={args.variant}, "
                                 f"mesh={args.mesh} ({len(recs)} cells)")
    if args.md_out:
        Path(args.md_out).write_text(md)
        print(f"wrote {args.md_out}")
    else:
        print(md)


if __name__ == "__main__":
    main()
