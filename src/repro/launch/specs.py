"""Dry-run lowering targets: ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation) plus the matching
``in_shardings`` trees for every (arch x shape x mesh) cell."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as M
from repro.models.sharding import (
    DEFAULT_RULES, LONG_CONTEXT_RULES, SERVE_RULES, SMALL_MODEL_RULES,
    SMALL_SERVE_RULES, ShardingCtx, param_shardings,
)

# d_model at or below this: TP all-reduce (O(B*S*d) per layer) outweighs its
# O(d^2) flops share; spend the model axis on DP instead (see SMALL_*_RULES)
SMALL_D_MODEL = 3072
from repro.serve.decode import make_prefill_step, make_serve_step
from repro.train.train_step import TrainHParams, make_train_step


def rules_for(cfg: ModelConfig, shape: ShapeConfig, rules_override=None):
    if rules_override is not None:
        return rules_override
    # MoE keeps DEFAULT even at small d_model: the expert dim is where the
    # parallelism lives; SMALL rules would replicate the expert weights.
    small = cfg.d_model <= SMALL_D_MODEL and cfg.moe is None
    if shape.kind == "train":
        return SMALL_MODEL_RULES if small else DEFAULT_RULES
    if shape.name == "long_500k":
        return LONG_CONTEXT_RULES
    return SMALL_SERVE_RULES if small else SERVE_RULES


def make_ctx(mesh, cfg: ModelConfig, shape: ShapeConfig,
             rules_override=None, **ctx_opts) -> ShardingCtx:
    return ShardingCtx(mesh=mesh, rules=rules_for(cfg, shape, rules_override),
                       **ctx_opts)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, ctx: ShardingCtx,
                *, dtype=jnp.bfloat16):
    """(ShapeDtypeStruct tree, NamedSharding tree) for the input batch."""
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    specs, shards = {}, {}
    if cfg.input_mode == "embeds":
        specs["embeds"] = _sds((B, S, cfg.d_model), dtype)
        shards["embeds"] = ctx.sharding_for((B, S, cfg.d_model),
                                            ("batch", "seq", "embed"))
    else:
        specs["tokens"] = _sds((B, S), jnp.int32)
        shards["tokens"] = ctx.sharding_for((B, S), ("batch", "seq"))
    if shape.kind == "train":
        specs["targets"] = _sds((B, S), jnp.int32)
        shards["targets"] = ctx.sharding_for((B, S), ("batch", "seq"))
    if cfg.mrope:
        specs["positions"] = _sds((3, B, S), jnp.int32)
        shards["positions"] = ctx.sharding_for((3, B, S),
                                               (None, "batch", "seq"))
    return specs, shards


def cache_specs(cfg: ModelConfig, B: int, max_len: int, ctx: ShardingCtx,
                dtype=jnp.bfloat16):
    shapes = jax.eval_shape(
        functools.partial(M.init_caches, cfg, B, max_len, dtype))
    axes = M.cache_logical_axes(cfg)
    specs, shards = [], []
    for pos_shapes, pos_axes in zip(shapes, axes):
        specs.append(tuple(_sds(s.shape, s.dtype) for s in pos_shapes))
        shards.append(tuple(
            ctx.sharding_for(s.shape, a) for s, a in zip(pos_shapes, pos_axes)))
    return specs, shards


def opt_specs(param_spec_tree, ctx: ShardingCtx, opt_impl: str = "adamw"):
    is_spec = lambda x: hasattr(x, "axes") and hasattr(x, "init")
    if opt_impl == "adamw8bit":
        from repro.optim.quantized import scale_shape

        def leaf_spec(s):
            return {
                "m_q": _sds(s.shape, jnp.int8),
                "m_s": _sds(scale_shape(s.shape), jnp.float32),
                "v_q": _sds(s.shape, jnp.int8),
                "v_s": _sds(scale_shape(s.shape), jnp.float32),
            }

        def leaf_shard(s):
            q = ctx.sharding_for(s.shape, s.axes)
            # scales share the param's axes; the reduced last dim falls back
            # to replication automatically when no longer divisible
            sshape = scale_shape(s.shape)
            saxes = (s.axes if len(sshape) == len(s.shape)
                     else s.axes + (None,))[: len(sshape)]
            sc = ctx.sharding_for(sshape, saxes)
            return {"m_q": q, "m_s": sc, "v_q": q, "v_s": sc}

        return (jax.tree.map(leaf_spec, param_spec_tree, is_leaf=is_spec),
                jax.tree.map(leaf_shard, param_spec_tree, is_leaf=is_spec))
    m = jax.tree.map(lambda s: _sds(s.shape, jnp.float32), param_spec_tree,
                     is_leaf=is_spec)
    sh = param_shardings(param_spec_tree, ctx)
    return {"m": m, "v": m}, {"m": sh, "v": sh}


def lower_target(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                 hp: Optional[TrainHParams] = None, param_dtype=jnp.bfloat16,
                 rules_override=None, **ctx_opts):
    """Returns (fn, args, in_shardings) ready for
    ``jax.jit(fn, in_shardings=...).lower(*args)``."""
    ctx = make_ctx(mesh, cfg, shape, rules_override, **ctx_opts)
    spec_tree = M.param_specs(cfg)
    params = M.abstract_model_params(cfg, param_dtype)
    p_shard = param_shardings(spec_tree, ctx)
    repl = NamedSharding(mesh, P())
    b_specs, b_shards = batch_specs(cfg, shape, ctx, dtype=param_dtype)

    if shape.kind == "train":
        # baseline: full remat — every cell must FIT 16GB v5e HBM first;
        # relaxing remat is a hillclimb lever where memory headroom exists
        hp = hp or TrainHParams(remat="full", ce_chunk=1024)
        fn = make_train_step(cfg, hp, ctx)
        o_specs, o_shards = opt_specs(spec_tree, ctx, hp.opt_impl)
        args = (params, o_specs, b_specs, _sds((), jnp.int32))
        shards = (p_shard, o_shards, b_shards, repl)
        return fn, args, shards, (0, 1)      # donate params + opt state

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, ctx, max_len=shape.seq_len)
        return fn, (params, b_specs), (p_shard, b_shards), ()

    # decode: one new token against a full cache of seq_len
    c_specs, c_shards = cache_specs(cfg, shape.global_batch, shape.seq_len,
                                    ctx, dtype=param_dtype)
    fn = make_serve_step(cfg, ctx)
    args = (params, b_specs, c_specs, _sds((), jnp.int32))
    shards = (p_shard, b_shards, c_shards, repl)
    return fn, args, shards, (2,)            # donate the KV/SSM caches


# ---------------------------------------------------------------------------
# Analytic model FLOPs (roofline numerator sanity)
# ---------------------------------------------------------------------------


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D (+ attention
    cache reads) for inference steps."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * D
        attn = 0.0
        n_attn = sum(1 for s in cfg.pattern if s.kind == "attn")
        n_attn_layers = n_attn * cfg.n_superblocks
        for spec in cfg.pattern:
            if spec.kind != "attn":
                continue
            ctx_len = min(cfg.window or shape.seq_len, shape.seq_len) \
                if spec.attn_type == "local" else shape.seq_len
            # fwd 2*2*B*S*ctx*Hq*D ; bwd ~2x
            attn += 3 * 2 * 2 * shape.global_batch * shape.seq_len * ctx_len \
                * cfg.n_heads * cfg.head_dim * 0.5 * cfg.n_superblocks
        return base + attn
    D = shape.global_batch  # one token per sequence
    base = 2.0 * n_active * D
    for spec in cfg.pattern:
        if spec.kind != "attn":
            continue
        ctx_len = min(cfg.window or shape.seq_len, shape.seq_len) \
            if spec.attn_type == "local" else shape.seq_len
        if shape.kind == "prefill":
            base += 2 * 2 * shape.global_batch * shape.seq_len * ctx_len * \
                cfg.n_heads * cfg.head_dim * 0.5 * cfg.n_superblocks
        else:
            base += 2 * 2 * shape.global_batch * ctx_len * cfg.n_heads * \
                cfg.head_dim * cfg.n_superblocks
    if shape.kind == "prefill":
        base = 2.0 * n_active * shape.global_batch * shape.seq_len + base \
            - 2.0 * n_active * shape.global_batch
    return base
