"""Trainer: the fault-tolerant training loop.

Wires together the data pipeline (stateless-by-step, prefetched), the train
step (GSPMD or explicit-RegC), the checkpoint manager (async, keep-last-k)
and the FT runtime (failure injection -> restore -> resume; straggler
monitor).  The loop is deliberately restart-shaped: ALL mutable state is
(params, opt_state, step); everything else is reconstructed from configs, so
recovery == restore + jump the pipeline.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data import DataConfig, make_pipeline
from repro.ft import FailureInjector, StragglerMonitor, WorkerFailure
from repro.models import model as M
from repro.optim.adamw import init_opt_state
from repro.train.train_step import (
    TrainHParams, make_train_step, make_train_step_regc,
)


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "ckpts"
    ckpt_keep: int = 3
    ckpt_async: bool = True
    log_every: int = 10
    path: str = "gspmd"               # 'gspmd' | 'regc'
    dp_axes: tuple = ("data",)
    max_restarts: int = 3
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, hp: TrainHParams, tc: TrainerConfig,
                 data: DataConfig, *, mesh=None, ctx=None,
                 injector: Optional[FailureInjector] = None,
                 log_fn: Callable[[str], None] = print):
        self.cfg, self.hp, self.tc, self.data = cfg, hp, tc, data
        self.mesh, self.ctx = mesh, ctx
        self.injector = injector
        self.log = log_fn
        self.ckpt = CheckpointManager(tc.ckpt_dir, keep=tc.ckpt_keep,
                                      async_write=tc.ckpt_async)
        if tc.path == "regc":
            assert mesh is not None, "explicit-RegC path needs a mesh"
            self.step_fn = make_train_step_regc(cfg, hp, mesh,
                                                dp_axes=tc.dp_axes,
                                                inner_ctx=ctx)
        else:
            self.step_fn = jax.jit(make_train_step(cfg, hp, ctx))
        self.straggler = StragglerMonitor(1)
        self.history: List[Dict] = []
        self.restarts = 0

    # ------------------------------------------------------------------
    def _init_state(self):
        params = M.init_model_params(self.cfg, jax.random.PRNGKey(self.tc.seed),
                                     jnp.float32)
        return params, init_opt_state(params)

    def _resume_or_init(self):
        last = self.ckpt.latest()
        if last is None:
            params, opt = self._init_state()
            return params, opt, 0
        params_t, opt_t = self._init_state()
        state = self.ckpt.restore(last, {"params": params_t, "opt": opt_t})
        self.log(f"[trainer] restored checkpoint step={last}")
        return state["params"], state["opt"], last

    # ------------------------------------------------------------------
    def run(self) -> Dict:
        while True:
            try:
                return self._run_inner()
            except WorkerFailure as e:
                self.restarts += 1
                if self.restarts > self.tc.max_restarts:
                    raise
                self.log(f"[trainer] {e} -> restart "
                         f"{self.restarts}/{self.tc.max_restarts}")

    def _run_inner(self) -> Dict:
        params, opt, start = self._resume_or_init()
        pipe = make_pipeline(self.data, start_step=start)
        t_prev = time.perf_counter()
        try:
            step = start
            while step < self.tc.total_steps:
                step, batch = next(pipe)
                if self.injector is not None:       # simulated failure point
                    self.injector.check(step)
                params, opt, metrics = self.step_fn(
                    params, opt, batch, jnp.asarray(step, jnp.int32))
                loss = float(metrics["loss"])       # blocks; paces the loop
                now = time.perf_counter()
                dur = now - t_prev
                t_prev = now
                slow = self.straggler.observe([dur])
                rec = {"step": step, "loss": loss, "t_s": dur,
                       "straggler": bool(slow)}
                self.history.append(rec)
                if step % self.tc.log_every == 0:
                    self.log(f"[trainer] step={step} loss={loss:.4f} "
                             f"({dur*1e3:.0f} ms)")
                next_step = step + 1
                if next_step % self.tc.ckpt_every == 0 \
                        or next_step == self.tc.total_steps:
                    self.ckpt.save(next_step,
                                   {"params": params, "opt": opt},
                                   extra={"loss": loss})
                step = next_step
        finally:
            pipe.close()
        self.ckpt.wait()
        return {"params": params, "opt": opt, "step": step,
                "history": self.history, "restarts": self.restarts}
