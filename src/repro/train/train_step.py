"""Train-step builders.

Two paths, per DESIGN.md §2.2:

* ``make_train_step``       — GSPMD: jit + sharding rules; XLA places the
  collectives.  Supports DP/FSDP/TP/EP.  This is the production default and
  the path the multi-pod dry-run lowers.
* ``make_train_step_regc``  — explicit RegC: ``shard_map`` manual over the DP
  axes (TP stays automatic inside), gradients accumulated locally over
  microbatches (ordinary region, lazy propagation) and synced once at the
  step barrier with policy-chosen granularity/compression; metrics and the
  global grad-norm go through ``span_reduce`` (the reduction extension).
  Requires params replicated across DP axes (no FSDP in the manual path).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.sharding import ShardingCtx, constrain
from repro.optim.adamw import (
    AdamWConfig, adamw_update, clip_by_global_norm, init_opt_state,
    warmup_cosine,
)
from repro.regc_sync.policies import (
    RegCSyncPolicy, barrier_sync_grads, span_reduce,
)
from repro.utils.tree import global_sq_norm, tree_add, tree_scale, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    adamw: AdamWConfig = AdamWConfig()
    n_micro: int = 1
    remat: Optional[str] = "dots"
    remat_segment: int = 0       # >1: sqrt-N segmented remat (see run_stack)
    attn_impl: str = "blocked"
    ce_chunk: int = 1024
    opt_impl: str = "adamw"      # 'adamw' | 'adamw8bit' (blockwise-int8 m,v)
    sync: RegCSyncPolicy = RegCSyncPolicy()


def batch_logical_axes(cfg: ModelConfig, key: str, ndim: int):
    if key == "positions" and cfg.mrope:
        return (None, "batch", "seq")
    if key == "embeds":
        return ("batch", "seq", "embed")
    return ("batch", "seq")[:ndim]


def _constrain_batch(cfg, batch, ctx):
    if ctx is None:
        return batch
    return {k: constrain(v, batch_logical_axes(cfg, k, v.ndim), ctx)
            for k, v in batch.items()}


def _microbatch(batch, n_micro, batch_dim_of):
    """Reshape each leaf's batch dim into (n_micro, b/n_micro)."""
    def resh(k, a):
        bd = batch_dim_of(k)
        b = a.shape[bd]
        assert b % n_micro == 0, (k, b, n_micro)
        new = a.shape[:bd] + (n_micro, b // n_micro) + a.shape[bd + 1:]
        a = a.reshape(new)
        return jnp.moveaxis(a, bd, 0)
    return {k: resh(k, v) for k, v in batch.items()}


# ---------------------------------------------------------------------------
# GSPMD path
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, hp: TrainHParams,
                    ctx: Optional[ShardingCtx] = None):
    sched = warmup_cosine(hp.lr, hp.warmup, hp.total_steps)
    if hp.opt_impl == "adamw8bit":
        from repro.optim.quantized import adamw8bit_update as opt_update
    else:
        opt_update = adamw_update

    def loss_f(params, batch):
        return M.loss_fn(cfg, params, batch, ctx, attn_impl=hp.attn_impl,
                         remat=hp.remat, ce_chunk=hp.ce_chunk,
                         remat_segment=hp.remat_segment)

    def train_step(params, opt_state, batch, step):
        batch = _constrain_batch(cfg, batch, ctx)
        if hp.n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_f, has_aux=True)(params, batch)
        else:
            bdim = lambda k: 1 if (k == "positions" and cfg.mrope) else 0
            mbatch = _microbatch(batch, hp.n_micro, bdim)

            def micro(carry, mb):
                g_acc, l_acc = carry
                mb = _constrain_batch(cfg, mb, ctx)
                (l, _), g = jax.value_and_grad(loss_f, has_aux=True)(params, mb)
                return (tree_add(g_acc, g), l_acc + l), None

            g0 = tree_zeros_like(params, jnp.float32)
            (grads, loss), _ = lax.scan(micro, (g0, jnp.zeros(())), mbatch)
            grads = tree_scale(grads, 1.0 / hp.n_micro)
            loss = loss / hp.n_micro
            metrics = {"ce": loss}
        new_params, new_opt, gnorm = opt_update(
            params, grads, opt_state, step, sched(step), hp.adamw)
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": sched(step)}
        out_metrics.update({k: v for k, v in metrics.items()
                            if v.ndim == 0})
        return new_params, new_opt, out_metrics

    return train_step


# ---------------------------------------------------------------------------
# Explicit RegC path (shard_map manual over DP axes)
# ---------------------------------------------------------------------------


def make_train_step_regc(cfg: ModelConfig, hp: TrainHParams, mesh,
                         dp_axes=("data",), inner_ctx: Optional[ShardingCtx] = None):
    """Params/opt replicated over dp_axes; batch sharded on its batch dim."""
    sched = warmup_cosine(hp.lr, hp.warmup, hp.total_steps)
    axis_sizes = {a: mesh.shape[a] for a in dp_axes}
    dp_world = 1
    for a in dp_axes:
        dp_world *= axis_sizes[a]

    def loss_f(params, batch):
        return M.loss_fn(cfg, params, batch, inner_ctx,
                         attn_impl=hp.attn_impl, remat=hp.remat,
                         ce_chunk=hp.ce_chunk,
                         remat_segment=hp.remat_segment)

    def inner(params, opt_state, batch, step):
        bdim = lambda k: 1 if (k == "positions" and cfg.mrope) else 0

        def local_grads(b):
            (l, mts), g = jax.value_and_grad(loss_f, has_aux=True)(params, b)
            return l, mts, g

        if hp.n_micro == 1:
            loss, mts, grads = local_grads(batch)
            if hp.sync.ordinary_sync == "eager":
                grads = barrier_sync_grads(grads, dp_axes, hp.sync,
                                           axis_sizes=axis_sizes)
        else:
            mbatch = _microbatch(batch, hp.n_micro, bdim)

            def micro(carry, mb):
                g_acc, l_acc = carry
                l, _, g = local_grads(mb)
                if hp.sync.ordinary_sync == "eager":
                    # RC-like: propagate ordinary stores at *every* release
                    g = barrier_sync_grads(g, dp_axes, hp.sync,
                                           axis_sizes=axis_sizes)
                return (tree_add(g_acc, g), l_acc + l), None

            g0 = tree_zeros_like(params, jnp.float32)
            (grads, loss), _ = lax.scan(micro, (g0, jnp.zeros(())), mbatch)
            grads = tree_scale(grads, 1.0 / hp.n_micro)
            loss = loss / hp.n_micro

        if hp.sync.ordinary_sync == "lazy":
            # RegC: ordinary stores propagated once, at the step barrier
            grads = barrier_sync_grads(grads, dp_axes, hp.sync,
                                       axis_sizes=axis_sizes)

        # consistency-region objects: reduction extension (fine-grained psum)
        loss = span_reduce(loss, dp_axes, "mean")
        sq = global_sq_norm(grads)  # already synced; identical on all shards
        if hp.adamw.clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, hp.adamw.clip_norm,
                                               sq_norm=sq)
        else:
            gnorm = jnp.sqrt(sq)
        adamw_nocap = dataclasses.replace(hp.adamw, clip_norm=None)
        new_params, new_opt, _ = adamw_update(
            params, grads, opt_state, step, sched(step), adamw_nocap)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": sched(step)}
        return new_params, new_opt, metrics

    def bspec(k):
        if k == "positions" and cfg.mrope:
            return P(None, dp_axes)
        return P(dp_axes)

    def step_fn(params, opt_state, batch, step):
        batch_specs = {k: bspec(k) for k in batch}
        from repro.compat import shard_map
        fn = shard_map(
            inner, mesh=mesh,
            in_specs=(P(), P(), batch_specs, P()),
            out_specs=(P(), P(), P()),
            axis_names=set(dp_axes),
            check_vma=False,
        )
        return fn(params, opt_state, batch, step)

    return step_fn


def init_train_state(cfg: ModelConfig, rng, dtype=jnp.float32):
    params = M.init_model_params(cfg, rng, dtype)
    return params, init_opt_state(params)
