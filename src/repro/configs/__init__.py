from repro.configs.base import (
    LayerSpec,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    SHAPES,
    shapes_for,
    reduce_config,
)
from repro.configs.registry import ARCH_IDS, all_cells, get_config, get_reduced, get_shape

__all__ = [
    "LayerSpec", "ModelConfig", "MoEConfig", "SSMConfig", "ShapeConfig",
    "SHAPES", "shapes_for", "reduce_config",
    "ARCH_IDS", "all_cells", "get_config", "get_reduced", "get_shape",
]
