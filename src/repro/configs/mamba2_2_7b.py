"""mamba2-2.7b — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]  64L d_model=2560 (attn-free) d_ff=0
vocab=50280, ssm_state=128.  d_inner = 2*d_model = 5120, SSD head_dim=64
(80 heads), conv4, chunk 256.  Sub-quadratic by construction: ``long_500k``
decode runs with O(1)-per-token recurrent state.
"""
from repro.configs.base import LayerSpec, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    pattern=(LayerSpec(kind="ssm", mlp="none"),),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    sub_quadratic=True,
    source="arXiv:2405.21060",
)
