"""granite-20b — llama-arch code model with MQA (kv=1).

[arXiv:2405.04324; hf]  52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152.  ``long_500k`` skipped (pure full attention).
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
    rope_theta=10_000.0,
    source="arXiv:2405.04324",
)
