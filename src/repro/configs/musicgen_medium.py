"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]  48L d_model=1536 24H (GQA kv=24 == MHA) d_ff=6144
vocab=2048.  The EnCodec frontend (RVQ codebooks, delay pattern) is a STUB:
``input_specs()`` provides precomputed frame embeddings (input_mode='embeds').
The backbone is the standard transformer decoder the paper trains.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
    rope_theta=10_000.0,
    input_mode="embeds",
    source="arXiv:2306.05284",
)
