"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shapes_for, reduce_config

ARCH_IDS = (
    "jamba-1.5-large-398b",
    "moonshot-v1-16b-a3b",
    "grok-1-314b",
    "musicgen-medium",
    "qwen2-vl-72b",
    "mamba2-2.7b",
    "internlm2-1.8b",
    "gemma2-27b",
    "llama3-405b",
    "granite-20b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells() -> Dict[str, tuple]:
    """Every runnable (arch x shape) dry-run cell."""
    return {a: shapes_for(get_config(a)) for a in ARCH_IDS}


def get_reduced(arch: str, **kw) -> ModelConfig:
    return reduce_config(get_config(arch), **kw)
