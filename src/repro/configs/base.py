"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig`` built from a
repeating ``pattern`` of ``LayerSpec``s (the *super-block*).  The model stack
is ``pattern * (n_layers // len(pattern))`` — the repeating structure is what
lets the model code ``lax.scan`` over super-blocks so HLO size is O(1) in
depth (126-layer models compile on one CPU core).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Top-k token-choice MoE (GShard-style dropping dispatch)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0           # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight (synced via regc.reduce)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD mixer config (arXiv:2405.21060)."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64          # SSD "P"; n_ssm_heads = expand*d_model // head_dim
    chunk: int = 256            # SSD chunk length (state-passing granularity)
    n_groups: int = 1           # B/C groups (GVA-style)


@dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating super-block."""

    kind: str = "attn"          # 'attn' | 'ssm'
    attn_type: str = "global"   # 'global' | 'local'   (only for kind='attn')
    mlp: str = "dense"          # 'dense' | 'moe' | 'none'


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'audio' | 'vlm'
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free archs
    n_kv_heads: int
    head_dim: int
    d_ff: int                   # dense-MLP hidden dim (0 if no MLP)
    vocab_size: int
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # attention details
    rope_theta: float = 10_000.0
    window: Optional[int] = None        # sliding-window size for 'local' layers
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    query_scale: Optional[float] = None  # default 1/sqrt(head_dim)
    mrope: bool = False                  # multimodal 3D RoPE (qwen2-vl); position
    #                                      ids (3, B, S) are a model *input*.

    # misc
    norm_eps: float = 1e-5
    use_post_norm: bool = False          # gemma2: post-block RMSNorm as well
    geglu: bool = False                  # gemma2 GeGLU; default SwiGLU
    tie_embeddings: bool = False
    input_mode: str = "tokens"           # 'tokens' | 'embeds' (audio/vlm stubs)
    sub_quadratic: bool = False          # True iff long_500k decode is runnable

    # citation / provenance (goes into DESIGN.md + config docstrings)
    source: str = ""

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern period {len(self.pattern)}"
        )
        if self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0

    # -- derived ----------------------------------------------------------
    @property
    def n_superblocks(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Params active per token (MoE: only routed top-k + shared experts)."""
        return _param_count(self, active_only=True)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    total = cfg.vocab_size * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model  # lm head
    per_period = 0
    for spec in cfg.pattern:
        per_period += cfg.d_model  # input norm
        if cfg.use_post_norm:
            per_period += cfg.d_model
        if spec.kind == "attn":
            per_period += cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim)
            per_period += cfg.q_dim * cfg.d_model
        elif spec.kind == "ssm":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            n_h = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            per_period += cfg.d_model * (2 * d_in + 2 * s.n_groups * s.d_state + n_h)
            per_period += conv_dim * s.d_conv + conv_dim  # depthwise conv + bias
            per_period += n_h * 2              # A_log, D
            per_period += n_h                  # dt_bias
            per_period += d_in                 # gate norm
            per_period += d_in * cfg.d_model   # out proj
        if spec.mlp == "dense":
            per_period += cfg.d_model  # post-attn norm
            if cfg.use_post_norm:
                per_period += cfg.d_model
            per_period += 3 * cfg.d_model * cfg.d_ff
        elif spec.mlp == "moe":
            m = cfg.moe
            per_period += cfg.d_model  # post-attn norm
            if cfg.use_post_norm:
                per_period += cfg.d_model
            per_period += cfg.d_model * m.n_experts  # router
            n_e = (m.top_k + m.n_shared) if active_only else (m.n_experts + m.n_shared)
            per_period += n_e * 3 * cfg.d_model * m.d_ff_expert
    total += per_period * cfg.n_superblocks
    total += cfg.d_model  # final norm
    return total


# ---------------------------------------------------------------------------
# Input shapes (assigned; LM shapes are seq_len x global_batch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shapes_for(cfg: ModelConfig) -> Tuple[str, ...]:
    """Which assigned shapes apply to this arch (long_500k needs sub-quadratic)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return tuple(names)


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def reduce_config(cfg: ModelConfig, *, n_periods: int = 1) -> ModelConfig:
    """Shrink a config to smoke-test scale while preserving its *structure*
    (same pattern, same family, same feature flags)."""
    small_moe = None
    if cfg.moe is not None:
        small_moe = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
        )
    small_ssm = None
    if cfg.ssm is not None:
        small_ssm = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=16, chunk=32,
        )
    n_heads = 4 if cfg.n_heads else 0
    n_kv = 0
    if cfg.n_heads:
        n_kv = 1 if cfg.n_kv_heads == 1 else (4 if cfg.n_kv_heads == cfg.n_heads else 2)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=len(cfg.pattern) * n_periods,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        moe=small_moe,
        ssm=small_ssm,
        window=min(cfg.window, 16) if cfg.window else None,
    )
