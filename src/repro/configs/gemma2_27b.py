"""gemma2-27b — dense, alternating local/global attention, logit softcaps.

[arXiv:2408.00118; hf]  46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000.  head_dim=128 (q_dim 4096 != d_model — separate o-proj),
query scale (d_model/n_heads)^-1/2 = 144^-1/2, sliding window 4096 on local
layers, attn softcap 50, final softcap 30, GeGLU, pre+post RMSNorm.

``long_500k`` is SKIPPED for this arch: half the layers are *global* full
attention, so 512k-token decode is not sub-quadratic (see DESIGN.md §5).
"""
from repro.configs.base import LayerSpec, ModelConfig

_PATTERN = (
    LayerSpec(kind="attn", attn_type="local", mlp="dense"),
    LayerSpec(kind="attn", attn_type="global", mlp="dense"),
)

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    pattern=_PATTERN,
    rope_theta=10_000.0,
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=144.0 ** -0.5,
    geglu=True,
    use_post_norm=True,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
