"""qwen2-vl-72b — VLM backbone with M-RoPE.

[arXiv:2409.12191; hf]  80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064.  M-RoPE (temporal/height/width rotary sections); the vision
frontend (ViT, dynamic resolution) is a STUB: ``input_specs()`` provides
precomputed patch embeddings plus (3, B, S) multimodal position ids.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
    rope_theta=1_000_000.0,
    mrope=True,
    input_mode="embeds",
    source="arXiv:2409.12191",
)
