"""moonshot-v1-16b-a3b — fine-grained MoE (kimi/moonlight).

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (GQA kv=16)
d_ff=1408 vocab=163840, MoE 64e top-6.  DeepSeek-style fine-grained experts
(small d_ff_expert, high top-k).
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    pattern=(LayerSpec(kind="attn", mlp="moe"),),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408),
    rope_theta=50_000.0,
    source="hf:moonshotai/Moonlight-16B-A3B",
)
