"""grok-1-314b — 8-expert top-2 MoE.

[hf:xai-org/grok-1; unverified]  64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    pattern=(LayerSpec(kind="attn", mlp="moe"),),
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768),
    rope_theta=10_000.0,
    source="hf:xai-org/grok-1",
)
