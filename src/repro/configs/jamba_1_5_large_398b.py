"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887 / 2408.12570; hf]  72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2.  Super-block of 8 layers with one
attention layer (index 4, as in the Jamba paper) and MoE on every other
layer (odd indices).  Sub-quadratic: only 9/72 layers carry a KV cache, so
the ``long_500k`` decode shape is runnable.
"""
from repro.configs.base import LayerSpec, ModelConfig, MoEConfig, SSMConfig

_PATTERN = tuple(
    LayerSpec(
        kind="attn" if i == 4 else "ssm",
        attn_type="global",
        mlp="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    pattern=_PATTERN,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    rope_theta=10_000.0,
    sub_quadratic=True,
    source="arXiv:2403.19887",
)
